"""Shared benchmark plumbing: dataset suite, schemes, timing, result io,
and the forced-4-device subprocess runner (also used by tests/conftest)."""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import time

import numpy as np

RESULTS = pathlib.Path(__file__).resolve().parent / "results"


def run_forced_four_devices(argv: list[str], timeout: int = 600):
    """Run ``python *argv`` from the repo root with 4 forced host devices.

    Genuinely distributed runs need
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` set *before*
    jax initializes its backends, hence a fresh subprocess. The child's
    ``XLA_FLAGS`` is pinned to exactly that flag — inherited values are
    dropped, so a stray user env can't override the device count or leak
    unrelated XLA options into the matrix. ``REPRO_EXPECT_DEVICE_COUNT``
    tells the child's conftest to assert the forced count actually took
    effect before any test runs. This is the single copy of that recipe —
    tests/conftest.py re-exports it for the distributed test legs.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["REPRO_EXPECT_DEVICE_COUNT"] = "4"
    env["JAX_PLATFORMS"] = "cpu"
    root = str(pathlib.Path(__file__).resolve().parent.parent)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), env.get("PYTHONPATH", "")]).rstrip(
        os.pathsep)
    return subprocess.run([sys.executable, *argv], env=env, cwd=root,
                          capture_output=True, text=True, timeout=timeout)


def _reject_constant(s: str):
    raise ValueError(f"non-standard JSON constant {s!r} in benchmark result")


def save_json(name: str, obj) -> pathlib.Path:
    """Write a result file as *strict* JSON.

    ``allow_nan=False`` refuses the Infinity/NaN literals Python's json
    would otherwise emit (they break every spec-compliant parser);
    harness code must encode unbounded values as ``None`` plus an
    explicit flag (e.g. ``break_even_never``). The round-trip below
    re-parses what we wrote with constants rejected, so a regression
    fails at save time, not in whatever reads the results later.
    """
    RESULTS.mkdir(parents=True, exist_ok=True)
    p = RESULTS / f"{name}.json"
    text = json.dumps(obj, indent=1, default=float, allow_nan=False)
    json.loads(text, parse_constant=_reject_constant)
    p.write_text(text)
    return p


def load_json(name: str):
    p = RESULTS / f"{name}.json"
    return json.loads(p.read_text()) if p.exists() else None


def bench_suite(scale: float = 0.5, seed: int = 7):
    """The paper's six datasets (regenerated in-kind, DESIGN.md §3)."""
    from repro.core.generators import dataset_suite
    return dataset_suite(scale=scale, seed=seed)


def schemes(include_gorder: bool = False):
    from repro.core.baselines import reordering_registry
    reg = reordering_registry()
    names = ["dbg", "sorder", "norder", "hubcluster", "lorder", "lorder-v2"]
    if include_gorder:
        names.append("gorder")
    return {n: reg[n] for n in names}


def time_call(fn, *args, repeats: int = 5, warmup: int = 1, **kw):
    """(mean_seconds, std). Blocks on jax outputs."""
    import jax
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.mean(ts)), float(np.std(ts))


def geomean(xs) -> float:
    xs = np.asarray([x for x in xs if x > 0], dtype=np.float64)
    return float(np.exp(np.log(xs).mean())) if len(xs) else float("nan")


def fmt_table(rows: list[dict], cols: list[str]) -> str:
    widths = {c: max(len(c), *(len(str(r.get(c, ""))) for r in rows))
              for c in cols}
    head = " | ".join(c.ljust(widths[c]) for c in cols)
    sep = "-|-".join("-" * widths[c] for c in cols)
    body = "\n".join(" | ".join(str(r.get(c, "")).ljust(widths[c])
                                for c in cols) for r in rows)
    return f"{head}\n{sep}\n{body}"
