"""Execution backends: bucketing mechanics, placement, hot-prefix policy.

Kernel-by-kernel result parity across backends lives in
tests/test_parity_matrix.py (six kernels x serving configs vs the numpy
baselines, incl. a 4-forced-device leg); this file covers the backend
*mechanics* — bucket geometry, compile sharing, routing guards, the
sharded runner-factory table, and how the policy derives
``hot_prefix_fraction`` and the ledger's sharded gain discount.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.algos import kernels as K
from repro.algos.graph_arrays import to_device
from repro.core.generators import powerlaw_community
from repro.engine import (SHARDED_KERNELS, BatchedExecutor, EngineSession,
                          GraphHandle, GraphProbes, ReorderPolicy,
                          ShardedBackend, SingleDeviceBackend, bucket_dims,
                          estimate_device_bytes, probe_graph)
from repro.engine.backends import _RUNNER_FACTORIES, GLOBAL, MULTI_SOURCE


# ---------------------------------------------------------------- buckets
def test_bucket_dims_geometric_and_sentinel_room():
    v, e = bucket_dims(1000, 9000)
    assert v >= 1001 and e >= 9000          # room for sentinel self-loops
    assert bucket_dims(1000, 9000) == bucket_dims(900, 8500)  # shared bucket
    # no edge padding needed -> vertex bucket may equal V exactly
    assert bucket_dims(256, 1024) == (256, 1024)
    # floors apply to tiny graphs
    assert bucket_dims(8, 12) == (256, 1024)
    with pytest.raises(ValueError):
        bucket_dims(10, 10, growth=1.0)


def test_estimate_device_bytes_monotone():
    assert estimate_device_bytes(100, 1000) < estimate_device_bytes(100, 2000)
    assert estimate_device_bytes(100, 1000) < estimate_device_bytes(200, 1000)


# ----------------------------------------------------- padded CSR parity
# (fixture-graph parity lives in the matrix; this helper backs the
# random-graph property test below)
def _parity_padded_vs_exact(g, srcs):
    bucketed = SingleDeviceBackend()
    handle = bucketed.prepare(g)
    assert handle.bucket[0] > g.num_vertices or handle.bucket == (
        g.num_vertices, g.num_edges)
    ga = to_device(g)
    for kernel in ("bfs", "sssp"):
        got = np.asarray(bucketed.run(handle, kernel, srcs))
        want = np.asarray(SingleDeviceBackend(bucketing=False).run_arrays(
            ga, kernel, srcs))
        assert got.shape == (len(srcs), g.num_vertices)
        np.testing.assert_array_equal(got, want)  # ints: bit-identical
    np.testing.assert_allclose(
        np.asarray(bucketed.run(handle, "pr")),
        np.asarray(K.pagerank(ga)), rtol=1e-5, atol=1e-9)
    for kernel in ("cc", "ccsv"):
        np.testing.assert_array_equal(
            np.asarray(bucketed.run(handle, kernel)),
            np.asarray(SingleDeviceBackend(bucketing=False).run_arrays(
                ga, kernel)))
    np.testing.assert_allclose(
        np.asarray(bucketed.run(handle, "bc", srcs)),
        np.asarray(K.bc_multi(ga, jnp.asarray(srcs, jnp.int32))),
        rtol=1e-5, atol=1e-5)


def test_bucket_padding_property_random_powerlaw():
    """Satellite: bucketed BFS/SSSP/PR == unpadded on random power-law
    graphs (hypothesis-driven when available)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=8, deadline=None)
    @given(n=st.integers(min_value=60, max_value=900),
           avg_degree=st.floats(min_value=2.0, max_value=12.0),
           seed=st.integers(min_value=0, max_value=2**16))
    def check(n, avg_degree, seed):
        g = powerlaw_community(n, avg_degree=avg_degree, seed=seed)
        rng = np.random.default_rng(seed)
        srcs = rng.integers(0, n, size=3).astype(np.int32)
        _parity_padded_vs_exact(g, srcs)

    check()


def test_compiled_executable_cache_lru_eviction():
    """Satellite (ROADMAP): a long stream of distinct shapes must keep the
    compiled-executable cache bounded — LRU eviction with telemetry, and
    an evicted shape that returns recompiles correctly."""
    backend = SingleDeviceBackend(bucketing=False, max_cached_executables=3)
    graphs = [powerlaw_community(n, avg_degree=4.0, seed=n)
              for n in (60, 90, 120, 150, 180, 210)]
    assert len({(g.num_vertices, g.num_edges) for g in graphs}) == 6
    outs = [np.asarray(backend.run(backend.prepare(g), "bfs",
                                   np.array([0], np.int32)))
            for g in graphs]
    assert len(backend._cache) <= 3
    assert backend.cache_evictions == 3
    t = backend.telemetry()
    assert t["cache_evictions"] == 3
    assert t["max_cached_executables"] == 3
    assert len(t["cached_keys"]) <= 3
    # evicted shape returns: a counted miss, bit-identical result
    misses = backend.cache_misses
    again = np.asarray(backend.run(backend.prepare(graphs[0]), "bfs",
                                   np.array([0], np.int32)))
    assert backend.cache_misses == misses + 1
    np.testing.assert_array_equal(again, outs[0])
    # a hit refreshes recency: the just-used key survives the next insert
    backend.run(backend.prepare(graphs[0]), "bfs", np.array([0], np.int32))
    backend.run(backend.prepare(powerlaw_community(240, avg_degree=4.0,
                                                   seed=240)),
                "bfs", np.array([0], np.int32))
    assert ("bfs", graphs[0].num_vertices, graphs[0].num_edges,
            False) in backend._cache
    # unbounded by default; cap of zero is rejected
    assert SingleDeviceBackend().max_cached_executables is None
    with pytest.raises(ValueError):
        SingleDeviceBackend(max_cached_executables=0)


def test_compile_sharing_across_distinct_shapes():
    """Graphs of different (V, E) in one bucket share one compile key."""
    backend = SingleDeviceBackend()
    sizes = (300, 330, 360, 390)
    graphs = [powerlaw_community(n, avg_degree=4.0, seed=n) for n in sizes]
    assert len({(g.num_vertices, g.num_edges) for g in graphs}) == len(sizes)
    outs = []
    for g in graphs:
        h = backend.prepare(g)
        outs.append(backend.run(h, "bfs", np.array([0], np.int32)))
    exact = SingleDeviceBackend(bucketing=False)
    for g in graphs:
        exact.run(exact.prepare(g), "bfs", np.array([0], np.int32))
    assert exact.cache_misses == len(sizes)
    assert backend.cache_misses < exact.cache_misses
    assert backend.cache_misses * 2 <= exact.cache_misses


# ----------------------------------------------- executor facade + guards
def test_empty_sources_guard_before_cache_telemetry(plc_graph):
    """Satellite: an empty batch (or unknown kernel) must not touch the
    compile-cache counters — formerly it booked a miss before raising."""
    ex = BatchedExecutor()
    ga = to_device(plc_graph)
    with pytest.raises(ValueError):
        ex.run(ga, "bfs", [])
    with pytest.raises(ValueError):
        ex.run(ga, "bfs", np.empty(0, np.int32))
    with pytest.raises(ValueError):
        ex.run(ga, "nope", [0])
    assert (ex.cache_hits, ex.cache_misses) == (0, 0)
    assert ex.queries_run == 0 and ex.sources_run == 0


def test_executor_rejects_unknown_target_and_backend(plc_graph):
    ex = BatchedExecutor()
    with pytest.raises(TypeError):
        ex.run(plc_graph, "bfs", [0])  # host Graph is not a served target
    with pytest.raises(ValueError):
        ex.backend("tpu-pod")


def test_executor_prepare_routes_and_merges_telemetry(plc_graph):
    ex = BatchedExecutor()
    h = ex.prepare(plc_graph)
    assert isinstance(h, GraphHandle) and h.backend == "single"
    ex.run(h, "bfs", [0, 1])
    t = ex.telemetry()
    assert t["compile_cache_misses"] == 1
    assert t["single"]["bucketing"]["graphs_prepared"] == 1
    assert t["sharded"] is None  # lazy: never built


# -------------------------------------------------------------- placement
def test_policy_places_by_device_budget(plc_graph):
    probes = probe_graph(plc_graph)
    need = estimate_device_bytes(probes.num_vertices, probes.num_edges)
    fits = ReorderPolicy(device_budget_bytes=need * 10).decide(probes, 256)
    assert fits.backend == "single"
    over = ReorderPolicy(device_budget_bytes=need // 4).decide(probes, 256)
    assert over.backend == "sharded" and "placement" in over.reason
    default = ReorderPolicy().decide(probes, 256)
    assert default.backend == "single"


def test_sharded_runner_factory_covers_every_served_kernel(plc_graph):
    """Six-kernel parity is structural: every kernel the executor serves
    has a sharded runner factory, and the factory table *is* the
    SHARDED_KERNELS contract (the old NotImplementedError is unreachable
    and now an assertion)."""
    assert set(SHARDED_KERNELS) == set(MULTI_SOURCE) | set(GLOBAL)
    assert set(_RUNNER_FACTORIES) == set(SHARDED_KERNELS)
    for factory in _RUNNER_FACTORIES.values():
        assert callable(factory)
    # unknown kernels are rejected up front with the executor's ValueError
    backend = ShardedBackend(num_shards=1)
    handle = backend.prepare(plc_graph)
    with pytest.raises(ValueError, match="unknown kernel"):
        backend.run(handle, "nope")
    assert backend.queries_run == 0  # rejected before anything counted


def test_session_sharded_serves_all_kernels_and_discount(plc_graph):
    """Session-level sharded serving: every kernel routes (parity proper
    is the matrix's job), the ledger discount reflects the hot-prefix
    exchange, and telemetry surfaces the prefix statistics."""
    session = EngineSession(device_budget_bytes=1024,
                            redecide_min_queries=10**6)
    gid = session.register(plc_graph, graph_id="over-budget",
                           expected_queries=256)
    entry = session.registry.get(gid)
    assert entry.backend == "sharded"
    assert entry.ledger.backend == "sharded"
    # plc is hub-heavy: the policy thins the exchange, so the collective
    # dilution — and with it the ledger discount — shrinks vs full
    assert entry.hot_prefix_fraction is not None
    assert (session.sharded_gain_discount
            < entry.ledger.gain_discount < 1.0)
    srcs = np.array([5, 321], np.int64)
    for kernel in ("bfs", "sssp", "bc"):
        assert session.submit(gid, kernel, srcs).shape == (
            2, plc_graph.num_vertices)
    for kernel in ("pr", "cc", "ccsv"):
        assert session.submit(gid, kernel).shape == (
            plc_graph.num_vertices,)
    t = session.telemetry()
    assert t["graphs"][gid]["backend"] == "sharded"
    assert t["graphs"][gid]["hot_prefix_fraction"] == \
        entry.hot_prefix_fraction
    assert t["executor"]["sharded"]["queries_run"] == 6
    hp = t["executor"]["sharded"]["hot_prefix"]
    assert hp["steps_full"] > 0
    kernels_with_prefix = {r["kernel"] for r in hp["runners"]}
    # monotone kernels run thinned; pr/bc stay synchronous full-exchange
    # and ccsv aliases to the cc runner (one partition, one compile)
    assert kernels_with_prefix == {"bfs", "sssp", "cc"}
    runners = entry.handle.shard_state._runners
    assert "ccsv" not in runners and "cc" in runners
    for r in hp["runners"]:
        assert 0.0 < r["prefix_hit_rate"] <= 1.0
        assert 1 <= r["h_local"] <= r["per_shard_vertices"]


def _probes(**kw) -> GraphProbes:
    base = dict(num_vertices=100_000, num_edges=1_000_000, avg_degree=10.0,
                degree_gini=0.6, hub_fraction=0.1, hub_mass=0.7,
                diameter=12, probe_seconds=0.0)
    base.update(kw)
    return GraphProbes(**base)


def test_policy_hot_prefix_from_hub_mass():
    """hub mass >= threshold + a hub-packing scheme => thinned exchange,
    fraction = clamp(margin x hub_fraction)."""
    policy = ReorderPolicy(device_budget_bytes=1)  # everything sharded
    d = policy.decide(_probes(), 256)
    assert d.backend == "sharded"
    assert d.hot_prefix_fraction == pytest.approx(0.2)  # 2.0 x 0.1
    assert "hot-prefix" in d.reason
    # diffuse degree mass: nothing to concentrate, full exchange
    diffuse = policy.decide(_probes(hub_mass=0.3), 256)
    assert diffuse.backend == "sharded"
    assert diffuse.hot_prefix_fraction is None
    # no reorder => hubs stay scattered => no prefix to exploit
    low_vol = policy.decide(_probes(), 1)
    assert low_vol.scheme == "original"
    assert low_vol.hot_prefix_fraction is None
    # bounds clamp both ends
    wide = ReorderPolicy(device_budget_bytes=1).decide(
        _probes(hub_fraction=0.45), 256)
    assert wide.hot_prefix_fraction == pytest.approx(0.5)
    narrow = ReorderPolicy(device_budget_bytes=1).decide(
        _probes(hub_fraction=0.001), 256)
    assert narrow.hot_prefix_fraction == pytest.approx(0.05)
    # single-device placement never carries a fraction
    single = ReorderPolicy().decide(_probes(), 256)
    assert single.backend == "single"
    assert single.hot_prefix_fraction is None


# ------------------------------------------------------ benchmark driver
def test_run_py_parse_only_accepts_lists():
    from benchmarks.run import HARNESSES, parse_only
    assert parse_only(None) == list(HARNESSES)
    assert parse_only("engine") == ["engine"]
    assert parse_only("engine,reorder_time") == ["engine", "reorder_time"]
    assert parse_only(" engine , skew ") == ["engine", "skew"]
    with pytest.raises(SystemExit):
        parse_only("engine,nope")
