"""Cross-request result cache with GRASP-style hot-entry pinning.

The paper's premise is that hot vertices are few and hit constantly;
Faldu et al. (*Domain-Specialized Cache Management for Graph Analytics*,
PAPERS.md) sharpen it into a cache-management rule: results keyed on
hot vertices are precisely the reusable ones, and the hot set is stable
over time. The request plane already computes the artifact that tells
hot from cold — the reorder permutation packs hubs into a low-id prefix
— so the scheduler can cache per-source result rows and *pin* the ones
whose source lands inside the hot prefix while cold entries ride a
size-bounded LRU.

Keying: ``(graph_id, generation, kernel, source)``, with ``source =
GLOBAL_SOURCE`` (-1) for source-independent kernels (pr/cc/ccsv). The
layout ``generation`` is part of the key, so a re-decision *cannot*
serve a row computed under a replaced layout even before
``invalidate_graph`` reclaims the stale entries — invalidation is a
memory optimization, correctness rides on the key.

Thread-safe (one lock around the stores): the scheduler may be polled
from a background auto-flush thread. Metrics land in the session's
`MetricsRegistry` (``engine_result_cache_*``) so hit/miss/eviction
traffic and occupancy export through ``to_prometheus()`` like every
other engine signal (docs/observability.md).
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict

import numpy as np

from .obs import MetricsRegistry

# source id used to key results of source-independent (global) kernels;
# real sources are validated non-negative at enqueue so -1 cannot collide
GLOBAL_SOURCE = -1

Key = tuple[str, int, str, int]


class ResultCache:
    """Size-bounded LRU of per-source result rows + a pinned hot store.

    ``get``/``put`` move complete result rows (original-id space, exactly
    what a future resolves with), so a hit is a pure memory read — no
    launch, no translation. Pinned entries (hot-prefix sources, global
    kernels) never ride the LRU clock; cold entries evict
    least-recently-used once ``max_entries`` is reached. ``max_pinned``
    bounds the pinned store too (overflow demotes to the LRU) so a
    pathological hot prefix cannot grow memory without bound.
    """

    def __init__(self, max_entries: int = 4096,
                 max_pinned: int | None = None,
                 registry: MetricsRegistry | None = None,
                 max_age_s: float | None = None,
                 max_bytes: int | None = None,
                 clock=None):
        if max_entries < 1:
            raise ValueError("max_entries must be >= 1")
        if max_age_s is not None and max_age_s <= 0:
            raise ValueError("max_age_s must be > 0 or None")
        if max_bytes is not None and max_bytes < 1:
            raise ValueError("max_bytes must be >= 1 or None")
        self.max_entries = max_entries
        self.max_pinned = max_pinned if max_pinned is not None else max_entries
        # optional freshness bound: entries older than max_age_s seconds
        # (by `clock`, injectable for tests / the session's virtual clock)
        # read as misses and are reclaimed on touch. Applies to pinned
        # entries too — pinning exempts a row from LRU pressure, not from
        # going stale.
        self.max_age_s = max_age_s
        # optional byte bound on resident rows: cold entries evict LRU
        # until under it (pinned bytes count toward it; max_pinned is the
        # lever bounding those)
        self.max_bytes = max_bytes
        self._clock = clock if clock is not None else time.monotonic
        # stores hold (row, stamp, nbytes)
        self._lru: OrderedDict[Key, tuple] = OrderedDict()
        self._pinned: dict[Key, tuple] = {}
        self._bytes = 0
        self._lock = threading.Lock()
        m = registry or MetricsRegistry()
        self.metrics = m
        self._c_hits = m.counter("engine_result_cache_hits_total",
                                 "result rows served from memory")
        self._c_misses = m.counter("engine_result_cache_misses_total",
                                   "result lookups that needed a launch")
        self._c_evictions = m.counter("engine_result_cache_evictions_total",
                                      "cold entries dropped by the LRU")
        self._c_expired = m.counter("engine_result_cache_expired_total",
                                    "entries dropped past max_age_s")
        self._g_pinned = m.gauge("engine_result_cache_pinned",
                                 "hot-prefix entries resident (pinned)")
        self._g_entries = m.gauge("engine_result_cache_entries",
                                  "total cached result rows (occupancy)")
        self._g_bytes = m.gauge("engine_result_cache_bytes",
                                "resident result-row payload bytes")

    # ------------------------------------------------------------ counters
    @property
    def hits(self) -> int:
        return self._c_hits.value

    @property
    def misses(self) -> int:
        return self._c_misses.value

    @property
    def evictions(self) -> int:
        return self._c_evictions.value

    @property
    def expired(self) -> int:
        return self._c_expired.value

    @property
    def pinned_count(self) -> int:
        return len(self._pinned)

    @property
    def entries(self) -> int:
        return len(self._lru) + len(self._pinned)

    @property
    def resident_bytes(self) -> int:
        return self._bytes

    # ------------------------------------------------------------- core api
    @staticmethod
    def key(graph_id: str, generation: int, kernel: str,
            source: int = GLOBAL_SOURCE) -> Key:
        return (graph_id, int(generation), kernel, int(source))

    def _fresh(self, entry: tuple) -> bool:
        if self.max_age_s is None:
            return True
        return self._clock() - entry[1] <= self.max_age_s

    def get(self, graph_id: str, generation: int, kernel: str,
            source: int = GLOBAL_SOURCE) -> np.ndarray | None:
        """The cached row, or None (counts a hit or a miss either way).
        An entry past ``max_age_s`` reads as a miss and is reclaimed."""
        k = self.key(graph_id, generation, kernel, source)
        with self._lock:
            entry = self._pinned.get(k)
            store = self._pinned
            if entry is None:
                entry = self._lru.get(k)
                store = self._lru
                if entry is not None:
                    self._lru.move_to_end(k)       # refresh recency
            if entry is not None and not self._fresh(entry):
                del store[k]
                self._bytes -= entry[2]
                self._c_expired.inc()
                self._sync_gauges()
                entry = None
            if entry is None:
                self._c_misses.inc()
                return None
            self._c_hits.inc()
            return entry[0]

    def put(self, graph_id: str, generation: int, kernel: str,
            source: int, row: np.ndarray, pinned: bool = False) -> None:
        """Insert one result row; ``pinned`` keeps it off the LRU clock."""
        k = self.key(graph_id, generation, kernel, source)
        entry = (row, self._clock(), int(getattr(row, "nbytes", 0)))
        with self._lock:
            # an already-pinned key refreshes in place even at max_pinned —
            # otherwise the write is silently dropped and the stale row
            # stays pinned forever
            if pinned and (k in self._pinned
                           or len(self._pinned) < self.max_pinned):
                old = self._lru.pop(k, None) or self._pinned.get(k)
                if old is not None:
                    self._bytes -= old[2]
                self._pinned[k] = entry
                self._bytes += entry[2]
            elif k not in self._pinned:
                old = self._lru.pop(k, None)
                if old is not None:
                    self._bytes -= old[2]
                self._lru[k] = entry
                self._bytes += entry[2]
                while len(self._lru) > self.max_entries:
                    _, dropped = self._lru.popitem(last=False)
                    self._bytes -= dropped[2]
                    self._c_evictions.inc()
                if self.max_bytes is not None:
                    # evict cold LRU entries until under the byte bound;
                    # pinned bytes are untouchable here by design
                    while self._bytes > self.max_bytes and self._lru:
                        _, dropped = self._lru.popitem(last=False)
                        self._bytes -= dropped[2]
                        self._c_evictions.inc()
            self._sync_gauges()

    def invalidate_graph(self, graph_id: str) -> int:
        """Drop every entry of one graph (all generations); returns the
        count. Called on re-decision — the generation key already makes
        stale rows unreachable, this reclaims their memory."""
        with self._lock:
            doomed = [k for k in self._lru if k[0] == graph_id]
            for k in doomed:
                self._bytes -= self._lru.pop(k)[2]
            doomed_pinned = [k for k in self._pinned if k[0] == graph_id]
            for k in doomed_pinned:
                self._bytes -= self._pinned.pop(k)[2]
            self._sync_gauges()
            return len(doomed) + len(doomed_pinned)

    def clear(self) -> None:
        with self._lock:
            self._lru.clear()
            self._pinned.clear()
            self._bytes = 0
            self._sync_gauges()

    def _sync_gauges(self) -> None:
        self._g_pinned.set(len(self._pinned))
        self._g_entries.set(len(self._lru) + len(self._pinned))
        self._g_bytes.set(self._bytes)

    # ----------------------------------------------------------- telemetry
    def stats(self) -> dict:
        looked = self.hits + self.misses
        return {
            "entries": self.entries,
            "pinned": self.pinned_count,
            "max_entries": self.max_entries,
            "bytes": self.resident_bytes,
            "max_bytes": self.max_bytes,
            "max_age_s": self.max_age_s,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "expired": self.expired,
            "hit_rate": round(self.hits / looked, 4) if looked else 0.0,
        }


__all__ = ["GLOBAL_SOURCE", "ResultCache"]
