"""rwkv6-3b [ssm]: 32L d2560 (attention-free) ff8960 v65536 — Finch,
data-dependent decay. [arXiv:2404.05892; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    num_layers=32, d_model=2560,
    num_heads=40, num_kv_heads=40, head_dim=64,   # wkv heads of size 64
    d_ff=8960, vocab_size=65536,
    block_pattern=("rwkv",) * 32,
    norm_type="layernorm",
    vocab_reorder=True, hot_vocab_fraction=0.05,
)
