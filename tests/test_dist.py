"""core/dist.py coverage: partition round-trips and true multi-shard parity.

The in-process suite runs on a single host device, so the genuinely
distributed check (4 shards) runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` — the flag must be
set before jax initializes its backends.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.dist import partition_edges


@pytest.mark.parametrize("num_shards", [1, 2, 4, 7])
def test_partition_edges_round_trip(any_graph, num_shards):
    """No edge lost or invented; local dst indices reconstruct globals."""
    g = any_graph
    s_pad, d_pad, valid, per = partition_edges(g, num_shards)
    assert s_pad.shape == d_pad.shape == valid.shape
    assert valid.sum() == g.num_edges
    src_rt, dst_rt = [], []
    for i in range(num_shards):
        assert (0 <= d_pad[i][valid[i]]).all()
        assert (d_pad[i][valid[i]] < per).all()
        src_rt.append(s_pad[i][valid[i]])
        dst_rt.append(d_pad[i][valid[i]] + i * per)
    pairs_rt = np.stack([np.concatenate(src_rt).astype(np.int64),
                         np.concatenate(dst_rt).astype(np.int64)], 1)
    order = np.lexsort((pairs_rt[:, 1], pairs_rt[:, 0]))
    np.testing.assert_array_equal(pairs_rt[order], g.edge_multiset())


def test_partition_edges_empty_shards():
    """A graph whose edges all land in shard 0 still partitions cleanly."""
    from repro.core.csr import from_edges
    g = from_edges(40, [10, 11, 12], [0, 1, 2])  # dst < 10 => shard 0 of 4
    s_pad, d_pad, valid, per = partition_edges(g, 4)
    assert per == 10
    assert valid[0].sum() == 3 and valid[1:].sum() == 0


def test_distributed_pagerank_parity_four_shards():
    """Sharded PR on 4 forced host devices == single-device PR."""
    prog = textwrap.dedent("""
        import numpy as np
        import jax
        assert jax.device_count() == 4, jax.devices()
        from repro.algos.graph_arrays import to_device
        from repro.algos.kernels import pagerank
        from repro.core.dist import make_distributed_pagerank
        from repro.core.generators import powerlaw_community

        g = powerlaw_community(2000, avg_degree=8.0, seed=3)
        mesh = jax.make_mesh((4,), ("data",))
        run, _ = make_distributed_pagerank(g, mesh, axis="data",
                                           num_iters=20)
        got = np.asarray(run())
        want = np.asarray(pagerank(to_device(g), num_iters=20))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-7)
        print("PARITY_OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["JAX_PLATFORMS"] = "cpu"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), env.get("PYTHONPATH", "")]).rstrip(
        os.pathsep)
    res = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=300)
    assert res.returncode == 0, f"stdout={res.stdout}\nstderr={res.stderr}"
    assert "PARITY_OK" in res.stdout
