"""Request plane: futures, micro-batch coalescing, dedup, ordering.

The contract under test is the tentpole of the scheduler redesign: any
interleaving of enqueued requests — mixed kernels, priorities, duplicate
global-kernel requests — must yield results bit-identical (allclose for
the float kernels bc/pr, whose launch shape can differ under coalescing)
to serving the same requests one at a time through the blocking
``submit``. The hypothesis property test generates those interleavings;
the 4-forced-device leg re-runs this whole module with the sharded
backend on a genuine mesh, like tests/test_parity_matrix.py.
"""
from __future__ import annotations

import os

import numpy as np
import pytest

from conftest import run_forced_four_devices
from repro.core.baselines import cc_baseline
from repro.engine import (AdmissionPolicy, AdmissionRejected,
                          DeadlineExceeded, EngineSession, ManualClock,
                          QueryFuture, ReorderPolicy,
                          canonical_component_labels, estimate_device_bytes)
from repro.engine.backends import source_bucket

FLOAT_KERNELS = ("pr", "bc")


def _session(**kw) -> EngineSession:
    kw.setdefault("redecide_min_queries", 10**6)
    return EngineSession(**kw)


def _assert_matches(kernel: str, got, want) -> None:
    got, want = np.asarray(got), np.asarray(want)
    if kernel in FLOAT_KERNELS:
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
    else:
        np.testing.assert_array_equal(got, want)


# ------------------------------------------------------------ future basics
def test_enqueue_returns_pending_future(plc_graph):
    session = _session()
    gid = session.register(plc_graph, expected_queries=256)
    fut = session.enqueue(gid, "bfs", [0, 1])
    assert isinstance(fut, QueryFuture)
    assert not fut.done()
    assert session.scheduler.pending() == 1
    served = session.flush()
    assert served == 1 and fut.done() and session.scheduler.pending() == 0
    assert fut.result().shape == (2, plc_graph.num_vertices)


def test_result_flushes_owning_graph(plc_graph):
    """A lone enqueue().result() behaves exactly like blocking submit."""
    session = _session()
    gid = session.register(plc_graph, expected_queries=256)
    fut = session.enqueue(gid, "bfs", [3])
    out = fut.result()          # no explicit flush
    assert fut.done() and session.scheduler.pending() == 0
    _assert_matches("bfs", out, _session_submit_reference(plc_graph, "bfs",
                                                          [3]))


def _session_submit_reference(graph, kernel, sources):
    ref = _session()
    rid = ref.register(graph, expected_queries=256)
    return ref.submit(rid, kernel, sources)


def test_enqueue_validates_eagerly(plc_graph):
    session = _session()
    gid = session.register(plc_graph, expected_queries=256)
    with pytest.raises(ValueError):
        session.enqueue(gid, "nope", [0])
    with pytest.raises(ValueError):
        session.enqueue(gid, "bfs", [])
    with pytest.raises(KeyError):
        session.enqueue("unregistered", "bfs", [0])
    # out-of-range ids fail the offending caller at enqueue — at launch
    # time they would poison every request coalesced alongside
    with pytest.raises(ValueError, match="sources must be in"):
        session.enqueue(gid, "bfs", [plc_graph.num_vertices])
    with pytest.raises(ValueError, match="sources must be in"):
        session.enqueue(gid, "bfs", [-1])
    assert session.scheduler.pending() == 0
    assert session.scheduler.requests_enqueued == 0


# ------------------------------------------------------------- coalescing
def test_multi_source_requests_coalesce_into_one_launch(plc_graph):
    session = _session()
    gid = session.register(plc_graph, expected_queries=256)
    rng = np.random.default_rng(0)
    batches = [rng.integers(0, plc_graph.num_vertices, size=n)
               for n in (3, 1, 4, 2)]
    futs = [session.enqueue(gid, "bfs", b) for b in batches]
    before = session.executor.queries_run
    session.flush(gid)
    assert session.executor.queries_run - before == 1   # one device launch
    assert session.scheduler.launches == 1
    assert session.scheduler.coalesced_requests == len(batches)
    for fut, batch in zip(futs, batches):
        assert fut.telemetry["coalesced_with"] == len(batches) - 1
        assert fut.telemetry["launch_batch_sources"] == 10
        _assert_matches("bfs", fut.result(),
                        _session_submit_reference(plc_graph, "bfs", batch))


def test_coalesced_batch_fills_source_bucket(plc_graph):
    """The combined launch pads to one power-of-two bucket, not per-request
    buckets: 3+1+4+2 = 10 distinct sources ride a 16-slot bucket in one
    launch."""
    session = _session()
    gid = session.register(plc_graph, expected_queries=256)
    base = 0
    for n in (3, 1, 4, 2):
        session.enqueue(gid, "bfs", np.arange(base, base + n))
        base += n
    session.flush()
    keys = session.executor.single.telemetry()["cached_keys"]
    assert len(keys) == 1  # one compiled shape for the whole burst
    assert source_bucket(10) == 16


def test_max_batch_sources_chunks_in_order(plc_graph):
    session = _session(max_batch_sources=4)
    gid = session.register(plc_graph, expected_queries=256)
    futs = [session.enqueue(gid, "bfs", np.arange(i * 3, i * 3 + 3))
            for i in range(3)]
    session.flush()
    # greedy packs r0 (3 sources), r1 would exceed the cap of 4 -> new
    # chunk [r1], then [r2]: 3 launches of 3 sources each
    assert session.scheduler.launches == 3
    idx = [f.telemetry["launch_index"] for f in futs]
    assert idx == sorted(idx)  # FIFO within equal priority


def test_global_requests_dedup_into_one_run(plc_graph):
    session = _session()
    gid = session.register(plc_graph, expected_queries=256)
    futs = [session.enqueue(gid, "pr") for _ in range(5)]
    before = session.executor.queries_run
    session.flush()
    assert session.executor.queries_run - before == 1
    assert session.scheduler.dedup_hits == 4
    outs = [np.asarray(f.result()) for f in futs]
    for out in outs[1:]:
        np.testing.assert_array_equal(outs[0], out)
    _assert_matches("pr", outs[0],
                    _session_submit_reference(plc_graph, "pr", None))


# ------------------------------------------------------ ordering semantics
def test_priority_orders_launches(plc_graph):
    session = _session()
    gid = session.register(plc_graph, expected_queries=256)
    low = session.enqueue(gid, "bfs", [0], priority=0)
    high = session.enqueue(gid, "sssp", [1], priority=10)
    session.flush()
    assert high.telemetry["launch_index"] < low.telemetry["launch_index"]


def test_deadline_orders_and_flags(plc_graph):
    session = _session()
    gid = session.register(plc_graph, expected_queries=256)
    relaxed = session.enqueue(gid, "bfs", [0], deadline_seconds=3600.0)
    urgent = session.enqueue(gid, "sssp", [1], deadline_seconds=0.0)
    none = session.enqueue(gid, "bc", [2])
    session.flush()
    # earliest absolute deadline first; no deadline sorts last
    assert (urgent.telemetry["launch_index"]
            < relaxed.telemetry["launch_index"]
            < none.telemetry["launch_index"])
    assert urgent.telemetry["deadline_missed"] is True  # 0 s budget
    assert relaxed.telemetry["deadline_missed"] is False
    assert session.scheduler.deadlines_missed == 1


# --------------------------------------------------- submit compatibility
def test_submit_is_enqueue_flush_sugar(plc_graph):
    session = _session()
    gid = session.register(plc_graph, expected_queries=256)
    out = session.submit(gid, "bfs", [0, 5])
    t = session.scheduler.telemetry()
    assert t["requests_served"] == 1 and t["launches"] == 1
    entry = session.registry.get(gid)
    assert entry.ledger.queries_served == 1
    assert entry.ledger.sources_served == 2
    assert entry.queries_observed == 1
    assert out.shape == (2, plc_graph.num_vertices)


def test_submit_serves_pending_futures_on_same_graph(plc_graph):
    session = _session()
    gid = session.register(plc_graph, expected_queries=256)
    queued = session.enqueue(gid, "bfs", [7])
    session.submit(gid, "bfs", [9])     # flush boundary serves both
    assert queued.done()


# -------------------------------------------------- component-label space
def test_component_labels_canonicalized_to_original_ids(plc_graph):
    """PR 4 leaked served-space label values; the session boundary now
    canonicalizes to min-original-id per component — bit-identical to the
    numpy baseline regardless of the reorder the policy picked."""
    session = _session()
    gid = session.register(plc_graph, expected_queries=256)
    entry = session.registry.get(gid)
    assert entry.decision.scheme != "original"  # a real reorder happened
    want = cc_baseline(plc_graph)
    for kernel in ("cc", "ccsv"):
        np.testing.assert_array_equal(session.submit(gid, kernel), want)


def test_canonical_component_labels_helper():
    labels = np.array([5, 5, 2, 2, 5])   # arbitrary representative space
    np.testing.assert_array_equal(canonical_component_labels(labels),
                                  np.array([0, 0, 2, 2, 0]))
    stacked = np.stack([labels, np.array([1, 0, 0, 3, 3])])
    got = canonical_component_labels(stacked)
    np.testing.assert_array_equal(got[0], [0, 0, 2, 2, 0])
    np.testing.assert_array_equal(got[1], [0, 1, 1, 3, 3])


# ----------------------------------------------------- generations / flush
def test_generation_bumps_on_redecision_and_stamps_futures(plc_graph):
    session = EngineSession(redecide_factor=2.0, redecide_min_queries=4)
    gid = session.register(plc_graph, expected_queries=1)  # volume-gated
    entry = session.registry.get(gid)
    assert entry.generation == 1
    assert entry.decision.scheme == "original"
    rng = np.random.default_rng(2)
    futs = []
    for _ in range(12):
        futs.append(session.enqueue(
            gid, "bfs", rng.integers(0, plc_graph.num_vertices, size=2)))
    session.drain()
    # the whole burst was one flush: every future served by generation 1,
    # the re-decision fired only at the flush boundary
    assert {f.telemetry["generation"] for f in futs} == {1}
    assert entry.generation > 1
    assert entry.decision.scheme != "original"
    assert session.redecision_log
    # post-re-decision requests are served by — and stamped with — the
    # new layout, and still answer in original vertex ids
    fut = session.enqueue(gid, "bfs", [3])
    _assert_matches("bfs", fut.result(),
                    _session_submit_reference(plc_graph, "bfs", [3]))
    assert fut.telemetry["generation"] == entry.generation


# -------------------------------------------------- placement v2 (S term)
def test_estimate_device_bytes_gains_batch_state_term():
    base = estimate_device_bytes(1000, 8000)
    assert estimate_device_bytes(1000, 8000, batch_sources=0) == base
    assert estimate_device_bytes(1000, 8000, batch_sources=16) == \
        base + 8 * 16 * 1000


def test_policy_observes_scheduler_batches(plc_graph):
    session = _session()
    gid = session.register(plc_graph, expected_queries=256)
    assert session.policy.batch_sources_hint == 1
    for i in range(3):
        session.enqueue(gid, "bfs", np.arange(i * 8, i * 8 + 8))
    session.flush()   # one coalesced 24-source launch observed
    assert session.policy.batches_observed == 1
    assert session.policy.batch_sources_hint == source_bucket(24)


def test_batch_state_tips_placement_to_sharded(plc_graph):
    """A graph whose CSR fits the budget but whose observed batch state
    does not must be re-placed sharded (ROADMAP placement v2)."""
    from repro.engine import probe_graph
    probes = probe_graph(plc_graph)
    from repro.engine.backends import bucket_dims
    v_b, e_b = bucket_dims(probes.num_vertices, probes.num_edges)
    # budget covers the bucketed CSR plus one query's state (the S=1
    # default before any batches are observed), with no room for more
    policy = ReorderPolicy(
        device_budget_bytes=estimate_device_bytes(v_b, e_b,
                                                  batch_sources=1) + 1)
    assert policy.decide(probes, 256).backend == "single"
    for _ in range(8):
        policy.observe_batch_sources(64)
    d = policy.decide(probes, 256)
    assert d.backend == "sharded"
    assert "query state" in d.reason


# -------------------------------------------- per-request exchange stats
def test_sharded_requests_carry_exchange_deltas(plc_graph):
    session = _session(device_budget_bytes=1024)
    gid = session.register(plc_graph, expected_queries=256)
    assert session.registry.get(gid).backend == "sharded"
    f1 = session.enqueue(gid, "bfs", [0, 1])
    f2 = session.enqueue(gid, "cc")
    session.flush()
    for f in (f1, f2):
        ex = f.telemetry["exchange"]
        assert ex is not None and ex["steps"] > 0
    # deltas are per run, not cumulative: the backend aggregate is the sum
    agg = session.executor.sharded.exchange_stats
    assert (f1.telemetry["exchange"]["steps"]
            + f2.telemetry["exchange"]["steps"]) == agg.steps
    # single-device requests carry no exchange block
    single = _session()
    sid = single.register(plc_graph, expected_queries=256)
    fut = single.enqueue(sid, "bfs", [0])
    single.flush()
    assert fut.telemetry["exchange"] is None


# ------------------------------------------------- interleaving property
KERNELS = ("bfs", "sssp", "bc", "pr", "cc", "ccsv")


def _run_interleaving(graph, specs, session_factory=None):
    """Serve ``specs`` batched (enqueue-all + drain) and sequentially
    (fresh session, per-request submit); assert per-request parity."""
    session_factory = session_factory or _session
    batched = session_factory()
    sequential = session_factory()
    bid = batched.register(graph, graph_id="b", expected_queries=256)
    sid = sequential.register(graph, graph_id="s", expected_queries=256)
    futs = [batched.enqueue(bid, k, srcs, priority=pr)
            for k, srcs, pr in specs]
    batched.drain()
    for fut, (kernel, srcs, _) in zip(futs, specs):
        _assert_matches(kernel, fut.result(),
                        sequential.submit(sid, kernel, srcs))
    # unbounded coalescing in one flush: exactly one launch per distinct
    # kernel, however many requests rode it
    assert batched.scheduler.launches == len({k for k, _, _ in specs})


@pytest.mark.parametrize("config", ["exact", "bucketed", "sharded"])
def test_mixed_kernel_interleaving_matches_sequential(plc_graph, config):
    """Coalescing parity across every serving config: batched enqueue +
    drain vs per-request submit, all six kernels in one interleaving."""
    rng = np.random.default_rng(7)
    specs = []
    for i in range(12):
        kernel = KERNELS[i % len(KERNELS)]
        srcs = (rng.integers(0, plc_graph.num_vertices, size=1 + i % 3)
                if kernel in ("bfs", "sssp", "bc") else None)
        specs.append((kernel, srcs, int(rng.integers(0, 3))))
    if config == "exact":
        from repro.engine import BatchedExecutor

        def factory():
            return _session(executor=BatchedExecutor(bucketing=False))
    elif config == "sharded":
        def factory():
            return _session(device_budget_bytes=1024)
    else:
        factory = _session
    _run_interleaving(plc_graph, specs, session_factory=factory)


def test_interleaving_property_random(tiny_graph):
    """Hypothesis: any interleaving of requests — kernels, priorities,
    duplicate globals — is bit-identical to sequential submit."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    n = tiny_graph.num_vertices
    spec = st.tuples(
        st.sampled_from(KERNELS),
        st.lists(st.integers(min_value=0, max_value=n - 1),
                 min_size=1, max_size=4),
        st.integers(min_value=-2, max_value=2),
    )

    @settings(max_examples=10, deadline=None)
    @given(specs=st.lists(spec, min_size=1, max_size=8))
    def check(specs):
        prepared = [(k, np.asarray(srcs) if k in ("bfs", "sssp", "bc")
                     else None, pr) for k, srcs, pr in specs]
        _run_interleaving(tiny_graph, prepared)

    check()


def test_interleaving_sharded(plc_graph):
    """Same contract when the graph is served sharded (1 shard in the
    plain suite; a real mesh under the 4-device leg below)."""
    rng = np.random.default_rng(11)
    specs = [("bfs", rng.integers(0, plc_graph.num_vertices, 2), 1),
             ("sssp", rng.integers(0, plc_graph.num_vertices, 3), 0),
             ("cc", None, 0), ("ccsv", None, 2), ("pr", None, 0),
             ("bc", rng.integers(0, plc_graph.num_vertices, 2), 0)]
    _run_interleaving(plc_graph, specs,
                      session_factory=lambda: _session(
                          device_budget_bytes=1024))


# ------------------------------------------------------------ result cache
def test_result_cache_serves_across_flush_windows(plc_graph):
    """A repeat of already-served sources costs no launch: rows come out
    of the (graph, generation, kernel, source) cache, bit-identical and
    order-correct, and the serve is visible as a cache_hit span."""
    session = _session()
    gid = session.register(plc_graph, expected_queries=256)
    f1 = session.enqueue(gid, "bfs", [0, 1, 2])
    session.flush()
    assert session.scheduler.launches == 1
    f2 = session.enqueue(gid, "bfs", [2, 1, 0])
    session.flush()
    assert session.scheduler.launches == 1          # no second launch
    assert f2.telemetry["served_from_cache"] is True
    assert f2.telemetry["cache_hit_sources"] == 3
    assert f2.telemetry["launch_batch_sources"] == 0
    np.testing.assert_array_equal(np.asarray(f2.result()),
                                  np.asarray(f1.result())[[2, 1, 0]])
    assert session.result_cache.hits >= 3
    names = {e["name"] for e in session.tracer.to_chrome()["traceEvents"]}
    assert "cache_hit" in names


def test_result_cache_partial_hit_launches_only_missing(plc_graph):
    session = _session()
    gid = session.register(plc_graph, expected_queries=256)
    session.enqueue(gid, "bfs", [4, 5])
    session.flush()
    f = session.enqueue(gid, "bfs", [5, 6])        # 5 cached, 6 fresh
    session.flush()
    assert session.scheduler.launches == 2
    assert f.telemetry["launch_batch_sources"] == 1  # only source 6 launched
    assert f.telemetry["cache_hit_sources"] == 1
    assert f.telemetry["served_from_cache"] is False
    _assert_matches("bfs", f.result(),
                    _session_submit_reference(plc_graph, "bfs", [5, 6]))


def test_within_window_duplicate_sources_dedup(plc_graph):
    """Two requests asking the same sources in one flush share one launch
    of the *unique* sources — the within-window form of the cache."""
    session = _session()
    gid = session.register(plc_graph, expected_queries=256)
    f1 = session.enqueue(gid, "bfs", [0, 1])
    f2 = session.enqueue(gid, "bfs", [1, 0])
    session.flush()
    assert session.scheduler.launches == 1
    assert f1.telemetry["launch_batch_sources"] == 2   # unique, not 4
    np.testing.assert_array_equal(np.asarray(f1.result())[[1, 0]],
                                  np.asarray(f2.result()))
    _assert_matches("bfs", f1.result(),
                    _session_submit_reference(plc_graph, "bfs", [0, 1]))


def test_global_kernels_cache_across_windows(plc_graph):
    session = _session()
    gid = session.register(plc_graph, expected_queries=256)
    p1 = session.submit(gid, "pr")
    before = session.executor.queries_run
    p2 = session.submit(gid, "pr")                  # across flush windows
    assert session.executor.queries_run == before   # zero device work
    np.testing.assert_array_equal(p1, p2)


def test_result_cache_disabled_matches_legacy_plane(plc_graph):
    """``result_cache=False`` restores the PR 5 coalescing exactly:
    duplicate sources ride the launch and repeats re-launch."""
    session = _session(result_cache=False)
    assert session.result_cache is None
    gid = session.register(plc_graph, expected_queries=256)
    f1 = session.enqueue(gid, "bfs", [0, 1])
    f2 = session.enqueue(gid, "bfs", [1, 0])
    session.flush()
    assert session.scheduler.launches == 1
    assert f1.telemetry["launch_batch_sources"] == 4   # dupes included
    session.enqueue(gid, "bfs", [0, 1])
    session.flush()
    assert session.scheduler.launches == 2             # repeat re-launches
    assert session.scheduler.telemetry()["result_cache"] is None
    _assert_matches("bfs", f2.result(),
                    _session_submit_reference(plc_graph, "bfs", [1, 0]))


# ------------------------------------------------------- multi-graph fairness
def test_round_robin_across_graphs_with_chunking(plc_graph, tiny_graph):
    """With max_batch_sources chunking, launches alternate between graphs
    instead of one graph's burst monopolizing consecutive launches."""
    session = _session(max_batch_sources=2, result_cache=False)
    g1 = session.register(plc_graph, graph_id="g1", expected_queries=256)
    g2 = session.register(tiny_graph, graph_id="g2", expected_queries=256)
    futs1 = [session.enqueue(g1, "bfs", [i]) for i in range(4)]
    futs2 = [session.enqueue(g2, "bfs", [i]) for i in range(4)]
    session.flush()
    idx1 = sorted({f.telemetry["launch_index"] for f in futs1})
    idx2 = sorted({f.telemetry["launch_index"] for f in futs2})
    # two chunks per graph, interleaved: g1 -> {1, 3}, g2 -> {2, 4} (not
    # g1 taking 1-2 and starving g2 until 3-4)
    assert idx1 == [1, 3] and idx2 == [2, 4]


def test_flush_rotation_changes_leading_graph(plc_graph, tiny_graph):
    """The graph that leads a multi-graph flush rotates between flushes,
    so repeated bursts don't always pay graph-order latency to the same
    victim."""
    session = _session(result_cache=False)
    g1 = session.register(plc_graph, graph_id="g1", expected_queries=256)
    g2 = session.register(tiny_graph, graph_id="g2", expected_queries=256)

    def burst():
        f1 = session.enqueue(g1, "bfs", [0])
        f2 = session.enqueue(g2, "bfs", [0])
        session.flush()
        return (f1.telemetry["launch_index"], f2.telemetry["launch_index"])

    a1, b1 = burst()
    a2, b2 = burst()
    assert (a1 < b1) != (a2 < b2)    # lead alternates across flushes


# ------------------------------------------------------ auto-flush / polling
def test_poll_flushes_overdue_requests_on_enqueue(plc_graph):
    clock = ManualClock()
    session = _session(clock=clock, max_delay=0.1)
    gid = session.register(plc_graph, expected_queries=256)
    f1 = session.enqueue(gid, "bfs", [0])
    assert not f1._done
    clock.advance(0.2)               # f1 is now older than max_delay
    f2 = session.enqueue(gid, "bfs", [1])   # piggy-backed poll fires
    assert f1._done and f2._done
    assert session.scheduler.auto_flushes == 1


def test_done_polls_the_scheduler(plc_graph):
    clock = ManualClock()
    session = _session(clock=clock, max_delay=0.1)
    gid = session.register(plc_graph, expected_queries=256)
    fut = session.enqueue(gid, "bfs", [0])
    assert not fut.done()            # not overdue yet: still pending
    clock.advance(0.2)
    assert fut.done()                # done() ticked the auto-flush
    assert session.scheduler.auto_flushes == 1


def test_deadline_triggers_poll_before_max_delay(plc_graph):
    clock = ManualClock()
    session = _session(clock=clock, max_delay=60.0)
    gid = session.register(plc_graph, expected_queries=256)
    fut = session.enqueue(gid, "bfs", [0], deadline_seconds=0.05)
    clock.advance(0.06)              # way below max_delay, past deadline
    assert session.poll() == 1 and fut._done
    assert fut.telemetry["deadline_missed"] is True


def test_background_auto_flush_thread(plc_graph):
    import time
    session = _session(max_delay=0.05, auto_flush_interval=0.02)
    gid = session.register(plc_graph, expected_queries=256)
    fut = session.enqueue(gid, "bfs", [0])
    deadline = time.monotonic() + 10.0
    while not fut._done and time.monotonic() < deadline:
        time.sleep(0.01)             # no flush()/poll()/done() calls here
    assert fut._done, "background thread never served the request"
    assert session.scheduler.auto_flushes >= 1
    assert session.scheduler.auto_flush_error is None
    session.close()
    assert session.scheduler._flusher is None


# --------------------------------------------------- deadlines / admission
def test_result_raises_deadline_exceeded_when_expired(plc_graph):
    clock = ManualClock()
    session = _session(clock=clock, max_delay=None)
    gid = session.register(plc_graph, expected_queries=256)
    fut = session.enqueue(gid, "bfs", [0], deadline_seconds=0.5)
    clock.advance(1.0)
    with pytest.raises(DeadlineExceeded):
        fut.result()
    assert fut.exception() is not None
    assert session.scheduler.pending() == 0      # removed from the queue
    assert session.scheduler.requests_expired == 1
    assert session.scheduler.deadlines_missed == 1
    assert session.scheduler.requests_failed == 1
    assert session.scheduler.launches == 0       # no wasted device work


def test_admission_rejects_at_queue_cap(plc_graph):
    session = _session(admission=AdmissionPolicy(max_pending=2),
                       max_delay=None)
    gid = session.register(plc_graph, expected_queries=256)
    futs = [session.enqueue(gid, "bfs", [i]) for i in range(2)]
    with pytest.raises(AdmissionRejected) as exc_info:
        session.enqueue(gid, "bfs", [9])
    assert exc_info.value.pending == 2 and not exc_info.value.shed
    assert session.scheduler.admission_rejected == 1
    assert session.scheduler.requests_enqueued == 2
    session.drain()
    assert all(f.done() for f in futs)           # admitted traffic unharmed


def test_admission_degrades_to_best_effort(plc_graph):
    session = _session(
        admission=AdmissionPolicy(max_pending=1, overload="degrade"),
        max_delay=None)
    gid = session.register(plc_graph, expected_queries=256)
    first = session.enqueue(gid, "bfs", [0], priority=5)
    over = session.enqueue(gid, "bfs", [1], priority=5, deadline_seconds=9.0)
    assert over.request.degraded
    assert over.request.priority == -1 and over.request.deadline is None
    assert session.scheduler.admission_degraded == 1
    session.flush()
    # degraded request drains after the fully admitted one
    assert first.telemetry["launch_index"] <= over.telemetry["launch_index"]
    assert over.telemetry["degraded"] is True


def test_admission_sheds_best_effort_under_missed_deadlines(plc_graph):
    clock = ManualClock()
    adm = AdmissionPolicy(max_pending=8, soft_fraction=0.25,
                          shed_miss_rate=0.5, min_miss_samples=4)
    session = _session(clock=clock, admission=adm, max_delay=None)
    gid = session.register(plc_graph, expected_queries=256)
    # miss a batch of deadlines to arm the shed window
    for i in range(4):
        session.enqueue(gid, "bfs", [i], deadline_seconds=0.01)
    clock.advance(1.0)
    session.flush()
    assert session.scheduler.deadlines_missed == 4
    # queue depth at the soft limit + hot miss window: best-effort sheds,
    # deadline-carrying traffic still gets in
    keep = [session.enqueue(gid, "bfs", [i], deadline_seconds=30.0)
            for i in range(10, 12)]
    with pytest.raises(AdmissionRejected) as exc_info:
        session.enqueue(gid, "bfs", [20])        # best-effort arrival
    assert exc_info.value.shed
    assert session.scheduler.admission_shed == 1
    urgent = session.enqueue(gid, "bfs", [21], deadline_seconds=30.0)
    session.drain()
    assert urgent.done() and all(f.done() for f in keep)


def test_scheduler_four_forced_devices():
    """Re-run this module on 4 forced host devices, so the sharded
    interleavings exercise a genuine mesh (same recipe as the parity
    matrix's distributed leg)."""
    res = run_forced_four_devices(
        ["-m", "pytest", "-q", os.path.abspath(__file__),
         "-k", "not four_forced"], timeout=900)
    assert res.returncode == 0, \
        f"stdout={res.stdout[-4000:]}\nstderr={res.stderr[-2000:]}"
