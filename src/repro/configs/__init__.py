"""Architecture registry: ``--arch <id>`` resolution + smoke reductions."""
from __future__ import annotations

import dataclasses
import importlib

from ..models.config import ModelConfig

_MODULES = {
    "chatglm3-6b": "chatglm3_6b",
    "minicpm-2b": "minicpm_2b",
    "starcoder2-7b": "starcoder2_7b",
    "qwen2.5-3b": "qwen2_5_3b",
    "rwkv6-3b": "rwkv6_3b",
    "zamba2-1.2b": "zamba2_1_2b",
    "paligemma-3b": "paligemma_3b",
    "hubert-xlarge": "hubert_xlarge",
    "mixtral-8x7b": "mixtral_8x7b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_MODULES)}")
    mod = importlib.import_module(f".{_MODULES[arch]}", __package__)
    return mod.CONFIG


def smoke_config(arch: str, *, layers: int = 4) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: small width/depth,
    few experts, tiny vocab — same block structure and code paths."""
    cfg = get_config(arch)
    pattern = cfg.block_pattern[:layers]
    if "shared_attn" in cfg.block_pattern and "shared_attn" not in pattern:
        pattern = pattern[:-1] + ("shared_attn",)
    kv = 4 if cfg.num_kv_heads >= cfg.num_heads else 1
    return dataclasses.replace(
        cfg,
        num_layers=layers,
        block_pattern=pattern,
        d_model=64, num_heads=4, num_kv_heads=kv, head_dim=16,
        d_ff=128,
        vocab_size=512,
        num_experts=min(cfg.num_experts, 4) if cfg.is_moe else 0,
        experts_per_token=min(cfg.experts_per_token, 2) if cfg.is_moe else 0,
        num_shared_experts=min(cfg.num_shared_experts, 1),
        prefix_tokens=4 if cfg.prefix_tokens else 0,
        window=8 if cfg.window else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=8,
        hot_vocab_fraction=0.125 if cfg.hot_vocab_fraction else 0.0,
        loss_chunk=16,
        remat=False,
    )
