"""Graph analytics serving engine (docs/engine.md, docs/policy.md).

Turns the one-shot reproduction benchmarks into a serving system: a
registry of probed graphs, an adaptive reorder policy that decides *when*
and *how* to reorder from cheap structural probes plus expected query
volume, a compile-cached batched executor, and a session front-end with
an amortization ledger. The front door is a request plane
(scheduler.py, docs/scheduler.md): ``enqueue`` returns a `QueryFuture`,
and a micro-batch scheduler coalesces concurrent multi-source requests
into shared vmapped launches, dedupes global-kernel requests, and drains
in priority/deadline order — ``submit`` survives as enqueue + flush
sugar. The loop is closed: realized outcomes calibrate the policy's
per-scheme strengths (calibration.py), the scheduler's observed batch
shapes feed placement (policy.py), and the session re-decides —
re-reordering in place at flush boundaries — when realized traffic
diverges from the registration hint or a reorder provably cannot
amortize.
"""
from .backends import (SHARDED_KERNELS, VECTOR_SOURCE, ExecutionBackend,
                       GraphHandle, ShardedBackend, SingleDeviceBackend,
                       bucket_dims, estimate_device_bytes)
from .calibration import DEFAULT_PRIORS, SchemeStats, StrengthCalibrator
from .executor import BatchedExecutor
from .obs import (Clock, Counter, Gauge, Histogram, ManualClock,
                  MetricsRegistry, ProfilerHook, RateWindow, Tracer,
                  validate_chrome_trace)
from .policy import (AdmissionPolicy, PolicyDecision, PolicyRecord,
                     ReorderPolicy, decision_changed)
from .registry import (GraphProbes, GraphRegistry, degree_histogram,
                       gini_from_histogram, hub_stats_from_histogram,
                       probe_graph)
from .result_cache import ResultCache
from .scheduler import (AdmissionRejected, DeadlineExceeded,
                        MicroBatchScheduler, QueryFuture, Request,
                        canonical_component_labels)
from .session import AmortizationLedger, EngineSession

__all__ = [
    "AdmissionPolicy", "AdmissionRejected", "AmortizationLedger",
    "BatchedExecutor", "Clock", "Counter", "DEFAULT_PRIORS",
    "DeadlineExceeded", "EngineSession", "ExecutionBackend", "Gauge",
    "GraphHandle", "GraphProbes", "GraphRegistry", "Histogram",
    "ManualClock", "MetricsRegistry", "MicroBatchScheduler",
    "PolicyDecision", "PolicyRecord", "ProfilerHook", "QueryFuture",
    "RateWindow", "ReorderPolicy", "Request", "ResultCache",
    "SHARDED_KERNELS", "SchemeStats", "ShardedBackend",
    "SingleDeviceBackend", "StrengthCalibrator", "Tracer",
    "VECTOR_SOURCE", "bucket_dims",
    "canonical_component_labels", "decision_changed", "degree_histogram",
    "estimate_device_bytes", "gini_from_histogram",
    "hub_stats_from_histogram", "probe_graph", "validate_chrome_trace",
]
