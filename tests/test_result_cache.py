"""Result cache: LRU/pinning mechanics, invalidation, and the bit-identity
property.

The correctness contract is absolute: a cache-served row must be
bit-identical to fresh execution, across any interleaving of enqueues,
flushes, and re-decision generation bumps — and a generation bump must
make every row of the old layout unreachable (the poison-sentinel test
proves both directions: the cache really serves, and a bump really
stops it).
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.engine import EngineSession, ResultCache
from repro.engine.result_cache import GLOBAL_SOURCE

FLOAT_KERNELS = ("pr", "bc")


def _session(**kw) -> EngineSession:
    kw.setdefault("redecide_min_queries", 10**6)
    return EngineSession(**kw)


def _assert_matches(kernel: str, got, want) -> None:
    got, want = np.asarray(got), np.asarray(want)
    if kernel in FLOAT_KERNELS:
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
    else:
        np.testing.assert_array_equal(got, want)


# ----------------------------------------------------------- unit mechanics
def _row(v: int) -> np.ndarray:
    return np.full(4, v, dtype=np.int64)


def test_lru_evicts_least_recently_used():
    c = ResultCache(max_entries=2)
    c.put("g", 1, "bfs", 0, _row(0))
    c.put("g", 1, "bfs", 1, _row(1))
    assert c.get("g", 1, "bfs", 0) is not None   # refresh 0's recency
    c.put("g", 1, "bfs", 2, _row(2))             # evicts 1, not 0
    assert c.evictions == 1
    assert c.get("g", 1, "bfs", 1) is None
    assert c.get("g", 1, "bfs", 0) is not None
    assert c.entries == 2


def test_pinned_entries_survive_lru_pressure():
    c = ResultCache(max_entries=1)
    c.put("g", 1, "bfs", 0, _row(0), pinned=True)
    for s in range(1, 5):
        c.put("g", 1, "bfs", s, _row(s))
    assert c.pinned_count == 1
    assert c.get("g", 1, "bfs", 0) is not None   # never evicted
    assert c.entries == 2                        # 1 pinned + 1 LRU slot
    assert c.evictions == 3


def test_pinned_overflow_demotes_to_lru():
    c = ResultCache(max_entries=8, max_pinned=1)
    c.put("g", 1, "bfs", 0, _row(0), pinned=True)
    c.put("g", 1, "bfs", 1, _row(1), pinned=True)   # pinned store full
    assert c.pinned_count == 1
    assert c.get("g", 1, "bfs", 1) is not None      # still cached, just LRU


def test_invalidate_graph_is_surgical():
    c = ResultCache()
    c.put("a", 1, "bfs", 0, _row(0), pinned=True)
    c.put("a", 1, "bfs", 1, _row(1))
    c.put("b", 1, "bfs", 0, _row(7))
    assert c.invalidate_graph("a") == 2
    assert c.get("a", 1, "bfs", 0) is None
    assert c.get("a", 1, "bfs", 1) is None
    assert c.get("b", 1, "bfs", 0) is not None      # other graph untouched
    assert c.pinned_count == 0


def test_generation_is_part_of_the_key():
    c = ResultCache()
    c.put("g", 1, "bfs", 0, _row(1))
    assert c.get("g", 2, "bfs", 0) is None          # new layout, no hit
    c.put("g", 2, "bfs", 0, _row(2))
    assert int(c.get("g", 1, "bfs", 0)[0]) == 1     # old gen still distinct
    assert int(c.get("g", 2, "bfs", 0)[0]) == 2


def test_stats_and_validation():
    c = ResultCache(max_entries=4)
    c.put("g", 1, "pr", GLOBAL_SOURCE, _row(0), pinned=True)
    c.get("g", 1, "pr", GLOBAL_SOURCE)
    c.get("g", 1, "pr", 5)
    s = c.stats()
    assert s["hits"] == 1 and s["misses"] == 1 and s["hit_rate"] == 0.5
    assert s["entries"] == 1 and s["pinned"] == 1
    with pytest.raises(ValueError):
        ResultCache(max_entries=0)


# ------------------------------------------------- engine-level invariants
def test_cache_metrics_export_through_prometheus(plc_graph):
    session = _session()
    gid = session.register(plc_graph, expected_queries=256)
    session.submit(gid, "bfs", [0])
    session.submit(gid, "bfs", [0])                 # guaranteed hit
    text = session.metrics().to_prometheus()
    for name in ("engine_result_cache_hits_total",
                 "engine_result_cache_misses_total",
                 "engine_result_cache_evictions_total",
                 "engine_result_cache_pinned",
                 "engine_result_cache_entries"):
        assert name in text
    snap = session.metrics().snapshot()
    assert snap["counters"]["engine_result_cache_hits_total"] >= 1
    assert snap["gauges"]["engine_result_cache_entries"] >= 1


def test_hot_prefix_sources_are_pinned(plc_graph):
    session = _session()
    gid = session.register(plc_graph, expected_queries=256)
    entry = session.registry.get(gid)
    assert entry.decision.scheme != "original"
    assert entry.hot_prefix_len > 0
    hot_original = int(np.argmin(entry.perm))   # maps to served id 0: hot
    cold_original = int(np.argmax(entry.perm))  # maps to last served id
    session.submit(gid, "bfs", [hot_original, cold_original])
    assert session.result_cache.pinned_count == 1
    assert session.result_cache.entries == 2


def test_poison_sentinel_proves_cache_serves_and_bump_invalidates(plc_graph):
    """Both directions of the staleness contract: a poisoned row under the
    current generation IS served (so the cache is actually on the path),
    and a generation bump makes it unreachable (so a re-decision can
    never serve a stale-layout row)."""
    session = _session()
    gid = session.register(plc_graph, expected_queries=256)
    entry = session.registry.get(gid)
    want = np.asarray(session.submit(gid, "bfs", [0]))
    sentinel = np.full_like(want[0], -77)
    session.result_cache.put(gid, entry.generation, "bfs", 0, sentinel,
                             pinned=True)
    got = np.asarray(session.submit(gid, "bfs", [0]))
    assert (got[0] == -77).all()                    # cache truly serves
    gen_before = entry.generation
    session._apply_decision(entry, entry.decision)  # re-decision bump
    assert entry.generation == gen_before + 1
    got2 = np.asarray(session.submit(gid, "bfs", [0]))
    np.testing.assert_array_equal(got2, want)       # fresh, not the poison


def test_redecision_invalidates_cached_rows(plc_graph):
    session = EngineSession(redecide_factor=2.0, redecide_min_queries=4)
    gid = session.register(plc_graph, expected_queries=1)
    rng = np.random.default_rng(5)
    for _ in range(12):
        session.enqueue(gid, "bfs",
                        rng.integers(0, plc_graph.num_vertices, size=2))
    session.drain()                     # re-decision at the flush boundary
    entry = session.registry.get(gid)
    assert entry.generation > 1
    # every surviving entry belongs to the current generation
    cache = session.result_cache
    keys = list(cache._lru) + list(cache._pinned)
    assert all(k[1] == entry.generation for k in keys) or not keys


# ------------------------------------------------------ bit-identity property
def test_cache_interleaving_property(tiny_graph):
    """Hypothesis: across random enqueue/flush/generation-bump
    interleavings, every future resolves bit-identical to a fresh
    sequential session — cache hits, partial hits, and invalidations
    included."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    n = tiny_graph.num_vertices
    enq = st.tuples(st.just("enqueue"),
                    st.sampled_from(("bfs", "sssp", "pr", "cc")),
                    st.lists(st.integers(min_value=0, max_value=n - 1),
                             min_size=1, max_size=3))
    op = st.one_of(enq, st.just(("flush",)), st.just(("bump",)))

    @settings(max_examples=12, deadline=None)
    @given(ops=st.lists(op, min_size=1, max_size=10))
    def check(ops):
        session = _session()
        reference = _session(result_cache=False)
        gid = session.register(tiny_graph, graph_id="c",
                               expected_queries=256)
        rid = reference.register(tiny_graph, graph_id="r",
                                 expected_queries=256)
        entry = session.registry.get(gid)
        futures = []
        for item in ops:
            if item[0] == "enqueue":
                _, kernel, srcs = item
                sources = (np.asarray(srcs)
                           if kernel in ("bfs", "sssp") else None)
                futures.append((kernel, sources,
                                session.enqueue(gid, kernel, sources)))
            elif item[0] == "flush":
                session.flush()
            else:  # bump: re-apply the decision -> generation += 1
                session._apply_decision(entry, entry.decision)
        session.drain()
        for kernel, sources, fut in futures:
            _assert_matches(kernel, fut.result(),
                            reference.submit(rid, kernel, sources))

    check()


# -------------------------------------------- freshness + byte bounds (v2)
def test_ttl_expiry_counts_and_reclaims():
    t = {"now": 0.0}
    c = ResultCache(max_entries=8, max_age_s=1.0, clock=lambda: t["now"])
    c.put("g", 0, "bfs", 1, _row(1))
    c.put("g", 0, "bfs", 2, _row(2), pinned=True)
    assert c.get("g", 0, "bfs", 1) is not None
    t["now"] = 1.5
    assert c.get("g", 0, "bfs", 1) is None   # stale reads as a miss
    assert c.get("g", 0, "bfs", 2) is None   # pinning != freshness
    assert c.expired == 2 and c.misses == 2 and c.hits == 1
    assert c.entries == 0 and c.resident_bytes == 0
    st = c.stats()
    assert st["max_age_s"] == 1.0 and st["expired"] == 2


def test_ttl_rewrite_restamps_the_entry():
    t = {"now": 0.0}
    c = ResultCache(max_age_s=1.0, clock=lambda: t["now"])
    c.put("g", 0, "bfs", 1, _row(1))
    t["now"] = 0.8
    c.put("g", 0, "bfs", 1, _row(1))
    t["now"] = 1.5                           # 1.5 - 0.8 is inside the TTL
    assert c.get("g", 0, "bfs", 1) is not None


def test_max_bytes_evicts_cold_lru_only():
    nb = _row(0).nbytes
    c = ResultCache(max_entries=100, max_bytes=3 * nb)
    c.put("g", 0, "bfs", 0, _row(0), pinned=True)
    for sid in (1, 2, 3):
        c.put("g", 0, "bfs", sid, _row(sid))
    assert c.resident_bytes <= 3 * nb
    assert c.get("g", 0, "bfs", 0) is not None   # pinned is untouchable
    assert c.get("g", 0, "bfs", 1) is None       # oldest cold row evicted
    assert c.get("g", 0, "bfs", 3) is not None
    assert c.evictions == 1
    assert c.stats()["max_bytes"] == 3 * nb


def test_cache_bound_validation():
    with pytest.raises(ValueError):
        ResultCache(max_age_s=0)
    with pytest.raises(ValueError):
        ResultCache(max_bytes=0)


def test_session_wires_ttl_and_byte_bounds(plc_graph):
    from repro.engine import ManualClock
    clock = ManualClock()
    session = _session(result_cache_max_age_s=10.0,
                       result_cache_max_bytes=1 << 20, clock=clock)
    assert session.result_cache.max_age_s == 10.0
    assert session.result_cache.max_bytes == 1 << 20
    gid = session.register(plc_graph, expected_queries=256)
    want = session.submit(gid, "pr")
    hits0 = session.result_cache.hits
    _assert_matches("pr", session.submit(gid, "pr"), want)  # fresh: a hit
    assert session.result_cache.hits == hits0 + 1
    clock.advance(11.0)
    exp0 = session.result_cache.expired
    _assert_matches("pr", session.submit(gid, "pr"), want)  # recomputed
    assert session.result_cache.expired == exp0 + 1
    stats = session.telemetry()["scheduler"]["result_cache"]
    assert stats["max_age_s"] == 10.0 and stats["max_bytes"] == 1 << 20
