"""Deterministic synthetic-corpus data pipeline.

Offline container ⇒ the corpus is generated, not downloaded, but the
pipeline is built like a production loader:

* **Zipf-community token source** — token frequencies follow a Zipf law
  and tokens are drawn per-document from topic clusters (a planted
  community structure over the vocabulary). This is the same generative
  family the vocab-LOrder feature exploits, so hot-slab coverage measured
  on this corpus is meaningful.
* **Deterministic sharding** — sample ``i`` of host ``h`` depends only on
  (seed, h, i): restartable from any step with no state files, and two
  hosts never emit the same sequence (the per-host substream is folded
  into the key).
* **Host prefetch** — a background thread keeps a bounded queue of ready
  batches (double buffering; device transfer overlaps compute).
* **Vocab reordering hook** — when a ``VocabReorder`` is attached, token
  ids are mapped through the permutation on the host (zero device cost),
  which is exactly how the paper's reordering is deployed (preprocessing).
"""
from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    num_topics: int = 64
    zipf_alpha: float = 1.2
    topic_concentration: float = 0.25   # fraction of tokens from the topic
    num_hosts: int = 1
    host_id: int = 0
    prefetch: int = 2

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


class ZipfCommunityCorpus:
    """Deterministic, seekable token source."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # global Zipf over a shuffled vocab (so raw id ≠ frequency rank —
        # the reordering has real work to do)
        ranks = rng.permutation(v)
        w = 1.0 / (1.0 + ranks.astype(np.float64)) ** cfg.zipf_alpha
        self.global_p = w / w.sum()
        # topics: contiguous rank-bands of the vocabulary per topic, so
        # co-occurrence has community structure
        t = cfg.num_topics
        by_rank = np.argsort(ranks, kind="stable")
        bands = np.array_split(by_rank, t)
        self.topic_tokens = bands
        self.topic_p = [self.global_p[b] / self.global_p[b].sum()
                        for b in bands]

    def sample_doc(self, key: tuple[int, ...], length: int) -> np.ndarray:
        """One document; ``key`` = (host, step, row) determines everything."""
        rng = np.random.default_rng(
            np.random.SeedSequence((self.cfg.seed, *key)))
        topic = int(rng.integers(self.cfg.num_topics))
        from_topic = rng.random(length) < self.cfg.topic_concentration
        n_t = int(from_topic.sum())
        doc = rng.choice(self.cfg.vocab_size, size=length, p=self.global_p)
        if n_t:
            doc[from_topic] = rng.choice(self.topic_tokens[topic], size=n_t,
                                         p=self.topic_p[topic])
        return doc.astype(np.int32)

    def batch(self, step: int) -> np.ndarray:
        """(host_batch, seq_len) int32 for this host at ``step``."""
        cfg = self.cfg
        rows = [self.sample_doc((cfg.host_id, step, r), cfg.seq_len)
                for r in range(cfg.host_batch)]
        return np.stack(rows)


class DataLoader:
    """Prefetching host loader with an optional vocab permutation."""

    def __init__(self, cfg: DataConfig, vocab_reorder=None,
                 start_step: int = 0):
        self.cfg = cfg
        self.corpus = ZipfCommunityCorpus(cfg)
        self.vocab_reorder = vocab_reorder
        self._step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=max(1, cfg.prefetch))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _produce(self, step: int) -> dict:
        tokens = self.corpus.batch(step)
        if self.vocab_reorder is not None:
            tokens = self.vocab_reorder.map_tokens(tokens).astype(np.int32)
        return {"tokens": tokens, "step": step}

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._produce(step)
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self) -> dict:
        return self._q.get()

    def __iter__(self):
        return self

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)


def token_histogram(cfg: DataConfig, num_batches: int = 4) -> np.ndarray:
    """Empirical token counts (hot-vocab calibration / vocab-LOrder input)."""
    corpus = ZipfCommunityCorpus(cfg)
    counts = np.zeros(cfg.vocab_size, dtype=np.int64)
    for s in range(num_batches):
        np.add.at(counts, corpus.batch(s).reshape(-1), 1)
    return counts


def corpus_sample(cfg: DataConfig, num_batches: int = 2) -> np.ndarray:
    """Flat token stream for building the co-occurrence graph."""
    corpus = ZipfCommunityCorpus(cfg)
    return np.concatenate(
        [corpus.batch(s).reshape(-1) for s in range(num_batches)])
