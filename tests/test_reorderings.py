"""Reordering schemes: permutation validity + scheme-specific invariants,
including the paper's LOrder Algorithms 1 & 2 invariants."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.baselines import (dbg_order, gorder_order, hubcluster_order,
                                  hubsort_order, identity_order, norder_order,
                                  random_order, reordering_registry,
                                  sort_order, sorder_order)
from repro.core.csr import validate_permutation
from repro.core.lorder import assign_ids, form_localities, lorder, lorder_v2

ALL_SCHEMES = sorted(reordering_registry())


@pytest.mark.parametrize("scheme", ALL_SCHEMES)
def test_scheme_returns_valid_permutation(scheme, any_graph):
    g = any_graph
    perm = reordering_registry()[scheme](g)
    assert validate_permutation(np.asarray(perm), g.num_vertices), scheme


def test_sort_order_descending_degree(plc_graph):
    g = plc_graph
    perm = sort_order(g)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(g.num_vertices)
    degs = g.degree[inv]          # degree by new id
    assert np.all(np.diff(degs.astype(np.int64)) <= 0)


def test_hubcluster_hot_first(plc_graph):
    g = plc_graph
    hot = g.hot_mask()
    perm = hubcluster_order(g)
    nhot = int(hot.sum())
    assert np.all(perm[hot] < nhot)
    assert np.all(perm[~hot] >= nhot)


def test_dbg_preserves_relative_order_within_group(plc_graph):
    g = plc_graph
    perm = dbg_order(g, num_groups=6)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(g.num_vertices)
    # vertices with equal degree-group must appear in ascending original id
    deg = g.degree.astype(np.float64)
    avg = max(g.average_degree, 1.0)
    thr = avg * (2.0 ** np.arange(4, -1, -1))
    group = np.full(g.num_vertices, 5)
    for gi, t in enumerate(thr):
        group[(group == 5) & (deg > t)] = gi
    for gi in range(6):
        ids = inv[group[inv] == gi]
        assert np.all(np.diff(ids) > 0), f"group {gi} reordered internally"


def test_dbg_groups_are_contiguous_and_hot_first(plc_graph):
    g = plc_graph
    perm = dbg_order(g)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(g.num_vertices)
    # max degree must be in the first position's group; degree of group
    # representatives must be non-increasing by construction
    assert g.degree[inv[0]] >= np.median(g.degree)


# ------------------------------------------------------------------ LOrder
def test_lorder_localities_disjoint_complete(plc_graph):
    g = plc_graph
    hot = g.hot_mask()
    members, info = form_localities(g, kappa=3, hot=hot)
    seen = np.concatenate(members)
    assert len(seen) == g.num_vertices
    assert len(np.unique(seen)) == g.num_vertices
    assert info.sizes.sum() == g.num_vertices
    # locality_id consistent with member lists
    for li, m in enumerate(members):
        assert np.all(info.locality_id[m] == li)


def test_lorder_hotness_counts(plc_graph):
    g = plc_graph
    hot = g.hot_mask()
    members, info = form_localities(g, kappa=3, hot=hot)
    for li, m in enumerate(members):
        assert info.hotness[li] == int(hot[m].sum())


def test_lorder_localities_sorted_by_hotness(plc_graph):
    g = plc_graph
    perm, info = lorder(g, kappa=3, return_info=True)
    hot = g.hot_mask()
    members, _ = form_localities(g, kappa=3, hot=hot)
    order = np.argsort(-info.hotness, kind="stable")
    # blocks must appear in hotness-descending order of localities
    start = 0
    for li in order:
        block = members[li]
        ids = np.sort(perm[block])
        assert ids[0] == start and ids[-1] == start + len(block) - 1, \
            "locality block not contiguous in new id space"
        start += len(block)


def test_lorder_hot_before_cold_within_locality(plc_graph):
    g = plc_graph
    hot = g.hot_mask()
    members, info = form_localities(g, kappa=3, hot=hot)
    perm = assign_ids(members, info, hot)
    for m in members:
        seed, rest = m[0], m[1:]
        if len(rest) == 0:
            continue
        h, c = rest[hot[rest]], rest[~hot[rest]]
        if len(h) and len(c):
            assert perm[h].max() < perm[c].min(), \
                "cold vertex numbered before a hot one inside a locality"
        # seed always first in its block
        assert perm[seed] == perm[m].min()


def test_lorder_kappa_default_uses_radius(ring_graph):
    # should run without explicit kappa and produce a valid permutation
    perm = lorder(ring_graph)
    assert validate_permutation(np.asarray(perm), ring_graph.num_vertices)


def test_lorder_v2_uses_ground_truth_communities(plc_graph):
    g = plc_graph
    assert g.communities is not None
    perm, info = lorder_v2(g, return_info=True)
    assert validate_permutation(np.asarray(perm), g.num_vertices)
    # every community occupies a contiguous new-id block
    labels = np.asarray(g.communities)
    for c in np.unique(labels):
        ids = np.sort(perm[labels == c])
        assert ids[-1] - ids[0] == len(ids) - 1, f"community {c} fragmented"


def test_lorder_v2_fallback_connected_components(grid_graph):
    g = grid_graph
    assert g.communities is None
    perm = lorder_v2(g)
    assert validate_permutation(np.asarray(perm), g.num_vertices)


def test_sorder_parameters(plc_graph):
    perm = sorder_order(plc_graph, kappa=2, hot_threshold=50.0)
    assert validate_permutation(np.asarray(perm), plc_graph.num_vertices)


def test_gorder_guard():
    from repro.core.generators import rmat
    g = rmat(10, edge_factor=4, seed=0)
    with pytest.raises(ValueError):
        gorder_order(g, max_vertices=100)


def test_gorder_valid_small(tiny_graph):
    perm = gorder_order(tiny_graph, window=3)
    assert validate_permutation(np.asarray(perm), tiny_graph.num_vertices)
