"""Engine harness — policy decisions, amortization, and the closed loop.

Phases:

1. **Decisions + amortization** — for each dataset: register with the
   serving engine (policy decides a scheme from probes + volume hint),
   then measure batched multi-source BFS latency on the *original* vs the
   *served* layout directly, and report the wall-clock break-even query
   count next to the ledger's cache-model estimate. Each registration's
   realized gain also feeds the strength calibrator.
2. **Online re-decision** — serve a synthetic bursty workload whose
   realized volume diverges from its registration hint and report the
   re-decisions the session makes (original -> cheap tier -> LOrder).
3. **Decisions after calibration** — replay a recorded outcome stream in
   which LOrder keeps realizing almost nothing (the misprediction regime
   Faldu et al. document), then re-run the policy on every dataset's
   probes: decisions that flip show the calibrated strengths overriding
   the static tree.
4. **Shape bucketing** — serve a stream of distinct-shape graphs through
   an exact-shape executor and a bucketed one; report the compile-miss
   reduction and check bucketed results are bit-identical.
5. **Sharded serving parity** — in a subprocess with 4 forced host
   devices, register a graph whose CSR footprint exceeds the device
   budget and serve **all six kernels** through ``EngineSession.submit``;
   report per-device memory, wall-clock per kernel, and parity against a
   single-device session serving the same graph (bit-identical for
   bfs/sssp/cc/ccsv, allclose for pr/bc).
6. **Hot-prefix exchange** — same 4-device mesh, hub-packed layout: run
   the sharded traversals with and without ``hot_prefix_fraction`` and
   report per-step exchanged bytes, the savings fraction, and the static
   prefix hit rate — results must stay bit-identical either way.
7. **Fused traversal loop** — same 4-device mesh: the fused on-device
   ``XLA::While`` drivers vs the host step loop, per kernel — dispatches
   per query (O(steps) -> O(1)), post-compile wall/step, bit-identical
   results (the ROADMAP item 1 receipt).
8. **Scheduler throughput** — a 16-request multi-source burst on one
   graph served two ways: sequential blocking ``submit`` (one device
   launch per request) vs the request plane (``enqueue`` + ``drain``,
   requests coalesced into shared vmapped launches). Reports device
   launches and wall per query for both, with per-request parity.
9. **Observability** — a 64-request mixed-kernel burst through one
   session; p50/p99 queue-wait and serve latency from the engine's own
   histograms, plus a structurally validated Chrome trace export.
10. **Sustained load** — open-loop Poisson arrivals against the
    always-on plane: Zipf-over-degree sources at ~0.5x measured
    capacity with the result cache on vs off (hit rate, launches per
    query, latency percentiles, bit-identity sampling), then ~3.5x
    capacity with and without bounded-queue admission control (p99
    queue wait bounded vs saturated, rejects counted).
11. **Churn** — sustained Zipf load with concurrent edge churn through
    ``update_graph``: the incremental patch tier re-permutes per delta
    at a wall cost >= 10x below a measured full LOrder pass, serve p99
    stays bounded across generations, and post-churn results stay
    bit-identical to a fresh session on the final mutated graph.
12. **knn** — the search workload (docs/search.md): a Zipf query mix
    over a clustered NSW corpus served through ``enqueue``, recall@10
    against brute force, serve p50/p99, the visit-telemetry reorder
    loop (``refresh_hotness``: full visitsort then the patch tier), and
    a simulated vector-cache miss-rate comparison of identity vs
    degree-ordered vs visit-ordered layouts — degree is uniform on
    search graphs, so the observed-visit layout must win.

Emits benchmarks/results/engine.json.
"""
from __future__ import annotations

import json
import textwrap

import numpy as np

from .common import (bench_suite, fmt_table, run_forced_four_devices,
                     save_json, time_call)


def _phase_decisions(session, suite, batch, repeats):
    from repro.algos.graph_arrays import to_device

    rng = np.random.default_rng(0)
    rows = []
    for dname, g in suite.items():
        gid = session.register(g, graph_id=dname, expected_queries=256)
        entry = session.registry.get(gid)
        srcs = rng.integers(0, g.num_vertices, size=batch).astype(np.int32)

        # both layouts timed through the same exact-shape path, so the
        # comparison isolates the *reordering* effect — the served
        # handle's bucket padding would otherwise be booked as loss
        ga_orig = to_device(g)
        ga_served = to_device(entry.served, canonical_ids=entry.inv_perm)
        srcs_served = entry.perm[srcs].astype(np.int32)
        t_before, _ = time_call(session.executor.run, ga_orig, "bfs", srcs,
                                repeats=repeats)
        t_after, _ = time_call(session.executor.run, ga_served, "bfs",
                               srcs_served, repeats=repeats)
        saving = t_before - t_after
        # "never amortizes" is encoded as null + a flag, not Infinity —
        # strict JSON (common.save_json) has no spelling for infinity
        never = saving <= 1e-9
        wall_break_even = None if never else entry.reorder_seconds / saving
        rec = next(r for r in session.policy.history if r.graph_id == gid)
        rows.append({
            "dataset": dname,
            "scheme": entry.decision.scheme,
            "kwargs": entry.decision.kwargs,
            "reason": entry.decision.reason,
            "reorder_seconds": round(entry.reorder_seconds, 4),
            "predicted_gain": rec.decision.predicted_gain,
            "realized_gain": round(rec.realized_gain, 4),
            "batch": int(batch),
            "query_seconds_before": round(t_before, 5),
            "query_seconds_after": round(t_after, 5),
            "wall_break_even_queries": (None if never
                                        else round(wall_break_even, 1)),
            "wall_break_even_never": never,
        })
        print(f"[engine] {dname}: {entry.decision.scheme} "
              f"{entry.decision.kwargs}, reorder "
              f"{entry.reorder_seconds:.2f}s, query "
              f"{t_before * 1e3:.1f}ms -> {t_after * 1e3:.1f}ms", flush=True)
    return rows


def _phase_redecision(session, scale):
    """Bursty workload: hint says 2 queries, reality delivers ~40."""
    from repro.core.generators import powerlaw_community

    g = powerlaw_community(max(2000, int(20_000 * scale)), avg_degree=12.0,
                           mixing=0.1, seed=21, name="burst")
    gid = session.register(g, graph_id="burst", expected_queries=2)
    entry = session.registry.get(gid)
    first = entry.decision.scheme
    rng = np.random.default_rng(5)
    for _ in range(40):
        session.submit(gid, "bfs", rng.integers(0, g.num_vertices, size=4))
    events = [e for e in session.redecision_log if e["graph_id"] == gid]
    print(f"[engine] burst workload: hint=2, served "
          f"{entry.queries_observed} batches, {len(events)} re-decisions: "
          + " -> ".join([first] + [e["new_scheme"] for e in events]),
          flush=True)
    return {
        "dataset": "burst",
        "expected_queries_hint": 2,
        "queries_observed": entry.queries_observed,
        "scheme_path": [first] + [e["new_scheme"] for e in events],
        "redecision_count": len(events),
        "events": events,
    }


def _phase_calibration_flip(session, suite):
    """Replay outcomes where LOrder collapses; re-decide every dataset."""
    policy = session.policy
    pre = {d: policy.decide(session.registry.get(d).probes, 256).scheme
           for d in suite}
    from repro.engine import PolicyDecision, ReorderPolicy

    probes = session.registry.get("burst").probes
    skew = ReorderPolicy._skew(probes)
    lorder = PolicyDecision("lorder", {}, "replayed historical decision",
                            0.75 * skew, skew)
    for i in range(25):
        # recorded outcome: near-zero realized reduction despite high skew
        policy.record(f"replay-{i}", lorder, miss_rate_before=0.5,
                      miss_rate_after=0.49, reorder_seconds=1.0)
    post = {d: policy.decide(session.registry.get(d).probes, 256).scheme
            for d in suite}
    changed = {d: (pre[d], post[d]) for d in suite if pre[d] != post[d]}
    cal = policy.calibrator
    print(f"[engine] after calibration replay: lorder strength "
          f"{cal.strength('lorder'):.3f} (prior 0.75), "
          f"{len(changed)} decision(s) changed: "
          + (", ".join(f"{d}: {a}->{b}" for d, (a, b) in changed.items())
             or "none"), flush=True)
    return {
        "strengths_after": cal.strengths(),
        "decisions_before": pre,
        "decisions_after": post,
        "changed": {d: list(v) for d, v in changed.items()},
    }


def _phase_bucketing(scale, batch: int = 4):
    """Distinct-shape graph stream: exact-shape vs bucketed compile counts."""
    from repro.core.generators import powerlaw_community
    from repro.engine import BatchedExecutor

    sizes = [int(n * max(scale, 0.25) / 0.5)
             for n in (1100, 1250, 1400, 1550, 1750, 1950)]
    graphs = [powerlaw_community(n, avg_degree=8.0, seed=100 + i,
                                 name=f"stream-{n}")
              for i, n in enumerate(sizes)]
    assert len({(g.num_vertices, g.num_edges) for g in graphs}) == len(graphs)

    exact = BatchedExecutor(bucketing=False)
    bucketed = BatchedExecutor()
    rng = np.random.default_rng(9)
    identical = True
    for g in graphs:
        srcs = rng.integers(0, g.num_vertices, size=batch).astype(np.int32)
        out_e = np.asarray(exact.run(exact.prepare(g), "bfs", srcs))
        out_b = np.asarray(bucketed.run(bucketed.prepare(g), "bfs", srcs))
        identical &= bool(np.array_equal(out_e, out_b))
    m_exact = exact.single.cache_misses
    m_bucket = bucketed.single.cache_misses
    buckets = bucketed.single.telemetry()["bucketing"]
    print(f"[engine] bucketing: {len(graphs)} distinct shapes -> "
          f"{m_exact} exact-shape compile misses vs {m_bucket} bucketed "
          f"({m_exact / max(m_bucket, 1):.1f}x fewer), "
          f"{buckets['distinct_buckets']} bucket(s), "
          f"bit-identical={identical}", flush=True)
    return {
        "graph_shapes": [[g.num_vertices, g.num_edges] for g in graphs],
        "compile_misses_exact": m_exact,
        "compile_misses_bucketed": m_bucket,
        "compile_reduction_x": round(m_exact / max(m_bucket, 1), 2),
        "buckets": buckets,
        "bit_identical": identical,
    }


def _run_four_devices(prog: str):
    """Run ``prog`` on 4 forced host devices; returns the json after its
    RESULT line or an error dict."""
    res = run_forced_four_devices(["-c", prog], timeout=900)
    if res.returncode != 0:
        return {"error": res.stderr[-2000:]}
    line = next(l for l in res.stdout.splitlines() if l.startswith("RESULT "))
    return json.loads(line[len("RESULT "):])


def _phase_sharded(scale):
    """4 forced host devices: serve an over-budget graph end-to-end —
    all six kernels, with parity against a single-device session serving
    the same graph (same policy => same reorder => cc/ccsv label spaces
    line up bit-for-bit)."""
    n = max(2000, int(20_000 * scale))
    prog = textwrap.dedent(f"""
        import json, time
        import numpy as np
        import jax, jax.numpy as jnp
        assert jax.device_count() == 4, jax.devices()
        from repro.core.generators import powerlaw_community
        from repro.engine import EngineSession, estimate_device_bytes

        g = powerlaw_community({n}, avg_degree=10.0, seed=31, name="big")
        budget = estimate_device_bytes(g.num_vertices, g.num_edges) // 2
        session = EngineSession(device_budget_bytes=budget,
                                redecide_min_queries=10**6)
        gid = session.register(g, expected_queries=256)
        entry = session.registry.get(gid)
        assert entry.backend == "sharded", entry.backend
        ref = EngineSession(redecide_min_queries=10**6)  # single-device
        rid = ref.register(g, graph_id="ref", expected_queries=256)
        srcs = np.arange(4) * (g.num_vertices // 5)
        walls, parity = {{}}, {{}}
        for kernel in ("bfs", "sssp", "bc", "pr", "cc", "ccsv"):
            args = (srcs,) if kernel in ("bfs", "sssp", "bc") else ()
            t0 = time.perf_counter()
            out = session.submit(gid, kernel, *args)
            walls[kernel] = time.perf_counter() - t0
            want = ref.submit(rid, kernel, *args)
            if kernel in ("pr", "bc"):
                parity[kernel] = bool(np.allclose(out, want,
                                                  rtol=1e-3, atol=1e-3))
            else:
                parity[kernel] = bool(np.array_equal(
                    np.asarray(out), np.asarray(want)))
        hp = session.executor.sharded.telemetry()["hot_prefix"]
        print("RESULT " + json.dumps({{
            "num_vertices": g.num_vertices,
            "num_edges": g.num_edges,
            "device_budget_bytes": budget,
            "graph_bytes": estimate_device_bytes(g.num_vertices,
                                                 g.num_edges),
            "per_device_bytes": entry.handle.device_bytes,
            "num_shards": session.executor.sharded.num_shards,
            "hot_prefix_fraction": entry.hot_prefix_fraction,
            "wall_seconds": {{k: round(v, 4) for k, v in walls.items()}},
            "parity": parity,
            "ledger_backend": entry.ledger.backend,
            "gain_discount": entry.ledger.gain_discount,
            "exchange": hp,
        }}))
    """)
    out = _run_four_devices(prog)
    if "error" in out:
        print(f"[engine] sharded phase FAILED:\n{out['error']}", flush=True)
        return out
    print(f"[engine] sharded: V={out['num_vertices']} across "
          f"{out['num_shards']} devices "
          f"(~{out['per_device_bytes'] / 1e6:.2f} MB/device vs "
          f"{out['graph_bytes'] / 1e6:.2f} MB whole), walls "
          + ", ".join(f"{k}={v * 1e3:.0f}ms"
                      for k, v in out["wall_seconds"].items())
          + f", parity={out['parity']}", flush=True)
    return out


def _phase_hot_prefix(scale):
    """4 forced host devices, hub-packed layout: per-step exchanged bytes
    with the hot-prefix exchange vs the full all-gather, at bit-identical
    results (SSSP + CC: int32 state either way, so the comparison is
    apples-to-apples; frontier BFS exchanges a bool frontier instead and
    is reported for context)."""
    n = max(2000, int(20_000 * scale))
    prog = textwrap.dedent(f"""
        import json
        import numpy as np
        import jax
        assert jax.device_count() == 4, jax.devices()
        from repro.core.baselines import dbg_order
        from repro.core.dist import (ExchangeStats, make_distributed_cc,
                                     make_distributed_sssp)
        from repro.core.generators import powerlaw_community

        g0 = powerlaw_community({n}, avg_degree=10.0, seed=31)
        perm = np.asarray(dbg_order(g0))
        g = g0.apply_permutation(perm)     # hubs packed into the prefix
        inv = np.empty_like(perm); inv[perm] = np.arange(len(perm))
        mesh = jax.make_mesh((4,), ("data",))
        srcs = np.arange(4) * (g.num_vertices // 5)
        out = {{}}
        for kernel, frac in (("sssp", 0.15), ("cc", 0.15)):
            full, hot = ExchangeStats(), ExchangeStats()
            if kernel == "sssp":
                run_f = make_distributed_sssp(g, mesh, canonical_ids=inv,
                                              stats=full)
                run_h = make_distributed_sssp(g, mesh, canonical_ids=inv,
                                              hot_prefix_fraction=frac,
                                              cold_every=5, stats=hot)
                a, b = run_f(srcs), run_h(srcs)
            else:
                run_f = make_distributed_cc(g, mesh, stats=full)
                run_h = make_distributed_cc(g, mesh,
                                            hot_prefix_fraction=frac,
                                            cold_every=5, stats=hot)
                a, b = run_f(), run_h()
            assert np.array_equal(np.asarray(a), np.asarray(b)), kernel
            out[kernel] = {{
                "hot_prefix_fraction": frac,
                "prefix_hit_rate": round(run_h.prefix_hit_rate, 4),
                "bytes_per_step_full": round(full.bytes_per_step, 1),
                "bytes_per_step_hot": round(hot.bytes_per_step, 1),
                "steps_full_variant": full.steps,
                "steps_hot_variant": hot.steps,
                "savings_fraction": round(hot.savings_fraction, 4),
                "smaller_per_step": hot.bytes_per_step
                                    < full.bytes_per_step,
                "bit_identical": True,
            }}
        print("RESULT " + json.dumps(out))
    """)
    out = _run_four_devices(prog)
    if "error" in out:
        print(f"[engine] hot-prefix phase FAILED:\n{out['error']}",
              flush=True)
        return out
    for kernel, r in out.items():
        print(f"[engine] hot-prefix {kernel}: "
              f"{r['bytes_per_step_full']:.0f} B/step full -> "
              f"{r['bytes_per_step_hot']:.0f} B/step hot "
              f"({100 * r['savings_fraction']:.0f}% fewer bytes vs "
              f"all-full, hit rate {r['prefix_hit_rate']:.2f}, "
              f"bit-identical={r['bit_identical']})", flush=True)
    return out


def _phase_scheduler(scale, requests: int = 16, sources_each: int = 2):
    """Request-plane throughput: the same multi-source burst served
    sequentially (blocking submit, one launch per request) vs coalesced
    (enqueue + drain, shared vmapped launches)."""
    import time

    from repro.core.generators import powerlaw_community
    from repro.engine import EngineSession

    n = max(2000, int(20_000 * scale))
    g = powerlaw_community(n, avg_degree=10.0, seed=41, name="front")
    rng = np.random.default_rng(17)
    bursts = [rng.integers(0, n, size=sources_each) for _ in range(requests)]

    # result_cache=False on both sides: this phase measures pure request
    # coalescing, and the warm-up submits would otherwise pre-populate the
    # burst's sources and corrupt the launch counts (the cache gets its
    # own sustained phase).
    seq = EngineSession(redecide_min_queries=10**6, result_cache=False)
    sid = seq.register(g, graph_id="seq", expected_queries=256)
    seq.submit(sid, "bfs", bursts[0])            # warm the per-request shape
    launches0 = seq.executor.queries_run
    t0 = time.perf_counter()
    seq_outs = [np.asarray(seq.submit(sid, "bfs", b)) for b in bursts]
    seq_wall = time.perf_counter() - t0
    seq_launches = seq.executor.queries_run - launches0

    bat = EngineSession(redecide_min_queries=10**6, result_cache=False)
    bid = bat.register(g, graph_id="bat", expected_queries=256)
    bat.submit(bid, "bfs", np.concatenate(bursts))  # warm the coalesced shape
    launches0 = bat.executor.queries_run
    t0 = time.perf_counter()
    futs = [bat.enqueue(bid, "bfs", b) for b in bursts]
    bat.drain()
    bat_wall = time.perf_counter() - t0
    bat_launches = bat.executor.queries_run - launches0

    identical = all(np.array_equal(np.asarray(f.result()), want)
                    for f, want in zip(futs, seq_outs))
    reduction = seq_launches / max(bat_launches, 1)
    out = {
        "requests": requests,
        "sources_each": sources_each,
        "launches_sequential": seq_launches,
        "launches_coalesced": bat_launches,
        "launch_reduction_x": round(reduction, 2),
        "wall_per_query_sequential_ms": round(seq_wall / requests * 1e3, 3),
        "wall_per_query_coalesced_ms": round(bat_wall / requests * 1e3, 3),
        "wall_speedup_x": round(seq_wall / max(bat_wall, 1e-9), 2),
        "bit_identical": identical,
        "scheduler": bat.scheduler.telemetry(),
    }
    print(f"[engine] scheduler: {requests}-request burst -> "
          f"{seq_launches} launches sequential vs {bat_launches} coalesced "
          f"({reduction:.0f}x fewer), "
          f"{out['wall_per_query_sequential_ms']:.1f}ms -> "
          f"{out['wall_per_query_coalesced_ms']:.1f}ms per query "
          f"({out['wall_speedup_x']:.1f}x), bit-identical={identical}",
          flush=True)
    return out


def _phase_observability(scale, requests: int = 64):
    """Observability plane: a 64-request mixed-kernel burst through one
    session, reporting p50/p99 queue-wait and serve latencies from the
    engine's own histograms, and exporting the request trace as
    Perfetto-loadable Chrome trace JSON next to the results. The trace is
    structurally validated (nesting, envelope) and every served future's
    trace id must appear in it."""
    from repro.core.generators import powerlaw_community
    from repro.engine import EngineSession
    from repro.engine.obs import (merge_histogram_snapshots,
                                  validate_chrome_trace)

    from .common import RESULTS

    n = max(2000, int(20_000 * scale))
    g = powerlaw_community(n, avg_degree=10.0, seed=51, name="obs")
    session = EngineSession(redecide_min_queries=10**6)
    gid = session.register(g, graph_id="obs", expected_queries=256)
    rng = np.random.default_rng(23)
    kernels = ("bfs", "sssp", "bc", "pr", "cc", "ccsv")
    futs = []
    for i in range(requests):
        kernel = kernels[i % len(kernels)]
        srcs = (rng.integers(0, n, size=2)
                if kernel in ("bfs", "sssp", "bc") else None)
        # a third of the burst carries deadlines so the slack histogram
        # (and deadlines_missed attribution) exercises too
        dl = 5.0 if i % 3 == 0 else None
        futs.append(session.enqueue(gid, kernel, srcs,
                                    deadline_seconds=dl))
    session.drain()
    for f in futs:
        np.asarray(f.result())

    snap = session.metrics().snapshot()
    qw = snap["histograms"]["engine_queue_wait_seconds"]
    sv = snap["histograms"]["engine_serve_seconds"]
    overall_qw = merge_histogram_snapshots(list(qw.values()))
    overall_sv = merge_histogram_snapshots(list(sv.values()))
    assert overall_qw["count"] == requests, overall_qw["count"]
    assert overall_sv["count"] == requests, overall_sv["count"]
    per_kernel = {
        key.split("kernel=")[-1]: {
            "count": s["count"],
            "p50_ms": round(s["p50"] * 1e3, 3),
            "p99_ms": round(s["p99"] * 1e3, 3),
        } for key, s in sorted(sv.items())}

    trace_path = session.tracer.export(RESULTS / "engine_trace.json")
    trace = json.loads(trace_path.read_text())
    stats = validate_chrome_trace(trace)
    traced = {e["args"]["trace_id"] for e in trace["traceEvents"]
              if e.get("ph") == "X" and "trace_id" in e.get("args", {})}
    missing = [f.trace_id for f in futs if f.trace_id not in traced]
    assert not missing, f"futures missing from trace: {missing}"

    out = {
        "requests": requests,
        "queue_wait": {"count": overall_qw["count"],
                       "p50_ms": round(overall_qw["p50"] * 1e3, 3),
                       "p99_ms": round(overall_qw["p99"] * 1e3, 3)},
        "serve": {"count": overall_sv["count"],
                  "p50_ms": round(overall_sv["p50"] * 1e3, 3),
                  "p99_ms": round(overall_sv["p99"] * 1e3, 3)},
        "per_kernel_serve": per_kernel,
        "trace_file": trace_path.name,
        "trace": stats,
        "dropped_events": trace["otherData"]["dropped_events"],
        "scheduler": session.scheduler.telemetry(),
    }
    print(f"[engine] observability: {requests}-request burst, queue-wait "
          f"p50={out['queue_wait']['p50_ms']:.1f}ms "
          f"p99={out['queue_wait']['p99_ms']:.1f}ms, serve "
          f"p50={out['serve']['p50_ms']:.1f}ms "
          f"p99={out['serve']['p99_ms']:.1f}ms, trace {trace_path.name}: "
          f"{stats['complete_spans']} spans on {stats['tracks']} tracks",
          flush=True)
    return out


def _phase_sustained(scale, paced_requests: int = 160,
                     overload_requests: int = 200):
    """Sustained open-loop load against the always-on request plane.

    Three sub-experiments on one hub-heavy graph:

    * **capacity** — closed-loop unique-source burst through the plane
      (enqueue + drain) to measure the service capacity the open-loop
      runs are paced against.
    * **paced** (~0.5x capacity, Poisson arrivals, Zipf sources ranked
      by vertex degree) — the same arrival sequence served with the
      result cache on vs off; reports cache hit rate, device launches
      per query, and p50/p99 queue-wait and serve latency. A sample of
      cache-served rows is checked bit-identical against a fresh
      reference session.
    * **overload** (~3.5x capacity, deadline-carrying requests) — with
      no admission control the queue grows with the run and p99 wait
      saturates; with a bounded queue (reject on overflow) the plane
      sheds load and p99 stays bounded. Both sides run uncached so the
      comparison isolates admission.
    """
    import time

    from repro.core.generators import powerlaw_community
    from repro.engine import AdmissionPolicy, AdmissionRejected, EngineSession
    from repro.engine.obs import merge_histogram_snapshots

    n = max(2000, int(20_000 * scale))
    g = powerlaw_community(n, avg_degree=10.0, seed=61, name="sustained")
    rng = np.random.default_rng(29)
    # Zipf(1.5) ranks mapped onto degree-descending vertex order: the
    # popular sources are the hubs, which is both what real query logs
    # look like and what the GRASP-style hot-prefix pinning targets.
    by_degree = np.argsort(-np.asarray(g.out_degree, dtype=np.int64))
    zipf_pool = by_degree[(rng.zipf(1.5, size=4 * paced_requests) - 1) % n]

    def _fresh(**kw):
        kw.setdefault("redecide_min_queries", 10**6)
        kw.setdefault("max_delay", 0.005)
        s = EngineSession(**kw)
        s.register(g, graph_id="sus", expected_queries=4096)
        return s

    def _warm(session):
        # compile every power-of-two source bucket the runs can hit,
        # then wipe the warm-up rows so they can't inflate hit rates
        for k in (1, 2, 4, 8, 16):
            session.submit("sus", "bfs", np.arange(k))
        if session.result_cache is not None:
            session.result_cache.clear()

    def _paced(session, sources, offered_qps, deadline=None):
        """Open-loop arrivals; returns per-accepted-request (future,
        lateness) where lateness is how far behind the open-loop schedule
        the enqueue actually ran — a single-threaded generator slips when
        the plane serves inline, and ignoring that slip (coordinated
        omission) would hide saturation entirely."""
        arrivals = np.cumsum(rng.exponential(1.0 / offered_qps,
                                             size=len(sources)))
        futs, lates, rejected = [], [], 0
        t0 = time.perf_counter()
        for src, at in zip(sources, arrivals):
            lag = t0 + at - time.perf_counter()
            if lag > 0:
                time.sleep(lag)
            try:
                fut = session.enqueue("sus", "bfs", [int(src)],
                                      deadline_seconds=deadline)
            except AdmissionRejected:
                rejected += 1
                continue
            futs.append(fut)
            lates.append(max(0.0, time.perf_counter() - (t0 + at)))
        session.drain()
        return futs, lates, rejected, time.perf_counter() - t0

    def _corrected(futs, lates):
        """Schedule-corrected end-to-end latency: generator lateness plus
        the in-plane enqueue->served time the engine accounted."""
        e2e = [late + f.telemetry["queue_seconds"]
               for f, late in zip(futs, lates) if f.telemetry]
        return {
            "e2e_p50_ms": round(float(np.percentile(e2e, 50)) * 1e3, 1),
            "e2e_p99_ms": round(float(np.percentile(e2e, 99)) * 1e3, 1),
        } if e2e else {"e2e_p50_ms": None, "e2e_p99_ms": None}

    def _latency(session):
        snap = session.metrics().snapshot()["histograms"]
        row = {}
        for label, name in (("queue_wait", "engine_queue_wait_seconds"),
                            ("serve", "engine_serve_seconds")):
            s = merge_histogram_snapshots(list(snap.get(name, {}).values()))
            row[f"{label}_p50_ms"] = round((s.get("p50") or 0.0) * 1e3, 3)
            row[f"{label}_p99_ms"] = round((s.get("p99") or 0.0) * 1e3, 3)
        return row

    # --- capacity: closed-loop unique-source burst through the plane
    cap_s = _fresh(result_cache=False)
    _warm(cap_s)
    uniq = rng.choice(n, size=48, replace=False)
    t0 = time.perf_counter()
    for src in uniq:
        cap_s.enqueue("sus", "bfs", [int(src)])
    cap_s.drain()
    capacity_qps = len(uniq) / max(time.perf_counter() - t0, 1e-9)
    cap_s.close(drain=False)

    # --- paced: cached vs uncached on the identical Zipf arrival stream
    offered = 0.5 * capacity_qps
    sources = zipf_pool[:paced_requests]
    paced = {"offered_qps": round(offered, 1), "requests": paced_requests}
    cached_futs = None
    for label, kw in (("cached", {}), ("uncached", {"result_cache": False})):
        s = _fresh(**kw)
        _warm(s)
        hits0 = s.result_cache.hits if s.result_cache else 0
        miss0 = s.result_cache.misses if s.result_cache else 0
        launches0 = s.executor.queries_run
        futs, lates, _, wall = _paced(s, sources, offered)
        launches = s.executor.queries_run - launches0
        row = {
            "launches": launches,
            "launches_per_query": round(launches / paced_requests, 4),
            "wall_seconds": round(wall, 3),
            **_corrected(futs, lates),
            **_latency(s),
        }
        if s.result_cache is not None:
            hits = s.result_cache.hits - hits0
            misses = s.result_cache.misses - miss0
            row["cache_hit_rate"] = round(hits / max(hits + misses, 1), 4)
            row["cache"] = s.result_cache.stats()
            cached_futs = futs
        paced[label] = row
        s.close(drain=False)
    # cache-served rows must be bit-identical to fresh execution
    ref = _fresh(result_cache=False)
    picks = rng.choice(paced_requests, size=min(12, paced_requests),
                       replace=False)
    paced["bit_identical"] = all(
        np.array_equal(np.asarray(cached_futs[i].result()),
                       np.asarray(ref.submit("sus", "bfs",
                                             [int(sources[i])])))
        for i in picks)
    ref.close(drain=False)

    # --- overload: no admission vs a bounded queue, deadlines attached
    over_offered = 3.5 * capacity_qps
    over_sources = rng.integers(0, n, size=overload_requests)
    overload = {"offered_qps": round(over_offered, 1),
                "requests": overload_requests}
    policies = (("no_admission", None),
                ("admission", AdmissionPolicy(max_pending=32,
                                              overload="reject")))
    for label, pol in policies:
        s = _fresh(result_cache=False, max_delay=10.0, admission=pol)
        _warm(s)
        futs, lates, rejected, wall = _paced(s, over_sources, over_offered,
                                             deadline=0.08)
        tel = s.scheduler.telemetry()
        overload[label] = {
            "served": tel["requests_served"],
            "rejected": rejected,
            "deadlines_missed": tel["deadlines_missed"],
            "wall_seconds": round(wall, 3),
            **_corrected(futs, lates),
            **_latency(s),
        }
        s.close(drain=False)
    overload["p99_bounded"] = (overload["admission"]["e2e_p99_ms"]
                               < overload["no_admission"]["e2e_p99_ms"])

    out = {"capacity_qps": round(capacity_qps, 1), "paced": paced,
           "overload": overload}
    print(f"[engine] sustained: capacity {capacity_qps:.0f} qps; paced "
          f"@{offered:.0f} qps hit-rate "
          f"{paced['cached']['cache_hit_rate']:.2f}, launches/query "
          f"{paced['cached']['launches_per_query']:.3f} cached vs "
          f"{paced['uncached']['launches_per_query']:.3f} uncached, "
          f"bit-identical={paced['bit_identical']}; overload "
          f"@{over_offered:.0f} qps e2e p99 "
          f"{overload['no_admission']['e2e_p99_ms']:.0f}ms open vs "
          f"{overload['admission']['e2e_p99_ms']:.0f}ms bounded "
          f"({overload['admission']['rejected']} rejected)", flush=True)
    return out


def _phase_fused(scale):
    """4 forced host devices: the fused on-device traversal loop vs the
    host step loop, per kernel — dispatches per query (O(steps) -> O(1)),
    post-compile wall clock and wall/step, at bit-identical results.
    This is the ROADMAP item 1 receipt: the engine stops being
    dispatch-bound before the reorder's locality gain can show up."""
    n = max(2000, int(20_000 * scale))
    prog = textwrap.dedent(f"""
        import json, time
        import numpy as np
        import jax
        assert jax.device_count() == 4, jax.devices()
        from repro.core.dist import (ExchangeStats, make_distributed_bc,
                                     make_distributed_bfs,
                                     make_distributed_cc,
                                     make_distributed_pagerank,
                                     make_distributed_sssp)
        from repro.core.generators import powerlaw_community

        g = powerlaw_community({n}, avg_degree=10.0, seed=31)
        mesh = jax.make_mesh((4,), ("data",))
        srcs = np.arange(4) * (g.num_vertices // 5)

        def build(kernel, stats, fused):
            if kernel == "pr":
                return make_distributed_pagerank(g, mesh, stats=stats,
                                                 fused=fused)[0]
            if kernel == "bc":
                return make_distributed_bc(g, mesh, stats=stats,
                                           fused=fused)
            f = dict(bfs=make_distributed_bfs, sssp=make_distributed_sssp,
                     cc=make_distributed_cc)[kernel]
            return f(g, mesh, hot_prefix_fraction=0.15, cold_every=5,
                     stats=stats, fused=fused)

        out = {{}}
        for kernel in ("bfs", "sssp", "cc", "pr", "bc"):
            res, row = {{}}, {{}}
            for mode in ("host", "fused"):
                stats = ExchangeStats()
                run = build(kernel, stats, mode == "fused")
                args = (srcs,) if kernel in ("bfs", "sssp", "bc") else ()
                jax.block_until_ready(run(*args))   # compile + warm
                before = stats.snapshot()
                t0 = time.perf_counter()
                res[mode] = np.asarray(jax.block_until_ready(run(*args)))
                wall = time.perf_counter() - t0
                d = stats.delta(before)
                row[mode] = {{
                    "wall_seconds": round(wall, 5),
                    "steps": d.steps,
                    "dispatches_per_query": d.dispatches,
                    "wall_per_step_ms": round(
                        wall * 1e3 / max(d.steps, 1), 4),
                }}
            row["bit_identical"] = bool(np.array_equal(res["host"],
                                                       res["fused"]))
            row["single_xla_while"] = \\
                row["fused"]["dispatches_per_query"] == 1
            out[kernel] = row
        print("RESULT " + json.dumps(out))
    """)
    out = _run_four_devices(prog)
    if "error" in out:
        print(f"[engine] fused phase FAILED:\n{out['error']}", flush=True)
        return out
    for kernel, r in out.items():
        print(f"[engine] fused {kernel}: dispatches/query "
              f"{r['host']['dispatches_per_query']} -> "
              f"{r['fused']['dispatches_per_query']}, wall/step "
              f"{r['host']['wall_per_step_ms']:.2f}ms -> "
              f"{r['fused']['wall_per_step_ms']:.2f}ms "
              f"({r['host']['steps']} steps, bit-identical="
              f"{r['bit_identical']})", flush=True)
    return out


def _phase_churn(scale, rounds: int = 8, queries_per_round: int = 12):
    """Sustained Zipf load with concurrent edge churn (dynamic graphs).

    One hub-heavy graph registered at high expected volume (a locality
    layout with a packed hot prefix), then ``rounds`` of: a burst of
    Zipf-over-degree BFS requests through the request plane, followed by
    an ``update_graph`` delta (remove random existing edges, add the
    same count of random ones) served by the **incremental patch tier**.
    Reports the patch-tier reorder wall against a measured full LOrder
    pass on the final graph (the acceptance bar is >= 10x cheaper),
    serve-latency percentiles across the churning run, and bit-identity
    of post-churn results against a fresh session registered directly on
    the final mutated graph.
    """
    import time

    from repro.core.lorder import lorder
    from repro.engine import EngineSession
    from repro.core.generators import powerlaw_community
    from repro.engine.obs import merge_histogram_snapshots

    n = max(1500, int(12_000 * scale))
    g = powerlaw_community(n, avg_degree=10.0, seed=71, name="churn")
    churn_edges = max(64, n // 25)
    rng = np.random.default_rng(37)
    by_degree = np.argsort(-np.asarray(g.degree, dtype=np.int64))

    s = EngineSession(redecide_min_queries=10**9, async_full_reorder=False)
    s.register(g, graph_id="churn", expected_queries=4096)
    entry = s.registry.get("churn")
    s.submit("churn", "bfs", np.arange(8))          # warm the compile

    patch_walls, mutate_walls = [], []
    for _ in range(rounds):
        srcs = by_degree[(rng.zipf(1.5, size=queries_per_round) - 1) % n]
        futs = [s.enqueue("churn", "bfs", [int(x)]) for x in srcs]
        s.flush()
        assert all(f.done() for f in futs)
        eidx = rng.choice(entry.graph.num_edges, churn_edges, replace=False)
        rem = np.stack([np.asarray(entry.graph.edge_src)[eidx],
                        entry.graph.indices[eidx]], axis=1)
        add = rng.integers(0, n, size=(churn_edges, 2))
        info = s.update_graph("churn", add_edges=add, remove_edges=rem,
                              reorder="patch")
        patch_walls.append(info["reorder_seconds"])
        mutate_walls.append(info["mutate_seconds"])

    # the full-tier cost the patch tier avoids: one measured LOrder pass
    # over the final mutated graph (the same work `reorder="full"` pays)
    final = entry.graph
    t0 = time.perf_counter()
    lorder(final)
    lorder_seconds = time.perf_counter() - t0

    ref = EngineSession(redecide_min_queries=10**9)
    ref.register(final, graph_id="ref", expected_queries=4096)
    picks = rng.choice(n, size=6, replace=False)
    bit_identical = all(
        np.array_equal(np.asarray(s.submit("churn", "bfs", [int(v)])),
                       np.asarray(ref.submit("ref", "bfs", [int(v)])))
        for v in picks)

    snap = s.metrics().snapshot()["histograms"]
    serve = merge_histogram_snapshots(
        list(snap.get("engine_serve_seconds", {}).values()))
    patch_median = float(np.median(patch_walls))
    speedup = lorder_seconds / max(patch_median, 1e-9)
    tel = s.telemetry()
    out = {
        "num_vertices": n,
        "num_edges_final": final.num_edges,
        "rounds": rounds,
        "churn_edges_per_round": churn_edges,
        "scheme": entry.decision.scheme,
        "registration_reorder_seconds": round(
            tel["graphs"]["churn"]["ledger"]["reorder_seconds"], 6),
        "full_lorder_seconds": round(lorder_seconds, 6),
        "patch_reorder_seconds_median": round(patch_median, 6),
        "patch_reorder_seconds_max": round(float(np.max(patch_walls)), 6),
        "mutate_seconds_median": round(float(np.median(mutate_walls)), 6),
        "patch_speedup_vs_lorder": round(speedup, 1),
        "patch_at_least_10x_cheaper": bool(speedup >= 10.0),
        "serve_p50_ms": round((serve.get("p50") or 0.0) * 1e3, 3),
        "serve_p99_ms": round((serve.get("p99") or 0.0) * 1e3, 3),
        "generations": entry.generation,
        "hot_prefix_len": entry.hot_prefix_len,
        "probe_drift": round(entry.probe_drift, 4),
        "mutations": tel["mutations"],
        "bit_identical": bit_identical,
    }
    s.close(drain=False)
    ref.close(drain=False)
    print(f"[engine] churn: {rounds} rounds x {churn_edges} edges on "
          f"{entry.decision.scheme}; patch {patch_median * 1e3:.1f}ms vs "
          f"LOrder {lorder_seconds:.2f}s ({speedup:.0f}x), serve p99 "
          f"{out['serve_p99_ms']:.1f}ms, bit-identical={bit_identical}",
          flush=True)
    return out


def _phase_knn(scale, bursts: int = 4, queries_per_burst: int = 24):
    """k-NN search serving: recall, latency, and visit-driven reordering.

    A clustered NSW corpus (Zipf cluster sizes) serves a Zipf query mix
    through the request plane. After traffic accumulates,
    ``refresh_hotness`` folds the visit telemetry into the layout (full
    visitsort, then the steady-state patch tier). The locality claim is
    checked with the cache simulator: the per-query visited-vertex
    traces are replayed over the *vector rows* under three layouts —
    identity, degree-ordered (hubsort; structurally blind here, every
    row has out-degree k), and visit-ordered — and the visit-ordered
    layout must show the lowest simulated miss rate. Recall@10 against
    brute force and bit-identity across the reorder are reported too.
    """
    from repro.cache.sim import CacheConfig, simulate_misses
    from repro.core.baselines import hubsort_order, knn_search_baseline
    from repro.core.generators import clustered_vectors
    from repro.engine import EngineSession
    from repro.engine.obs import merge_histogram_snapshots
    from repro.search import (SearchParams, build_nsw_graph,
                              knn_brute_force, medoid_entry, visit_order)

    n = max(700, int(2400 * scale))
    dim, k_out, k_ret, beam = 16, 12, 10, 32
    # spread 0.4: clusters overlap enough for greedy search to stay
    # navigable across them at this dimensionality (recall ~1.0 at beam
    # 32) while each query still touches only ~20% of the corpus — the
    # visit skew the reorder loop needs
    vecs, _ = clustered_vectors(n, dim=dim, num_clusters=8, zipf=1.2,
                                seed=21, spread=0.4)
    g = build_nsw_graph(vecs, k=k_out)
    oracle_entry = medoid_entry(vecs)

    s = EngineSession(redecide_min_queries=10**9, async_full_reorder=False)
    s.register(g, graph_id="knn", vectors=vecs, expected_queries=1024,
               search_params=SearchParams(k_out=k_out, beam_width=beam,
                                          k_return=k_ret))
    entry = s.registry.get("knn")

    def zipf_queries(seed):
        r = np.random.default_rng(seed)
        base = (r.zipf(1.2, size=queries_per_burst) - 1) % n
        return (vecs[base]
                + r.normal(0, 0.02, (queries_per_burst, dim))
                ).astype(np.float32)

    all_q, all_ids = [], []

    def serve_burst(seed):
        q = zipf_queries(seed)
        fut = s.enqueue("knn", "knn", q)
        s.flush("knn")
        all_q.append(q)
        all_ids.append(np.asarray(fut.result()))

    serve_burst(0)
    r1 = s.refresh_hotness("knn")       # telemetry present -> visitsort
    # bit-identity across the reorder: replay burst 0 under the new layout
    replay = np.asarray(s.submit("knn", "knn", all_q[0]))
    reorder_bit_identical = bool(np.array_equal(replay, all_ids[0]))
    for i in range(1, bursts):
        serve_burst(i)
    r2 = s.refresh_hotness("knn")       # steady state -> patch tier

    queries = np.concatenate(all_q)
    served = np.concatenate(all_ids)
    oracle = knn_brute_force(vecs, queries, k_ret)
    recall = float(np.mean([
        len(set(map(int, a)) & set(map(int, b))) / k_ret
        for a, b in zip(served, oracle)]))

    # ---- simulated miss rates per layout -------------------------------
    # trace: visited original ids per query (host mirror of the served
    # kernel), replayed as accesses to a 4-byte per-vertex property
    # array (visit counters / distance caches — 16 vertices per line,
    # where hot-prefix packing creates line sharing; the 64-byte vector
    # rows each fill a whole line, so they are permutation-invariant by
    # construction). Capacity ~70% of the property array keeps the
    # packed hot set resident while cold traffic churns.
    trace = np.concatenate([
        np.nonzero(knn_search_baseline(g, vecs, q, oracle_entry,
                                       beam_width=beam)[1])[0]
        for q in queries])
    cfg = CacheConfig(size_bytes=max(1024, n * 4 * 7 // 10),
                      ways=8, line_bytes=64, prop_bytes=4, sample_rate=1)
    visits = np.zeros(n)
    visits[:len(entry.visit_ewma)] = entry.visit_ewma
    perms = {
        "identity": np.arange(n, dtype=np.int64),
        "degree": hubsort_order(g),
        "visits": visit_order(visits),
    }
    miss = {name: round(simulate_misses(perm[trace], cfg)["miss_rate"], 4)
            for name, perm in perms.items()}

    snap = s.metrics().snapshot()["histograms"]
    serve = merge_histogram_snapshots(
        list(snap.get("engine_serve_seconds", {}).values()))
    tel = s.telemetry()
    out = {
        "num_vectors": n,
        "dim": dim,
        "k_out": k_out,
        "queries": int(len(queries)),
        "recall_at_10": round(recall, 4),
        "recall_ok": bool(recall >= 0.95),
        "reorder_bit_identical": reorder_bit_identical,
        "refresh_first": {k: r1[k] for k in
                          ("tier", "scheme", "hotness_source",
                           "hot_prefix_len")},
        "refresh_steady": {k: r2[k] for k in ("tier", "scheme")},
        "visit_gini": round(entry.probes.visit_gini, 4),
        "visit_hub_fraction": round(entry.probes.visit_hub_fraction, 4),
        "patch_reorders": tel["mutations"]["patch_reorders"],
        "sim_miss_rate": miss,
        "visits_beats_degree": bool(miss["visits"] <= miss["degree"]),
        "serve_p50_ms": round((serve.get("p50") or 0.0) * 1e3, 3),
        "serve_p99_ms": round((serve.get("p99") or 0.0) * 1e3, 3),
        "result_cache": tel["scheduler"]["result_cache"],
    }
    s.close(drain=False)
    print(f"[engine] knn: {n} vectors, recall@10 {recall:.3f}, "
          f"{r1['tier']}/{r1['scheme']} then {r2['tier']}; sim miss "
          f"identity {miss['identity']:.3f} / degree {miss['degree']:.3f}"
          f" / visits {miss['visits']:.3f}, serve p99 "
          f"{out['serve_p99_ms']:.1f}ms", flush=True)
    return out


PHASES = ("decisions", "redecision", "calibration", "bucketing", "sharded",
          "hot_prefix", "fused", "scheduler", "observability", "sustained",
          "churn", "knn")


def parse_phases(value: str | None) -> list[str]:
    if not value:
        return list(PHASES)
    names = [n.strip() for n in value.split(",") if n.strip()]
    unknown = sorted(set(names) - set(PHASES))
    if unknown:
        raise SystemExit(f"unknown phase(s) {', '.join(unknown)}; "
                         f"choose from {', '.join(PHASES)}")
    return names


def run(scale: float = 0.5, batch: int = 8, repeats: int = 5,
        phases: list[str] | None = None) -> list[dict]:
    from repro.core.generators import road_grid
    from repro.engine import EngineSession

    todo = set(phases or PHASES)
    # the calibration replay reads state the earlier phases create (the
    # suite registrations and the "burst" graph's probes)
    if "calibration" in todo:
        todo |= {"decisions", "redecision"}

    session = EngineSession()
    suite = dict(bench_suite(scale))
    side = max(32, int(128 * np.sqrt(scale)))
    suite["road-sim"] = road_grid(side, shortcuts=64, seed=13,
                                  name="road-sim")

    rows = []
    out = {}
    if "decisions" in todo:
        rows = _phase_decisions(session, suite, batch, repeats)
        out["rows"] = rows
    if "redecision" in todo:
        out["redecision"] = _phase_redecision(session, scale)
    if "calibration" in todo:
        out["calibration_flip"] = _phase_calibration_flip(session, suite)
    if "bucketing" in todo:
        out["bucketing"] = _phase_bucketing(scale)
    if "sharded" in todo:
        out["sharded"] = _phase_sharded(scale)
    if "hot_prefix" in todo:
        out["hot_prefix"] = _phase_hot_prefix(scale)
    if "fused" in todo:
        out["fused"] = _phase_fused(scale)
    if "scheduler" in todo:
        out["scheduler"] = _phase_scheduler(scale)
    if "observability" in todo:
        out["observability"] = _phase_observability(scale)
    if "sustained" in todo:
        out["sustained"] = _phase_sustained(scale)
    if "churn" in todo:
        out["churn"] = _phase_churn(scale)
    if "knn" in todo:
        out["knn"] = _phase_knn(scale)

    out["calibration"] = session.policy.calibrator.as_dict()
    out["executor"] = session.executor.telemetry()
    save_json("engine", out)
    return rows


def main(scale: float = 0.5, phases: list[str] | None = None):
    rows = run(scale, phases=phases)
    if rows:
        cols = ["dataset", "scheme", "reorder_seconds", "predicted_gain",
                "realized_gain", "query_seconds_before",
                "query_seconds_after", "wall_break_even_queries"]
        print("\n=== engine policy + amortization ===")
        print(fmt_table(rows, cols))


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.5)
    ap.add_argument("--phases", default=None,
                    help="comma-separated subset of: " + ", ".join(PHASES))
    a = ap.parse_args()
    main(a.scale, parse_phases(a.phases))
