"""Assigned input shapes (seq_len × global_batch) and per-cell input specs.

``decode_32k``/``long_500k`` lower ``serve_step`` (one token + a KV cache of
seq_len); ``train_4k`` lowers ``train_step``; ``prefill_32k`` lowers the
prefill forward. Skip rules (recorded in EXPERIMENTS.md):
* long_500k only for sub-quadratic archs (rwkv6, zamba2, mixtral-SWA);
* encoder-only archs (hubert) have no decode step.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def cell_supported(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(supported, reason-if-not) for one (arch × shape) cell."""
    if cfg.is_encoder and shape.kind == "decode":
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch; 500k decode needs sub-quadratic attention"
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "decode":
        from ..models.transformer import init_cache
        cache = jax.eval_shape(lambda: init_cache(cfg, b, s))
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32), "cache": cache}

    specs: dict = {}
    if cfg.input_mode == "embeddings":
        specs["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                               jnp.bfloat16)
        if shape.kind == "train":
            specs["targets"] = jax.ShapeDtypeStruct((b, s), i32)
    else:
        if cfg.prefix_tokens > 0:
            specs["prefix"] = jax.ShapeDtypeStruct(
                (b, cfg.prefix_tokens, cfg.d_model), jnp.bfloat16)
            specs["tokens"] = jax.ShapeDtypeStruct(
                (b, s - cfg.prefix_tokens), i32)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
    return specs
