"""hubert-xlarge [audio]: 48L d1280 16H (MHA) ff5120 v504 — encoder-only
transformer backbone (w2v2 arch). Modality frontend (conv feature
extractor) is a STUB: input_specs provides precomputed frame embeddings.
Masked-unit prediction over 504 cluster targets. [arXiv:2106.07447]

Arch-applicability (DESIGN.md §4): continuous frame inputs and a 504-way
head have no skewed sparse lookup — the paper's reordering technique is
inapplicable; the arch is built without it.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
    d_ff=5120, vocab_size=504,
    causal=False,                      # encoder-only
    input_mode="embeddings",
    mlp_type="gelu", mlp_bias=True, norm_type="layernorm",
    rotary_pct=0.0,                    # hubert uses conv rel-pos (stubbed)
    vocab_reorder=False, hot_vocab_fraction=0.0,
)
