"""Pallas TPU kernel: hot/cold split embedding gather.

Payoff path of the vocab-LOrder feature (DESIGN.md §3.3): after reordering,
the hot vocabulary is a contiguous low-id slab. The kernel keeps that slab
VMEM-resident and serves hot lookups from it; cold lookups (rare, Zipf
tail) are masked out and served by a standard XLA gather in the wrapper.
Grid walks id blocks; the hot slab block is reused across all grid steps
(constant index_map) so it stays pinned in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ID_BLOCK = 512


def _kernel(ids_ref, slab_ref, out_ref, *, hot_size: int):
    ids = ids_ref[...]
    is_hot = ids < hot_size
    safe = jnp.where(is_hot, ids, 0)
    rows = jnp.take(slab_ref[...], safe, axis=0)
    out_ref[...] = jnp.where(is_hot[:, None], rows, 0.0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def hot_gather_pallas(ids, hot_slab, *, interpret: bool = True):
    """ids (B,) int32; hot_slab (H, D). Returns (B, D): rows for hot ids,
    zeros for cold ids (caller overlays the cold gather)."""
    b = ids.shape[0]
    h, d = hot_slab.shape
    assert b % ID_BLOCK == 0
    grid = (b // ID_BLOCK,)
    return pl.pallas_call(
        functools.partial(_kernel, hot_size=h),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ID_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((h, d), lambda i: (0, 0)),   # pinned hot slab
        ],
        out_specs=pl.BlockSpec((ID_BLOCK, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), hot_slab.dtype),
        interpret=interpret,
    )(ids, hot_slab)
