"""Engine harness — policy decisions, amortization, and the closed loop.

Three phases, one session:

1. **Decisions + amortization** — for each dataset: register with the
   serving engine (policy decides a scheme from probes + volume hint),
   then measure batched multi-source BFS latency on the *original* vs the
   *served* layout directly, and report the wall-clock break-even query
   count next to the ledger's cache-model estimate. Each registration's
   realized gain also feeds the strength calibrator.
2. **Online re-decision** — serve a synthetic bursty workload whose
   realized volume diverges from its registration hint and report the
   re-decisions the session makes (original -> cheap tier -> LOrder).
3. **Decisions after calibration** — replay a recorded outcome stream in
   which LOrder keeps realizing almost nothing (the misprediction regime
   Faldu et al. document), then re-run the policy on every dataset's
   probes: decisions that flip show the calibrated strengths overriding
   the static tree.

Emits benchmarks/results/engine.json.
"""
from __future__ import annotations

import numpy as np

from .common import bench_suite, fmt_table, save_json, time_call


def _phase_decisions(session, suite, batch, repeats):
    from repro.algos.graph_arrays import to_device

    rng = np.random.default_rng(0)
    rows = []
    for dname, g in suite.items():
        gid = session.register(g, graph_id=dname, expected_queries=256)
        entry = session.registry.get(gid)
        srcs = rng.integers(0, g.num_vertices, size=batch).astype(np.int32)

        ga_orig = to_device(g)
        srcs_served = entry.perm[srcs].astype(np.int32)
        t_before, _ = time_call(session.executor.run, ga_orig, "bfs", srcs,
                                repeats=repeats)
        t_after, _ = time_call(session.executor.run, entry.arrays, "bfs",
                               srcs_served, repeats=repeats)
        saving = t_before - t_after
        wall_break_even = (entry.reorder_seconds / saving
                           if saving > 1e-9 else float("inf"))
        rec = next(r for r in session.policy.history if r.graph_id == gid)
        rows.append({
            "dataset": dname,
            "scheme": entry.decision.scheme,
            "kwargs": entry.decision.kwargs,
            "reason": entry.decision.reason,
            "reorder_seconds": round(entry.reorder_seconds, 4),
            "predicted_gain": rec.decision.predicted_gain,
            "realized_gain": round(rec.realized_gain, 4),
            "batch": int(batch),
            "query_seconds_before": round(t_before, 5),
            "query_seconds_after": round(t_after, 5),
            "wall_break_even_queries": (round(wall_break_even, 1)
                                        if np.isfinite(wall_break_even)
                                        else "inf"),
        })
        print(f"[engine] {dname}: {entry.decision.scheme} "
              f"{entry.decision.kwargs}, reorder "
              f"{entry.reorder_seconds:.2f}s, query "
              f"{t_before * 1e3:.1f}ms -> {t_after * 1e3:.1f}ms", flush=True)
    return rows


def _phase_redecision(session, scale):
    """Bursty workload: hint says 2 queries, reality delivers ~40."""
    from repro.core.generators import powerlaw_community

    g = powerlaw_community(max(2000, int(20_000 * scale)), avg_degree=12.0,
                           mixing=0.1, seed=21, name="burst")
    gid = session.register(g, graph_id="burst", expected_queries=2)
    entry = session.registry.get(gid)
    first = entry.decision.scheme
    rng = np.random.default_rng(5)
    for _ in range(40):
        session.submit(gid, "bfs", rng.integers(0, g.num_vertices, size=4))
    events = [e for e in session.redecision_log if e["graph_id"] == gid]
    print(f"[engine] burst workload: hint=2, served "
          f"{entry.queries_observed} batches, {len(events)} re-decisions: "
          + " -> ".join([first] + [e["new_scheme"] for e in events]),
          flush=True)
    return {
        "dataset": "burst",
        "expected_queries_hint": 2,
        "queries_observed": entry.queries_observed,
        "scheme_path": [first] + [e["new_scheme"] for e in events],
        "redecision_count": len(events),
        "events": events,
    }


def _phase_calibration_flip(session, suite):
    """Replay outcomes where LOrder collapses; re-decide every dataset."""
    policy = session.policy
    pre = {d: policy.decide(session.registry.get(d).probes, 256).scheme
           for d in suite}
    from repro.engine import PolicyDecision, ReorderPolicy

    probes = session.registry.get("burst").probes
    skew = ReorderPolicy._skew(probes)
    lorder = PolicyDecision("lorder", {}, "replayed historical decision",
                            0.75 * skew, skew)
    for i in range(25):
        # recorded outcome: near-zero realized reduction despite high skew
        policy.record(f"replay-{i}", lorder, miss_rate_before=0.5,
                      miss_rate_after=0.49, reorder_seconds=1.0)
    post = {d: policy.decide(session.registry.get(d).probes, 256).scheme
            for d in suite}
    changed = {d: (pre[d], post[d]) for d in suite if pre[d] != post[d]}
    cal = policy.calibrator
    print(f"[engine] after calibration replay: lorder strength "
          f"{cal.strength('lorder'):.3f} (prior 0.75), "
          f"{len(changed)} decision(s) changed: "
          + (", ".join(f"{d}: {a}->{b}" for d, (a, b) in changed.items())
             or "none"), flush=True)
    return {
        "strengths_after": cal.strengths(),
        "decisions_before": pre,
        "decisions_after": post,
        "changed": {d: list(v) for d, v in changed.items()},
    }


def run(scale: float = 0.5, batch: int = 8, repeats: int = 5) -> list[dict]:
    from repro.core.generators import road_grid
    from repro.engine import EngineSession

    session = EngineSession()
    suite = dict(bench_suite(scale))
    side = max(32, int(128 * np.sqrt(scale)))
    suite["road-sim"] = road_grid(side, shortcuts=64, seed=13,
                                  name="road-sim")

    rows = _phase_decisions(session, suite, batch, repeats)
    redecision = _phase_redecision(session, scale)
    flip = _phase_calibration_flip(session, suite)

    out = {
        "rows": rows,
        "redecision": redecision,
        "calibration_flip": flip,
        "calibration": session.policy.calibrator.as_dict(),
        "executor": session.executor.telemetry(),
    }
    save_json("engine", out)
    return rows


def main(scale: float = 0.5):
    rows = run(scale)
    cols = ["dataset", "scheme", "reorder_seconds", "predicted_gain",
            "realized_gain", "query_seconds_before", "query_seconds_after",
            "wall_break_even_queries"]
    print("\n=== engine policy + amortization ===")
    print(fmt_table(rows, cols))


if __name__ == "__main__":
    main()
