"""Execution backends: bucketing correctness, placement, sharded serving.

The genuinely distributed checks (4 shards) run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` — the flag must be
set before jax initializes its backends (CI also runs this whole file
under a 4-device step).
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp
import pytest

from repro.algos import kernels as K
from repro.algos.graph_arrays import to_device
from repro.core.generators import powerlaw_community
from repro.engine import (BatchedExecutor, EngineSession, GraphHandle,
                          ReorderPolicy, ShardedBackend, SingleDeviceBackend,
                          bucket_dims, estimate_device_bytes, probe_graph)


# ---------------------------------------------------------------- buckets
def test_bucket_dims_geometric_and_sentinel_room():
    v, e = bucket_dims(1000, 9000)
    assert v >= 1001 and e >= 9000          # room for sentinel self-loops
    assert bucket_dims(1000, 9000) == bucket_dims(900, 8500)  # shared bucket
    # no edge padding needed -> vertex bucket may equal V exactly
    assert bucket_dims(256, 1024) == (256, 1024)
    # floors apply to tiny graphs
    assert bucket_dims(8, 12) == (256, 1024)
    with pytest.raises(ValueError):
        bucket_dims(10, 10, growth=1.0)


def test_estimate_device_bytes_monotone():
    assert estimate_device_bytes(100, 1000) < estimate_device_bytes(100, 2000)
    assert estimate_device_bytes(100, 1000) < estimate_device_bytes(200, 1000)


# ----------------------------------------------------- padded CSR parity
def _parity_padded_vs_exact(g, srcs):
    bucketed = SingleDeviceBackend()
    handle = bucketed.prepare(g)
    assert handle.bucket[0] > g.num_vertices or handle.bucket == (
        g.num_vertices, g.num_edges)
    ga = to_device(g)
    for kernel in ("bfs", "sssp"):
        got = np.asarray(bucketed.run(handle, kernel, srcs))
        want = np.asarray(SingleDeviceBackend(bucketing=False).run_arrays(
            ga, kernel, srcs))
        assert got.shape == (len(srcs), g.num_vertices)
        np.testing.assert_array_equal(got, want)  # ints: bit-identical
    np.testing.assert_allclose(
        np.asarray(bucketed.run(handle, "pr")),
        np.asarray(K.pagerank(ga)), rtol=1e-5, atol=1e-9)
    for kernel in ("cc", "ccsv"):
        np.testing.assert_array_equal(
            np.asarray(bucketed.run(handle, kernel)),
            np.asarray(SingleDeviceBackend(bucketing=False).run_arrays(
                ga, kernel)))
    np.testing.assert_allclose(
        np.asarray(bucketed.run(handle, "bc", srcs)),
        np.asarray(K.bc_multi(ga, jnp.asarray(srcs, jnp.int32))),
        rtol=1e-5, atol=1e-5)


def test_bucket_padding_exact_all_kernels(plc_graph):
    _parity_padded_vs_exact(plc_graph, np.array([0, 7, 42, 1999], np.int32))


def test_bucket_padding_exact_tiny(tiny_graph):
    # 8 vertices pad all the way up to the (256, 1024) floor bucket
    _parity_padded_vs_exact(tiny_graph, np.array([0, 3], np.int32))


def test_bucket_padding_property_random_powerlaw():
    """Satellite: bucketed BFS/SSSP/PR == unpadded on random power-law
    graphs (hypothesis-driven when available)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=8, deadline=None)
    @given(n=st.integers(min_value=60, max_value=900),
           avg_degree=st.floats(min_value=2.0, max_value=12.0),
           seed=st.integers(min_value=0, max_value=2**16))
    def check(n, avg_degree, seed):
        g = powerlaw_community(n, avg_degree=avg_degree, seed=seed)
        rng = np.random.default_rng(seed)
        srcs = rng.integers(0, n, size=3).astype(np.int32)
        _parity_padded_vs_exact(g, srcs)

    check()


def test_compile_sharing_across_distinct_shapes():
    """Graphs of different (V, E) in one bucket share one compile key."""
    backend = SingleDeviceBackend()
    sizes = (300, 330, 360, 390)
    graphs = [powerlaw_community(n, avg_degree=4.0, seed=n) for n in sizes]
    assert len({(g.num_vertices, g.num_edges) for g in graphs}) == len(sizes)
    outs = []
    for g in graphs:
        h = backend.prepare(g)
        outs.append(backend.run(h, "bfs", np.array([0], np.int32)))
    exact = SingleDeviceBackend(bucketing=False)
    for g in graphs:
        exact.run(exact.prepare(g), "bfs", np.array([0], np.int32))
    assert exact.cache_misses == len(sizes)
    assert backend.cache_misses < exact.cache_misses
    assert backend.cache_misses * 2 <= exact.cache_misses


# ----------------------------------------------- executor facade + guards
def test_empty_sources_guard_before_cache_telemetry(plc_graph):
    """Satellite: an empty batch (or unknown kernel) must not touch the
    compile-cache counters — formerly it booked a miss before raising."""
    ex = BatchedExecutor()
    ga = to_device(plc_graph)
    with pytest.raises(ValueError):
        ex.run(ga, "bfs", [])
    with pytest.raises(ValueError):
        ex.run(ga, "bfs", np.empty(0, np.int32))
    with pytest.raises(ValueError):
        ex.run(ga, "nope", [0])
    assert (ex.cache_hits, ex.cache_misses) == (0, 0)
    assert ex.queries_run == 0 and ex.sources_run == 0


def test_executor_rejects_unknown_target_and_backend(plc_graph):
    ex = BatchedExecutor()
    with pytest.raises(TypeError):
        ex.run(plc_graph, "bfs", [0])  # host Graph is not a served target
    with pytest.raises(ValueError):
        ex.backend("tpu-pod")


def test_executor_prepare_routes_and_merges_telemetry(plc_graph):
    ex = BatchedExecutor()
    h = ex.prepare(plc_graph)
    assert isinstance(h, GraphHandle) and h.backend == "single"
    ex.run(h, "bfs", [0, 1])
    t = ex.telemetry()
    assert t["compile_cache_misses"] == 1
    assert t["single"]["bucketing"]["graphs_prepared"] == 1
    assert t["sharded"] is None  # lazy: never built


# -------------------------------------------------------------- placement
def test_policy_places_by_device_budget(plc_graph):
    probes = probe_graph(plc_graph)
    need = estimate_device_bytes(probes.num_vertices, probes.num_edges)
    fits = ReorderPolicy(device_budget_bytes=need * 10).decide(probes, 256)
    assert fits.backend == "single"
    over = ReorderPolicy(device_budget_bytes=need // 4).decide(probes, 256)
    assert over.backend == "sharded" and "placement" in over.reason
    default = ReorderPolicy().decide(probes, 256)
    assert default.backend == "single"


def test_session_sharded_single_shard_parity(plc_graph):
    """In-process (1 host device = 1 shard): sharded serving through
    ``EngineSession.submit`` matches single-device kernels exactly."""
    session = EngineSession(device_budget_bytes=1024)
    gid = session.register(plc_graph, graph_id="over-budget",
                           expected_queries=256)
    entry = session.registry.get(gid)
    assert entry.backend == "sharded"
    assert entry.ledger.backend == "sharded"
    assert entry.ledger.gain_discount == session.sharded_gain_discount < 1.0
    ga = to_device(plc_graph)
    srcs = np.array([5, 321, 1500])
    depth = session.submit(gid, "bfs", srcs)
    dist = session.submit(gid, "sssp", srcs)
    for i, s in enumerate(srcs):
        np.testing.assert_array_equal(depth[i],
                                      np.asarray(K.bfs(ga, jnp.int32(s))))
        np.testing.assert_array_equal(dist[i],
                                      np.asarray(K.sssp(ga, jnp.int32(s))))
    np.testing.assert_allclose(session.submit(gid, "pr"),
                               np.asarray(K.pagerank(ga)),
                               rtol=1e-4, atol=1e-8)
    with pytest.raises(NotImplementedError):
        session.submit(gid, "bc", srcs)
    t = session.telemetry()
    assert t["graphs"][gid]["backend"] == "sharded"
    assert t["executor"]["sharded"]["queries_run"] == 3  # bc raised, uncounted


def test_sharded_backend_four_devices_session_submit():
    """Sharded serving across 4 forced host devices, end-to-end through
    ``EngineSession.submit`` (bfs + sssp exact, pr allclose)."""
    prog = textwrap.dedent("""
        import numpy as np
        import jax, jax.numpy as jnp
        assert jax.device_count() == 4, jax.devices()
        from repro.algos import kernels as K
        from repro.algos.graph_arrays import to_device
        from repro.core.generators import powerlaw_community
        from repro.engine import EngineSession

        g = powerlaw_community(2000, avg_degree=8.0, seed=3)
        session = EngineSession(device_budget_bytes=50_000)
        gid = session.register(g, graph_id="big", expected_queries=256)
        entry = session.registry.get(gid)
        assert entry.backend == "sharded", entry.backend
        assert session.executor.sharded.num_shards == 4
        srcs = np.array([3, 99, 500, 1500])
        ga = to_device(g)
        depth = session.submit(gid, "bfs", srcs)
        dist = session.submit(gid, "sssp", srcs)
        for i, s in enumerate(srcs):
            np.testing.assert_array_equal(
                depth[i], np.asarray(K.bfs(ga, jnp.int32(s))))
            np.testing.assert_array_equal(
                dist[i], np.asarray(K.sssp(ga, jnp.int32(s))))
        np.testing.assert_allclose(
            session.submit(gid, "pr"), np.asarray(K.pagerank(ga)),
            rtol=1e-4, atol=1e-7)
        print("SHARDED_PARITY_OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["JAX_PLATFORMS"] = "cpu"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), env.get("PYTHONPATH", "")]).rstrip(
        os.pathsep)
    res = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, f"stdout={res.stdout}\nstderr={res.stderr}"
    assert "SHARDED_PARITY_OK" in res.stdout


def test_sharded_backend_unsupported_kernel_message(plc_graph):
    backend = ShardedBackend(num_shards=1)
    handle = backend.prepare(plc_graph)
    with pytest.raises(NotImplementedError, match="bfs"):
        backend.run(handle, "cc")


# ------------------------------------------------------ benchmark driver
def test_run_py_parse_only_accepts_lists():
    from benchmarks.run import HARNESSES, parse_only
    assert parse_only(None) == list(HARNESSES)
    assert parse_only("engine") == ["engine"]
    assert parse_only("engine,reorder_time") == ["engine", "reorder_time"]
    assert parse_only(" engine , skew ") == ["engine", "skew"]
    with pytest.raises(SystemExit):
        parse_only("engine,nope")
