"""Shim: the while-loop-aware HLO cost accounting lives in the package
(repro.launch.hlo_analysis) so the dry-run can use it; benchmarks import
it from here for backwards compatibility."""
from repro.launch.hlo_analysis import *          # noqa: F401,F403
from repro.launch.hlo_analysis import analyse_hlo  # noqa: F401
