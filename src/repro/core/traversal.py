"""Host-side (numpy) BFS primitives shared by the reordering schemes.

Reordering is preprocessing and runs on the host CPU in any real
deployment; these are vectorized level-synchronous BFS routines over CSR.
"""
from __future__ import annotations

import numpy as np

from .csr import Graph, ranges_to_indices


def bfs_levels(g: Graph, source: int, max_hops: int | None = None,
               blocked: np.ndarray | None = None) -> np.ndarray:
    """Level-synchronous BFS. Returns dist (V,), -1 = unreached.

    ``blocked`` — boolean mask of vertices BFS must not enter (used by the
    locality-formation pass to restrict to unassigned vertices).
    """
    n = g.num_vertices
    dist = np.full(n, -1, dtype=np.int32)
    if blocked is not None and blocked[source]:
        return dist
    dist[source] = 0
    frontier = np.array([source], dtype=np.int64)
    level = 0
    while frontier.size and (max_hops is None or level < max_hops):
        level += 1
        nbrs = g.frontier_neighbors(frontier)
        if nbrs.size == 0:
            break
        cand = np.unique(nbrs)
        new = cand[dist[cand] < 0]
        if blocked is not None:
            new = new[~blocked[new]]
        if new.size == 0:
            break
        dist[new] = level
        frontier = new
    return dist


def bfs_order(g: Graph, source: int, max_hops: int | None,
              assigned: np.ndarray) -> np.ndarray:
    """BFS discovery order from ``source``, restricted to unassigned vertices.

    Mutates ``assigned`` (marks every discovered vertex). Discovery order is
    level-by-level, within a level by ascending vertex id (deterministic,
    matching a serial CSR scan). Returns the discovered vertex ids in order,
    beginning with ``source``.
    """
    out = [np.array([source], dtype=np.int64)]
    assigned[source] = True
    frontier = out[0]
    level = 0
    while frontier.size and (max_hops is None or level < max_hops):
        level += 1
        nbrs = g.frontier_neighbors(frontier)
        if nbrs.size == 0:
            break
        cand = np.unique(nbrs)
        new = cand[~assigned[cand]]
        if new.size == 0:
            break
        assigned[new] = True
        out.append(new)
        frontier = new
    return np.concatenate(out)


def farthest_vertex(g: Graph, source: int) -> tuple[int, int]:
    """(vertex, eccentricity) of the farthest reachable vertex from source."""
    dist = bfs_levels(g, source)
    ecc = int(dist.max())
    return int(np.argmax(dist)), ecc
