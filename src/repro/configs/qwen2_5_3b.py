"""qwen2.5-3b [dense]: 36L d2048 16H (GQA kv=2) ff11008 v151936 — GQA with
QKV bias. [hf:Qwen/Qwen2.5-*; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b", family="dense",
    num_layers=36, d_model=2048, num_heads=16, num_kv_heads=2,
    d_ff=11008, vocab_size=151_936,
    rope_theta=1e6,
    qkv_bias=True,
    mlp_type="swiglu", norm_type="rmsnorm",
    tie_embeddings=True,
    vocab_reorder=True, hot_vocab_fraction=0.04,
)
