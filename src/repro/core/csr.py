"""Compressed Sparse Row graph container (paper §2.2).

Graphs are stored as out-edge CSR (``indptr``, ``indices``) in numpy on the
host — reordering is host-side preprocessing, exactly as in real deployments
— with cached in-edge CSR (the transpose) for pull-mode kernels and lazy JAX
views for the compute layer.

Vertex relabeling semantics: ``perm[old_id] == new_id``. Applying a
permutation produces an isomorphic graph whose CSR arrays realize the new
memory layout; per-row neighbor lists are kept sorted (as CSR construction
would produce), matching the paper's Figure 2.2.1 layout.
"""
from __future__ import annotations

import dataclasses
from functools import cached_property

import numpy as np


def ranges_to_indices(starts: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """Flatten [starts[i], starts[i]+counts[i]) ranges into one index array.

    Vectorized equivalent of ``np.concatenate([np.arange(s, s+c) ...])``.
    """
    starts = np.asarray(starts, dtype=np.int64)
    counts = np.asarray(counts, dtype=np.int64)
    nz = counts > 0
    starts, counts = starts[nz], counts[nz]
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, dtype=np.int64)
    out = np.ones(total, dtype=np.int64)
    out[0] = starts[0]
    pos = np.cumsum(counts)[:-1]
    out[pos] = starts[1:] - (starts[:-1] + counts[:-1] - 1)
    return np.cumsum(out)


@dataclasses.dataclass
class Graph:
    """Directed multigraph in CSR (out-edge) form."""

    indptr: np.ndarray   # (V+1,) int64
    indices: np.ndarray  # (E,) int32 — destination vertex of each out-edge
    communities: np.ndarray | None = None  # optional ground-truth labels (V,)
    name: str = "graph"

    def __post_init__(self):
        self.indptr = np.asarray(self.indptr, dtype=np.int64)
        self.indices = np.asarray(self.indices, dtype=np.int32)

    # ------------------------------------------------------------------ sizes
    @property
    def num_vertices(self) -> int:
        return len(self.indptr) - 1

    @property
    def num_edges(self) -> int:
        return len(self.indices)

    # ---------------------------------------------------------------- degrees
    @cached_property
    def out_degree(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int32)

    @cached_property
    def in_degree(self) -> np.ndarray:
        return np.bincount(self.indices, minlength=self.num_vertices).astype(np.int32)

    @cached_property
    def degree(self) -> np.ndarray:
        """Total degree (in+out) — the hotness basis (paper §2.1)."""
        return self.out_degree + self.in_degree

    @property
    def average_degree(self) -> float:
        """The paper's hotness threshold λ = avg degree (0.0 when V = 0)."""
        if self.num_vertices == 0:
            return 0.0
        return float(self.degree.mean())

    def hot_mask(self, threshold: float | None = None) -> np.ndarray:
        """Hot vertex := degree > threshold (default: average degree)."""
        thr = self.average_degree if threshold is None else threshold
        return self.degree > thr

    # ------------------------------------------------------------- structure
    @cached_property
    def edge_src(self) -> np.ndarray:
        """(E,) source vertex per edge (COO row), aligned with ``indices``."""
        return np.repeat(
            np.arange(self.num_vertices, dtype=np.int32), self.out_degree
        )

    @cached_property
    def transpose(self) -> "Graph":
        """In-edge CSR (for pull-mode kernels)."""
        order = np.argsort(self.indices, kind="stable")
        t_indices = self.edge_src[order]
        t_indptr = np.zeros(self.num_vertices + 1, dtype=np.int64)
        np.cumsum(
            np.bincount(self.indices, minlength=self.num_vertices),
            out=t_indptr[1:],
        )
        return Graph(t_indptr, t_indices, self.communities, self.name + ".T")

    @cached_property
    def undirected(self) -> "Graph":
        """Symmetrized view (u->v and v->u), dedup per row."""
        src = np.concatenate([self.edge_src, self.indices])
        dst = np.concatenate([self.indices, self.edge_src])
        return from_edges(self.num_vertices, src, dst, dedup=True,
                          communities=self.communities, name=self.name + ".sym")

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    def frontier_neighbors(self, frontier: np.ndarray) -> np.ndarray:
        """All out-neighbors of a vertex frontier (vectorized, with repeats)."""
        starts = self.indptr[frontier]
        counts = self.indptr[frontier + 1] - starts
        return self.indices[ranges_to_indices(starts, counts)]

    # ------------------------------------------------------------ relabeling
    def apply_permutation(self, perm: np.ndarray) -> "Graph":
        """Return the isomorphic graph with vertex u renamed perm[u]."""
        perm = np.asarray(perm, dtype=np.int64)
        n = self.num_vertices
        assert perm.shape == (n,)
        inv = np.empty(n, dtype=np.int64)
        inv[perm] = np.arange(n, dtype=np.int64)

        deg = self.out_degree
        new_indptr = np.zeros(n + 1, dtype=np.int64)
        np.cumsum(deg[inv], out=new_indptr[1:])

        gather = ranges_to_indices(self.indptr[inv], deg[inv].astype(np.int64))
        new_indices = perm[self.indices[gather]].astype(np.int32)
        # keep per-row neighbor lists sorted, as fresh CSR construction would
        row = np.repeat(np.arange(n, dtype=np.int64), deg[inv])
        order = np.lexsort((new_indices, row))
        new_indices = new_indices[order]
        comm = None if self.communities is None else self.communities[inv]
        return Graph(new_indptr, new_indices, comm, self.name)

    def edge_multiset(self) -> np.ndarray:
        """Canonical sorted (src,dst) pairs — isomorphism-check helper."""
        pairs = np.stack([self.edge_src.astype(np.int64), self.indices.astype(np.int64)], 1)
        order = np.lexsort((pairs[:, 1], pairs[:, 0]))
        return pairs[order]


def from_edges(num_vertices: int, src, dst, *, dedup: bool = False,
               communities=None, name: str = "graph") -> Graph:
    """Build CSR from COO edge lists (drops self-loops if dedup)."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if dedup:
        keep = src != dst
        src, dst = src[keep], dst[keep]
        key = src * np.int64(num_vertices) + dst
        _, uniq = np.unique(key, return_index=True)
        src, dst = src[uniq], dst[uniq]
    order = np.argsort(src * np.int64(num_vertices) + dst, kind="stable")
    src, dst = src[order], dst[order]
    indptr = np.zeros(num_vertices + 1, dtype=np.int64)
    np.cumsum(np.bincount(src, minlength=num_vertices), out=indptr[1:])
    return Graph(indptr, dst.astype(np.int32), communities, name)


def validate_permutation(perm: np.ndarray, n: int) -> bool:
    perm = np.asarray(perm)
    return perm.shape == (n,) and np.array_equal(np.sort(perm), np.arange(n))
