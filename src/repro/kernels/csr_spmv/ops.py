"""Jit'd public wrapper for csr_spmv with backend dispatch.

On CPU (this container) the Pallas path runs in ``interpret=True`` for
validation and the XLA segment-sum path is the production fallback; on TPU
``use_pallas=True`` compiles the real kernel.
"""
from __future__ import annotations

import jax
import numpy as np

from .csr_spmv import csr_spmv_pallas, pack_edges
from .ref import csr_spmv_ref


class SpMV:
    """Pre-packed SpMV operator bound to one graph (in-CSR)."""

    def __init__(self, t_indptr, t_indices, weights=None, *,
                 use_pallas: bool | None = None, interpret: bool | None = None):
        self.t_indptr = np.asarray(t_indptr)
        self.t_indices = np.asarray(t_indices)
        self.weights = weights
        on_tpu = jax.default_backend() == "tpu"
        self.use_pallas = on_tpu if use_pallas is None else use_pallas
        self.interpret = (not on_tpu) if interpret is None else interpret
        if self.use_pallas:
            (self.src, self.dst_local, self.val, self.bpt, self.ntiles,
             self.n_pad) = pack_edges(self.t_indptr, self.t_indices, weights)

    def __call__(self, x):
        if self.use_pallas:
            return csr_spmv_pallas(
                self.src, self.dst_local, self.val, x,
                blocks_per_tile=self.bpt, num_tiles=self.ntiles,
                n_pad=self.n_pad, interpret=self.interpret)
        w = (np.ones(len(self.t_indices), np.float32)
             if self.weights is None else self.weights)
        return csr_spmv_ref(self.t_indptr, self.t_indices, w, x)
