# Benchmark driver — one harness per paper table/figure + the beyond-paper
# locality tables + the roofline report. Results land in
# benchmarks/results/*.json and are summarized in EXPERIMENTS.md.
#
#   PYTHONPATH=src python -m benchmarks.run                      # everything
#   PYTHONPATH=src python -m benchmarks.run --only skew          # one harness
#   PYTHONPATH=src python -m benchmarks.run --only engine,skew   # a subset
#   PYTHONPATH=src python -m benchmarks.run --scale 0.25         # smaller
from __future__ import annotations

import argparse
import time


HARNESSES = ("skew", "reorder_time", "cache_stats", "kappa_sweep",
             "speedups", "engine", "vocab_locality", "moe_locality",
             "roofline")


def parse_only(value: str | None) -> list[str]:
    """Comma-separated harness subset -> validated list (None = all)."""
    if not value:
        return list(HARNESSES)
    names = [n.strip() for n in value.split(",") if n.strip()]
    unknown = sorted(set(names) - set(HARNESSES))
    if unknown:
        raise SystemExit(f"unknown harness(es) {', '.join(unknown)}; "
                         f"choose from {', '.join(HARNESSES)}")
    return names


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ", ".join(HARNESSES))
    ap.add_argument("--scale", type=float, default=0.5,
                    help="graph-size multiplier for the paper suite")
    args = ap.parse_args()

    todo = parse_only(args.only)
    for name in todo:
        t0 = time.time()
        print(f"\n{'=' * 70}\n== {name}\n{'=' * 70}", flush=True)
        if name == "skew":
            from .skew import main as m
            m(args.scale)
        elif name == "reorder_time":
            from .reorder_time import main as m
            m(args.scale)
        elif name == "cache_stats":
            from .cache_stats import main as m
            m(args.scale)
        elif name == "kappa_sweep":
            from .kappa_sweep import main as m
            m(min(args.scale, 0.25))
        elif name == "speedups":
            from .speedups import main as m
            m(args.scale)
        elif name == "engine":
            from .engine import main as m
            m(args.scale)
        elif name == "vocab_locality":
            from .vocab_locality import main as m
            m()
        elif name == "moe_locality":
            from .moe_locality import main as m
            m()
        elif name == "roofline":
            from .roofline import main as m
            m()
        print(f"[{name}] {time.time() - t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
