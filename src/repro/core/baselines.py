"""Comparison reordering schemes (paper §3): Sort, DBG, HubSort/HubCluster,
SOrder, NOrder and (windowed-greedy) GOrder, plus identity/random controls —
and host-side numpy *kernel baselines* (bottom of this module), the
independent oracles every execution backend is checked against
(tests/test_parity_matrix.py).

All schemes return ``perm`` with ``perm[old_id] = new_id``.
"""
from __future__ import annotations

import heapq

import numpy as np

from .csr import Graph
from .traversal import bfs_levels, bfs_order


# --------------------------------------------------------------- controls
def identity_order(g: Graph) -> np.ndarray:
    return np.arange(g.num_vertices, dtype=np.int64)


def random_order(g: Graph, seed: int = 0) -> np.ndarray:
    return np.random.default_rng(seed).permutation(g.num_vertices)


# ------------------------------------------------------------------- Sort
def sort_order(g: Graph) -> np.ndarray:
    """Full sort by degree descending (stable)."""
    by_deg = np.argsort(-g.degree.astype(np.int64), kind="stable")
    perm = np.empty(g.num_vertices, dtype=np.int64)
    perm[by_deg] = np.arange(g.num_vertices)
    return perm


# ------------------------------------------------------- HubSort / HubCluster
def hubsort_order(g: Graph, hot_threshold: float | None = None) -> np.ndarray:
    """Hot vertices first sorted by degree desc; cold keep original order."""
    hot = g.hot_mask(hot_threshold)
    hot_ids = np.nonzero(hot)[0]
    hot_ids = hot_ids[np.argsort(-g.degree[hot_ids].astype(np.int64), kind="stable")]
    cold_ids = np.nonzero(~hot)[0]
    perm = np.empty(g.num_vertices, dtype=np.int64)
    perm[np.concatenate([hot_ids, cold_ids])] = np.arange(g.num_vertices)
    return perm


def hubcluster_order(g: Graph, hot_threshold: float | None = None) -> np.ndarray:
    """Hot vertices first (original relative order); cold after (ditto)."""
    hot = g.hot_mask(hot_threshold)
    perm = np.empty(g.num_vertices, dtype=np.int64)
    perm[np.concatenate([np.nonzero(hot)[0], np.nonzero(~hot)[0]])] = \
        np.arange(g.num_vertices)
    return perm


# -------------------------------------------------------------------- DBG
def dbg_order(g: Graph, num_groups: int = 8) -> np.ndarray:
    """Degree-Based Grouping (paper §3.5): power-law degree bins, vertices
    keep original relative order within each bin; hotter bins get lower ids.

    Bin boundaries follow the power law: avg·2^k for k = num_groups-2 … 0,
    then the sub-average group.
    """
    deg = g.degree.astype(np.float64)
    avg = max(g.average_degree, 1.0)
    # group 0 = hottest. deg > avg*2^(G-2) -> 0, ..., deg > avg -> G-2, else G-1
    thresholds = avg * (2.0 ** np.arange(num_groups - 2, -1, -1))
    group = np.full(g.num_vertices, num_groups - 1, dtype=np.int64)
    for gi, t in enumerate(thresholds):
        group[(group == num_groups - 1) & (deg > t)] = gi
    order = np.argsort(group, kind="stable")  # stable keeps original order
    perm = np.empty(g.num_vertices, dtype=np.int64)
    perm[order] = np.arange(g.num_vertices)
    return perm


# ----------------------------------------------------------------- SOrder
def sorder_order(g: Graph, kappa: int = 2,
                 hot_threshold: float | None = 50.0) -> np.ndarray:
    """Structure-preserved reordering (paper §3.3).

    Hypernode = κ-hop BFS aggregate of adjacent *cold* unvisited vertices
    from a seed; emit hypernode members, then their hot neighbours, then
    their cold neighbours. Paper evaluation uses λ=50, κ=2.
    """
    thr = g.average_degree if hot_threshold is None else hot_threshold
    hot = g.degree > thr
    n = g.num_vertices
    assigned = np.zeros(n, dtype=bool)
    pieces: list[np.ndarray] = []
    for v in range(n):
        if assigned[v]:
            continue
        if hot[v]:  # hot seeds form singleton hypernodes
            assigned[v] = True
            pieces.append(np.array([v], dtype=np.int64))
            continue
        # grow hypernode over cold unassigned vertices only
        blocked = assigned | hot
        blocked[v] = False
        hyper = bfs_order(g, v, kappa, blocked)
        assigned[hyper] = True
        # neighbours of the hypernode, split hot-first
        nbrs = np.unique(g.frontier_neighbors(hyper))
        nbrs = nbrs[~assigned[nbrs]]
        hn, cn = nbrs[hot[nbrs]], nbrs[~hot[nbrs]]
        assigned[hn] = True
        assigned[cn] = True
        pieces.append(np.concatenate([hyper, hn, cn]))
    order = np.concatenate(pieces)
    perm = np.empty(n, dtype=np.int64)
    perm[order] = np.arange(n)
    return perm


# ----------------------------------------------------------------- NOrder
def norder_order(g: Graph, hot_threshold: float | None = None) -> np.ndarray:
    """Neighbourhood reordering (paper §3.4): first sort vertices by hotness
    descending; then BFS serially from each listed vertex (skipping visited);
    new ids follow traversal order. Two full traversals => ~2x reorder time.
    """
    n = g.num_vertices
    by_deg = np.argsort(-g.degree.astype(np.int64), kind="stable")
    assigned = np.zeros(n, dtype=bool)
    pieces: list[np.ndarray] = []
    for v in by_deg:
        if assigned[v]:
            continue
        pieces.append(bfs_order(g, int(v), None, assigned))
    order = np.concatenate(pieces)
    perm = np.empty(n, dtype=np.int64)
    perm[order] = np.arange(n)
    return perm


# ----------------------------------------------------------------- GOrder
def gorder_order(g: Graph, window: int = 8,
                 max_vertices: int = 1 << 17) -> np.ndarray:
    """Windowed-greedy GOrder (paper §3.2, Wei et al.).

    Greedy maximisation of F(φ) = Σ_{0<φ(v)-φ(u)<=ω} S(u,v) with
    S = #common in-neighbours + #direct edges, via a lazy-update max-heap.
    Deliberately expensive — that is the paper's point — so guarded by
    ``max_vertices``.
    """
    n = g.num_vertices
    if n > max_vertices:
        raise ValueError(f"GOrder guard: {n} > {max_vertices} vertices")
    gt = g.transpose  # in-neighbours
    und = g.undirected

    score = np.zeros(n, dtype=np.float64)  # score vs current window
    placed = np.zeros(n, dtype=bool)
    heap: list[tuple[float, int]] = []

    def bump(vs: np.ndarray, delta: float):
        if len(vs) == 0:
            return
        np.add.at(score, vs, delta)
        for v in np.unique(vs):
            if not placed[v]:
                heapq.heappush(heap, (-score[v], int(v)))

    def contributions(v: int) -> np.ndarray:
        """Vertices whose S(·,v) gets a +1 when v joins/leaves the window:
        direct neighbours (sibling term S_n) and out-neighbours' other
        in-neighbours (common in-neighbour term S_s)."""
        direct = und.neighbors(v)
        sibs = gt.frontier_neighbors(np.asarray(g.neighbors(v), dtype=np.int64))
        return np.concatenate([direct, sibs])

    start = int(np.argmax(g.degree))
    order = np.empty(n, dtype=np.int64)
    window_buf: list[int] = []
    heapq.heappush(heap, (-0.0, start))
    score[start] = 0.0
    seq = iter(np.argsort(-g.degree.astype(np.int64), kind="stable"))

    for pos in range(n):
        v = None
        while heap:
            negs, cand = heapq.heappop(heap)
            if placed[cand]:
                continue
            if -negs != score[cand]:
                continue  # stale entry
            v = cand
            break
        if v is None:  # disconnected remainder: next unplaced by degree
            for cand in seq:
                if not placed[cand]:
                    v = int(cand)
                    break
        placed[v] = True
        order[pos] = v
        window_buf.append(v)
        bump(contributions(v), +1.0)
        if len(window_buf) > window:
            old = window_buf.pop(0)
            bump(contributions(old), -1.0)

    perm = np.empty(n, dtype=np.int64)
    perm[order] = np.arange(n)
    return perm


# ------------------------------------------------- numpy kernel baselines
#
# Pure-host reference implementations of the six served kernels, written
# against a different execution model (python loops + np.ufunc.at) than
# the JAX kernels so parity failures implicate the device path, not a
# shared bug. BFS depths come from core.traversal.bfs_levels.


def bfs_baseline(g: Graph, source: int) -> np.ndarray:
    """(V,) hop depths, -1 unreached."""
    return bfs_levels(g, source)


def pagerank_baseline(g: Graph, damping: float = 0.85, iters: int = 20,
                      tol: float = 1e-6) -> np.ndarray:
    """(V,) PageRank, pull mode with uniform dangling redistribution."""
    n = g.num_vertices
    r = np.full(n, 1.0 / n)
    outdeg = np.maximum(g.out_degree.astype(np.float64), 1.0)
    t = g.transpose
    for _ in range(iters):
        contrib = r / outdeg
        summed = np.zeros(n)
        np.add.at(summed, t.edge_src, contrib[t.indices])
        dangling = r[g.out_degree == 0].sum()
        r_new = (1 - damping) / n + damping * (summed + dangling / n)
        if np.abs(r_new - r).sum() <= tol:
            return r_new
        r = r_new
    return r


def cc_baseline(g: Graph) -> np.ndarray:
    """(V,) component labels = min vertex id, union-find over symmetrized
    edges (the labeling cc_labelprop converges to)."""
    parent = np.arange(g.num_vertices)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for u, v in zip(g.edge_src, g.indices):
        ru, rv = find(u), find(v)
        if ru != rv:
            parent[max(ru, rv)] = min(ru, rv)
    return np.array([find(v) for v in range(g.num_vertices)])


def sssp_baseline(g: Graph, weights: np.ndarray, source: int) -> np.ndarray:
    """(V,) Bellman-Ford distances for the given out-CSR-aligned weights."""
    n = g.num_vertices
    INF = np.int64(2**31 - 1)
    dist = np.full(n, INF)
    dist[source] = 0
    for _ in range(n):
        du = dist[g.edge_src]
        cand = np.where(du == INF, INF, du + weights)
        new = dist.copy()
        np.minimum.at(new, g.indices, cand)
        if np.array_equal(new, dist):
            break
        dist = new
    return dist


def bc_baseline(g: Graph, sources) -> np.ndarray:
    """(V,) Brandes betweenness aggregated over ``sources`` (unweighted)."""
    n = g.num_vertices
    total = np.zeros(n)
    for s in sources:
        depth = bfs_levels(g, s)
        sigma = np.zeros(n)
        sigma[s] = 1.0
        maxl = depth.max()
        src, dst = g.edge_src, g.indices
        tree = (depth[dst] == depth[src] + 1) & (depth[src] >= 0)
        for lvl in range(maxl):
            m = tree & (depth[src] == lvl)
            np.add.at(sigma, dst[m], sigma[src[m]])
        delta = np.zeros(n)
        for lvl in range(maxl - 1, -1, -1):
            m = tree & (depth[src] == lvl)
            contrib = sigma[src[m]] / np.maximum(sigma[dst[m]], 1e-30) \
                * (1.0 + delta[dst[m]])
            np.add.at(delta, src[m], contrib)
        delta[s] = 0.0
        total += delta
    return total


def knn_search_baseline(g: Graph, vectors: np.ndarray, query: np.ndarray,
                        entry: int, beam_width: int = 32, k_return: int = 10,
                        max_steps: int | None = None
                        ) -> tuple[np.ndarray, np.ndarray]:
    """Host beam search mirroring algos.kernels.knn_search in original-id
    space: same composite (float32-distance-bits, id) ranking keys, same
    bounded beam-and-merge, same visited accounting. Returns
    ``(ids (k_return,) int64 with -1 padding, visited (V,) bool)``.

    Distances are float32 like the kernel's; summation *order* may differ
    from XLA's, so exact key parity holds when coordinates are
    integer-valued (exact float32 sums) and is recall-level otherwise.
    """
    vecs = np.asarray(vectors, np.float32)
    q = np.asarray(query, np.float32)
    if max_steps is None:
        max_steps = 2 * beam_width + 32  # search.serve.default_max_steps

    def key(v):
        d = np.float32(((vecs[v] - q) ** 2).sum(dtype=np.float32))
        return (int(d.view(np.int32)), int(v))  # lexicographic, like jnp

    beam = [(key(entry), int(entry), False)]
    visited = np.zeros(g.num_vertices, dtype=bool)
    visited[entry] = True
    for _ in range(max_steps):
        frontier = [(k, v) for k, v, e in beam if not e]
        if not frontier:
            break
        _, best = min(frontier)
        beam = [(k, v, e or v == best) for k, v, e in beam]
        for w in map(int, g.neighbors(best)):
            if visited[w]:
                continue
            visited[w] = True
            beam.append((key(w), w, False))
        beam.sort(key=lambda t: t[0])
        del beam[beam_width:]
    ids = np.full(k_return, -1, dtype=np.int64)
    for i, (_, v, _) in enumerate(beam[:k_return]):
        ids[i] = v
    return ids, visited


# ---------------------------------------------------------------- registry
def reordering_registry() -> dict:
    """name -> callable(graph, **kw) for the benchmark harness."""
    from .lorder import lorder, lorder_v2
    return {
        "original": identity_order,
        "random": random_order,
        "sort": sort_order,
        "hubsort": hubsort_order,
        "hubcluster": hubcluster_order,
        "dbg": dbg_order,
        "sorder": sorder_order,
        "norder": norder_order,
        "gorder": gorder_order,
        "lorder": lorder,
        "lorder-v2": lorder_v2,
    }
