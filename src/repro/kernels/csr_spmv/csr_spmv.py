"""Pallas TPU kernel: pull-mode CSR SpMV / PageRank gather-reduce.

The paper's hot loop is ``y[v] = Σ_{u→v} x[u]`` over the in-CSR edge array —
random reads of the vertex-property array ``x``. TPU adaptation (DESIGN.md
§3): after LOrder, hot vertices occupy a low-id prefix, so the property
array's hot working set is a *contiguous slab*. The kernel keeps the whole
property vector VMEM-resident (graph property arrays are O(MB)) and tiles
the *edge* stream: edges are pre-sorted by destination (in-CSR order) and
padded so each edge block lands in exactly one destination tile, letting
each grid step accumulate into a single output tile.

Grid: ``(num_dst_tiles, blocks_per_tile)`` — the second dimension walks the
edge blocks of one destination tile and accumulates in-place (output
revisiting), initializing at block 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DST_TILE = 512      # output rows per tile (8-sublane aligned x f32)
EDGE_BLOCK = 2048   # edge-stream block (lane aligned)


def _kernel(src_ref, dstloc_ref, val_ref, x_ref, y_ref):
    """One edge block -> accumulate into one destination tile."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    src = src_ref[...]        # (EDGE_BLOCK,) int32 global src ids
    dst = dstloc_ref[...]     # (EDGE_BLOCK,) int32 dst ids local to tile
    val = val_ref[...]        # (EDGE_BLOCK,) f32 edge weight (0 for padding)
    gathered = jnp.take(x_ref[...], src, axis=0) * val
    y_ref[...] += jax.ops.segment_sum(gathered, dst, num_segments=DST_TILE)


def pack_edges(t_indptr: np.ndarray, t_indices: np.ndarray,
               weights: np.ndarray | None = None,
               dst_tile: int = DST_TILE, edge_block: int = EDGE_BLOCK):
    """Host-side packing of the in-CSR edge stream into tile-aligned blocks.

    Returns (src, dst_local, val, blocks_per_tile, num_tiles, n_pad) with
    src/dst/val shaped (num_tiles * blocks_per_tile * edge_block,).
    """
    n = len(t_indptr) - 1
    num_tiles = -(-n // dst_tile)
    n_pad = num_tiles * dst_tile
    dst = np.repeat(np.arange(n, dtype=np.int64), np.diff(t_indptr))
    src = np.asarray(t_indices, dtype=np.int32)
    val = (np.ones(len(src), np.float32) if weights is None
           else np.asarray(weights, np.float32))
    tile_of = dst // dst_tile
    counts = np.bincount(tile_of, minlength=num_tiles)
    bpt = max(1, int(-(-counts.max() // edge_block)))
    cap = bpt * edge_block
    S = np.zeros((num_tiles, cap), np.int32)
    D = np.zeros((num_tiles, cap), np.int32)
    V = np.zeros((num_tiles, cap), np.float32)
    off = 0
    for t in range(num_tiles):
        c = int(counts[t])
        S[t, :c] = src[off:off + c]
        D[t, :c] = (dst[off:off + c] - t * dst_tile).astype(np.int32)
        V[t, :c] = val[off:off + c]
        off += c
    return (S.reshape(-1), D.reshape(-1), V.reshape(-1), bpt, num_tiles, n_pad)


@functools.partial(jax.jit, static_argnames=("blocks_per_tile", "num_tiles",
                                             "n_pad", "interpret"))
def csr_spmv_pallas(src, dst_local, val, x, *, blocks_per_tile: int,
                    num_tiles: int, n_pad: int, interpret: bool = True):
    """y = A^T-gather-reduce(x) with A in packed edge-block form."""
    x_pad = jnp.zeros((n_pad,), x.dtype).at[: x.shape[0]].set(x)
    eb = EDGE_BLOCK
    grid = (num_tiles, blocks_per_tile)

    def edge_map(i, j):
        return (i * blocks_per_tile + j,)

    y = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((eb,), edge_map),            # src ids
            pl.BlockSpec((eb,), edge_map),            # dst local
            pl.BlockSpec((eb,), edge_map),            # edge values
            pl.BlockSpec((n_pad,), lambda i, j: (0,)),  # x resident
        ],
        out_specs=pl.BlockSpec((DST_TILE,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), x.dtype),
        interpret=interpret,
    )(src.reshape(num_tiles * blocks_per_tile, eb).reshape(-1),
      dst_local.reshape(-1), val.reshape(-1), x_pad)
    return y[: x.shape[0]] if x.shape[0] != n_pad else y
