"""The paper's technique as first-class LM-framework features.

* ``vocab``   — LOrder over token co-occurrence graphs → embedding layout;
* ``moe``     — routing-locality analysis + expert-affinity placement.

Applicability per assigned architecture is recorded in DESIGN.md §4;
``applies_to`` is the programmatic form used by drivers and tests.
"""
from __future__ import annotations

from ..models.config import ModelConfig


def applies_to(cfg: ModelConfig) -> dict:
    """Which locality features the paper's technique provides for ``cfg``."""
    return {
        "vocab_reorder": cfg.vocab_reorder and cfg.input_mode == "tokens",
        "hot_embed": cfg.hot_vocab_fraction > 0,
        "moe_locality_sort": cfg.is_moe and cfg.moe_locality_sort,
        "inapplicable": (not cfg.vocab_reorder) and not cfg.is_moe,
    }
