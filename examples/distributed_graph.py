"""Cluster-scale graph analytics — the paper's workload on a device mesh.

Runs edge-partitioned PageRank via shard_map on every local device (on
this container: 8 XLA host-platform devices), shows that LOrder
concentrates the *useful* share of the all-gather payload into a hot
prefix — the cluster-level analogue of the paper's cache-line locality —
and validates against the single-device kernel.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/distributed_graph.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8")

import jax
import numpy as np


def hot_prefix_payload(g, perm, num_shards: int, prefix_frac: float = 0.1):
    """Share of cross-shard property reads served by the hottest
    ``prefix_frac`` of vertex ids (what a prefix-cached all-gather saves)."""
    gp = g.apply_permutation(perm) if perm is not None else g
    reads = gp.transpose.indices            # property reads, pull mode
    n = gp.num_vertices
    per = -(-n // num_shards)
    dst = gp.transpose.edge_src
    cross = (reads // per) != (dst // per)  # read crosses a shard boundary
    hot = reads < int(n * prefix_frac)
    return float((cross & hot).sum() / max(cross.sum(), 1))


def main():
    from repro.algos.graph_arrays import to_device
    from repro.algos.kernels import pagerank
    from repro.core.dist import make_distributed_pagerank
    from repro.core.generators import powerlaw_community
    from repro.core.lorder import lorder

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",))
    print(f"[mesh] {n_dev} devices on axis 'data'")

    g = powerlaw_community(40_000, avg_degree=12, seed=13)
    print(f"[graph] V={g.num_vertices:,} E={g.num_edges:,}")

    print("[lorder] reordering...")
    perm = np.asarray(lorder(g))
    gp = g.apply_permutation(perm)

    for name, graph, p in (("original", g, None), ("lorder", gp, perm)):
        share = hot_prefix_payload(g, p, n_dev)
        print(f"   {name:9s}: hottest 10% of ids serve "
              f"{100 * share:.1f}% of cross-shard property reads")

    print("[dist-pr] running edge-partitioned PageRank on the mesh...")
    run, _ = make_distributed_pagerank(gp, mesh, axis="data", num_iters=20)
    r_dist = np.asarray(run())
    r_single = np.asarray(pagerank(to_device(gp), num_iters=20))
    err = np.abs(r_dist - r_single).max()
    print(f"[dist-pr] max |dist - single| = {err:.2e} "
          f"({'OK' if err < 1e-5 else 'MISMATCH'})")


if __name__ == "__main__":
    main()
