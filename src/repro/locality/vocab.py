"""Vocabulary reordering — the paper's LOrder run on a token co-occurrence
graph (DESIGN.md §3.3).

Token frequencies are Zipf-distributed (the power law the paper exploits)
and co-occurrence is community-structured (topics). We build a directed
co-occurrence graph from a corpus sample — vertex = token id, edge u→v for
each adjacent pair (u, v) within a window — and run *the actual LOrder
algorithm* on it. The resulting permutation maps hot tokens to a
contiguous low-id slab:

* embedding table + output head rows are permuted once at init;
* the data pipeline maps token ids through the permutation on the host;
* the ``hot_embed`` kernel pins rows [0, hot_size) in VMEM.

`vocab_permutation` is exact LOrder; `degree_permutation` is the
DBG-style lightweight fallback (frequency binning) used when no corpus
sample is available at init time.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.csr import Graph, from_edges, validate_permutation
from ..core.lorder import lorder
from ..core.baselines import dbg_order


@dataclasses.dataclass
class VocabReorder:
    """perm[old_token_id] = new_token_id, plus diagnostics."""
    perm: np.ndarray
    inverse: np.ndarray
    hot_size: int
    scheme: str

    def map_tokens(self, tokens: np.ndarray) -> np.ndarray:
        return self.perm[tokens]

    def unmap_tokens(self, tokens: np.ndarray) -> np.ndarray:
        return self.inverse[tokens]

    def apply_to_params(self, params: dict) -> dict:
        """Permute embedding table (and untied head) rows in-place-ish."""
        import jax.numpy as jnp
        emb = dict(params["embed"])
        inv = jnp.asarray(self.inverse)
        emb["table"] = jnp.take(params["embed"]["table"], inv, axis=0)
        if "head" in emb:
            emb["head"] = jnp.take(params["embed"]["head"], inv, axis=1)
        return dict(params, embed=emb)


def cooccurrence_graph(corpus: np.ndarray, vocab_size: int,
                       window: int = 1, max_pairs: int = 4_000_000) -> Graph:
    """Directed co-occurrence multigraph from a flat token stream."""
    toks = np.asarray(corpus, dtype=np.int64).reshape(-1)
    srcs, dsts = [], []
    budget = max_pairs
    for off in range(1, window + 1):
        s, d = toks[:-off], toks[off:]
        if len(s) > budget:
            s, d = s[:budget], d[:budget]
        srcs.append(s)
        dsts.append(d)
        budget -= len(s)
        if budget <= 0:
            break
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    return from_edges(vocab_size, src, dst, name="vocab-cooc")


def vocab_permutation(corpus: np.ndarray, vocab_size: int,
                      kappa: int = 2, hot_fraction: float = 0.05,
                      window: int = 1) -> VocabReorder:
    """LOrder over the co-occurrence graph. κ defaults to 2: co-occurrence
    graphs are near-small-world (D ≈ 4-6 through hub tokens), so the
    paper's κ = D/2 rule lands at ~2."""
    g = cooccurrence_graph(corpus, vocab_size, window)
    perm = np.asarray(lorder(g, kappa=kappa), dtype=np.int64)
    assert validate_permutation(perm, vocab_size)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(vocab_size)
    hot = max(1, int(vocab_size * hot_fraction))
    return VocabReorder(perm, inv, hot, scheme="lorder")


def degree_permutation(token_counts: np.ndarray,
                       hot_fraction: float = 0.05) -> VocabReorder:
    """Frequency-sort fallback (DBG-flavoured; no graph needed)."""
    n = len(token_counts)
    order = np.argsort(-np.asarray(token_counts, dtype=np.int64),
                       kind="stable")
    perm = np.empty(n, dtype=np.int64)
    perm[order] = np.arange(n)
    inv = order.astype(np.int64)
    hot = max(1, int(n * hot_fraction))
    return VocabReorder(perm, inv, hot, scheme="frequency")


def hot_coverage(corpus: np.ndarray, reorder: VocabReorder) -> float:
    """Fraction of corpus tokens served by the hot slab after reordering —
    the metric the hot_embed kernel's win is proportional to."""
    mapped = reorder.map_tokens(np.asarray(corpus).reshape(-1))
    return float((mapped < reorder.hot_size).mean())
