"""The six GAP-style graph kernels in pure JAX (paper §5.1).

Each kernel is edge-parallel (COO segment ops) with `lax.while_loop`
outer iteration — the JAX-native rendering of the level-synchronous /
iterative structure the paper's C++ GAPS kernels use. All are `jit`-able;
vertex property arrays are the reuse-heavy state the paper reorders for.

Bucket padding: when a `GraphArrays` carries ``vertex_valid`` /
``edge_valid`` masks (shape-bucketed uploads, see engine/backends.py),
every kernel excludes sentinel edges and padded vertices, so results on
the real ``[:V]`` prefix are exactly the unpadded results. The masks are
``None`` for unpadded uploads and the branches below are resolved at
trace time, so unbucketed serving lowers to the identical XLA program as
before.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .graph_arrays import GraphArrays

INF_I32 = jnp.int32(2**31 - 1)


def _seg_sum(vals, segs, n):
    return jax.ops.segment_sum(vals, segs, num_segments=n)


def _seg_max(vals, segs, n):
    return jax.ops.segment_max(vals, segs, num_segments=n)


def _seg_min(vals, segs, n):
    return jax.ops.segment_min(vals, segs, num_segments=n)


# ---------------------------------------------------------------------- BFS
@jax.jit
def bfs(g: GraphArrays, source: jnp.ndarray) -> jnp.ndarray:
    """Level-synchronous BFS (push). Returns depth (V,), -1 unreached."""
    n = g.num_vertices
    depth0 = jnp.full((n,), -1, jnp.int32).at[source].set(0)
    front0 = jnp.zeros((n,), jnp.bool_).at[source].set(True)

    def cond(state):
        _, front, _ = state
        return front.any()

    def body(state):
        depth, front, level = state
        # gather(prop, src) over the edge array: the hot access the paper
        # optimizes — property reads follow g.indices / g.src layout.
        active = front[g.src]
        if g.edge_valid is not None:
            active &= g.edge_valid
        touched = _seg_max(active, g.indices, n)
        new = touched & (depth < 0)
        depth = jnp.where(new, level + 1, depth)
        return depth, new, level + 1

    depth, _, _ = lax.while_loop(cond, body, (depth0, front0, jnp.int32(0)))
    return depth


# ----------------------------------------------------------------- PageRank
def pagerank(g: GraphArrays, num_iters: int = 20, damping: float = 0.85,
             tol: float = 1e-6) -> jnp.ndarray:
    return _pagerank(g, num_iters, damping, tol)


@jax.jit
def _pagerank(g: GraphArrays, num_iters, damping, tol):
    """Pull-mode PR: r[v] = (1-d)/N + d * Σ_{u→v} r[u]/outdeg[u].

    With bucket masks, N is the count of *real* vertices and all rank mass
    (base, dangling redistribution, the rank vector itself) stays on real
    vertices; padded vertices hold rank 0 throughout, so the real prefix
    matches the unpadded run.
    """
    n = g.num_vertices
    valid = g.vertex_valid
    if valid is None:
        n_real = jnp.float32(n)
        dangling_mask = g.out_degree == 0
    else:
        n_real = valid.sum().astype(jnp.float32)
        dangling_mask = (g.out_degree == 0) & valid
    base = (1.0 - damping) / n_real
    outdeg = jnp.maximum(g.out_degree, 1).astype(jnp.float32)

    def body(state):
        r, _, it = state
        contrib = r / outdeg
        # pull over in-CSR: gather(contrib, t_indices) is the reuse-heavy read
        summed = _seg_sum(contrib[g.t_indices], g.t_dst, n)
        # dangling mass redistributed uniformly (GAP semantics)
        dangling = jnp.where(dangling_mask, r, 0.0).sum()
        r_new = base + damping * (summed + dangling / n_real)
        if valid is not None:
            r_new = jnp.where(valid, r_new, 0.0)
        err = jnp.abs(r_new - r).sum()
        return r_new, err, it + 1

    def cond(state):
        _, err, it = state
        return (it < num_iters) & (err > tol)

    r0 = jnp.ones((n,), jnp.float32) / n_real
    if valid is not None:
        r0 = jnp.where(valid, r0, 0.0)
    r, _, _ = lax.while_loop(cond, body, (r0, jnp.float32(jnp.inf), jnp.int32(0)))
    return r


# --------------------------------------------------- PageRank via Pallas SpMV
def pagerank_spmv(g: GraphArrays, spmv_src: jnp.ndarray,
                  spmv_dst: jnp.ndarray, spmv_val: jnp.ndarray,
                  num_iters: int = 20, damping: float = 0.85,
                  tol: float = 1e-6, *, blocks_per_tile: int,
                  num_tiles: int, n_pad: int,
                  interpret: bool = True) -> jnp.ndarray:
    """`_pagerank` with the pull relaxation routed through the Pallas
    CSR-SpMV kernel (kernels/csr_spmv) inside the same ``while_loop``.

    ``spmv_src``/``spmv_dst``/``spmv_val`` are the graph's in-CSR edge
    stream pre-packed by `kernels.csr_spmv.pack_edges` into dst-tiled
    blocks — after LOrder the hot-prefix rows land in the first tiles and
    the VMEM-resident property vector's hot slab stays resident across
    the edge stream. Sentinel edges of bucketed uploads carry
    ``spmv_val == 0`` so they contribute nothing; the remaining mask
    handling is identical to `_pagerank`, and results agree with it to
    float tolerance (the tile-blocked summation order differs).

    Not jitted here: the engine wraps it per pack shape
    (``blocks_per_tile``/``num_tiles``/``n_pad`` are static arguments of
    the pallas_call), so its compile-cache keys stay pack-aware.
    """
    from ..kernels.csr_spmv.csr_spmv import csr_spmv_pallas

    n = g.num_vertices
    valid = g.vertex_valid
    if valid is None:
        n_real = jnp.float32(n)
        dangling_mask = g.out_degree == 0
    else:
        n_real = valid.sum().astype(jnp.float32)
        dangling_mask = (g.out_degree == 0) & valid
    base = (1.0 - damping) / n_real
    outdeg = jnp.maximum(g.out_degree, 1).astype(jnp.float32)

    def body(state):
        r, _, it = state
        contrib = r / outdeg
        summed = csr_spmv_pallas(
            spmv_src, spmv_dst, spmv_val, contrib,
            blocks_per_tile=blocks_per_tile, num_tiles=num_tiles,
            n_pad=n_pad, interpret=interpret)
        dangling = jnp.where(dangling_mask, r, 0.0).sum()
        r_new = base + damping * (summed + dangling / n_real)
        if valid is not None:
            r_new = jnp.where(valid, r_new, 0.0)
        err = jnp.abs(r_new - r).sum()
        return r_new, err, it + 1

    def cond(state):
        _, err, it = state
        return (it < num_iters) & (err > tol)

    r0 = jnp.ones((n,), jnp.float32) / n_real
    if valid is not None:
        r0 = jnp.where(valid, r0, 0.0)
    r, _, _ = lax.while_loop(cond, body,
                             (r0, jnp.float32(jnp.inf), jnp.int32(0)))
    return r


# ------------------------------------------------- Connected Components (LP)
@jax.jit
def cc_labelprop(g: GraphArrays) -> jnp.ndarray:
    """CC by iterative min-label propagation over the symmetrized edges."""
    n = g.num_vertices

    def body(state):
        lab, _ = state
        lab_src, lab_dst = lab[g.src], lab[g.indices]
        if g.edge_valid is not None:
            lab_src = jnp.where(g.edge_valid, lab_src, INF_I32)
            lab_dst = jnp.where(g.edge_valid, lab_dst, INF_I32)
        m1 = _seg_min(lab_src, g.indices, n)
        m2 = _seg_min(lab_dst, g.src, n)
        new = jnp.minimum(lab, jnp.minimum(m1, m2))
        return new, (new != lab).any()

    def cond(state):
        return state[1]

    lab0 = jnp.arange(n, dtype=jnp.int32)
    lab, _ = lax.while_loop(cond, body, (lab0, jnp.bool_(True)))
    return lab


# ------------------------------------------- Connected Components (CC-SV)
@jax.jit
def cc_shiloach_vishkin(g: GraphArrays) -> jnp.ndarray:
    """Shiloach-Vishkin: alternating hook + pointer-jumping (paper's CC_SV)."""
    n = g.num_vertices

    def body(state):
        parent, _ = state
        pu = parent[g.src]
        pv = parent[g.indices]
        # hook: root(pu) adopts smaller pv (and symmetrically)
        lo = jnp.minimum(pu, pv)
        hi = jnp.maximum(pu, pv)
        if g.edge_valid is not None:
            # sentinel edges hook nothing: min with INF is a no-op
            lo = jnp.where(g.edge_valid, lo, INF_I32)
            hi = jnp.where(g.edge_valid, hi, 0)
        parent1 = parent.at[hi].min(lo)
        # pointer jumping to full compression
        def jump(st):
            p, _ = st
            p2 = p[p]
            return p2, (p2 != p).any()
        parent2, _ = lax.while_loop(lambda st: st[1], jump,
                                    (parent1, jnp.bool_(True)))
        return parent2, (parent2 != parent).any()

    p0 = jnp.arange(n, dtype=jnp.int32)
    parent, _ = lax.while_loop(lambda st: st[1], body, (p0, jnp.bool_(True)))
    return parent


# -------------------------------------------------------- SSSP (Bellman-Ford)
@jax.jit
def sssp(g: GraphArrays, source: jnp.ndarray) -> jnp.ndarray:
    """Bellman-Ford with edge-parallel relaxation (paper's SSSP)."""
    n = g.num_vertices
    dist0 = jnp.full((n,), INF_I32).at[source].set(0)

    def body(state):
        dist, _, it = state
        du = dist[g.src]
        cand = jnp.where(du == INF_I32, INF_I32, du + g.weights)
        if g.edge_valid is not None:
            cand = jnp.where(g.edge_valid, cand, INF_I32)
        relaxed = _seg_min(cand, g.indices, n)
        new = jnp.minimum(dist, relaxed)
        return new, (new != dist).any(), it + 1

    def cond(state):
        _, changed, it = state
        return changed & (it < n)

    dist, _, _ = lax.while_loop(cond, body, (dist0, jnp.bool_(True), jnp.int32(0)))
    return dist


# -------------------------------------------- Betweenness Centrality (Brandes)
@jax.jit
def bc_single_source(g: GraphArrays, source: jnp.ndarray) -> jnp.ndarray:
    """Brandes dependency accumulation for one source (unweighted)."""
    n = g.num_vertices
    depth = bfs(g, source)
    max_level = depth.max()

    # forward: path counts sigma, level-synchronous over out-edges
    sigma0 = jnp.zeros((n,), jnp.float32).at[source].set(1.0)
    du = depth[g.src]
    dv = depth[g.indices]
    tree_edge = (dv == du + 1) & (du >= 0)
    if g.edge_valid is not None:
        tree_edge &= g.edge_valid

    def fwd(level, sigma):
        mask = tree_edge & (du == level)
        add = _seg_sum(jnp.where(mask, sigma[g.src], 0.0), g.indices, n)
        return sigma + add

    sigma = lax.fori_loop(0, max_level + 1, fwd, sigma0)

    # backward: delta[u] += sigma[u]/sigma[v] * (1 + delta[v]) along tree edges
    def bwd(i, delta):
        level = max_level - 1 - i
        mask = tree_edge & (du == level)
        sig_v = jnp.maximum(sigma[g.indices], 1e-30)
        contrib = jnp.where(mask, sigma[g.src] / sig_v * (1.0 + delta[g.indices]), 0.0)
        return delta + _seg_sum(contrib, g.src, n)

    delta = lax.fori_loop(0, jnp.maximum(max_level, 0), bwd,
                          jnp.zeros((n,), jnp.float32))
    return delta.at[source].set(0.0)


# ------------------------------------------------------- k-NN beam search
#
# The search-serving workload (ROADMAP item 4, Coleman et al.): greedy
# best-first traversal of a fixed out-degree k-NN graph with a bounded
# beam, one `lax.while_loop` per query in the PR 7 fused-loop style.
# Candidates are ranked by the lexicographic pair
#
#     (float32_dist_bits, canonical_id)
#
# squared-L2 distances are non-negative, so their float32 bit patterns
# are order-preserving as int32 — and the canonical (original) vertex id
# breaks every distance tie layout-invariantly. That single invariant is
# what buys bit-identical results across {exact, bucketed, sharded}
# backends and any reorder. (A packed ``bits << 31 | id`` int64 key would
# be one array instead of two, but x64 stays off repo-wide; `lexsort`
# over the pair is the same total order.) KNN_SENTINEL exceeds the bit
# pattern of any real distance (+inf is 0x7F800000), so empty beam slots
# and already-visited candidates sort strictly last.

KNN_SENTINEL = 2**31 - 1  # int32 max


def _dist_bits(dist: jnp.ndarray) -> jnp.ndarray:
    return lax.bitcast_convert_type(dist.astype(jnp.float32), jnp.int32)


def knn_search(g: GraphArrays, vectors: jnp.ndarray, canon: jnp.ndarray,
               entry: jnp.ndarray, query: jnp.ndarray, *, k_out: int,
               beam_width: int, k_return: int, max_steps: int
               ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One query -> ``(ids, visited)``: the ``k_return`` nearest served
    vertex ids found (-1 in empty slots) and the (V,) visited mask whose
    per-query sum is the visit-frequency telemetry the reorder policy
    consumes.

    ``vectors`` are in served order, ``canon`` maps served -> original
    id. Rows must hold exactly ``k_out`` distinct non-self neighbors
    (self-loop padding is inert: a row's owner is already visited when
    the row is expanded). Not module-jitted — the engine wraps it per
    (shape, params) so compile-cache keys stay static-arg-aware, like
    ``pagerank_spmv``.
    """
    n = g.num_vertices
    q = query.astype(jnp.float32)
    sent = jnp.int32(KNN_SENTINEL)

    def dists(ids):
        diff = vectors[ids] - q
        return jnp.sum(diff * diff, axis=-1)

    e = entry.astype(jnp.int32)
    bits0 = jnp.full((beam_width,), sent, jnp.int32)
    bits0 = bits0.at[0].set(_dist_bits(dists(e[None])[0]))
    tie0 = jnp.full((beam_width,), sent, jnp.int32)
    tie0 = tie0.at[0].set(canon[e])
    ids0 = jnp.zeros((beam_width,), jnp.int32).at[0].set(e)
    exp0 = jnp.zeros((beam_width,), jnp.bool_)
    visited0 = jnp.zeros((n,), jnp.bool_).at[e].set(True)

    def cond(state):
        bits, _, _, exp, _, step = state
        return (~exp & (bits < sent)).any() & (step < max_steps)

    def body(state):
        bits, tie, ids, exp, visited, step = state
        # nearest unexpanded slot under the (bits, tie) order: min bits
        # first, canonical id breaks distance ties (each vertex enters
        # the beam at most once, so ties are genuinely distinct vertices)
        masked_bits = jnp.where(exp, sent, bits)
        m = masked_bits.min()
        slot = jnp.argmin(jnp.where(exp | (bits != m), sent, tie))
        v = ids[slot]
        exp = exp.at[slot].set(True)
        nbrs = lax.dynamic_slice(g.indices, (g.indptr[v],), (k_out,))
        fresh = ~visited[nbrs]
        visited = visited.at[nbrs].set(True)
        # gather(vectors, nbrs): the reuse-heavy read the reorder packs
        nbits = jnp.where(fresh, _dist_bits(dists(nbrs)), sent)
        ntie = jnp.where(fresh, canon[nbrs], sent)
        all_bits = jnp.concatenate([bits, nbits])
        all_tie = jnp.concatenate([tie, ntie])
        all_ids = jnp.concatenate([ids, nbrs.astype(jnp.int32)])
        all_exp = jnp.concatenate(
            [exp, jnp.zeros((k_out,), jnp.bool_)])
        keep = jnp.lexsort((all_tie, all_bits))[:beam_width]
        return (all_bits[keep], all_tie[keep], all_ids[keep],
                all_exp[keep], visited, step + 1)

    bits, _, ids, _, visited, _ = lax.while_loop(
        cond, body, (bits0, tie0, ids0, exp0, visited0, jnp.int32(0)))
    # the beam is kept sorted by every merge, so the head is the result
    top = jnp.where(bits[:k_return] < sent, ids[:k_return], -1)
    return top, visited


def knn_search_multi(g: GraphArrays, vectors: jnp.ndarray,
                     canon: jnp.ndarray, entry: jnp.ndarray,
                     queries: jnp.ndarray, valid: jnp.ndarray, *,
                     k_out: int, beam_width: int, k_return: int,
                     max_steps: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Batched search: (S, d) queries -> ((S, k_return) served ids,
    (V,) int32 visit counts). ``valid`` masks padded query lanes out of
    the visit accounting (pad lanes repeat row 0 and would otherwise
    inflate the telemetry)."""
    ids, visited = jax.vmap(
        lambda qv: knn_search(g, vectors, canon, entry, qv, k_out=k_out,
                              beam_width=beam_width, k_return=k_return,
                              max_steps=max_steps))(queries)
    visits = (visited & valid[:, None]).sum(axis=0).astype(jnp.int32)
    return ids, visits


# ---------------------------------------------- batched multi-source variants
#
# The serving engine amortizes one compile over many concurrent queries:
# sources become a batch axis via `vmap`. The while/fori loops inside the
# single-source kernels batch cleanly — JAX's while_loop batching rule runs
# until every lane's predicate clears and select-freezes converged lanes.

@jax.jit
def bfs_multi(g: GraphArrays, sources: jnp.ndarray) -> jnp.ndarray:
    """Batched BFS: (S,) sources -> (S, V) depth rows, -1 unreached."""
    return jax.vmap(bfs, in_axes=(None, 0))(g, sources)


@jax.jit
def sssp_multi(g: GraphArrays, sources: jnp.ndarray) -> jnp.ndarray:
    """Batched Bellman-Ford: (S,) sources -> (S, V) distance rows."""
    return jax.vmap(sssp, in_axes=(None, 0))(g, sources)


@jax.jit
def bc_multi(g: GraphArrays, sources: jnp.ndarray) -> jnp.ndarray:
    """Batched Brandes: (S,) sources -> (S, V) per-source dependencies."""
    return jax.vmap(bc_single_source, in_axes=(None, 0))(g, sources)


@jax.jit
def bc_weighted(g: GraphArrays, sources: jnp.ndarray,
                weights: jnp.ndarray) -> jnp.ndarray:
    """BC aggregate with per-source weights (0-weight lanes = padding)."""
    deltas = bc_multi(g, sources)
    return (deltas * weights[:, None]).sum(axis=0)


def bc(g: GraphArrays, sources, chunk: int = 16) -> jnp.ndarray:
    """BC over a source sample (GAP uses sampled sources for large graphs).

    Batched over sources via `vmap` (one fused device launch per chunk)
    instead of the former per-source Python loop. Chunking caps peak
    memory at ``chunk × V`` floats — the unchunked (S, V) dependency
    matrix would not fit for large V × many sampled sources. Numerically
    this only reorders the final float32 accumulation.
    """
    srcs = jnp.atleast_1d(jnp.asarray(sources, jnp.int32))
    out = jnp.zeros((g.num_vertices,), jnp.float32)
    for i in range(0, srcs.shape[0], chunk):
        out = out + bc_multi(g, srcs[i:i + chunk]).sum(axis=0)
    return out


KERNELS = {
    "bfs": lambda g, src=0: bfs(g, jnp.int32(src)),
    "pr": lambda g: pagerank(g),
    "cc": lambda g: cc_labelprop(g),
    "ccsv": lambda g: cc_shiloach_vishkin(g),
    "sssp": lambda g, src=0: sssp(g, jnp.int32(src)),
    "bc": lambda g, sources=(0, 1, 2, 3): bc(g, sources),
}
