"""mixtral-8x7b [moe]: 32L d4096 32H (GQA kv=8) ff14336 v32000 — 8 experts
top-2, sliding-window attention. [arXiv:2401.04088; hf]

Strongest fit for the paper's technique: token→expert routing is the
skewed bipartite access graph; locality-sorted dispatch is LOrder's
hot-first grouping (DESIGN.md §3.2)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=32000,
    window=4096,                       # SWA — makes long_500k decodable
    rope_theta=1e6,
    num_experts=8, experts_per_token=2,
    mlp_type="swiglu", norm_type="rmsnorm",
    vocab_reorder=True, hot_vocab_fraction=0.1,
    moe_locality_sort=True,
)
