"""minicpm-2b [dense]: 40L d2304 36H (MHA) ff5760 v122753 — llama-like with
mup-style scaling knobs and the WSD schedule. [arXiv:2404.06395; hf]"""
from ..models.config import ModelConfig

_DIM_BASE = 256  # minicpm dim_model_base

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36,
    d_ff=5760, vocab_size=122_753,
    mlp_type="swiglu", norm_type="rmsnorm",
    tie_embeddings=True,
    emb_scale=12.0,                          # scale_emb
    logit_scale=_DIM_BASE / 2304,            # 1 / (d / dim_model_base)
    residual_scale=1.4 / 40 ** 0.5,          # scale_depth / sqrt(L)
    vocab_reorder=True, hot_vocab_fraction=0.05,
)

# WSD (warmup-stable-decay) is minicpm's training schedule; selected via
# TrainConfig.schedule="wsd" in train/optim.py.
SCHEDULE = "wsd"
