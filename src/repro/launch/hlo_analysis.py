"""While-loop-aware cost accounting over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body **once**,
so scan-over-layers programs (and scan-over-time RWKV, chunked-loss scans)
under-report FLOPs, bytes and collective payloads by the trip count.
This module re-derives the three roofline terms from the HLO text itself:

* ``dot`` FLOPs = 2 · |output| · |contracted dims|  (from shapes + attrs);
* HBM bytes     = Σ over top-level instructions of operand+output bytes
  for memory-moving ops (fusions are the HBM-traffic unit on TPU; pure
  reshapes/bitcasts/tuples are free);
* collective bytes = output payloads of all-reduce / all-gather /
  reduce-scatter / all-to-all / collective-permute;

with every ``while`` body multiplied by its trip count (parsed from the
condition computation's loop bound; nested whiles multiply). Validated
against cost_analysis on unrolled control programs in tests.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*->", re.M)
_INSTR = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\(.*?\))|(?:[a-z0-9]+\[[0-9,]*\][^\s]*))\s+"
    r"([a-z0-9\-]+)\((.*)$")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
# Whitelist of ops whose operands+outputs are real HBM traffic. On TPU,
# elementwise chains fuse into their producers; the host-CPU HLO we lower
# leaves them standalone, so counting every op would overstate the memory
# term several-fold. Fusions are the traffic unit; dot/gather/scatter/DUS
# appear unfused and move their operands; everything else is treated as
# fused-away (a *lower*-bound bias that offsets the CPU-HLO inflation).
_MEM_OPS = {"fusion", "dot", "gather", "scatter", "dynamic-slice",
            "dynamic-update-slice", "convolution", "sort", "copy",
            "concatenate", "reduce", "reduce-window", "select-and-scatter"}


def _shape_elems(dt: str, dims: str) -> tuple[int, int]:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n, n * _DTYPE_BYTES.get(dt, 0)


def _type_bytes(type_str: str) -> int:
    return sum(_shape_elems(dt, dims)[1]
               for dt, dims in _SHAPE_RE.findall(type_str))


def _type_elems(type_str: str) -> int:
    return sum(_shape_elems(dt, dims)[0]
               for dt, dims in _SHAPE_RE.findall(type_str))


@dataclasses.dataclass
class CompCost:
    dot_flops: float = 0.0
    mem_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=dict)
    calls: list = dataclasses.field(default_factory=list)  # (kind, comp, extra)


def _split_computations(hlo: str) -> dict[str, list[str]]:
    """Header = top-level line ending in '{' containing '->' (params may be
    nested tuples, so we only trust the name token before the first '(')."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        is_header = (line and not line[0].isspace() and
                     stripped.endswith("{") and "->" in stripped and
                     "(" in stripped)
        if is_header:
            head = stripped.split("(", 1)[0].strip()
            head = head.replace("ENTRY", "").strip().lstrip("%")
            if head:
                cur = head
                comps[cur] = []
            continue
        if stripped == "}":
            cur = None
        elif cur is not None:
            comps[cur].append(line)
    return comps


def _dot_flops(result_type: str, line: str, types: dict[str, str]) -> float:
    out_elems = _type_elems(result_type)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    ops = re.findall(r"%([\w\.\-]+)", line.split("(", 1)[1])
    if not ops:
        return 0.0
    lhs_type = types.get(ops[0], "")
    shapes = _SHAPE_RE.findall(lhs_type)
    if not shapes:
        return 0.0
    dims = [int(d) for d in shapes[0][1].split(",") if d]
    contract = 1
    if m and m.group(1):
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(dims):
                contract *= dims[i]
    return 2.0 * out_elems * contract


def _operand_names(line: str) -> list[str]:
    args = line.split("(", 1)[1]
    args = args.split("),", 1)[0]
    return re.findall(r"%([\w\.\-]+)", args)


def _instr_operand_bytes(line: str, types: dict[str, str]) -> int:
    return sum(_type_bytes(types.get(op, ""))
               for op in _operand_names(line))


_TRIP_RE = [
    re.compile(r"compare\(.*\)\s*,\s*direction=LT"),
]


def _trip_count(cond_lines: list[str]) -> int:
    """Loop bound from the condition computation (max int constant)."""
    best = 1
    for line in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


def analyse_hlo(hlo: str) -> dict:
    comps = _split_computations(hlo)
    types_per_comp: dict[str, dict[str, str]] = {}
    costs: dict[str, CompCost] = {}

    # pre-pass: fusions whose root is a dynamic-update-slice write only the
    # update region (the output buffer is aliased) — e.g. the scan-carry
    # stacking fusion, which would otherwise charge the full 36-layer stack
    # per layer iteration
    dus_roots: set[str] = set()
    ds_comps: set[str] = set()
    for cname, lines in comps.items():
        for line in lines:
            s = line.strip()
            # any DUS inside the fusion ⇒ its big buffer is aliased
            # in-place (scan-carry / remat-stack update); root may be a
            # tuple for multi-output fusions
            if " dynamic-update-slice(" in s:
                dus_roots.add(cname)
            if " dynamic-slice(" in s:
                ds_comps.add(cname)

    # first pass: per-computation direct costs + call edges
    for cname, lines in comps.items():
        types: dict[str, str] = {}
        # parameters: declared inline in body as %name = TYPE parameter(i)
        cost = CompCost()
        for line in lines:
            m = _INSTR.match(line)
            if not m:
                continue
            name, rtype, op, rest = m.groups()
            types[name] = rtype
            if op == "while":
                cm = re.search(r"condition=%?([\w\.\-]+)", line)
                bm = re.search(r"body=%?([\w\.\-]+)", line)
                # XLA records its analyzed loop bound on the instruction
                tm_ = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', line)
                trip = int(tm_.group(1)) if tm_ else None
                if cm and bm:
                    cost.calls.append(("while", bm.group(1),
                                       (cm.group(1), trip)))
                continue
            if op in ("call", "fusion", "conditional", "custom-call"):
                for target in re.findall(
                        r"(?:to_apply|calls|branch_computations=\{)[=%]*%?([\w\.\-]+)",
                        line):
                    cost.calls.append(("call", target, None))
            if op == "dot":
                cost.dot_flops += _dot_flops(rtype, line, types)
            if op in COLLECTIVES or (op.endswith("-start")
                                     and op[:-6] in COLLECTIVES):
                kind = op[:-6] if op.endswith("-start") else op
                b = _type_bytes(rtype)
                cost.coll_bytes += b
                d = cost.coll_by_kind.setdefault(kind, {"count": 0, "bytes": 0})
                d["count"] += 1
                d["bytes"] += b
            if op in _MEM_OPS and not op.endswith("-done"):
                if op in ("gather", "dynamic-slice"):
                    # reads only the gathered/sliced slab, writes it once —
                    # counting the full operand would charge a scanned
                    # layer-stack 36× per step
                    cost.mem_bytes += 2 * _type_bytes(rtype)
                elif op in ("dynamic-update-slice", "scatter"):
                    # reads + writes the update region (buffer is aliased)
                    ops_ = _operand_names(line)
                    upd = types.get(ops_[1], "") if len(ops_) > 1 else rtype
                    cost.mem_bytes += 2 * _type_bytes(upd)
                elif op == "fusion":
                    tgt = re.search(r"calls=%?([\w\.\-]+)", line)
                    out_b = _type_bytes(rtype)
                    opb = [_type_bytes(types.get(o, ""))
                           for o in _operand_names(line)]
                    tname = tgt.group(1) if tgt else ""
                    if tname in dus_roots and opb:
                        # in-place carry update: traffic ≈ the non-carry
                        # operands read + written once (exclude the aliased
                        # full-buffer operand)
                        cost.mem_bytes += 2 * (sum(opb) - max(opb))
                    elif tname in ds_comps:
                        # fusion dynamic-slices its big operands (scan-input
                        # reads): each slice read is output-sized, not the
                        # full stacked buffer
                        cost.mem_bytes += out_b + sum(
                            min(b, max(out_b, 1)) for b in opb)
                    else:
                        cost.mem_bytes += out_b + sum(opb)
                else:
                    cost.mem_bytes += _type_bytes(rtype) \
                        + _instr_operand_bytes(line, types)
        types_per_comp[cname] = types
        costs[cname] = cost

    # fusion computations: their internals are NOT HBM traffic; the fusion
    # instruction's operands/outputs (counted above) are. So drop call
    # edges into fused computations for mem, but keep dot flops/collectives.
    memo: dict[str, tuple] = {}

    def total(cname: str, for_mem: bool) -> tuple:
        key = (cname, for_mem)
        if key in memo:
            return memo[key]
        memo[key] = (0.0, 0.0, 0.0, {})  # cycle guard
        c = costs.get(cname)
        if c is None:
            return 0.0, 0.0, 0.0, {}
        flops, mem, coll = c.dot_flops, c.mem_bytes, c.coll_bytes
        by_kind = {k: dict(v) for k, v in c.coll_by_kind.items()}
        for kind, target, cond in c.calls:
            mult = 1
            if kind == "while":
                cond_name, trip = cond if isinstance(cond, tuple) \
                    else (cond, None)
                if trip is not None:
                    mult = trip
                elif cond_name in comps:
                    mult = _trip_count(comps[cond_name])
            tf, tm, tc, tbk = total(target, for_mem)
            flops += mult * tf
            coll += mult * tc
            if kind == "while":
                mem += mult * tm
            # 'call'/fusion body mem excluded: fusion op already counted
            for k, v in tbk.items():
                d = by_kind.setdefault(k, {"count": 0, "bytes": 0})
                d["count"] += mult * v["count"]
                d["bytes"] += mult * v["bytes"]
        memo[key] = (flops, mem, coll, by_kind)
        return memo[key]

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY") and "(" in line:
            entry = line.split("(", 1)[0].replace(
                "ENTRY", "").strip().lstrip("%")
            break
    if entry is None or entry not in costs:
        entry = max(costs, key=lambda c: len(comps[c]))

    flops, mem, coll, by_kind = total(entry, True)
    return {
        "dot_flops": flops,
        "mem_bytes": mem,
        "collective_bytes": coll,
        "collectives_by_kind": by_kind,
        "entry": entry,
        "num_computations": len(comps),
    }
