"""Serving engine: probes, policy decisions, executor cache, session parity."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest

from repro.algos import kernels as K
from repro.algos.graph_arrays import to_device
from repro.core.generators import powerlaw_community, road_grid
from repro.engine import (BatchedExecutor, EngineSession, ReorderPolicy,
                          probe_graph)
from repro.engine.registry import degree_gini


# ----------------------------------------------------------------- probes
def test_degree_gini_bounds():
    assert degree_gini(np.full(100, 7)) == pytest.approx(0.0, abs=1e-9)
    extreme = np.zeros(1000, np.int64)
    extreme[0] = 10_000
    assert degree_gini(extreme) > 0.99
    assert degree_gini(np.empty(0, np.int64)) == 0.0


def test_probes_separate_regimes(plc_graph, grid_graph):
    p_skew = probe_graph(plc_graph)
    p_mesh = probe_graph(grid_graph)
    assert p_skew.degree_gini > 0.3 > p_mesh.degree_gini
    assert p_mesh.diameter > p_skew.diameter
    assert p_skew.num_vertices == plc_graph.num_vertices
    assert p_skew.num_edges == plc_graph.num_edges


# ----------------------------------------------------------------- policy
def test_policy_volume_gate(plc_graph):
    pol = ReorderPolicy()
    probes = probe_graph(plc_graph)
    d = pol.decide(probes, expected_queries=1)
    assert d.scheme == "original" and d.predicted_gain == 0.0


def test_policy_skew_gate(grid_graph):
    pol = ReorderPolicy()
    d = pol.decide(probe_graph(grid_graph), expected_queries=1000)
    assert d.scheme == "original"


def test_policy_tiers(plc_graph):
    pol = ReorderPolicy()
    probes = probe_graph(plc_graph)
    cheap = pol.decide(probes, expected_queries=8)
    rich = pol.decide(probes, expected_queries=500)
    assert cheap.scheme in ("hubcluster", "dbg")
    assert rich.scheme == "lorder"
    # kappa derives from the diameter probe: ceil(D/2)
    assert rich.kwargs["kappa"] == max(1, (probes.diameter + 1) // 2)
    assert rich.predicted_gain > cheap.predicted_gain > 0


def test_policy_record_tracks_realized_gain(plc_graph):
    pol = ReorderPolicy()
    d = pol.decide(probe_graph(plc_graph), expected_queries=500)
    rec = pol.record("g", d, miss_rate_before=0.5, miss_rate_after=0.3,
                     reorder_seconds=1.0)
    assert rec.realized_gain == pytest.approx(0.4)
    assert pol.history == [rec]


# --------------------------------------------------------------- executor
def test_executor_compile_cache_keys(plc_graph, grid_graph):
    ex = BatchedExecutor()
    ga1, ga2 = to_device(plc_graph), to_device(grid_graph)
    srcs = np.array([0, 1], np.int32)
    ex.run(ga1, "bfs", srcs)
    assert (ex.cache_hits, ex.cache_misses) == (0, 1)
    ex.run(ga1, "bfs", np.array([5], np.int32))
    assert (ex.cache_hits, ex.cache_misses) == (1, 1)
    ex.run(ga2, "bfs", srcs)  # different (V, E) -> new entry
    assert (ex.cache_hits, ex.cache_misses) == (1, 2)
    t = ex.telemetry()
    assert t["queries_run"] == 3 and t["sources_run"] == 5


def test_executor_ragged_batches_match_single(tiny_graph):
    ex = BatchedExecutor()
    ga = to_device(tiny_graph)
    for srcs in ([3], [0, 1, 2], list(range(7))):  # pads to 1 / 4 / 8
        out = np.asarray(ex.run(ga, "bfs", np.asarray(srcs)))
        assert out.shape == (len(srcs), tiny_graph.num_vertices)
        for i, s in enumerate(srcs):
            np.testing.assert_array_equal(
                out[i], np.asarray(K.bfs(ga, jnp.int32(s))))


def test_executor_global_kernels(plc_graph):
    ex = BatchedExecutor()
    ga = to_device(plc_graph)
    pr = ex.run(ga, "pr")
    np.testing.assert_allclose(np.asarray(pr), np.asarray(K.pagerank(ga)),
                               rtol=1e-5, atol=1e-8)
    with pytest.raises(ValueError):
        ex.run(ga, "nope")
    with pytest.raises(ValueError):
        ex.run(ga, "bfs", np.empty(0, np.int32))


# ---------------------------------------------------------------- session
@pytest.fixture(scope="module")
def served_session():
    session = EngineSession()
    g_pl = powerlaw_community(1500, avg_degree=10.0, seed=3, name="pl")
    g_mesh = road_grid(25, shortcuts=6, seed=5, name="mesh")
    session.register(g_pl, expected_queries=256)
    session.register(g_mesh, expected_queries=256)
    return session, g_pl, g_mesh


def test_session_policy_differs_by_structure(served_session):
    session, _, _ = served_session
    d_pl = session.registry.get("pl").decision
    d_mesh = session.registry.get("mesh").decision
    assert d_pl.scheme == "lorder" and d_mesh.scheme == "original"


def test_session_multi_source_parity(served_session):
    session, g_pl, g_mesh = served_session
    rng = np.random.default_rng(1)
    for gid, g in (("pl", g_pl), ("mesh", g_mesh)):
        srcs = rng.integers(0, g.num_vertices, size=3)
        ga = to_device(g)
        depth = session.submit(gid, "bfs", srcs)
        dist = session.submit(gid, "sssp", srcs)
        for i, s in enumerate(srcs):
            np.testing.assert_array_equal(
                depth[i], np.asarray(K.bfs(ga, jnp.int32(s))))
            np.testing.assert_array_equal(
                dist[i], np.asarray(K.sssp(ga, jnp.int32(s))))
        np.testing.assert_allclose(
            session.bc_aggregate(gid, srcs),
            np.asarray(K.bc(ga, srcs)), rtol=1e-4, atol=1e-4)


def test_session_global_kernel_parity(served_session):
    session, g_pl, _ = served_session
    got = session.submit("pl", "pr")
    want = np.asarray(K.pagerank(to_device(g_pl)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-8)


def test_session_telemetry_and_ledger(served_session):
    session, g_pl, _ = served_session
    session.submit("pl", "bfs", [0, 1, 2, 3])
    t = session.telemetry()
    assert t["executor"]["compile_cache_misses"] >= 1
    assert len(t["policy"]) == 2
    led = t["graphs"]["pl"]["ledger"]
    assert led["queries_served"] >= 1
    assert led["reorder_seconds"] > 0
    # reordered power-law graph should realize a miss-rate reduction
    rec = next(r for r in t["policy"] if r["graph_id"] == "pl")
    assert rec["realized_gain"] > 0
    assert 0 <= rec["predicted_gain"] <= 1


def test_session_duplicate_id_rejected(served_session):
    session, g_pl, _ = served_session
    with pytest.raises(KeyError):
        session.register(g_pl, graph_id="pl")


# ------------------------------------------------- batched kernel parity
def test_bc_batched_matches_python_loop(plc_graph):
    """The vmapped bc() must reproduce the former per-source loop."""
    ga = to_device(plc_graph)
    srcs = np.array([0, 11, 42, 77], np.int32)
    loop = jnp.zeros((ga.num_vertices,), jnp.float32)
    for s in srcs:
        loop = loop + K.bc_single_source(ga, jnp.int32(s))
    np.testing.assert_allclose(np.asarray(K.bc(ga, srcs)),
                               np.asarray(loop), rtol=1e-5, atol=1e-5)


def test_multi_source_kernels_match_single(tiny_graph):
    ga = to_device(tiny_graph)
    srcs = jnp.asarray(np.arange(tiny_graph.num_vertices), jnp.int32)
    bm = np.asarray(K.bfs_multi(ga, srcs))
    sm = np.asarray(K.sssp_multi(ga, srcs))
    cm = np.asarray(K.bc_multi(ga, srcs))
    for s in range(tiny_graph.num_vertices):
        np.testing.assert_array_equal(
            bm[s], np.asarray(K.bfs(ga, jnp.int32(s))))
        np.testing.assert_array_equal(
            sm[s], np.asarray(K.sssp(ga, jnp.int32(s))))
        np.testing.assert_allclose(
            cm[s], np.asarray(K.bc_single_source(ga, jnp.int32(s))),
            rtol=1e-5, atol=1e-5)


def test_bc_weighted_masks_padding(tiny_graph):
    ga = to_device(tiny_graph)
    srcs = jnp.asarray([0, 2, 2], jnp.int32)   # lane 2 is padding
    w = jnp.asarray([1.0, 1.0, 0.0])
    got = K.bc_weighted(ga, srcs, w)
    want = (K.bc_single_source(ga, jnp.int32(0))
            + K.bc_single_source(ga, jnp.int32(2)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
