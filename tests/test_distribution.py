"""Distribution layer on the degenerate host mesh: shardings coverage,
sharded graph engine vs single-device, hlo trip-count accounting."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, smoke_config
from repro.launch.mesh import make_host_mesh
from repro.launch.shardings import batch_specs, cache_specs, param_specs


def _tree_paths(tree):
    return {jax.tree_util.keystr(p)
            for p, _ in jax.tree_util.tree_flatten_with_path(tree)[0]}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_cover_param_tree(arch):
    """Every param leaf has a spec leaf at the same path, and ranks match."""
    from repro.models.transformer import init_params
    cfg = smoke_config(arch, layers=2)
    mesh = make_host_mesh()
    params = init_params(cfg, jax.random.PRNGKey(0))
    specs = param_specs(cfg, mesh)
    flat_p = dict(jax.tree_util.tree_flatten_with_path(params)[0])
    flat_s = {p: s for p, s in
              jax.tree_util.tree_flatten_with_path(
                  specs, is_leaf=lambda x: isinstance(x, P))[0]}
    pk = {jax.tree_util.keystr(k) for k in flat_p}
    sk = {jax.tree_util.keystr(k) for k in flat_s}
    assert pk == sk, f"spec/param path mismatch: {pk ^ sk}"
    for (kp, arr) in jax.tree_util.tree_flatten_with_path(params)[0]:
        spec = dict((jax.tree_util.keystr(k), s) for k, s in
                    jax.tree_util.tree_flatten_with_path(
                        specs, is_leaf=lambda x: isinstance(x, P))[0])[
            jax.tree_util.keystr(kp)]
        assert len(spec) <= arr.ndim, f"{kp}: spec {spec} vs {arr.shape}"


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "rwkv6-3b", "zamba2-1.2b",
                                  "mixtral-8x7b"])
def test_cache_specs_cover_cache_tree(arch):
    from repro.models.transformer import init_cache
    cfg = smoke_config(arch, layers=2)
    mesh = make_host_mesh()
    cache = init_cache(cfg, 4, 32)
    specs = cache_specs(cfg, mesh, 4)
    pk = _tree_paths(cache)
    sk = {jax.tree_util.keystr(p) for p, _ in
          jax.tree_util.tree_flatten_with_path(
              specs, is_leaf=lambda x: isinstance(x, P))[0]}
    assert pk == sk, f"{pk ^ sk}"


def test_batch_specs_shapes():
    cfg = smoke_config("paligemma-3b", layers=2)
    mesh = make_host_mesh()
    out = batch_specs(cfg, mesh, 8)
    assert "tokens" in out and "prefix" in out


def test_distributed_pagerank_matches_single(plc_graph):
    from repro.algos.graph_arrays import to_device
    from repro.algos.kernels import pagerank
    from repro.core.dist import make_distributed_pagerank
    g = plc_graph
    mesh = make_host_mesh()
    run, _ = make_distributed_pagerank(g, mesh, axis="data", num_iters=20)
    got = np.asarray(run())
    want = np.asarray(pagerank(to_device(g), num_iters=20))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-7)


def test_sharded_spmv_partition_edges(plc_graph):
    from repro.core.dist import partition_edges
    s, d, v, per = partition_edges(plc_graph, 4)
    assert s.shape == d.shape == v.shape
    assert v.sum() == plc_graph.num_edges
    # every edge lands in the shard owning its destination
    for i in range(4):
        dst_global = d[i][v[i]] + i * per
        assert (dst_global // per == i).all()


# ------------------------------------------------------- hlo accounting
def test_hlo_trip_count_scaling():
    """analyse_hlo must multiply while-body costs by the trip count."""
    from benchmarks.hlo_analysis import analyse_hlo

    def body(c, _):
        x, w = c
        return (jnp.tanh(x @ w), w), ()

    def prog(x, w):
        (y, _), _ = jax.lax.scan(body, (x, w), None, length=7)
        return y

    x = jnp.ones((64, 64))
    w = jnp.ones((64, 64))
    hlo = jax.jit(prog).lower(x, w).compile().as_text()
    out = analyse_hlo(hlo)
    # 7 iterations × 2·64³ flops
    expect = 7 * 2 * 64 ** 3
    assert abs(out["dot_flops"] - expect) / expect < 0.05, out["dot_flops"]


def test_hlo_unrolled_matches_cost_analysis():
    from benchmarks.hlo_analysis import analyse_hlo

    def prog(x, w):
        for _ in range(3):
            x = x @ w
        return x

    x = jnp.ones((32, 32))
    w = jnp.ones((32, 32))
    compiled = jax.jit(prog).lower(x, w).compile()
    got = analyse_hlo(compiled.as_text())["dot_flops"]
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # jax <= 0.4.x wraps per-device dicts in a list
        ca = ca[0]
    want = ca.get("flops", 0.0)
    assert abs(got - want) / max(want, 1) < 0.05


def test_hlo_collective_bytes_counted():
    from benchmarks.hlo_analysis import analyse_hlo
    mesh = make_host_mesh()

    def prog(x):
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(mesh, P()))

    # trivial: no collectives on a 1-device mesh, just exercise the parser
    hlo = jax.jit(lambda x: x.sum()).lower(jnp.ones((8, 8))).compile().as_text()
    out = analyse_hlo(hlo)
    assert out["collective_bytes"] == 0


def test_dryrun_collective_regex():
    from repro.launch.dryrun import collective_stats
    hlo = """
  %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = bf16[64]{0} all-gather-start(%y), dimensions={0}
  %done = bf16[64]{0} all-gather-done(%ag.1)
"""
    out = collective_stats(hlo)
    assert out["all-reduce"]["bytes"] == 128 * 256 * 4
    assert out["total_bytes"] >= 128 * 256 * 4
