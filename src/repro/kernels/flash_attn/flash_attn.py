"""Pallas TPU kernel: blocked causal (optionally sliding-window) attention.

Substrate kernel for the 32k-prefill cells. Q is tiled over the grid; K/V
for the (batch, head) arrive as whole-sequence VMEM blocks and are walked
with an in-kernel fori_loop over key tiles using the online-softmax
recurrence (running max / normalizer). Sliding-window masking covers the
Mixtral SWA path. Production note: for >32k sequences the key walk moves to
a third grid dimension with VMEM double-buffering; the recurrence is
unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Q_TILE = 256
K_TILE = 256
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, sm_scale: float, window: int,
            seq_len: int):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * sm_scale          # (Q_TILE, d)
    q_pos = qi * Q_TILE + jax.lax.iota(jnp.int32, Q_TILE)

    def step(t, carry):
        acc, m_prev, l_prev = carry
        k = k_ref[0, pl.dslice(t * K_TILE, K_TILE), :].astype(jnp.float32)
        v = v_ref[0, pl.dslice(t * K_TILE, K_TILE), :].astype(jnp.float32)
        s = q @ k.T                                      # (Q_TILE, K_TILE)
        k_pos = t * K_TILE + jax.lax.iota(jnp.int32, K_TILE)
        mask = q_pos[:, None] >= k_pos[None, :]          # causal
        if window > 0:                                   # sliding window
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_new, l_new

    d = q_ref.shape[-1]
    acc0 = jnp.zeros((Q_TILE, d), jnp.float32)
    m0 = jnp.full((Q_TILE,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((Q_TILE,), jnp.float32)
    # causal: key tiles beyond the diagonal contribute nothing — skip them
    num_kt = (qi + 1) * Q_TILE // K_TILE
    acc, _, l = jax.lax.fori_loop(0, num_kt, step, (acc0, m0, l0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("sm_scale", "window", "interpret"))
def flash_attention_pallas(q, k, v, *, sm_scale: float | None = None,
                           window: int = 0, interpret: bool = True):
    """q,k,v: (BH, S, d) with S % max(Q_TILE,K_TILE) == 0; causal."""
    bh, s, d = q.shape
    assert s % Q_TILE == 0 and s % K_TILE == 0
    scale = (d ** -0.5) if sm_scale is None else sm_scale
    grid = (bh, s // Q_TILE)
    return pl.pallas_call(
        functools.partial(_kernel, sm_scale=scale, window=window, seq_len=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q_TILE, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, s, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, s, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q_TILE, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
