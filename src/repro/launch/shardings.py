"""Sharding rules: param/activation/cache PartitionSpecs per architecture.

Scheme (DESIGN.md §5): megatron-style tensor parallelism on the ``model``
axis (attention heads / ffn hidden / vocab), ZeRO-3-style FSDP on the
``data`` axis (params+opt state sharded, gathered per layer by GSPMD),
pure replication across ``pod`` for params (cross-pod traffic = gradient
all-reduce only — the hierarchical-bandwidth-friendly layout).

Dims that do not divide the axis size fall back to replication
(`_maybe`): e.g. rwkv6's 40 wkv-heads or paligemma's MQA kv=1.
"""
from __future__ import annotations

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig

DP_AXES = ("pod", "data")


def _axsize(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape.get(a, 1)
        return out
    return mesh.shape.get(axis, 1)


def _maybe(mesh: Mesh, dim: int, axis):
    """axis if it divides dim (and exists in the mesh), else None."""
    n = _axsize(mesh, axis)
    return axis if (n > 1 and dim % n == 0) else None


def dp_axes(mesh: Mesh):
    axes = tuple(a for a in DP_AXES if a in mesh.axis_names)
    return axes if axes else (None,)


def param_specs(cfg: ModelConfig, mesh: Mesh, serve: bool = False):
    """PartitionSpec tree mirroring init_params(cfg).

    ``serve=True`` drops the ZeRO/FSDP data-axis sharding (§Perf iteration
    5): training wants params sharded over `data` (optimizer state scales),
    but decode re-gathers those shards EVERY layer EVERY token — the
    serving layout keeps weights TP-sharded over `model` only, replicated
    across `data` (weights are read-only at inference)."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    hd, nh, nkv = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    mdl, dat = "model", (None if serve else "data")
    L = None  # scanned leading layer dim: never sharded

    def attn_spec(scanned: bool):
        lead = (L,) if scanned else ()
        s = {
            "wq": P(*lead, _maybe(mesh, d, dat), _maybe(mesh, nh * hd, mdl)),
            "wk": P(*lead, _maybe(mesh, d, dat), _maybe(mesh, nkv * hd, mdl)),
            "wv": P(*lead, _maybe(mesh, d, dat), _maybe(mesh, nkv * hd, mdl)),
            "wo": P(*lead, _maybe(mesh, nh * hd, mdl), _maybe(mesh, d, dat)),
        }
        if cfg.qkv_bias:
            s["bq"] = P(*lead, _maybe(mesh, nh * hd, mdl))
            s["bk"] = P(*lead, _maybe(mesh, nkv * hd, mdl))
            s["bv"] = P(*lead, _maybe(mesh, nkv * hd, mdl))
        if cfg.attn_out_bias:
            s["bo"] = P(*lead, None)
        return s

    def mlp_spec(scanned: bool):
        lead = (L,) if scanned else ()
        if cfg.mlp_type == "swiglu":
            s = {"w_gate": P(*lead, _maybe(mesh, d, dat), _maybe(mesh, f, mdl)),
                 "w_up": P(*lead, _maybe(mesh, d, dat), _maybe(mesh, f, mdl)),
                 "w_down": P(*lead, _maybe(mesh, f, mdl), _maybe(mesh, d, dat))}
        else:
            s = {"w_in": P(*lead, _maybe(mesh, d, dat), _maybe(mesh, f, mdl)),
                 "w_out": P(*lead, _maybe(mesh, f, mdl), _maybe(mesh, d, dat))}
            if cfg.mlp_bias:
                s["b_in"] = P(*lead, _maybe(mesh, f, mdl))
                s["b_out"] = P(*lead, None)
        return s

    def moe_spec():
        # TP-within-expert storage (§Perf iteration 4): every model shard
        # holds the F/|model| slice of every expert — the exact layout the
        # locality-sorted dispatch consumes, so no per-layer re-layout
        # collectives. The expert dim stays unsharded; D shards over data
        # (FSDP-style, gathered once per layer).
        fmdl = _maybe(mesh, f, mdl)
        s = {
            "router": P(L, _maybe(mesh, d, dat), None),
            "w_gate": P(L, None, _maybe(mesh, d, dat), fmdl),
            "w_up": P(L, None, _maybe(mesh, d, dat), fmdl),
            "w_down": P(L, None, fmdl, _maybe(mesh, d, dat)),
        }
        if cfg.num_shared_experts:
            fs = f * cfg.num_shared_experts
            s["shared"] = {
                "w_gate": P(L, _maybe(mesh, d, dat), _maybe(mesh, fs, mdl)),
                "w_up": P(L, _maybe(mesh, d, dat), _maybe(mesh, fs, mdl)),
                "w_down": P(L, _maybe(mesh, fs, mdl), _maybe(mesh, d, dat)),
            }
        return s

    def norm_spec(scanned: bool = True):
        lead = (L,) if scanned else ()
        s = {"scale": P(*lead, None)}
        if cfg.norm_type == "layernorm":
            s["bias"] = P(*lead, None)
        return s

    def mamba_spec():
        di = cfg.d_inner
        return {
            "w_in": P(L, _maybe(mesh, d, dat), None),
            "conv": P(L, None, None),
            "a_log": P(L, None),
            "dt_bias": P(L, None),
            "d_skip": P(L, None),
            "norm_scale": P(L, None),
            "w_out": P(L, _maybe(mesh, di, mdl), _maybe(mesh, d, dat)),
        }

    def rwkv_spec():
        return {
            "mu_base": P(L, None, None),
            "ddl_w1": P(L, _maybe(mesh, d, dat), None),
            "ddl_w2": P(L, None, None, None),
            "wr": P(L, _maybe(mesh, d, dat), _maybe(mesh, d, mdl)),
            "wk": P(L, _maybe(mesh, d, dat), _maybe(mesh, d, mdl)),
            "wv": P(L, _maybe(mesh, d, dat), _maybe(mesh, d, mdl)),
            "wg": P(L, _maybe(mesh, d, dat), _maybe(mesh, d, mdl)),
            "wo": P(L, _maybe(mesh, d, mdl), _maybe(mesh, d, dat)),
            "w_base": P(L, None),
            "dec_w1": P(L, _maybe(mesh, d, dat), None),
            "dec_w2": P(L, None, None),
            "u_bonus": P(L, None, None),
            "ln_scale": P(L, None),
            "cm_mu": P(L, None, None),
            "cm_k": P(L, _maybe(mesh, d, dat), _maybe(mesh, f, mdl)),
            "cm_v": P(L, _maybe(mesh, f, mdl), _maybe(mesh, d, dat)),
            "cm_r": P(L, _maybe(mesh, d, dat), _maybe(mesh, d, mdl)),
        }

    from ..models.transformer import trunk_kind
    kind = trunk_kind(cfg)
    if kind == "attn":
        layer = {"norm1": norm_spec(), "norm2": norm_spec(),
                 "attn": attn_spec(True),
                 "ffn": moe_spec() if cfg.is_moe else mlp_spec(True)}
    elif kind == "rwkv":
        layer = {"norm1": norm_spec(), "norm2": norm_spec(),
                 "rwkv": rwkv_spec()}
    else:
        layer = {"norm1": norm_spec(), "mamba": mamba_spec()}

    specs = {
        "embed": {"table": P(_maybe(mesh, v, mdl), _maybe(mesh, d, dat))},
        "layers": layer,
        "final_norm": norm_spec(scanned=False),
    }
    if not cfg.tie_embeddings:
        specs["embed"]["head"] = P(_maybe(mesh, d, dat), _maybe(mesh, v, mdl))
    if "shared_attn" in cfg.block_pattern:
        specs["shared_attn"] = {
            "norm1": norm_spec(False), "norm2": norm_spec(False),
            "attn": attn_spec(False), "ffn": mlp_spec(False),
        }
    return specs


def batch_specs(cfg: ModelConfig, mesh: Mesh, global_batch: int):
    """Input batch PartitionSpecs (tokens/embeds/prefix/targets)."""
    dp = dp_axes(mesh)
    bspec = dp if (global_batch % _axsize(mesh, tuple(a for a in dp if a))
                   == 0 and dp != (None,)) else None
    out = {"tokens": P(bspec, None)}
    if cfg.input_mode == "embeddings":
        out = {"embeds": P(bspec, None, None), "targets": P(bspec, None)}
    if cfg.prefix_tokens:
        out["prefix"] = P(bspec, None, None)
    return out


def cache_specs(cfg: ModelConfig, mesh: Mesh, global_batch: int,
                max_len: int | None = None):
    """KV/state cache PartitionSpecs mirroring init_cache(cfg).

    When kv heads don't divide the model axis (minicpm 36H, starcoder2
    kv=4, qwen/chatglm kv=2 on a 16-way axis), the cache is sharded on the
    SEQUENCE dim instead and decode runs the context-parallel shard_map
    path (§Perf iteration 3) — otherwise those caches replicate over
    'model' (193 GB/device for minicpm decode_32k) and every step
    all-gathers them.
    """
    from ..models.layers import _seq_shards
    from ..models.transformer import trunk_kind
    dp = dp_axes(mesh)
    b_ok = (dp != (None,) and
            global_batch % _axsize(mesh, tuple(a for a in dp if a)) == 0)
    bspec = dp if b_ok else None
    kind = trunk_kind(cfg)
    kv_ax = _maybe(mesh, cfg.num_kv_heads, "model")
    t = max_len if max_len is not None else 0
    seq_ax = "model" if (kv_ax is None and
                         _seq_shards(mesh, cfg, t) > 1) else None
    if kind == "attn":
        layers = {"k": P(None, bspec, seq_ax, kv_ax, None),
                  "v": P(None, bspec, seq_ax, kv_ax, None),
                  "length": P(None)}
    elif kind == "rwkv":
        h = cfg.num_heads
        h_ax = _maybe(mesh, h, "model")
        layers = {"tm": {"shift": P(None, bspec, None),
                         "wkv": P(None, bspec, h_ax, None, None)},
                  "cm": {"shift": P(None, bspec, None)}}
    else:
        h_ax = _maybe(mesh, cfg.ssm_heads, "model")
        layers = {"conv": P(None, bspec, None, None),
                  "ssd": P(None, bspec, h_ax, None, None)}
    specs = {"layers": layers, "pos": P()}
    if "shared_attn" in cfg.block_pattern:
        specs["shared"] = {"k": P(None, bspec, seq_ax, kv_ax, None),
                           "v": P(None, bspec, seq_ax, kv_ax, None),
                           "length": P(None)}
    return specs


def to_named(tree, mesh: Mesh):
    import jax
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))
