"""Search-graph builders: exact k-NN and NSW-style incremental insert.

Both emit a **fixed out-degree** CSR (every row exactly ``k`` slots) so
the beam-search kernel can gather neighbor rows with one
``lax.dynamic_slice`` and the graph rides the existing
``GraphArrays``/bucketing upload path unchanged. Rows with fewer than
``k`` real links are padded with self-loops — a self-loop is inert under
beam search (the owning vertex is already visited when its row is
expanded) — while *non-self* duplicates within a row are forbidden
(``validate_search_graph``) because visit accounting counts each
first-touch once per row scan.
"""
from __future__ import annotations

from collections import Counter

import numpy as np

from ..core.csr import Graph, from_edges


def _sq_dists(points: np.ndarray, q: np.ndarray) -> np.ndarray:
    """(N,) squared L2 distances in float64 (build-time precision; the
    serving kernel ranks in float32 — see algos.kernels.knn_search)."""
    d = points.astype(np.float64) - q.astype(np.float64)
    return np.einsum("nd,nd->n", d, d)


def medoid_entry(vectors: np.ndarray) -> int:
    """Vertex nearest the corpus centroid — the canonical entry point."""
    c = np.asarray(vectors, np.float64).mean(axis=0)
    return int(np.argmin(_sq_dists(np.asarray(vectors), c)))


def knn_brute_force(vectors: np.ndarray, queries: np.ndarray,
                    k: int) -> np.ndarray:
    """Exact (Q, k) nearest-neighbor ids, ties broken by vertex id — the
    recall ground truth every served result is scored against."""
    vecs = np.asarray(vectors, np.float64)
    out = np.empty((len(queries), k), dtype=np.int64)
    for i, q in enumerate(np.asarray(queries, np.float64)):
        d = _sq_dists(vecs, q)
        out[i] = np.argsort(d, kind="stable")[:k]
    return out


def build_knn_graph(vectors: np.ndarray, k: int,
                    name: str = "knn") -> Graph:
    """Brute-force exact k-NN graph (CI scale): each vertex points at its
    ``k`` nearest *other* vertices, ties broken by id."""
    vecs = np.asarray(vectors, np.float64)
    n = len(vecs)
    if not 0 < k < n:
        raise ValueError(f"need 0 < k < num_vectors, got k={k}, n={n}")
    dst = np.empty((n, k), dtype=np.int64)
    for v in range(n):
        d = _sq_dists(vecs, vecs[v])
        d[v] = np.inf
        dst[v] = np.argsort(d, kind="stable")[:k]
    src = np.repeat(np.arange(n, dtype=np.int64), k)
    return from_edges(n, src, dst.ravel(), name=name)


def _beam_search_rows(rows: list, vecs: np.ndarray, q: np.ndarray,
                      entry: int, beam_width: int) -> list[tuple[float, int]]:
    """Host best-first search over mutable adjacency rows (build-time only;
    the serving-path mirror lives in core.baselines.knn_search_baseline)."""
    dq = lambda v: float(_sq_dists(vecs[v][None], q)[0])
    beam = [(dq(entry), entry)]
    expanded: set[int] = set()
    visited = {entry}
    while True:
        frontier = [(d, v) for d, v in beam if v not in expanded]
        if not frontier:
            return beam
        _, v = min(frontier)
        expanded.add(v)
        for w in rows[v]:
            if w in visited:
                continue
            visited.add(w)
            beam.append((dq(w), w))
        beam.sort()
        del beam[beam_width:]


def _sqd(vecs: np.ndarray, a: int, b: int) -> float:
    d = vecs[a] - vecs[b]
    return float(d @ d)


def _diverse_k(vecs: np.ndarray, u: int, cands, k: int) -> list[int]:
    """HNSW-style select-neighbors heuristic (Malkov & Yashunin alg. 4):
    walk candidates nearest-first and keep one only if it is closer to
    ``u`` than to every neighbor already kept, backfilling with the
    nearest skipped. Plain keep-the-k-nearest would converge every row
    to the exact k-NN graph — which is *disconnected* across clusters;
    the diversity rule is what preserves the long-range edges greedy
    search needs to hop between them."""
    order = sorted({int(c) for c in cands} - {u},
                   key=lambda w: (_sqd(vecs, u, w), w))
    kept: list[int] = []
    skipped: list[int] = []
    for c in order:
        if len(kept) >= k:
            break
        dc = _sqd(vecs, u, c)
        if all(dc < _sqd(vecs, c, s) for s in kept):
            kept.append(c)
        else:
            skipped.append(c)
    kept += skipped[:k - len(kept)]
    return kept


def _nsw_connect(rows: dict, vecs: np.ndarray, new: int,
                 neighbors: list[int], k: int) -> None:
    """Link ``new`` -> ``neighbors`` and reverse-link each neighbor back,
    re-selecting overfull rows with the diversity heuristic so every row
    keeps exactly ``k`` slots (self-loop padded while underfull)."""
    rows[new] = list(neighbors) + [new] * (k - len(neighbors))
    for u in neighbors:
        row = [w for w in rows[u] if w != u]  # drop self-loop pads
        if new in row:
            continue
        row.append(new)
        if len(row) > k:
            row = _diverse_k(vecs, u, row, k)
        rows[u] = row + [u] * (k - len(row))


def _nsw_rows(vecs: np.ndarray, k: int, ef: int,
              start_rows: list | None = None,
              order=None) -> list:
    """Insert vertices per ``order`` (default: remaining ids ascending)
    into the rows of ``start_rows``; returns all rows id-ordered."""
    rows: dict[int, list] = dict(enumerate(start_rows or []))
    inserted = list(rows)
    if order is None:
        order = range(len(rows), len(vecs))
    for v in order:
        if not rows:
            rows[v] = [v] * k  # first vertex: all self-loops
            inserted.append(v)
            continue
        cands = _beam_search_rows(rows, vecs, vecs[v], inserted[0], ef)
        nbrs = _diverse_k(vecs, v, [w for _, w in cands], k)
        _nsw_connect(rows, vecs, v, nbrs, k)
        inserted.append(v)
    return [rows[i] for i in range(len(vecs))]


def build_nsw_graph(vectors: np.ndarray, k: int, ef: int | None = None,
                    name: str = "nsw") -> Graph:
    """NSW-style incremental-insert graph: each point is beam-searched
    against the already-inserted set and linked to its ``ef``-best
    candidates' top ``k``, with capped reverse links. Early inserts keep
    long-range edges, which is what makes greedy search navigable across
    clusters (Coleman et al. §2) — so insertion runs in a deterministic
    *shuffled* order: corpora often arrive cluster-sorted (e.g.
    `core.generators.clustered_vectors`), and inserting cluster-by-cluster
    leaves no early cross-cluster links for later reverse-link
    replacement to preserve."""
    vecs = np.asarray(vectors, np.float64)
    order = np.random.default_rng(7).permutation(len(vecs))
    rows = _nsw_rows(vecs, k, ef or 2 * k + 16, order=order)
    src = np.repeat(np.arange(len(rows), dtype=np.int64), k)
    return from_edges(len(rows), src, np.concatenate(
        [np.asarray(r, np.int64) for r in rows]), name=name)


def nsw_insert_deltas(g: Graph, vectors: np.ndarray,
                      new_vectors: np.ndarray, ef: int | None = None
                      ) -> tuple[int, np.ndarray, np.ndarray]:
    """Incremental NSW insert as an ``update_graph`` delta.

    Returns ``(add_vertices, add_edges, remove_edges)`` growing ``g``
    (built over ``vectors``) by ``new_vectors``, for
    ``session.update_graph(..., add_vertices=, add_edges=,
    remove_edges=, vectors=new_vectors)``.
    """
    k = validate_search_graph(g)
    vecs = np.concatenate([np.asarray(vectors, np.float64),
                           np.asarray(new_vectors, np.float64)])
    base = g.num_vertices
    grown = _nsw_rows(vecs, k, ef or 2 * k + 16,
                      start_rows=[list(map(int, g.neighbors(v)))
                                  for v in range(base)])
    added, removed = [], []
    for v in range(base, len(vecs)):
        added.extend((v, w) for w in grown[v])
    for u in range(base):  # multiset diff of each pre-existing row
        cb = Counter(map(int, g.neighbors(u)))
        ca = Counter(grown[u])
        for e, c in (ca - cb).items():
            added.extend([(u, e)] * c)
        for e, c in (cb - ca).items():
            removed.extend([(u, e)] * c)
    to_arr = lambda es: (np.asarray(es, np.int64).reshape(-1, 2)
                         if es else np.empty((0, 2), np.int64))
    return len(new_vectors), to_arr(added), to_arr(removed)


def validate_search_graph(g: Graph) -> int:
    """Check fixed out-degree and no duplicate non-self neighbors;
    returns the out-degree ``k``."""
    deg = g.out_degree
    if g.num_vertices == 0:
        raise ValueError("empty search graph")
    k = int(deg[0])
    if not np.all(deg == k) or k == 0:
        raise ValueError("search graph must have fixed nonzero out-degree, "
                         f"got degrees in [{deg.min()}, {deg.max()}]")
    for v in range(g.num_vertices):
        row = g.neighbors(v)
        real = row[row != v]
        if len(np.unique(real)) != len(real):
            raise ValueError(f"duplicate neighbors in row {v}")
    return k
