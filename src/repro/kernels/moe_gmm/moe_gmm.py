"""Pallas TPU kernel: grouped matmul over locality-sorted MoE tokens.

LOrder's mechanism — sort skew-accessed items so hot groups are contiguous
— applied to expert dispatch (DESIGN.md §3.2): tokens are pre-sorted by
expert id and groups padded to the row-tile size, so every (row-tile,
col-tile) grid step multiplies one contiguous token block by exactly one
expert's weights. The expert id per row tile arrives via scalar prefetch
and indexes the weight BlockSpec, i.e. expert weights stream HBM→VMEM once
per contiguous group instead of once per token — the MXU analogue of a
cache line served from the hot slab.

Grid: (num_row_tiles, num_col_tiles, num_k_tiles); f32 accumulation in the
output tile across the k dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE_M = 128
TILE_N = 128
TILE_K = 128


def _kernel(tile_expert_ref, x_ref, w_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[0],
                          preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gmm_pallas(x, w, tile_expert, *, interpret: bool = True):
    """x: (M, K) tokens sorted+padded by expert; w: (E, K, N);
    tile_expert: (M//TILE_M,) expert id per row tile."""
    m, kdim = x.shape
    e, _, n = w.shape
    assert m % TILE_M == 0 and kdim % TILE_K == 0 and n % TILE_N == 0
    grid = (m // TILE_M, n // TILE_N, kdim // TILE_K)

    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((TILE_M, TILE_K), lambda i, j, k, te: (i, k)),
                pl.BlockSpec((1, TILE_K, TILE_N),
                             lambda i, j, k, te: (te[i], k, j)),
            ],
            out_specs=pl.BlockSpec((TILE_M, TILE_N),
                                   lambda i, j, k, te: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(tile_expert, x, w)


def pad_groups(group_sizes: np.ndarray, tile_m: int = TILE_M):
    """Host helper: per-group padded offsets + per-tile expert map.

    Returns (padded_offsets (E+1,), tile_expert (T,), total_rows)."""
    padded = -(-group_sizes // tile_m) * tile_m
    padded = np.maximum(padded, 0)
    offs = np.zeros(len(group_sizes) + 1, np.int64)
    np.cumsum(padded, out=offs[1:])
    tile_expert = np.repeat(np.arange(len(group_sizes), dtype=np.int32),
                            padded // tile_m)
    if len(tile_expert) == 0:  # degenerate: no tokens at all
        tile_expert = np.zeros(1, np.int32)
        offs[1:] = tile_m
    return offs, tile_expert, int(offs[-1])
