"""Observability plane: metrics registry, trace spans, profiling hooks.

The paper's central claim is about *where time goes* — cache-miss latency
dominating traversal execution — yet until this module the engine could
only report coarse wall-clock sums and hand-maintained counters. This is
the dependency-free telemetry substrate every other engine layer now
writes into:

* **Metrics registry** (`MetricsRegistry`) — named counters, gauges, and
  log-bucketed histograms, optionally labelled (e.g. per
  ``(graph_id, kernel)``). ``snapshot()`` returns one nested dict of
  everything; ``to_prometheus()`` renders the standard text exposition
  format so a scrape endpoint is a two-liner. The scheduler/backends'
  legacy ``telemetry()`` dicts are *views* over these instruments — the
  old shapes survive byte-for-byte, the registry is the source of truth.

* **Trace spans** (`Tracer`) — Chrome-trace-event JSON (load the exported
  file in https://ui.perfetto.dev or ``chrome://tracing``). Engine-side
  phases (flush, coalesce, translate, launch, device_sync, per-step
  sharded ``exchange``, reorder, redecide) land on the engine track;
  each request gets its own track carrying ``enqueue`` → ``queue_wait``
  → ``serve``, tied together by the ``trace_id`` every `QueryFuture`
  carries. Events are buffered (bounded, drop-oldest-never: excess
  events are counted in ``dropped``) and exported on demand.

* **Profiling hooks** (`ProfilerHook`) — an optional ``jax.profiler``
  integration enabled per-session: ``start()``/``stop()`` bracket a
  device-level trace into a log dir, and ``step(name)`` wraps each
  launch in a `StepTraceAnnotation` so engine launches line up with XLA
  events in the profiler UI. Fully inert (and import-error-proof) when
  no log dir is configured.

* **Clocks** (`Clock` / `ManualClock`) — the single injectable monotonic
  time source. The session owns one and the scheduler/tracer read it,
  so deadline and latency tests advance a `ManualClock` instead of
  sleeping.

docs/observability.md has the metric catalog and the span taxonomy.
"""
from __future__ import annotations

import bisect
import collections
import contextlib
import json
import math
import pathlib
import time


# ------------------------------------------------------------------- clocks
class Clock:
    """Injectable monotonic clock — the engine's single time source.

    Everything the session and scheduler time (queue waits, launch walls,
    deadlines, trace timestamps) reads ``now()`` so tests can substitute
    `ManualClock` and assert latency math deterministically.
    """

    def now(self) -> float:
        return time.perf_counter()


class ManualClock(Clock):
    """Deterministic clock for tests: time moves only via ``advance``."""

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def now(self) -> float:
        return self._now

    def advance(self, seconds: float) -> float:
        if seconds < 0:
            raise ValueError("time is monotonic; cannot advance backwards")
        self._now += seconds
        return self._now


# ------------------------------------------------------------------ buckets
def log_boundaries(lo: float = 1e-6, hi: float = 128.0,
                   factor: float = 2.0) -> tuple[float, ...]:
    """Geometric bucket boundaries ``lo, lo*f, ... >= hi`` (seconds)."""
    if lo <= 0 or factor <= 1.0:
        raise ValueError("need lo > 0 and factor > 1")
    out = [lo]
    while out[-1] < hi:
        out.append(out[-1] * factor)
    return tuple(out)


def signed_log_boundaries(lo: float = 1e-6, hi: float = 128.0,
                          factor: float = 2.0) -> tuple[float, ...]:
    """Mirrored log boundaries for signed quantities (deadline slack)."""
    pos = log_boundaries(lo, hi, factor)
    return tuple([-b for b in reversed(pos)] + [0.0] + list(pos))


# --------------------------------------------------------------- rate window
class RateWindow:
    """Sliding-window event fraction over the last ``size`` observations.

    The scheduler records one boolean per deadline-carrying request at
    serve/expiry time (missed or met); ``rate`` is the recent miss
    fraction feeding the admission shed policy — a bounded deque, so an
    old overload stops biasing the signal once healthy serves displace
    it.
    """

    def __init__(self, size: int = 64):
        if size < 1:
            raise ValueError("window size must be >= 1")
        self._events: collections.deque[bool] = collections.deque(maxlen=size)

    def record(self, event: bool) -> None:
        self._events.append(bool(event))

    @property
    def rate(self) -> float:
        if not self._events:
            return 0.0
        return sum(self._events) / len(self._events)

    def __len__(self) -> int:
        return len(self._events)


# -------------------------------------------------------------- instruments
class Counter:
    """Monotonically increasing count."""

    kind = "counter"

    def __init__(self, name: str, labels: dict | None = None):
        self.name = name
        self.labels = dict(labels or {})
        self.value = 0

    def inc(self, n: int | float = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        self.value += n

    def snapshot(self):
        return self.value


class Gauge:
    """Point-in-time value (can move both ways)."""

    kind = "gauge"

    def __init__(self, name: str, labels: dict | None = None):
        self.name = name
        self.labels = dict(labels or {})
        self.value = 0

    def set(self, v) -> None:
        self.value = v

    def inc(self, n=1) -> None:
        self.value += n

    def dec(self, n=1) -> None:
        self.value -= n

    def snapshot(self):
        return self.value


class Histogram:
    """Log-bucketed distribution with streaming quantile estimates.

    ``boundaries`` are upper bucket edges; an observation lands in the
    first bucket whose edge is >= value (one implicit overflow bucket
    past the last edge). Quantiles interpolate linearly inside the
    winning bucket — coarse but monotone and dependency-free, and at the
    default factor-of-2 spacing the estimate is within 2x, which is what
    a latency SLO dashboard needs.
    """

    kind = "histogram"

    def __init__(self, name: str, labels: dict | None = None,
                 boundaries: tuple[float, ...] | None = None):
        self.name = name
        self.labels = dict(labels or {})
        self.boundaries = tuple(boundaries or log_boundaries())
        if list(self.boundaries) != sorted(self.boundaries):
            raise ValueError("histogram boundaries must be sorted")
        self.bucket_counts = [0] * (len(self.boundaries) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float) -> None:
        value = float(value)
        self.bucket_counts[bisect.bisect_left(self.boundaries, value)] += 1
        self.count += 1
        self.sum += value
        self.min = min(self.min, value)
        self.max = max(self.max, value)

    def quantile(self, q: float) -> float:
        """Estimated q-quantile (q in [0, 1]); nan when empty."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        if self.count == 0:
            return math.nan
        rank = q * (self.count - 1)
        seen = 0
        for i, c in enumerate(self.bucket_counts):
            if c == 0:
                continue
            if seen + c > rank:
                lo = (self.boundaries[i - 1] if i > 0 else
                      min(self.min, self.boundaries[0]))
                hi = (self.boundaries[i] if i < len(self.boundaries)
                      else self.max)
                lo = max(lo, self.min)
                hi = min(hi, self.max)
                if c == 1 or hi <= lo:
                    return float(hi)
                return float(lo + (hi - lo) * (rank - seen) / c)
            seen += c
        return float(self.max)

    def snapshot(self) -> dict:
        empty = self.count == 0
        return {
            "count": self.count,
            "sum": self.sum,
            "min": None if empty else self.min,
            "max": None if empty else self.max,
            "p50": None if empty else self.quantile(0.50),
            "p90": None if empty else self.quantile(0.90),
            "p99": None if empty else self.quantile(0.99),
            "boundaries": list(self.boundaries),
            "bucket_counts": list(self.bucket_counts),
        }


def merge_histogram_snapshots(snaps: list[dict]) -> dict:
    """Aggregate same-boundary histogram snapshots (e.g. the per-label
    children of one family) into one distribution snapshot."""
    snaps = [s for s in snaps if s]
    if not snaps:
        return Histogram("merged").snapshot()
    merged = Histogram("merged", boundaries=tuple(snaps[0]["boundaries"]))
    for s in snaps:
        if list(s["boundaries"]) != list(merged.boundaries):
            raise ValueError("cannot merge histograms with "
                             "different boundaries")
        merged.bucket_counts = [a + b for a, b in
                                zip(merged.bucket_counts,
                                    s["bucket_counts"])]
        merged.count += s["count"]
        merged.sum += s["sum"]
        if s["count"]:
            merged.min = min(merged.min, s["min"])
            merged.max = max(merged.max, s["max"])
    return merged.snapshot()


# ------------------------------------------------------------------ registry
def _label_key(labels: dict) -> str:
    return ",".join(f"{k}={labels[k]}" for k in sorted(labels))


class _Family:
    """All children of one metric name (one per distinct label set)."""

    def __init__(self, name: str, kind: str, help: str,
                 boundaries: tuple[float, ...] | None):
        self.name = name
        self.kind = kind
        self.help = help
        self.boundaries = boundaries
        self.children: dict[str, Counter | Gauge | Histogram] = {}

    def child(self, labels: dict):
        key = _label_key(labels)
        got = self.children.get(key)
        if got is None:
            if self.kind == "counter":
                got = Counter(self.name, labels)
            elif self.kind == "gauge":
                got = Gauge(self.name, labels)
            else:
                got = Histogram(self.name, labels, self.boundaries)
            self.children[key] = got
        return got


class MetricsRegistry:
    """Named counters / gauges / histograms with labels.

    One registry per engine session (backends built standalone own a
    private one; a session adopts its executor's so everything lands in
    a single namespace). Re-requesting an existing ``(name, labels)``
    returns the same instrument; re-requesting a name as a *different*
    kind raises — silent type drift is how metrics rot.
    """

    def __init__(self):
        self._families: dict[str, _Family] = {}

    def _family(self, name: str, kind: str, help: str,
                boundaries=None) -> _Family:
        fam = self._families.get(name)
        if fam is None:
            fam = _Family(name, kind, help, boundaries)
            self._families[name] = fam
        elif fam.kind != kind:
            raise ValueError(f"metric {name!r} already registered as "
                             f"{fam.kind}, not {kind}")
        return fam

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._family(name, "counter", help).child(labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._family(name, "gauge", help).child(labels)

    def histogram(self, name: str, help: str = "", boundaries=None,
                  **labels) -> Histogram:
        return self._family(name, "histogram", help,
                            tuple(boundaries) if boundaries else None
                            ).child(labels)

    def family(self, name: str) -> dict:
        """label-key -> instrument for one metric name ({} if absent)."""
        fam = self._families.get(name)
        return dict(fam.children) if fam else {}

    # ------------------------------------------------------------- export
    def snapshot(self) -> dict:
        """Everything, as one nested plain dict (JSON-safe).

        Shape: ``{"counters"|"gauges"|"histograms": {name: value-or-
        {label_key: value}}}`` — unlabelled instruments collapse to their
        bare value; labelled families keep one entry per label set.
        """
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        section = {"counter": "counters", "gauge": "gauges",
                   "histogram": "histograms"}
        for name, fam in sorted(self._families.items()):
            vals = {k: c.snapshot() for k, c in sorted(fam.children.items())}
            if list(vals) == [""]:      # unlabelled: collapse
                vals = vals[""]
            out[section[fam.kind]][name] = vals
        return out

    def to_prometheus(self) -> str:
        """Standard Prometheus text exposition format."""
        lines = []
        for name, fam in sorted(self._families.items()):
            if fam.help:
                lines.append(f"# HELP {name} {fam.help}")
            lines.append(f"# TYPE {name} {fam.kind}")
            for _, child in sorted(fam.children.items()):
                lbl = ",".join(f'{k}="{v}"' for k, v in
                               sorted(child.labels.items()))
                if fam.kind != "histogram":
                    lines.append(f"{name}{{{lbl}}} {child.value}" if lbl
                                 else f"{name} {child.value}")
                    continue
                cum = 0
                for edge, c in zip(child.boundaries, child.bucket_counts):
                    cum += c
                    le = f'le="{edge}"'
                    full = f"{lbl},{le}" if lbl else le
                    lines.append(f"{name}_bucket{{{full}}} {cum}")
                inf = f'le="+Inf"'
                full = f"{lbl},{inf}" if lbl else inf
                lines.append(f"{name}_bucket{{{full}}} {child.count}")
                suffix = f"{{{lbl}}}" if lbl else ""
                lines.append(f"{name}_sum{suffix} {child.sum}")
                lines.append(f"{name}_count{suffix} {child.count}")
        return "\n".join(lines) + "\n"


# -------------------------------------------------------------------- tracer
ENGINE_TID = 0          # engine-side phases: flush/launch/reorder/exchange
REQUEST_TID_BASE = 1000  # each request's lifecycle gets its own track


class Tracer:
    """Chrome-trace-event collector (Perfetto/chrome://tracing loadable).

    Timestamps come from the injected clock and are exported in
    microseconds relative to tracer construction. ``span`` is the
    primary API — a context manager emitting one complete ("X") event
    whose ``args`` dict the caller may still mutate inside the block
    (e.g. to mark a launch as compile vs cache hit once known). ``emit``
    takes explicit start/end times for spans whose lifetime doesn't
    match a Python block (queue waits, per-step exchanges).
    """

    def __init__(self, clock: Clock | None = None,
                 max_events: int = 200_000, pid: int = 1):
        self.clock = clock or Clock()
        self.max_events = max_events
        self.pid = pid
        self.events: list[dict] = []
        self.dropped = 0
        self._t0 = self.clock.now()
        self._thread_names: dict[int, str] = {}
        self.set_thread_name(ENGINE_TID, "engine")

    # ------------------------------------------------------------ plumbing
    def _ts(self, t: float) -> float:
        return round((t - self._t0) * 1e6, 3)

    def _push(self, event: dict) -> None:
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        self.events.append(event)

    def set_thread_name(self, tid: int, name: str) -> None:
        self._thread_names[tid] = name

    # ------------------------------------------------------------- emitters
    @contextlib.contextmanager
    def span(self, name: str, tid: int = ENGINE_TID, **args):
        """Complete event covering the ``with`` block; yields the args
        dict so facts discovered inside the block can be attached."""
        start = self.clock.now()
        try:
            yield args
        finally:
            self.emit(name, start, self.clock.now(), tid=tid, args=args)

    def emit(self, name: str, start: float, end: float,
             tid: int = ENGINE_TID, args: dict | None = None) -> None:
        """Complete event with explicit clock times (seconds)."""
        self._push({
            "name": name, "ph": "X", "pid": self.pid, "tid": tid,
            "ts": self._ts(start),
            "dur": max(round((end - start) * 1e6, 3), 0.0),
            "args": dict(args or {}),
        })

    def instant(self, name: str, tid: int = ENGINE_TID, **args) -> None:
        self._push({
            "name": name, "ph": "i", "s": "t", "pid": self.pid,
            "tid": tid, "ts": self._ts(self.clock.now()),
            "args": dict(args),
        })

    # -------------------------------------------------------------- export
    def to_chrome(self) -> dict:
        meta = [{"name": "thread_name", "ph": "M", "pid": self.pid,
                 "tid": tid, "args": {"name": name}}
                for tid, name in sorted(self._thread_names.items())]
        return {"traceEvents": meta + list(self.events),
                "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def export(self, path) -> pathlib.Path:
        """Write the Chrome trace JSON; open it in ui.perfetto.dev."""
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.to_chrome()))
        return p


def validate_chrome_trace(trace: dict) -> dict:
    """Structural validation of an exported trace (tests + CI smoke).

    Checks the Chrome-trace envelope, event field types, and that the
    complete ("X") events on every thread are *properly nested*: sorted
    by start time, each event either contains or is disjoint from the
    next — the invariant Perfetto's track builder relies on. Returns
    summary stats (event/track counts, span names).
    """
    assert isinstance(trace, dict) and "traceEvents" in trace, \
        "not a Chrome trace object"
    by_tid: dict[int, list[dict]] = {}
    names = set()
    for ev in trace["traceEvents"]:
        assert isinstance(ev.get("name"), str) and "ph" in ev, ev
        if ev["ph"] != "X":
            continue
        assert isinstance(ev["ts"], (int, float)), ev
        assert isinstance(ev["dur"], (int, float)) and ev["dur"] >= 0, ev
        names.add(ev["name"])
        by_tid.setdefault(ev["tid"], []).append(ev)
    for tid, evs in by_tid.items():
        evs.sort(key=lambda e: (e["ts"], -e["dur"]))
        stack: list[tuple[float, float]] = []
        for ev in evs:
            s, e = ev["ts"], ev["ts"] + ev["dur"]
            while stack and s >= stack[-1][1] - 1e-2:
                stack.pop()
            # 0.01 µs slop both ways: ts/dur are rounded independently
            # on export, so adjacent spans sharing a clock instant
            # (queue_wait end == serve start) may overlap by < 0.01 µs
            assert not stack or e <= stack[-1][1] + 1e-2, (
                f"span {ev['name']!r} on tid {tid} overlaps its "
                f"neighbour without nesting: [{s}, {e}] vs {stack[-1]}")
            stack.append((s, e))
    return {"events": len(trace["traceEvents"]),
            "complete_spans": sum(len(v) for v in by_tid.values()),
            "tracks": len(by_tid),
            "span_names": sorted(names)}


# ------------------------------------------------------------ profiler hook
class ProfilerHook:
    """Optional ``jax.profiler`` bridge, enabled by giving a log dir.

    ``start()``/``stop()`` bracket a device-level profiler trace written
    to ``log_dir`` (open with TensorBoard's profile plugin or
    ui.perfetto.dev); ``step(name)`` wraps one engine launch in a
    `StepTraceAnnotation` so scheduler launches are attributable inside
    the XLA timeline. Everything is a no-op when unconfigured, and any
    profiler failure (unsupported platform, double-start) is recorded in
    ``error`` instead of failing the serving path.
    """

    def __init__(self, log_dir: str | None = None):
        self.log_dir = str(log_dir) if log_dir else None
        self.active = False
        self.error: str | None = None

    @property
    def enabled(self) -> bool:
        return self.log_dir is not None

    def start(self) -> bool:
        if not self.enabled or self.active:
            return False
        try:
            import jax
            jax.profiler.start_trace(self.log_dir)
            self.active = True
        except Exception as exc:  # profiling must never fail serving
            self.error = f"start_trace: {exc}"
        return self.active

    def stop(self) -> bool:
        if not self.active:
            return False
        try:
            import jax
            jax.profiler.stop_trace()
        except Exception as exc:
            self.error = f"stop_trace: {exc}"
        self.active = False
        return True

    def step(self, name: str, step_num: int = 0):
        """Context manager around one launch (inert unless active)."""
        if not self.active:
            return contextlib.nullcontext()
        try:
            import jax
            return jax.profiler.StepTraceAnnotation(name,
                                                    step_num=step_num)
        except Exception as exc:
            self.error = f"step: {exc}"
            return contextlib.nullcontext()


__all__ = [
    "Clock", "Counter", "ENGINE_TID", "Gauge", "Histogram", "ManualClock",
    "MetricsRegistry", "ProfilerHook", "REQUEST_TID_BASE", "RateWindow",
    "Tracer",
    "log_boundaries", "merge_histogram_snapshots", "signed_log_boundaries",
    "validate_chrome_trace",
]
