"""Pure-jnp oracle for the grouped matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp


def gmm_ref(x, w, row_expert):
    """out[i] = x[i] @ w[row_expert[i]] — dense per-row oracle."""
    return jnp.einsum("mk,mkn->mn", x, w[row_expert]).astype(jnp.float32)
