"""Graph registry: per-graph serving state + cheap structural probes.

The engine's reorder policy needs exactly the structural facts the paper
shows modulate reordering payoff — degree skew (§2.1 hotness) and diameter
(the κ = D/2 analysis) — but must obtain them at a cost far below a
reorder pass. The probes here are O(E) single passes: a degree Gini
coefficient, the hot-vertex fraction and hot edge mass (λ = avg degree,
the paper's threshold), and a single double-sweep BFS diameter bound.

Registry entries carry everything serving needs per graph: the original
layout (query ids stay in this space), the chosen permutation and its
inverse, the reordered ("served") layout, and the device arrays. Entries
also track *realized* query volume (``queries_observed``) independently
of the amortization ledger — the ledger resets on every re-decision, but
the volume history that triggers re-decisions must not.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from ..core.csr import Graph
from ..core.diameter import two_sweep_diameter
from ..core.mutate import MutationDelta


@dataclasses.dataclass(frozen=True)
class GraphProbes:
    """Cheap structural summary feeding the reorder policy.

    The ``visit_*`` fields mirror the degree probes but over *observed
    visit frequency* (EWMA, ``GraphRegistry.note_visits``) — the skew
    signal for search graphs, whose out-degree is fixed by construction
    so degree probes read as uniform (Coleman et al., docs/search.md).
    They stay 0 until serving telemetry arrives.
    """

    num_vertices: int
    num_edges: int
    avg_degree: float
    degree_gini: float    # 0 = uniform degrees, →1 = extreme skew
    hub_fraction: float   # fraction of vertices with degree > λ (avg)
    hub_mass: float       # fraction of total degree held by hub vertices
    diameter: int         # double-sweep BFS lower bound
    probe_seconds: float
    family: str = "analytics"    # workload family: "analytics" | "search"
    visit_gini: float = 0.0      # Gini of EWMA visit counts
    visit_hub_fraction: float = 0.0  # fraction with above-mean visits
    visit_hub_mass: float = 0.0      # visit mass held by that hot set


def degree_gini(degrees: np.ndarray) -> float:
    """Gini coefficient of the degree distribution (skew probe)."""
    d = np.sort(degrees.astype(np.float64))
    n = len(d)
    total = d.sum()
    if n == 0 or total == 0:
        return 0.0
    ranks = np.arange(1, n + 1, dtype=np.float64)
    return float(2.0 * (ranks * d).sum() / (n * total) - (n + 1) / n)


def degree_histogram(degrees: np.ndarray) -> np.ndarray:
    """Degree histogram — the O(max_degree) basis of incremental probes."""
    if len(degrees) == 0:
        return np.zeros(1, dtype=np.int64)
    return np.bincount(degrees.astype(np.int64))


def gini_from_histogram(hist: np.ndarray) -> float:
    """Degree Gini from a degree histogram, O(max_degree).

    Equals ``degree_gini(degrees)`` exactly: with degrees sorted
    ascending, a degree value d occupying ranks r0+1..r0+c contributes
    d · (c·r0 + c(c+1)/2) to Σ rank·degree.
    """
    hist = np.asarray(hist, dtype=np.float64)
    counts = hist
    values = np.arange(len(hist), dtype=np.float64)
    n = counts.sum()
    total = (values * counts).sum()
    if n == 0 or total == 0:
        return 0.0
    r0 = np.concatenate([[0.0], np.cumsum(counts)[:-1]])
    rank_sum = (values * (counts * r0 + counts * (counts + 1) / 2.0)).sum()
    return float(2.0 * rank_sum / (n * total) - (n + 1) / n)


def hub_stats_from_histogram(hist: np.ndarray) -> tuple[float, float, float]:
    """(avg_degree, hub_fraction, hub_mass) from a degree histogram.

    Hot := degree > λ (= avg degree), matching ``Graph.hot_mask``.
    """
    hist = np.asarray(hist, dtype=np.float64)
    values = np.arange(len(hist), dtype=np.float64)
    n = hist.sum()
    total = (values * hist).sum()
    if n == 0:
        return 0.0, 0.0, 0.0
    lam = total / n
    hot = values > lam
    hub_fraction = float(hist[hot].sum() / n)
    hub_mass = float((values[hot] * hist[hot]).sum() / total) if total else 0.0
    return float(lam), hub_fraction, hub_mass


def probe_graph(g: Graph, family: str = "analytics") -> GraphProbes:
    """Compute all policy probes in one pass over degrees + two BFS."""
    t0 = time.perf_counter()
    deg = g.degree
    hot = g.hot_mask()
    total = float(deg.sum())
    return GraphProbes(
        num_vertices=g.num_vertices,
        num_edges=g.num_edges,
        avg_degree=g.average_degree,
        degree_gini=degree_gini(deg),
        hub_fraction=float(hot.mean()) if g.num_vertices else 0.0,
        hub_mass=float(deg[hot].sum() / total) if total else 0.0,
        diameter=two_sweep_diameter(g),
        probe_seconds=time.perf_counter() - t0,
        family=family,
    )


@dataclasses.dataclass
class GraphEntry:
    """Per-graph serving state. Fields after ``expected_queries`` are
    populated by the session once the policy has run."""

    graph_id: str
    graph: Graph                      # original layout (query id space)
    probes: GraphProbes
    expected_queries: int             # volume hint; refreshed on re-decision
    perm: np.ndarray | None = None    # perm[old_id] = served_id
    inv_perm: np.ndarray | None = None
    served: Graph | None = None       # reordered layout actually executed
    arrays: object | None = None      # GraphArrays of `served` (single only)
    handle: object | None = None      # engine.backends.GraphHandle
    backend: str = "single"           # placement the policy chose
    bucket_shape: tuple | None = None  # padded (V_b, E_b) upload shape
    hot_prefix_fraction: float | None = None  # sharded exchange thinning
    # served-id prefix length considered "hot" under the current layout
    # (0 for identity/random layouts): result-cache entries whose source
    # permutes below this index are pinned (GRASP-style, result_cache.py)
    hot_prefix_len: int = 0
    reorder_seconds: float = 0.0
    decision: object | None = None    # engine.policy.PolicyDecision
    ledger: object | None = None      # engine.session.AmortizationLedger
    queries_observed: int = 0         # realized volume, survives re-decisions
    redecisions: int = 0
    # layout generation: bumped every time a policy decision is (re-)applied
    # or the graph mutates. The scheduler translates each request through
    # the generation current at launch time and stamps it into the
    # request's telemetry, so layout replacements are observable and never
    # straddle an in-flight future.
    generation: int = 0
    # --- dynamic-graph state (core/mutate.py deltas) -------------------
    mutations: int = 0                # applied deltas; doubles as the token
    #                                   fencing stale async full reorders
    degree_hist: np.ndarray | None = None  # basis of incremental probes
    # accumulated |delta| / E since the last full probe_graph; past the
    # session's drift threshold the next mutation pays a full re-probe
    probe_drift: float = 0.0
    # --- search-graph state (search/, knn_search) ----------------------
    vectors: np.ndarray | None = None      # (V, d) float32, original order
    search_params: object | None = None    # search.serve.SearchParams
    entry_point: int = 0                   # entry vertex, original id
    visit_ewma: np.ndarray | None = None   # (V,) EWMA visits, original ids
    visits_total: int = 0                  # raw visit-count sum observed
    visit_queries: int = 0                 # queries behind visit_ewma


class GraphRegistry:
    """Ingests graphs, probes them, and holds serving state by id."""

    def __init__(self):
        self._entries: dict[str, GraphEntry] = {}

    def add(self, graph: Graph, graph_id: str | None = None,
            expected_queries: int = 64,
            family: str = "analytics") -> GraphEntry:
        if graph_id is not None and not graph_id:
            # an explicit empty id must not silently alias to graph.name
            raise ValueError("graph_id must be a non-empty string")
        gid = graph_id if graph_id is not None else graph.name
        if not gid:
            raise ValueError(
                "graph has an empty name; pass an explicit graph_id")
        if gid in self._entries:
            raise KeyError(f"graph id {gid!r} already registered")
        entry = GraphEntry(gid, graph, probe_graph(graph, family=family),
                           expected_queries)
        entry.degree_hist = degree_histogram(graph.degree)
        self._entries[gid] = entry
        return entry

    def apply_mutation(self, graph_id: str, new_graph: Graph,
                       delta: MutationDelta,
                       drift_threshold: float = 0.5) -> str:
        """Swap in the mutated graph and refresh probes; returns the probe
        mode used, ``"incremental"`` or ``"full"``.

        Incremental mode updates the degree histogram from the delta's
        per-vertex degree changes (O(|delta| + max_degree)) and
        recomputes Gini/hub stats from it — exact, since both are pure
        functions of the degree multiset. The diameter probe is *not* a
        function of degrees, so it goes stale under incremental mode;
        accumulated drift (Σ |delta| / E) past ``drift_threshold``
        forces a full ``probe_graph`` (fresh diameter) and resets drift.
        """
        entry = self._entries[graph_id]
        old_degrees = entry.graph.degree  # cached; pre-mutation values
        n_old = len(old_degrees)
        t0 = time.perf_counter()
        entry.graph = new_graph
        entry.mutations += 1
        entry.probe_drift += delta.edges_changed / max(entry.probes.num_edges, 1)
        if entry.degree_hist is None or entry.probe_drift > drift_threshold:
            entry.probes = probe_graph(new_graph,
                                       family=entry.probes.family)
            entry.degree_hist = degree_histogram(new_graph.degree)
            entry.probe_drift = 0.0
            return "full"

        hist = entry.degree_hist
        changed = delta.changed_vertices
        # vertices added by this delta enter the multiset at degree 0
        # before their edge endpoints are applied; ids >= the old vertex
        # count must read old degree 0, not index out of the old array
        if delta.vertices_added:
            hist = hist.copy()
            hist[0] += delta.vertices_added
            old_d = np.where(changed < n_old,
                             old_degrees[np.minimum(changed, n_old - 1)],
                             0).astype(np.int64)
        else:
            old_d = old_degrees[changed].astype(np.int64)
        new_d = old_d + delta.degree_delta
        max_d = int(new_d.max()) if len(new_d) else 0
        if max_d >= len(hist):
            hist = np.concatenate(
                [hist, np.zeros(max_d - len(hist) + 1, dtype=hist.dtype)])
        np.subtract.at(hist, old_d, 1)
        np.add.at(hist, new_d, 1)
        entry.degree_hist = hist
        lam, hub_fraction, hub_mass = hub_stats_from_histogram(hist)
        entry.probes = dataclasses.replace(
            entry.probes,
            num_vertices=new_graph.num_vertices,
            num_edges=new_graph.num_edges,
            avg_degree=lam,
            degree_gini=gini_from_histogram(hist),
            hub_fraction=hub_fraction,
            hub_mass=hub_mass,
            # diameter: stale until the next full re-probe (drift-gated)
            probe_seconds=time.perf_counter() - t0,
        )
        return "incremental"

    def get(self, graph_id: str) -> GraphEntry:
        return self._entries[graph_id]

    def note_queries(self, graph_id: str, n: int = 1) -> int:
        """Count realized query batches against a graph; returns total."""
        entry = self._entries[graph_id]
        entry.queries_observed += n
        return entry.queries_observed

    def note_visits(self, graph_id: str, visits: np.ndarray,
                    num_queries: int = 1, alpha: float = 0.3) -> np.ndarray:
        """Fold one launch's per-vertex visit counts (original-id space)
        into the entry's EWMA hotness estimate.

        The estimate tracks *visits per query* so batch size doesn't
        scale it; ``alpha`` is the EWMA smoothing weight on the newest
        batch. Returns the updated EWMA array.
        """
        entry = self._entries[graph_id]
        rate = np.asarray(visits, dtype=np.float64) / max(num_queries, 1)
        if entry.visit_ewma is None or len(entry.visit_ewma) != len(rate):
            # first telemetry, or the vertex set grew (update_graph
            # add_vertices=): start fresh at the observed rate
            entry.visit_ewma = rate.copy()
        else:
            entry.visit_ewma += alpha * (rate - entry.visit_ewma)
        entry.visits_total += int(np.asarray(visits).sum())
        entry.visit_queries += num_queries
        return entry.visit_ewma

    def refresh_visit_probes(self, graph_id: str) -> GraphProbes:
        """Recompute the visit-skew probe fields from the current EWMA
        (the search-family analogue of the degree probes); returns the
        refreshed probes. No-op (returns current) without telemetry."""
        entry = self._entries[graph_id]
        v = entry.visit_ewma
        if v is None or v.sum() <= 0:
            return entry.probes
        hot = v > v.mean()
        total = float(v.sum())
        entry.probes = dataclasses.replace(
            entry.probes,
            visit_gini=degree_gini(v),
            visit_hub_fraction=float(hot.mean()),
            visit_hub_mass=float(v[hot].sum() / total),
        )
        return entry.probes

    def ids(self) -> list[str]:
        return list(self._entries)

    def __contains__(self, graph_id: str) -> bool:
        return graph_id in self._entries

    def __len__(self) -> int:
        return len(self._entries)
