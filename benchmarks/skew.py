"""Paper Table 1 analogue — power-law skew of the regenerated datasets:
hot-vertex fraction (degree > average) and the share of edges they carry.
"""
from __future__ import annotations

import numpy as np

from .common import bench_suite, fmt_table, save_json


def run(scale: float = 0.5) -> list[dict]:
    rows = []
    for name, g in bench_suite(scale).items():
        hot = g.hot_mask()
        deg = g.degree.astype(np.int64)
        rows.append({
            "dataset": name,
            "V": g.num_vertices,
            "E": g.num_edges,
            "avg_degree": round(g.average_degree, 2),
            "hot_frac_%": round(100 * hot.mean(), 2),
            "hot_edge_share_%": round(100 * deg[hot].sum() / deg.sum(), 2),
        })
    save_json("skew", rows)
    return rows


def main(scale: float = 0.5):
    rows = run(scale)
    print(fmt_table(rows, ["dataset", "V", "E", "avg_degree",
                           "hot_frac_%", "hot_edge_share_%"]))
    assert all(r["hot_frac_%"] < 50 for r in rows)
    print("\nhot vertices are a minority carrying a majority of edges "
          "(power law, paper Table 1)")


if __name__ == "__main__":
    main()
