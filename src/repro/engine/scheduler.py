"""Request plane: futures + micro-batch scheduling over the serving engine.

The paper's economic argument is *amortization* — a reorder pays off only
across many traversals — yet a blocking one-caller ``submit`` launches one
device program per call, so concurrent traffic can never share a vmapped
launch and the policy never observes real batch shapes. This module turns
the front door into an **always-on** request plane:

* ``EngineSession.enqueue(...)`` returns a `QueryFuture` immediately;
  nothing touches a device until a **flush boundary**.
* `MicroBatchScheduler` queues requests per ``(graph_id, kernel)`` and, at
  ``flush()``/``drain()``:

  - **coalesces** pending multi-source requests (bfs/sssp/bc) into one
    vmapped launch whose concatenated sources fill a power-of-two
    `source_bucket`, then slices each request's rows back out of the
    ``(S, V)`` result — N requests, one device program;
  - **deduplicates** concurrent global-kernel requests (pr/cc/ccsv) into
    a single run fanned out to every waiter — the result is
    source-independent, so running it twice is pure waste;
  - drains queues in **priority / deadline order** (higher ``priority``
    first, then earlier absolute deadline, then FIFO), so a latency-bound
    request is never stuck behind a bulk scan that arrived first;
  - **round-robins across graphs** when several graphs are pending in one
    flush: launches alternate one chunk per ``(graph_id, kernel)`` stream
    per cycle (graphs rotated between flushes), so one graph's burst
    chunked by ``max_batch_sources`` cannot monopolize consecutive
    launches.

* **auto-flush** — production traffic never calls ``flush()``. A flush
  tick (`poll`) fires whenever any pending request is past its deadline
  or older than ``max_delay``; it piggy-backs on every ``enqueue`` and
  ``QueryFuture.done()`` through the session's injectable clock, and an
  optional background thread (`start_auto_flush`) covers fully idle
  callers. No request waits past ``max_delay``/its deadline without a
  launch, flush() or not.

* **admission control** — an `engine.policy.AdmissionPolicy` bounds the
  queue: at ``max_pending`` an arrival is rejected with a typed
  `AdmissionRejected` or degraded to best-effort; below the cap,
  best-effort arrivals are shed while the recent deadline-miss rate
  (`obs.RateWindow`) says the plane is already overloaded. A pending
  request read past its deadline raises a typed `DeadlineExceeded` from
  ``result()`` instead of blocking on a flush that may never come.

* **result cache** — identical rows are served from memory inside a
  flush window *and* across windows: per-source rows are cached under
  ``(graph_id, generation, kernel, source)`` with hot-prefix sources
  pinned (`engine.result_cache`, GRASP-style), so repeat-heavy traffic
  stops re-launching what it asked seconds ago. Generation bumps from
  re-decision make stale rows unreachable by key.

* **generations** — every (re-)applied policy decision bumps the graph
  entry's ``generation``; a request's sources are translated through the
  layout *at launch time* and its result translated back before the
  flush-boundary re-decision check runs, so an in-flight future is never
  served half from a layout that was just replaced. Re-decision moves
  from per-submit to per-flush: one check per graph per flush, after all
  of its pending requests were served.

* **telemetry** — every future carries per-request serving facts: the
  launch it rode, how many requests shared it, its wall share, the
  generation that served it, whether its deadline was met, how many of
  its rows came from the result cache, and (sharded placements) the
  per-run `ExchangeStats` delta from ``core/dist.py``.

* **observability** (obs.py, docs/observability.md) — every counter here
  is a view over the session's `MetricsRegistry` (the old ``telemetry()``
  dict shape is preserved as a facade), queue-wait / serve-latency /
  deadline-slack histograms are recorded per ``(graph_id, kernel)``, and
  each request carries a ``trace_id`` tying its per-request trace track
  (enqueue → queue_wait → serve) to the engine track's flush / coalesce /
  translate / launch / cache_hit spans. All timing flows through the
  session's injectable clock, so latency tests are deterministic.

``EngineSession.submit`` is reimplemented as enqueue + flush sugar, so
the blocking API is exactly one request riding a one-element batch —
bit-identical results, same id translation, same ledger accounting.
docs/scheduler.md documents the lifecycle and the migration path.
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import threading
from typing import TYPE_CHECKING

import numpy as np

from ..search.serve import query_digest
from .backends import (GLOBAL, MULTI_SOURCE, VECTOR_SOURCE, build_kernel,
                       source_bucket)
from .obs import REQUEST_TID_BASE, RateWindow, signed_log_boundaries
from .result_cache import GLOBAL_SOURCE

if TYPE_CHECKING:  # import cycle: session builds the scheduler
    from .session import EngineSession

# component-label kernels whose *values* (not just positions) are vertex
# ids and must be canonicalized back to original id space at the boundary
LABEL_KERNELS = ("cc", "ccsv")


class AdmissionRejected(RuntimeError):
    """The request plane refused an arrival (bounded queue / shed band).

    ``shed`` distinguishes the soft path (best-effort arrival shed while
    deadlines are being missed) from the hard queue cap.
    """

    def __init__(self, message: str, pending: int, limit: int,
                 shed: bool = False):
        super().__init__(message)
        self.pending = pending
        self.limit = limit
        self.shed = shed


class DeadlineExceeded(TimeoutError):
    """``result()`` was called on a request already past its deadline
    while still pending — the caller gets a typed error *now* instead of
    paying for a launch whose answer it already declared worthless."""

    def __init__(self, message: str, deadline: float, now: float):
        super().__init__(message)
        self.deadline = deadline
        self.now = now


def canonical_component_labels(labels: np.ndarray) -> np.ndarray:
    """Relabel component ids to the **minimum original vertex id** of each
    component.

    ``labels[v]`` must be a consistent per-component representative (any
    id space — the engine's served layout uses served ids). The output is
    layout-independent: bit-identical to `core.baselines.cc_baseline`
    whatever permutation the graph was served under, which is what lets
    the parity matrix demand cross-backend bit-identity for cc/ccsv.
    """
    labels = np.asarray(labels)
    n = labels.shape[-1]
    flat = labels.reshape(-1, n).astype(np.int64, copy=False)
    out = np.empty_like(flat)
    for i, row in enumerate(flat):
        rep_min = np.full(int(row.max()) + 1, n, dtype=np.int64)
        np.minimum.at(rep_min, row, np.arange(n, dtype=np.int64))
        out[i] = rep_min[row]
    return out.reshape(labels.shape)


@dataclasses.dataclass
class Request:
    """One enqueued query: what to run, how urgently, and for whom."""

    seq: int                       # FIFO tiebreak, assigned at enqueue
    graph_id: str
    kernel: str
    # original-id space for MULTI_SOURCE; (S, d) float32 query rows for
    # VECTOR_SOURCE (a knn "source" is a vector); None for GLOBAL
    sources: np.ndarray | None
    priority: int                  # higher drains first
    deadline: float | None         # absolute perf_counter() time, or None
    enqueued_at: float
    future: "QueryFuture"
    generation: int | None = None  # layout generation that served it
    trace_id: str | None = None    # ties this request's spans together
    degraded: bool = False         # admitted best-effort under overload

    @property
    def num_sources(self) -> int:
        if self.sources is None:
            return 0
        # a 2-D source batch is S query *rows*, not S x d scalars
        if self.sources.ndim == 2:
            return int(len(self.sources))
        return int(self.sources.size)

    def order_key(self) -> tuple:
        """Drain order: priority desc, earliest deadline, FIFO."""
        return (-self.priority,
                self.deadline if self.deadline is not None else float("inf"),
                self.seq)


class QueryFuture:
    """Handle to a pending (or served) request.

    ``result()`` is the blocking read: if the request has not been served
    yet it flushes the owning scheduler for this request's graph first,
    so a lone ``enqueue(...).result()`` behaves exactly like the old
    blocking ``submit`` — unless the deadline already passed, in which
    case it raises `DeadlineExceeded` instead of launching work whose
    answer is already stale. ``done()`` doubles as the auto-flush tick:
    polling a future gives the scheduler a chance to serve anything
    overdue. ``telemetry`` is populated at serve time (see
    `MicroBatchScheduler._account`).
    """

    def __init__(self, scheduler: "MicroBatchScheduler", request: Request):
        self._scheduler = scheduler
        self._result: np.ndarray | None = None
        self._exception: BaseException | None = None
        self._done = False
        self.request = request
        self.telemetry: dict = {}

    # ------------------------------------------------------------ protocol
    def done(self) -> bool:
        if not self._done:
            self._scheduler.poll()      # piggy-backed auto-flush tick
        return self._done

    def result(self) -> np.ndarray:
        if not self._done:
            req = self.request
            if (req.deadline is not None
                    and self._scheduler.session.clock.now() > req.deadline):
                self._scheduler._expire(req)
            if not self._done:
                self._scheduler.flush(req.graph_id)
        if not self._done:  # defensive: flush must have served us
            raise RuntimeError(
                f"flush did not serve request {self.request.seq} "
                f"({self.request.graph_id}/{self.request.kernel})")
        if self._exception is not None:
            raise self._exception
        return self._result

    def exception(self) -> BaseException | None:
        """The launch failure, if any (None while pending or on success)."""
        return self._exception

    @property
    def trace_id(self) -> str:
        """Id shared by every trace span of this request's lifecycle."""
        return self.request.trace_id

    # ------------------------------------------------------------ internal
    def _set_result(self, value: np.ndarray) -> None:
        self._result = value
        self._done = True

    def _set_exception(self, exc: BaseException) -> None:
        self._exception = exc
        self._done = True


class MicroBatchScheduler:
    """Per-(graph, kernel) request queues drained as micro-batches.

    One scheduler fronts one `EngineSession`; the session owns the
    registry/policy/executor and exposes the launch internals the
    scheduler drives (`EngineSession._launch` / ``_maybe_redecide``).
    ``max_batch_sources`` caps how many concatenated sources one coalesced
    launch may carry (None = coalesce everything pending into a single
    launch; the executor still pads the batch to its power-of-two
    `source_bucket`). ``max_delay`` is the auto-flush age bound (None
    disables the tick); ``admission`` an `engine.policy.AdmissionPolicy`
    (None admits everything). A single re-entrant lock serializes
    enqueue/flush/poll so the optional background flusher and the caller
    thread compose.
    """

    def __init__(self, session: "EngineSession",
                 max_batch_sources: int | None = None,
                 max_delay: float | None = 0.25,
                 admission=None):
        if max_batch_sources is not None and max_batch_sources < 1:
            raise ValueError("max_batch_sources must be >= 1 or None")
        if max_delay is not None and max_delay < 0:
            raise ValueError("max_delay must be >= 0 or None")
        self.session = session
        self.max_batch_sources = max_batch_sources
        self.max_delay = max_delay
        self.admission = admission
        self._queues: dict[tuple[str, str], list[Request]] = {}
        self._seq = itertools.count()
        self._lock = threading.RLock()
        self._rr_cursor = 0          # rotates which graph leads a flush
        self._miss_window = RateWindow(
            admission.miss_window if admission is not None else 64)
        self._flusher: threading.Thread | None = None
        self._flusher_stop: threading.Event | None = None
        self.auto_flush_error: BaseException | None = None
        # counters live in the session's metrics registry; the public
        # attributes below (and telemetry()) are read-through views, so
        # the pre-obs shapes survive while the registry is the one truth
        m = session.metrics_registry
        self._c_enqueued = m.counter(
            "engine_requests_enqueued_total", "requests accepted by enqueue")
        self._c_served = m.counter(
            "engine_requests_served_total", "futures resolved with a result")
        self._c_failed = m.counter(
            "engine_requests_failed_total", "futures resolved with an error")
        self._c_launches = m.counter(
            "engine_launches_total", "device launches issued")
        self._c_launches_failed = m.counter(
            "engine_launches_failed_total", "device launches that raised")
        self._c_coalesced = m.counter(
            "engine_coalesced_requests_total", "requests that shared a launch")
        self._c_dedup = m.counter(
            "engine_dedup_hits_total", "global requests served without a run")
        self._c_flushes = m.counter("engine_flushes_total", "flush boundaries")
        self._c_deadlines = m.counter(
            "engine_deadlines_missed_total", "requests served past deadline")
        self._c_auto = m.counter(
            "engine_auto_flushes_total",
            "flush boundaries triggered by the max-delay/deadline tick")
        self._c_expired = m.counter(
            "engine_requests_expired_total",
            "pending requests failed with DeadlineExceeded at result()")
        self._c_adm_rejected = m.counter(
            "engine_admission_rejected_total",
            "arrivals rejected at the pending-queue cap")
        self._c_adm_degraded = m.counter(
            "engine_admission_degraded_total",
            "arrivals demoted to best-effort at the pending-queue cap")
        self._c_adm_shed = m.counter(
            "engine_admission_shed_total",
            "best-effort arrivals shed while deadlines were being missed")
        self._g_pending = m.gauge(
            "engine_pending_requests", "requests enqueued but not served")
        self._metrics = m

    # --------------------------------------------- registry-backed counters
    @property
    def requests_enqueued(self) -> int:
        return self._c_enqueued.value

    @property
    def requests_served(self) -> int:
        return self._c_served.value

    @property
    def requests_failed(self) -> int:
        return self._c_failed.value

    @property
    def launches(self) -> int:
        return self._c_launches.value

    @property
    def launches_failed(self) -> int:
        return self._c_launches_failed.value

    @property
    def coalesced_requests(self) -> int:
        return self._c_coalesced.value

    @property
    def dedup_hits(self) -> int:
        return self._c_dedup.value

    @property
    def flushes(self) -> int:
        return self._c_flushes.value

    @property
    def deadlines_missed(self) -> int:
        return self._c_deadlines.value

    @property
    def auto_flushes(self) -> int:
        return self._c_auto.value

    @property
    def requests_expired(self) -> int:
        return self._c_expired.value

    @property
    def admission_rejected(self) -> int:
        return self._c_adm_rejected.value

    @property
    def admission_degraded(self) -> int:
        return self._c_adm_degraded.value

    @property
    def admission_shed(self) -> int:
        return self._c_adm_shed.value

    # ------------------------------------------------------------- enqueue
    def enqueue(self, graph_id: str, kernel: str, sources=None,
                priority: int = 0,
                deadline_seconds: float | None = None) -> QueryFuture:
        """Queue one request; returns its future. Validation is eager —
        unknown kernel/graph and empty source batches raise *here*, not at
        flush time where they would poison a coalesced batch. Admission
        control also runs here: an overloaded plane rejects/degrades/sheds
        before the request ever holds queue memory."""
        build_kernel(kernel)                    # ValueError on unknown
        entry = self.session.registry.get(graph_id)  # KeyError on unknown
        srcs = None
        if kernel in MULTI_SOURCE:
            srcs = np.atleast_1d(np.asarray(sources, dtype=np.int64))
            if srcs.size == 0:
                raise ValueError(f"{kernel} needs at least one source")
            n = entry.graph.num_vertices
            if int(srcs.min()) < 0 or int(srcs.max()) >= n:
                # out-of-range ids must fail *this* caller now — at launch
                # time they would poison every request coalesced alongside
                raise ValueError(
                    f"{kernel} sources must be in [0, {n}); got "
                    f"[{int(srcs.min())}, {int(srcs.max())}]")
        elif kernel in VECTOR_SOURCE:
            if entry.vectors is None:
                raise ValueError(
                    f"graph {graph_id!r} was registered without vectors=; "
                    f"{kernel} queries need a vector corpus")
            srcs = np.atleast_2d(np.asarray(sources, dtype=np.float32))
            if srcs.size == 0:
                raise ValueError(f"{kernel} needs at least one query vector")
            dim = int(entry.vectors.shape[1])
            if srcs.ndim != 2 or srcs.shape[1] != dim:
                raise ValueError(
                    f"{kernel} queries must be (S, {dim}) float32 rows "
                    f"matching the registered corpus, got shape "
                    f"{srcs.shape}")
        with self._lock:
            priority, deadline_seconds, degraded = self._admit(
                graph_id, kernel, priority, deadline_seconds)
            now = self.session.clock.now()
            seq = next(self._seq)
            req = Request(
                seq=seq, graph_id=graph_id, kernel=kernel,
                sources=srcs, priority=priority,
                deadline=(now + deadline_seconds
                          if deadline_seconds is not None else None),
                enqueued_at=now, future=None,  # type: ignore[arg-type]
                trace_id=f"req-{seq}", degraded=degraded)
            req.future = QueryFuture(self, req)
            self._queues.setdefault((graph_id, kernel), []).append(req)
            self._c_enqueued.inc()
            self._g_pending.inc()
            tracer = self.session.tracer
            tracer.set_thread_name(REQUEST_TID_BASE + seq, req.trace_id)
            tracer.instant("enqueue", tid=REQUEST_TID_BASE + seq,
                           trace_id=req.trace_id, graph_id=graph_id,
                           kernel=kernel, priority=priority)
            self.poll()                  # piggy-backed auto-flush tick
        return req.future

    def _admit(self, graph_id: str, kernel: str, priority: int,
               deadline_seconds: float | None) -> tuple[int, float | None,
                                                        bool]:
        """Apply the admission policy to one arrival; returns the possibly
        degraded ``(priority, deadline_seconds, degraded)`` or raises
        `AdmissionRejected`."""
        adm = self.admission
        if adm is None:
            return priority, deadline_seconds, False
        pending = self.pending()
        if pending >= min(adm.max_pending, adm.soft_limit):
            # the plane looks overloaded — tick it before judging the
            # arrival, so admission sees the post-flush depth and a queue
            # full of *overdue* work can't wedge into a reject storm where
            # nothing ever drains (every rejected enqueue bails before the
            # piggy-backed poll that would have flushed it)
            self.poll()
            pending = self.pending()
        if pending >= adm.max_pending:
            if adm.overload == "degrade":
                self._c_adm_degraded.inc()
                return min(priority, adm.degraded_priority), None, True
            self._c_adm_rejected.inc()
            raise AdmissionRejected(
                f"queue full: {pending} pending >= max_pending="
                f"{adm.max_pending} ({graph_id}/{kernel})",
                pending=pending, limit=adm.max_pending)
        best_effort = deadline_seconds is None and priority <= 0
        if (best_effort and pending >= adm.soft_limit
                and len(self._miss_window) >= adm.min_miss_samples
                and self._miss_window.rate >= adm.shed_miss_rate):
            self._c_adm_shed.inc()
            raise AdmissionRejected(
                f"shedding best-effort arrival: {pending} pending >= "
                f"soft_limit={adm.soft_limit} with recent deadline-miss "
                f"rate {self._miss_window.rate:.2f} ({graph_id}/{kernel})",
                pending=pending, limit=adm.soft_limit, shed=True)
        return priority, deadline_seconds, False

    def pending(self, graph_id: str | None = None) -> int:
        return sum(len(reqs) for (gid, _), reqs in self._queues.items()
                   if graph_id is None or gid == graph_id)

    # ---------------------------------------------------------- auto-flush
    def poll(self) -> int:
        """The auto-flush tick: flush every graph holding an *overdue*
        request — older than ``max_delay`` or past its deadline. Cheap
        when nothing is overdue (one pass over the pending queues);
        piggy-backed on ``enqueue``/``done()`` and driven by the optional
        background thread, so the plane serves traffic even when no one
        ever calls ``flush()``."""
        with self._lock:
            now = self.session.clock.now()
            due: list[str] = []
            for (gid, _), reqs in self._queues.items():
                if gid in due:
                    continue
                for r in reqs:
                    if ((r.deadline is not None and now >= r.deadline)
                            or (self.max_delay is not None
                                and now - r.enqueued_at >= self.max_delay)):
                        due.append(gid)
                        break
            if not due:
                return 0
            self._c_auto.inc()
            return self._flush_graphs(due)

    def start_auto_flush(self, interval: float | None = None
                         ) -> threading.Thread:
        """Run ``poll()`` from a daemon thread every ``interval`` seconds
        (default ``max_delay / 2``) so fully idle callers still get their
        overdue requests served. Idempotent; `stop_auto_flush` (or
        ``EngineSession.close``) tears it down."""
        with self._lock:
            if self._flusher is not None:
                return self._flusher
            if interval is None:
                interval = (self.max_delay / 2 if self.max_delay else 0.05)
            interval = max(float(interval), 1e-3)
            stop = threading.Event()

            def _loop():
                while not stop.wait(interval):
                    try:
                        self.poll()
                    except Exception as exc:   # futures already carry it
                        self.auto_flush_error = exc
            t = threading.Thread(target=_loop, name="engine-auto-flush",
                                 daemon=True)
            self._flusher, self._flusher_stop = t, stop
            t.start()
            return t

    def stop_auto_flush(self) -> None:
        with self._lock:
            t, stop = self._flusher, self._flusher_stop
            self._flusher = self._flusher_stop = None
        if t is not None:
            stop.set()
            t.join(timeout=5.0)

    # --------------------------------------------------------------- flush
    def flush(self, graph_id: str | None = None) -> int:
        """Serve everything currently pending (for one graph, or all).

        Queues drain in priority/deadline order within each stream, with
        launches round-robined across streams; each graph gets exactly
        one re-decision check *after* all of its pending requests were
        served — the flush boundary — so no in-flight future straddles a
        layout replacement. Graphs holding a completed async full-reorder
        (`EngineSession.update_graph`) join the flush set even with no
        pending requests, so the flush boundary can swap their layout in.
        """
        with self._lock:
            graphs: list[str] = []
            for (gid, _), reqs in self._queues.items():
                if reqs and (graph_id is None or gid == graph_id):
                    if gid not in graphs:
                        graphs.append(gid)
            for gid in self.session._swap_pending_ids():
                if (graph_id is None or gid == graph_id) and gid not in graphs:
                    graphs.append(gid)
            return self._flush_graphs(graphs)

    def drain(self) -> int:
        """Flush until no request is pending anywhere (lifecycle close).
        A final flush applies any still-pending layout swaps."""
        served = 0
        with self._lock:
            while self.pending():
                served += self.flush()
            if self.session._swap_pending_ids():
                served += self.flush()
        return served

    @contextlib.contextmanager
    def fence(self, graph_id: str):
        """Mutation fence: serve every in-flight request of ``graph_id``
        under its current (pre-mutation) generation, then hold the
        plane's lock while the caller mutates — enqueues from other
        threads block until the mutation completes, so no future ever
        straddles a mutation. Re-entrant (the lock is an RLock), so a
        fenced mutation may itself flush or apply decisions."""
        with self._lock:
            self.flush(graph_id)
            yield

    def _expire(self, req: Request) -> None:
        """Fail one still-pending request with `DeadlineExceeded` (called
        from ``result()`` once the deadline has passed). No-op if a
        concurrent flush already took it."""
        with self._lock:
            q = self._queues.get((req.graph_id, req.kernel))
            if q is None or req not in q:
                return        # already being served; result() re-checks
            q.remove(req)
            now = self.session.clock.now()
            self._c_deadlines.inc()
            self._c_expired.inc()
            self._c_failed.inc()
            self._g_pending.dec()
            self._miss_window.record(True)
            self.session.tracer.instant(
                "expired", tid=REQUEST_TID_BASE + req.seq,
                trace_id=req.trace_id, graph_id=req.graph_id,
                kernel=req.kernel)
            req.future._set_exception(DeadlineExceeded(
                f"request {req.seq} ({req.graph_id}/{req.kernel}) missed "
                f"its deadline by {now - req.deadline:.4f}s before any "
                "flush served it", deadline=req.deadline, now=now))

    # ------------------------------------------------------ flush internals
    def _take_queues(self, graph_id: str) -> list[tuple[str, list[Request]]]:
        """Pop this graph's non-empty queues, ordered by their most urgent
        request (so a high-priority sssp drains before a bulk bfs)."""
        taken = []
        for (gid, kernel), reqs in list(self._queues.items()):
            if gid == graph_id and reqs:
                taken.append((kernel, reqs))
                del self._queues[(gid, kernel)]
        taken.sort(key=lambda kv: min(r.order_key() for r in kv[1]))
        return taken

    def _flush_graphs(self, graphs: list[str]) -> int:
        """One flush boundary over ``graphs``: take every stream, then
        round-robin launches one chunk per ``(graph_id, kernel)`` stream
        per cycle. The graph order rotates between flushes (`_rr_cursor`),
        so with `max_batch_sources` chunking no graph's burst can
        monopolize consecutive launches across flushes either."""
        session = self.session
        self._c_flushes.inc()
        if not graphs:
            return 0
        if len(graphs) > 1:
            lead = self._rr_cursor % len(graphs)
            graphs = graphs[lead:] + graphs[:lead]
        self._rr_cursor += 1
        # streams: [graph_id, kernel, entry, chunk list] in fair-drain order
        entries = {gid: session.registry.get(gid) for gid in graphs}
        streams: list[list] = []
        taken_reqs: list[Request] = []
        for gid in graphs:
            for kernel, reqs in self._take_queues(gid):
                reqs.sort(key=Request.order_key)
                taken_reqs.extend(reqs)
                chunks = ([reqs] if kernel in GLOBAL else self._chunks(reqs))
                streams.append([gid, kernel, entries[gid], chunks])
        served = 0
        try:
            with session.tracer.span("flush", graphs=len(graphs),
                                     requests=len(taken_reqs)):
                while streams:
                    survivors: list[list] = []
                    for stream in streams:
                        gid, kernel, entry, chunks = stream
                        chunk = chunks.pop(0)
                        if kernel in GLOBAL:
                            self._serve_global(entry, kernel, chunk)
                        else:
                            self._serve_multi(entry, kernel, chunk)
                        served += len(chunk)
                        if chunks:
                            survivors.append(stream)
                    streams = survivors
        except Exception as exc:
            # a failed launch must not strand the rest of the flush set:
            # every taken-but-unserved future fails with the same cause
            for r in taken_reqs:
                if not r.future._done:
                    r.future._set_exception(exc)
                    self._c_failed.inc()
                    self._g_pending.dec()
            raise
        finally:
            # requests resolved before a mid-flush failure were genuinely
            # served: keep the counter consistent with their futures
            self._c_served.inc(served)
        # flush boundary: all pending requests for these graphs are
        # answered and translated under the generation that served them —
        # only now may layouts be replaced (skipped if the flush aborted).
        # A completed async full-reorder swaps in here; a graph whose
        # layout just swapped skips the re-decision check this boundary
        for gid in graphs:
            if session._apply_pending_swap(entries[gid]):
                continue
            session._maybe_redecide(entries[gid])
        return served

    def _chunks(self, reqs: list[Request]) -> list[list[Request]]:
        """Greedy coalescing under the source cap, in drain order."""
        if self.max_batch_sources is None:
            return [reqs]
        chunks: list[list[Request]] = []
        cur: list[Request] = []
        total = 0
        for r in reqs:
            if cur and total + r.num_sources > self.max_batch_sources:
                chunks.append(cur)
                cur, total = [], 0
            cur.append(r)
            total += r.num_sources
        if cur:
            chunks.append(cur)
        return chunks

    @staticmethod
    def _source_items(kernel: str, req: Request) -> list[tuple[int, object]]:
        """Per-source ``(cache_key, launch_payload)`` pairs for one
        request. Integer sources key as themselves; a knn query row keys
        as its content digest (`search.serve.query_digest`) — what makes
        float vectors addressable by the result cache — and its payload
        is the row itself."""
        if kernel in VECTOR_SOURCE:
            return [(query_digest(row), row) for row in req.sources]
        return [(int(s), int(s)) for s in req.sources]

    def _serve_multi(self, entry, kernel: str, reqs: list[Request]) -> None:
        """One vmapped launch for the chunk's *uncached* sources; cached
        rows come from the result cache (within-window dedup falls out of
        the same lookup), per-request rows are reassembled per source."""
        session = self.session
        cache = session.result_cache
        launch_begin = session.clock.now()
        if cache is None:
            self._serve_multi_uncached(entry, kernel, reqs, launch_begin)
            return
        is_vec = kernel in VECTOR_SOURCE
        gid, gen = entry.graph_id, entry.generation
        req_items = [self._source_items(kernel, r) for r in reqs]
        rows: dict[int, np.ndarray] = {}       # cache key -> result row
        missing: list = []                     # fresh payloads, first-seen
        missing_keys: list[int] = []
        missing_set: set[int] = set()
        for items in req_items:
            for key, payload in items:
                if key in rows or key in missing_set:
                    continue
                row = cache.get(gid, gen, kernel, key)
                if row is None:
                    missing.append(payload)
                    missing_keys.append(key)
                    missing_set.add(key)
                else:
                    rows[key] = row
        wall, exchange = 0.0, None
        if missing:
            with session.tracer.span("coalesce", graph_id=gid, kernel=kernel,
                                     requests=len(reqs),
                                     cached_sources=len(rows)):
                launch_sources = (np.stack(missing).astype(np.float32)
                                  if is_vec
                                  else np.asarray(missing, dtype=np.int64))
            try:
                out, wall = session._launch(entry, kernel, launch_sources)
            except Exception as exc:
                self._fail_launch(reqs, exc)
                raise
            exchange = session._last_exchange(entry)
            session.policy.observe_batch_sources(len(missing))
            self._c_launches.inc()
            hot = entry.hot_prefix_len
            for i, key in enumerate(missing_keys):
                # copy: a slice view would pin the whole (S, V) launch
                # array for as long as any one cached row is retained
                row = out[i].copy()
                rows[key] = row
                # knn rows are keyed by content digest, not vertex id, so
                # GRASP pinning (a vertex-prefix rule) never applies
                pinned = (not is_vec and hot > 0
                          and int(entry.perm[key]) < hot)
                cache.put(gid, gen, kernel, key, row, pinned=pinned)
        else:
            # every row came from memory — the whole chunk serves with no
            # device work at all; make that visible on the engine track
            with session.tracer.span("cache_hit", graph_id=gid,
                                     kernel=kernel, requests=len(reqs),
                                     sources=len(rows)):
                pass
        if len(reqs) > 1:
            self._c_coalesced.inc(len(reqs))
        # launch wall is shared pro-rata over freshly launched rows only:
        # a fully cached request costs (and is charged) ~nothing
        fresh = [sum(1 for key, _ in items if key in missing_set)
                 for items in req_items]
        fresh_total = sum(fresh) or 1
        with session.tracer.span("slice_out", graph_id=gid, kernel=kernel,
                                 requests=len(reqs)):
            for r, items, n_fresh in zip(reqs, req_items, fresh):
                out_rows = np.stack([rows[key] for key, _ in items])
                self._account(entry, r, out_rows, wall,
                              wall * (n_fresh / fresh_total), len(reqs),
                              len(missing), exchange, launch_begin,
                              cache_hits=r.num_sources - n_fresh,
                              from_cache=not missing)

    def _serve_multi_uncached(self, entry, kernel: str, reqs: list[Request],
                              launch_begin: float) -> None:
        """Cache-off path: pure coalescing, byte-identical to the PR 5
        plane (duplicate sources ride the launch)."""
        session = self.session
        with session.tracer.span("coalesce", graph_id=entry.graph_id,
                                 kernel=kernel, requests=len(reqs)):
            all_sources = np.concatenate([r.sources for r in reqs])
        try:
            out, wall = session._launch(entry, kernel, all_sources)
        except Exception as exc:
            self._fail_launch(reqs, exc)
            raise
        exchange = session._last_exchange(entry)
        total = int(len(all_sources))   # rows for (S, d) vector batches
        session.policy.observe_batch_sources(total)
        self._c_launches.inc()
        if len(reqs) > 1:
            self._c_coalesced.inc(len(reqs))
        offset = 0
        with session.tracer.span("slice_out", graph_id=entry.graph_id,
                                 kernel=kernel, requests=len(reqs)):
            for r in reqs:
                # copy: a slice view would pin the whole (S_total, V) launch
                # array for as long as any one future's result is retained
                rows = out[offset:offset + r.num_sources].copy()
                offset += r.num_sources
                share = wall * (r.num_sources / max(total, 1))
                self._account(entry, r, rows, wall, share, len(reqs), total,
                              exchange, launch_begin)

    def _serve_global(self, entry, kernel: str, reqs: list[Request]) -> None:
        """One run, fanned out to every waiter (the result is
        source-independent, so concurrent requests are duplicates) — and
        served straight from the result cache across flush windows."""
        session = self.session
        cache = session.result_cache
        launch_begin = session.clock.now()
        gid, gen = entry.graph_id, entry.generation
        out = (cache.get(gid, gen, kernel, GLOBAL_SOURCE)
               if cache is not None else None)
        from_cache = out is not None
        wall, exchange = 0.0, None
        if out is None:
            try:
                out, wall = session._launch(entry, kernel, None)
            except Exception as exc:
                self._fail_launch(reqs, exc)
                raise
            exchange = session._last_exchange(entry)
            self._c_launches.inc()
            if cache is not None:
                # global results are one row per graph and every request
                # wants it: always worth pinning
                cache.put(gid, gen, kernel, GLOBAL_SOURCE, out, pinned=True)
            if len(reqs) > 1:
                self._c_dedup.inc(len(reqs) - 1)
        else:
            with session.tracer.span("cache_hit", graph_id=gid,
                                     kernel=kernel, requests=len(reqs)):
                pass
            self._c_dedup.inc(len(reqs))
        if len(reqs) > 1:
            self._c_coalesced.inc(len(reqs))
        for r in reqs:
            self._account(entry, r, out, wall, wall / len(reqs), len(reqs),
                          0, exchange, launch_begin,
                          cache_hits=1 if from_cache else 0,
                          from_cache=from_cache)

    def _fail_launch(self, reqs: list[Request], exc: BaseException) -> None:
        """One launch raised: fail its riders, count the outcome."""
        self._c_launches_failed.inc()
        for r in reqs:
            r.future._set_exception(exc)
            self._c_failed.inc()
            self._g_pending.dec()

    def _account(self, entry, req: Request, result: np.ndarray, wall: float,
                 wall_share: float, sharing: int, batch_sources: int,
                 exchange: dict | None, launch_begin: float,
                 cache_hits: int = 0, from_cache: bool = False) -> None:
        """Resolve one future: ledger, realized-volume, telemetry,
        latency histograms, and the request's trace track."""
        session = self.session
        req.generation = entry.generation
        entry.ledger.record_query(req.num_sources, wall_share)
        session.registry.note_queries(entry.graph_id)
        served_at = session.clock.now()
        missed = req.deadline is not None and served_at > req.deadline
        if missed:
            self._c_deadlines.inc()
        if req.deadline is not None:
            self._miss_window.record(missed)
        labels = {"graph_id": req.graph_id, "kernel": req.kernel}
        queue_wait = launch_begin - req.enqueued_at
        serve_latency = served_at - req.enqueued_at
        m = self._metrics
        m.histogram("engine_queue_wait_seconds",
                    "enqueue -> launch start", **labels).observe(queue_wait)
        m.histogram("engine_serve_seconds",
                    "enqueue -> result resolved (end-to-end)",
                    **labels).observe(serve_latency)
        if req.deadline is not None:
            # slack > 0: met with room; < 0: by how much it was missed —
            # the attributable version of the deadlines_missed counter
            m.histogram("engine_deadline_slack_seconds",
                        "deadline - served_at (negative = missed by)",
                        boundaries=signed_log_boundaries(),
                        **labels).observe(req.deadline - served_at)
        tid = REQUEST_TID_BASE + req.seq
        tracer = session.tracer
        span_args = {"trace_id": req.trace_id, **labels}
        tracer.emit("queue_wait", req.enqueued_at, launch_begin, tid=tid,
                    args=span_args)
        tracer.emit("serve", launch_begin, served_at, tid=tid,
                    args={**span_args, "coalesced_with": sharing - 1,
                          "deadline_missed": missed,
                          "served_from_cache": from_cache})
        self._g_pending.dec()
        req.future.telemetry = {
            "kernel": req.kernel,
            "graph_id": req.graph_id,
            "priority": req.priority,
            "generation": req.generation,
            "launch_index": self.launches,  # 1-based, in launch order
            "launch_wall_seconds": wall,
            "wall_share_seconds": wall_share,
            "coalesced_with": sharing - 1,
            "launch_batch_sources": batch_sources,
            "queue_seconds": serve_latency,
            "deadline_missed": missed,
            "cache_hit_sources": cache_hits,
            "served_from_cache": from_cache,
            "degraded": req.degraded,
            "exchange": exchange,
            "trace_id": req.trace_id,
        }
        req.future._set_result(result)

    # ----------------------------------------------------------- telemetry
    def telemetry(self) -> dict:
        """Pre-obs dict shape (a view over the metrics registry) plus the
        launch/request failure, auto-flush, admission, and result-cache
        counters."""
        cache = self.session.result_cache
        return {
            "requests_enqueued": self.requests_enqueued,
            "requests_served": self.requests_served,
            "pending": self.pending(),
            "launches": self.launches,
            "coalesced_requests": self.coalesced_requests,
            "dedup_hits": self.dedup_hits,
            "flushes": self.flushes,
            "deadlines_missed": self.deadlines_missed,
            "launches_failed": self.launches_failed,
            "requests_failed": self.requests_failed,
            "max_batch_sources": self.max_batch_sources,
            "max_delay": self.max_delay,
            "auto_flushes": self.auto_flushes,
            "requests_expired": self.requests_expired,
            "admission": (self.admission.as_dict()
                          if self.admission is not None else None),
            "admission_rejected": self.admission_rejected,
            "admission_degraded": self.admission_degraded,
            "admission_shed": self.admission_shed,
            "deadline_miss_rate": round(self._miss_window.rate, 4),
            "result_cache": cache.stats() if cache is not None else None,
        }


__all__ = ["AdmissionRejected", "DeadlineExceeded", "LABEL_KERNELS",
           "MicroBatchScheduler", "QueryFuture", "Request",
           "canonical_component_labels", "source_bucket"]
