"""Differential harness for the fused on-device traversal loops.

Every sharded kernel now runs its step loop as a single ``XLA::While``
under shard_map (``fused=True``, the default) instead of a host loop
that dispatches one step at a time (``fused=False``, kept as the
reference). This module locks the fusion in three ways:

* **bit-identity** — for all six kernels, the fused drivers must produce
  exactly the bits of the host-loop reference, across hot-prefix
  fractions {None, 0.05, 0.5} and ``cold_every`` {1, 4}, and the
  engine's serving configs {exact, bucketed, sharded} must agree with
  the `core/baselines.py` oracles;
* **dispatch collapse** — the obs registry's
  ``engine_dispatches_total`` must count exactly one host->device launch
  per fused query where the host loop pays one per step (O(steps) ->
  O(1)), and the per-step exchange accounting (`ExchangeStats`) must be
  unchanged by fusion;
* **convergence bounds** — hypothesis-generated random graphs assert a
  step-count upper bound from `ExchangeStats` (diameter-based for
  BFS/CC whose step count is a hop count; V-based for weighted SSSP,
  whose hop-limited relaxation count is not bounded by the unweighted
  diameter), so convergence regressions fail loudly, not just value
  regressions.

The 4-forced-device leg re-runs this whole module in a subprocess so the
same differential holds on a genuine 4-shard mesh.
"""
from __future__ import annotations

import os

import jax
import numpy as np
import pytest

from conftest import run_forced_four_devices
from repro.algos.graph_arrays import to_device
from repro.core.baselines import (bc_baseline, bfs_baseline, cc_baseline,
                                  pagerank_baseline, sssp_baseline)
from repro.core.dist import (ExchangeStats, make_distributed_bc,
                             make_distributed_bfs, make_distributed_cc,
                             make_distributed_pagerank,
                             make_distributed_sssp)
from repro.core.generators import powerlaw_community
from repro.engine import BatchedExecutor, EngineSession

SOURCES = np.array([0, 17, 203])

# (hot_prefix_fraction, cold_every): fraction None ignores the cadence
# (every step is a full exchange), so one config covers it
EXCHANGE_CONFIGS = [(None, 1), (0.05, 1), (0.05, 4), (0.5, 1), (0.5, 4)]


@pytest.fixture(scope="module")
def fused_graph():
    return powerlaw_community(400, avg_degree=6.0, seed=11)


@pytest.fixture(scope="module")
def mesh():
    n = jax.device_count()
    return jax.make_mesh((n,), ("data",))


def _pair(factory, mesh, **kw):
    """Build (fused_runner, host_runner, fused_stats, host_stats)."""
    sf, sh = ExchangeStats(), ExchangeStats()
    fused = factory(mesh=mesh, stats=sf, fused=True, **kw)
    host = factory(mesh=mesh, stats=sh, fused=False, **kw)
    return fused, host, sf, sh


def _assert_stats_match(sf: ExchangeStats, sh: ExchangeStats):
    """Fusion must not change the exchange ledger — only the dispatch
    count: the fused While replays the same per-step full/hot sequence
    the host loop recorded, in one launch instead of one per step."""
    assert sf.snapshot()[:5] == sh.snapshot()[:5], (
        f"exchange accounting diverged: fused={sf.as_dict()} "
        f"host={sh.as_dict()}")
    assert sf.dispatches < sh.dispatches or sh.steps <= 1
    assert sh.dispatches >= sh.steps  # host pays >= one launch per step


@pytest.mark.parametrize("fraction,cold_every", EXCHANGE_CONFIGS,
                         ids=[f"f{f}-c{c}" for f, c in EXCHANGE_CONFIGS])
@pytest.mark.parametrize("kernel", ["bfs", "sssp", "cc"])
def test_fused_matches_host_loop_minrelax(fused_graph, mesh, kernel,
                                          fraction, cold_every):
    """Fused while_loop == host step loop, bit for bit, for the
    min-relaxation traversals across the full exchange-config matrix."""
    g = fused_graph
    factory = {"bfs": make_distributed_bfs, "sssp": make_distributed_sssp,
               "cc": make_distributed_cc}[kernel]
    fused, host, sf, sh = _pair(factory, mesh, g=g,
                                hot_prefix_fraction=fraction,
                                cold_every=cold_every)
    if kernel == "cc":
        got, want = np.asarray(fused()), np.asarray(host())
    else:
        got, want = np.asarray(fused(SOURCES)), np.asarray(host(SOURCES))
    np.testing.assert_array_equal(got, want)
    _assert_stats_match(sf, sh)
    # one launch per run after fusion (cc runs once, bfs/sssp once batched)
    assert sf.dispatches == 1


def test_fused_matches_host_loop_pagerank(fused_graph, mesh):
    fused_run, host_run, sf, sh = _pair(
        lambda mesh, stats, fused: make_distributed_pagerank(
            fused_graph, mesh, stats=stats, fused=fused)[0], mesh)
    np.testing.assert_array_equal(np.asarray(fused_run()),
                                  np.asarray(host_run()))
    _assert_stats_match(sf, sh)
    assert sf.dispatches == 1


def test_fused_matches_host_loop_bc(fused_graph, mesh):
    fused_run, host_run, sf, sh = _pair(
        lambda mesh, stats, fused: make_distributed_bc(
            fused_graph, mesh, stats=stats, fused=fused), mesh)
    np.testing.assert_array_equal(np.asarray(fused_run(SOURCES)),
                                  np.asarray(host_run(SOURCES)))
    _assert_stats_match(sf, sh)
    # BC is three passes compiled into one program: still one launch
    assert sf.dispatches == 1


# --------------------------------------------------- engine-level parity
def _session(config: str, fused: bool = True) -> EngineSession:
    if config == "exact":
        return EngineSession(executor=BatchedExecutor(bucketing=False,
                                                      fused=fused),
                             redecide_min_queries=10**6)
    if config == "bucketed":
        return EngineSession(executor=BatchedExecutor(fused=fused),
                             redecide_min_queries=10**6)
    return EngineSession(executor=BatchedExecutor(fused=fused),
                         device_budget_bytes=1024,
                         redecide_min_queries=10**6)


@pytest.fixture(scope="module")
def engine_outputs(fused_graph):
    """kernel -> config -> output, fused sessions across all three
    serving configs plus the host-loop sharded reference."""
    g = fused_graph
    out: dict[str, dict[str, np.ndarray]] = {}
    sessions = {}
    for config in ("exact", "bucketed", "sharded"):
        sessions[config] = _session(config)
    sessions["sharded-hostloop"] = _session("sharded", fused=False)
    for name, session in sessions.items():
        gid = session.register(g, graph_id=f"fused-{name}",
                               expected_queries=256)
        for kernel in ("bfs", "sssp", "bc", "pr", "cc", "ccsv"):
            srcs = None if kernel in ("pr", "cc", "ccsv") else SOURCES
            out.setdefault(kernel, {})[name] = np.asarray(
                session.submit(gid, kernel, srcs))
    return out, sessions


@pytest.mark.parametrize("kernel", ["bfs", "sssp", "bc", "pr", "cc", "ccsv"])
def test_engine_fused_matches_host_reference(engine_outputs, kernel):
    """The fused sharded engine path is bit-identical to the retired
    host-loop path, end-to-end through EngineSession.submit."""
    out, _ = engine_outputs
    np.testing.assert_array_equal(out[kernel]["sharded"],
                                  out[kernel]["sharded-hostloop"])


@pytest.mark.parametrize("kernel", ["bfs", "sssp", "bc", "pr", "cc", "ccsv"])
@pytest.mark.parametrize("config", ["exact", "bucketed", "sharded"])
def test_engine_fused_matches_oracles(engine_outputs, fused_graph, config,
                                      kernel):
    """All three serving configs against the numpy oracles: exact for
    the integer kernels, allclose for the float ones."""
    out, _ = engine_outputs
    g = fused_graph
    got = out[kernel][config]
    if kernel == "bfs":
        want = np.stack([bfs_baseline(g, int(s)) for s in SOURCES])
        np.testing.assert_array_equal(got, want)
    elif kernel == "sssp":
        w = np.asarray(to_device(g).weights)
        want = np.stack([sssp_baseline(g, w, int(s)) for s in SOURCES])
        np.testing.assert_array_equal(got.astype(np.int64), want)
    elif kernel == "bc":
        np.testing.assert_allclose(got.sum(axis=0),
                                   bc_baseline(g, SOURCES),
                                   rtol=1e-3, atol=1e-3)
    elif kernel == "pr":
        np.testing.assert_allclose(got, pagerank_baseline(g),
                                   rtol=1e-4, atol=1e-7)
    else:
        np.testing.assert_array_equal(got, cc_baseline(g))


def test_dispatch_counts_collapse(engine_outputs):
    """After fusion every sharded query is exactly one host->device
    launch; the host-loop reference pays one per exchange step. Counted
    by the obs registry (`engine_dispatches_total`, surfaced through
    backend telemetry)."""
    _, sessions = engine_outputs
    fused_t = sessions["sharded"].executor.sharded.telemetry()
    host_t = sessions["sharded-hostloop"].executor.sharded.telemetry()
    assert fused_t["fused"] and not host_t["fused"]
    # one compile per kernel (runner factories are cached per graph),
    # one launch per query
    assert fused_t["dispatches"] == fused_t["queries_run"]
    assert host_t["dispatches"] >= host_t["hot_prefix"]["steps"]
    assert host_t["dispatches"] > host_t["queries_run"]
    # fusion must not change how much data the exchange moves
    assert (fused_t["hot_prefix"]["steps"],
            fused_t["hot_prefix"]["bytes_exchanged"]) == \
           (host_t["hot_prefix"]["steps"],
            host_t["hot_prefix"]["bytes_exchanged"])
    # single-device launches were already 1:1 with queries
    for name in ("exact", "bucketed"):
        t = sessions[name].executor.single.telemetry()
        assert t["dispatches"] == t["queries_run"]


def test_fused_dispatch_is_per_query_not_per_runner(fused_graph, mesh):
    """Re-running an already-compiled fused runner adds exactly one
    dispatch (and replays the full per-step exchange ledger)."""
    stats = ExchangeStats()
    run = make_distributed_bfs(fused_graph, mesh, hot_prefix_fraction=0.05,
                               cold_every=4, stats=stats, fused=True)
    run(SOURCES)
    before = stats.snapshot()
    run(SOURCES)
    delta = stats.delta(before)
    assert delta.dispatches == 1
    assert delta.steps > 1  # the steps are still visible, in one launch


# ------------------------------------------------ convergence properties
def _bfs_ecc(g, src: int) -> int:
    d = bfs_baseline(g, src)
    return int(d.max(initial=0))


def _und_diameter(g) -> int:
    from repro.core.traversal import bfs_levels
    und = g.undirected
    return max(int(bfs_levels(und, v).max(initial=0))
               for v in range(und.num_vertices))


def test_fused_random_graphs_match_oracles_with_step_bound():
    """Satellite: hypothesis graphs through the fused sharded drivers vs
    the numpy oracles, with convergence asserted from `ExchangeStats`:

    * BFS steps  <= ecc(src) + cold_every + 2 (hop count + cadence slack)
    * CC  steps  <= und_diameter + cold_every + 2
    * SSSP steps <= V + cold_every + 2 (weighted relaxation counts hops
      of shortest *weighted* paths, which the unweighted diameter does
      not bound — V does)
    """
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    from test_properties import graphs

    n_dev = jax.device_count()
    mesh = jax.make_mesh((n_dev,), ("data",))

    @settings(max_examples=10, deadline=None)
    @given(g=graphs(max_v=40, max_e=128),
           fraction=st.sampled_from([None, 0.3]),
           cold_every=st.sampled_from([1, 4]),
           src_seed=st.integers(0, 10_000))
    def check(g, fraction, cold_every, src_seed):
        src = int(np.random.default_rng(src_seed).integers(g.num_vertices))

        stats = ExchangeStats()
        bfs = make_distributed_bfs(g, mesh, hot_prefix_fraction=fraction,
                                   cold_every=cold_every, stats=stats)
        np.testing.assert_array_equal(np.asarray(bfs([src]))[0],
                                      bfs_baseline(g, src))
        assert stats.steps <= _bfs_ecc(g, src) + cold_every + 2
        assert stats.dispatches == 1

        stats = ExchangeStats()
        cc = make_distributed_cc(g, mesh, hot_prefix_fraction=fraction,
                                 cold_every=cold_every, stats=stats)
        np.testing.assert_array_equal(np.asarray(cc()), cc_baseline(g))
        assert stats.steps <= _und_diameter(g) + cold_every + 2
        assert stats.dispatches == 1

        stats = ExchangeStats()
        sssp = make_distributed_sssp(g, mesh, hot_prefix_fraction=fraction,
                                     cold_every=cold_every, stats=stats)
        w = np.asarray(to_device(g).weights)
        np.testing.assert_array_equal(
            np.asarray(sssp([src]))[0].astype(np.int64),
            sssp_baseline(g, w, src))
        assert stats.steps <= g.num_vertices + cold_every + 2
        assert stats.dispatches == 1

    check()


# ----------------------------------------------------- 4-device sharded
def test_fused_four_forced_devices():
    """Re-run this module on a genuine 4-shard mesh: the same fused ==
    host differential, exchange ledger parity and dispatch collapse must
    hold when the collectives actually cross devices. (The hypothesis
    leg is skipped in the child — compile-bound, and shard-count
    independent by construction.)"""
    res = run_forced_four_devices(
        ["-m", "pytest", "-q", os.path.abspath(__file__),
         "-k", "not four_forced and not random_graphs"], timeout=900)
    assert res.returncode == 0, \
        f"stdout={res.stdout[-4000:]}\nstderr={res.stderr[-2000:]}"
