"""Per-arch smoke tests (reduced configs) + consistency properties."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.models.transformer import (decode_step, forward, init_cache,
                                      init_params, loss_fn)

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32):
    if cfg.input_mode == "embeddings":
        return {"embeds": jax.random.normal(KEY, (b, s, cfg.d_model),
                                            jnp.bfloat16),
                "targets": jax.random.randint(KEY, (b, s), 0,
                                              cfg.vocab_size)}
    if cfg.prefix_tokens:
        return {"tokens": jax.random.randint(KEY, (b, s - cfg.prefix_tokens),
                                             0, cfg.vocab_size),
                "prefix": jax.random.normal(KEY, (b, cfg.prefix_tokens,
                                                  cfg.d_model), jnp.bfloat16)}
    return {"tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full configs carry the exact published shapes."""
    cfg = get_config(arch)
    expect = {
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expect


def test_moe_configs():
    m = get_config("mixtral-8x7b")
    assert (m.num_experts, m.experts_per_token) == (8, 2)
    k = get_config("moonshot-v1-16b-a3b")
    assert (k.num_experts, k.experts_per_token) == (64, 6)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_loss(arch):
    cfg = smoke_config(arch)
    params = init_params(cfg, KEY)
    batch = _batch(cfg)
    logits, aux = jax.jit(lambda p, b: forward(p, b, cfg))(params, batch)
    s = 32
    assert logits.shape == (2, s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    loss, metrics = jax.jit(lambda p, b: loss_fn(p, b, cfg))(params, batch)
    assert bool(jnp.isfinite(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_grads_finite_and_nonzero(arch):
    cfg = smoke_config(arch, layers=2)
    params = init_params(cfg, KEY)
    batch = _batch(cfg, b=1, s=16)
    grads = jax.jit(jax.grad(lambda p: loss_fn(p, batch, cfg)[0]))(params)
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g.astype(jnp.float32)).all())
               for g in leaves)
    total = sum(float(jnp.abs(g.astype(jnp.float32)).sum()) for g in leaves)
    assert total > 0


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if a != "hubert-xlarge"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode (token-by-token through the cache) reproduces
    the full forward logits — KV/state cache correctness."""
    cfg = smoke_config(arch, layers=2)
    cfg = dataclasses.replace(cfg, prefix_tokens=0)   # pure token stream
    params = init_params(cfg, KEY)
    b, s = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                cfg.vocab_size)
    full, _ = forward(params, {"tokens": tokens}, cfg)
    cache = init_cache(cfg, b, max_len=16)
    outs = []
    step = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg))
    for i in range(s):
        lg, cache = step(params, cache, tokens[:, i:i + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full, np.float32),
        rtol=0.15, atol=0.15)  # bf16 matmul reassociation tolerance
    # argmax agreement is the serving-relevant bar
    agree = (jnp.argmax(dec, -1) == jnp.argmax(full, -1)).mean()
    assert float(agree) > 0.95


def test_sliding_window_decode_matches_forward():
    """Mixtral's SWA ring-buffer cache vs full forward with window mask."""
    cfg = smoke_config("mixtral-8x7b", layers=2)
    params = init_params(cfg, KEY)
    b, s = 1, 16   # window=8 in smoke config: exercises wraparound
    tokens = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0,
                                cfg.vocab_size)
    full, _ = forward(params, {"tokens": tokens}, cfg)
    cache = init_cache(cfg, b, max_len=s)
    outs = []
    step = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg))
    for i in range(s):
        lg, cache = step(params, cache, tokens[:, i:i + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    agree = (jnp.argmax(dec, -1) == jnp.argmax(full, -1)).mean()
    assert float(agree) > 0.9


def test_moe_sorted_equals_unsorted_dispatch():
    """Locality-sorted (ragged) dispatch == dense gather dispatch."""
    from repro.models.moe import apply_moe, init_moe
    cfg = smoke_config("mixtral-8x7b", layers=2)
    p = init_moe(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, cfg.d_model),
                          jnp.bfloat16)
    y_sorted, aux1 = apply_moe(p, x, cfg)
    cfg_unsorted = dataclasses.replace(cfg, moe_locality_sort=False)
    y_dense, aux2 = apply_moe(p, x, cfg_unsorted)
    np.testing.assert_allclose(np.asarray(y_sorted, np.float32),
                               np.asarray(y_dense, np.float32),
                               rtol=0.1, atol=0.02)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-5)


def test_moe_aux_loss_balanced_routing():
    """Uniform router ⇒ aux ≈ 1 (switch normalization)."""
    from repro.models.moe import _route
    cfg = smoke_config("mixtral-8x7b")
    d, e = cfg.d_model, cfg.num_experts
    p = {"router": jnp.zeros((d, e), jnp.float32)}
    x = jax.random.normal(KEY, (128, d))
    _, _, aux = _route(p, x, cfg)
    assert abs(float(aux) - 1.0) < 0.05


def test_hubert_encoder_attends_bidirectionally():
    cfg = smoke_config("hubert-xlarge", layers=2)
    params = init_params(cfg, KEY)
    b, s = 1, 16
    em = jax.random.normal(KEY, (b, s, cfg.d_model), jnp.bfloat16)
    base, _ = forward(params, {"embeds": em}, cfg)
    em2 = em.at[:, -1].set(em[:, -1] + 10.0)   # perturb the LAST frame
    out, _ = forward(params, {"embeds": em2}, cfg)
    # encoder: early positions must change too
    delta = jnp.abs(out[:, 0] - base[:, 0]).max()
    assert float(delta) > 0


def test_causal_lm_ignores_future():
    cfg = smoke_config("qwen2.5-3b", layers=2)
    params = init_params(cfg, KEY)
    t1 = jax.random.randint(KEY, (1, 16), 0, cfg.vocab_size)
    t2 = t1.at[0, -1].set((t1[0, -1] + 7) % cfg.vocab_size)
    l1, _ = forward(params, {"tokens": t1}, cfg)
    l2, _ = forward(params, {"tokens": t2}, cfg)
    np.testing.assert_allclose(np.asarray(l1[:, :-1], np.float32),
                               np.asarray(l2[:, :-1], np.float32),
                               rtol=1e-5, atol=1e-5)


def test_paligemma_prefix_is_bidirectional():
    cfg = smoke_config("paligemma-3b", layers=2)
    params = init_params(cfg, KEY)
    b = 1
    tokens = jax.random.randint(KEY, (b, 12), 0, cfg.vocab_size)
    prefix = jax.random.normal(KEY, (b, cfg.prefix_tokens, cfg.d_model),
                               jnp.bfloat16)
    base, _ = forward(params, {"tokens": tokens, "prefix": prefix}, cfg)
    # perturb the LAST prefix position; the FIRST prefix position's output
    # must change (prefix-LM bidirectional over the image tokens)
    prefix2 = prefix.at[:, -1].set(prefix[:, -1] + 10.0)
    out, _ = forward(params, {"tokens": tokens, "prefix": prefix2}, cfg)
    assert float(jnp.abs(out[:, 0] - base[:, 0]).max()) > 0


def test_param_count_analytic_close_to_actual():
    for arch in ("qwen2.5-3b", "mixtral-8x7b", "rwkv6-3b"):
        cfg = smoke_config(arch, layers=2)
        params = init_params(cfg, KEY)
        actual = sum(int(np.prod(p.shape))
                     for p in jax.tree.leaves(params))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.35, \
            f"{arch}: analytic {analytic} vs actual {actual}"


def test_active_params_less_than_total_for_moe():
    cfg = get_config("mixtral-8x7b")
    assert cfg.active_param_count() < cfg.param_count()
    dense = get_config("qwen2.5-3b")
    assert dense.active_param_count() == dense.param_count()
