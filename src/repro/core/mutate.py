"""Edge-delta mutation for CSR graphs (the dynamic-graph substrate).

Serving graphs mutate: edges appear and disappear under load. The CSR
container (`csr.Graph`) memoizes derived views (`out_degree`,
`transpose`, `undirected`, ...) via ``cached_property``, so mutating its
arrays in place would silently serve stale views. `apply_edge_delta`
therefore builds a **fresh** `Graph` for every delta — no cache can go
stale because no populated cache survives — while transplanting the
degree caches it can update in O(V + |delta|) (a bincount-free update,
the expensive O(E) recomputes stay lazy).

Removal semantics are multiset: each listed ``(src, dst)`` pair removes
exactly one occurrence of that edge, so parallel edges survive until
each copy is removed. Removing an edge that does not exist raises — a
mutation stream that believes in edges the graph doesn't have is a bug
upstream, not something to paper over.

The returned `MutationDelta` is the O(|delta|)-sized summary the engine's
incremental probe maintenance consumes (`engine/registry.py`): which
vertices changed degree and by how much, without touching the O(V)
degree arrays on the mutation path.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .csr import Graph, from_edges, ranges_to_indices


@dataclasses.dataclass(frozen=True)
class MutationDelta:
    """O(|delta|)-sized account of one applied edge delta."""

    added: int
    removed: int
    changed_vertices: np.ndarray   # vertex ids whose degree changed
    out_degree_delta: np.ndarray   # per changed vertex, may be 0
    in_degree_delta: np.ndarray
    degree_delta: np.ndarray       # out + in, aligned with changed_vertices
    vertices_added: int = 0        # vertex-set growth (ids appended at top)

    @property
    def edges_changed(self) -> int:
        return self.added + self.removed


def _as_edge_pairs(edges, num_vertices: int,
                   what: str) -> tuple[np.ndarray, np.ndarray]:
    """Normalize an edge list (k, 2) array / pair iterable; validate ids."""
    if edges is None:
        empty = np.empty(0, dtype=np.int64)
        return empty, empty
    arr = np.asarray(edges, dtype=np.int64)
    if arr.size == 0:
        empty = np.empty(0, dtype=np.int64)
        return empty, empty
    try:
        arr = arr.reshape(-1, 2)
    except ValueError:
        raise ValueError(f"{what} must be (k, 2) edge pairs, "
                         f"got shape {arr.shape}") from None
    if arr.size and (int(arr.min()) < 0 or int(arr.max()) >= num_vertices):
        raise ValueError(
            f"{what} endpoints must be in [0, {num_vertices}); got "
            f"[{int(arr.min())}, {int(arr.max())}]")
    return arr[:, 0].copy(), arr[:, 1].copy()


def _sparse_degree_delta(touched: np.ndarray, add: np.ndarray,
                         rem: np.ndarray) -> np.ndarray:
    """Per-``touched``-vertex count of ``add`` minus ``rem`` endpoints."""
    delta = np.zeros(len(touched), dtype=np.int64)
    if add.size:
        np.add.at(delta, np.searchsorted(touched, add), 1)
    if rem.size:
        np.subtract.at(delta, np.searchsorted(touched, rem), 1)
    return delta


def apply_edge_delta(g: Graph, add_edges=None, remove_edges=None,
                     add_vertices: int = 0) -> tuple[Graph, MutationDelta]:
    """Apply an edge delta; returns ``(fresh_graph, delta_summary)``.

    ``add_vertices`` grows the vertex set by that many ids, appended at
    the top of the id range — ``add_edges`` may then reference the new
    ids (the search workload's incremental NSW inserts arrive this way,
    `search.knn_graph.nsw_insert_deltas`). With ``add_vertices=0`` the
    vertex set is fixed — deltas add/remove *edges* between existing
    vertices (a graph can drain to edgeless and regrow). The fresh graph
    keeps the `Graph` CSR invariants (rows ascending, per-row neighbor
    lists sorted) and carries the original ``communities``/``name``.
    An empty delta returns ``g`` itself (every cached view still valid).
    """
    if add_vertices < 0:
        raise ValueError(f"add_vertices must be >= 0, got {add_vertices}")
    n = g.num_vertices + int(add_vertices)
    asrc, adst = _as_edge_pairs(add_edges, n, "add_edges")
    rsrc, rdst = _as_edge_pairs(remove_edges, n, "remove_edges")
    if asrc.size == 0 and rsrc.size == 0 and add_vertices == 0:
        touched = np.empty(0, dtype=np.int64)
        zero = np.empty(0, dtype=np.int64)
        return g, MutationDelta(0, 0, touched, zero, zero.copy(), zero.copy())
    if rsrc.size and (rsrc >= g.num_vertices).any():
        raise ValueError("remove_edges references newly added vertices")

    key = g.edge_src.astype(np.int64) * np.int64(n) + g.indices
    key = np.sort(key, kind="stable")  # defensive: manual CSRs may be ragged
    if rsrc.size:
        rkey = rsrc * np.int64(n) + rdst
        r_uniq, r_counts = np.unique(rkey, return_counts=True)
        left = np.searchsorted(key, r_uniq, side="left")
        right = np.searchsorted(key, r_uniq, side="right")
        short = r_counts > (right - left)
        if short.any():
            missing = [(int(k // n), int(k % n)) for k in r_uniq[short][:5]]
            raise ValueError(
                f"remove_edges lists edges the graph does not hold "
                f"(or more copies than it holds): {missing}"
                f"{' ...' if int(short.sum()) > 5 else ''}")
        drop = np.zeros(len(key), dtype=bool)
        drop[ranges_to_indices(left, r_counts)] = True
        key = key[~drop]
    new_src = np.concatenate([key // n, asrc])
    new_dst = np.concatenate([key % n, adst])
    # per-vertex metadata (communities) doesn't extend to grown ids
    comms = g.communities if add_vertices == 0 else None
    new_g = from_edges(n, new_src, new_dst, communities=comms, name=g.name)

    # transplant the degree caches in O(V + |delta|): the delta fully
    # describes every endpoint change, so the fresh graph never pays the
    # O(E) bincount that `in_degree` would lazily recompute
    grow = (0, int(add_vertices))
    out_deg = np.pad(np.asarray(g.out_degree, dtype=np.int64), grow)
    in_deg = np.pad(np.asarray(g.in_degree, dtype=np.int64), grow)
    if asrc.size:
        np.add.at(out_deg, asrc, 1)
        np.add.at(in_deg, adst, 1)
    if rsrc.size:
        np.subtract.at(out_deg, rsrc, 1)
        np.subtract.at(in_deg, rdst, 1)
    new_g.__dict__["out_degree"] = out_deg.astype(np.int32)
    new_g.__dict__["in_degree"] = in_deg.astype(np.int32)
    new_g.__dict__["degree"] = (out_deg + in_deg).astype(np.int32)

    touched = np.unique(np.concatenate([asrc, adst, rsrc, rdst]))
    out_delta = _sparse_degree_delta(touched, asrc, rsrc)
    in_delta = _sparse_degree_delta(touched, adst, rdst)
    total = out_delta + in_delta
    changed = total != 0
    delta = MutationDelta(int(asrc.size), int(rsrc.size),
                          touched[changed], out_delta[changed],
                          in_delta[changed], total[changed],
                          vertices_added=int(add_vertices))
    return new_g, delta


__all__ = ["MutationDelta", "apply_edge_delta"]
