"""Dynamic graphs: update_graph deltas, patch reordering, async swaps.

The contract under test is the mutation tentpole: any sequence of edge
deltas applied through ``EngineSession.update_graph`` must leave the
session serving results bit-identical (allclose for the float kernels
pr/bc, same convention as test_scheduler.py) to a fresh session
registered with the final graph — across {exact, bucketed, sharded}
backends and both reorder tiers. The hypothesis property test generates
those sequences; regression tests cover the lifecycle bugfixes that
rode along (empty/edgeless probes, pinned-refresh drops, empty graph
ids), and the 4-forced-device leg re-runs the module on a genuine mesh.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np
import pytest

from conftest import run_forced_four_devices
from repro.core.csr import from_edges
from repro.core.diameter import estimate_diameter, two_sweep_diameter
from repro.core.generators import powerlaw_community
from repro.core.mutate import apply_edge_delta
from repro.core.patch_reorder import patch_permutation
from repro.engine import (BatchedExecutor, EngineSession, GraphRegistry,
                          PolicyDecision, ResultCache, decision_changed,
                          degree_histogram, gini_from_histogram,
                          hub_stats_from_histogram, probe_graph)
from repro.engine.registry import degree_gini
from repro.engine.session import _PendingSwap

FLOAT_KERNELS = ("pr", "bc")
KERNELS = ("bfs", "sssp", "bc", "pr", "cc", "ccsv")


def _session(**kw) -> EngineSession:
    kw.setdefault("redecide_min_queries", 10**6)
    kw.setdefault("async_full_reorder", False)  # deterministic by default
    return EngineSession(**kw)


def _make(config: str) -> EngineSession:
    if config == "exact":
        return _session(executor=BatchedExecutor(bucketing=False))
    if config == "sharded":
        return _session(device_budget_bytes=1024)
    assert config == "bucketed"
    return _session()


def _assert_matches(kernel: str, got, want) -> None:
    got, want = np.asarray(got), np.asarray(want)
    if kernel in FLOAT_KERNELS:
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
    else:
        np.testing.assert_array_equal(got, want)


def _edge_pairs(g) -> np.ndarray:
    """The graph's edge multiset as a (E, 2) original-id pair array."""
    return np.stack([np.asarray(g.edge_src, dtype=np.int64),
                     np.asarray(g.indices, dtype=np.int64)], axis=1)


def _random_delta(g, rng, n_add: int, n_remove: int):
    pairs = _edge_pairs(g)
    n_remove = min(n_remove, g.num_edges)
    remove = None
    if n_remove:
        idx = rng.choice(g.num_edges, size=n_remove, replace=False)
        remove = pairs[idx]
    add = None
    if n_add:
        add = rng.integers(0, g.num_vertices, size=(n_add, 2))
    return add, remove


# ------------------------------------------------------- core.mutate deltas
def test_apply_edge_delta_matches_fresh_rebuild():
    rng = np.random.default_rng(0)
    n, m = 60, 240
    src, dst = rng.integers(0, n, m), rng.integers(0, n, m)
    g = from_edges(n, src, dst, name="g")
    pairs = _edge_pairs(g)
    rem_idx = rng.choice(m, size=50, replace=False)
    add = rng.integers(0, n, size=(70, 2))
    new_g, delta = apply_edge_delta(g, add_edges=add,
                                    remove_edges=pairs[rem_idx])
    keep = np.ones(m, dtype=bool)
    keep[rem_idx] = False
    want = from_edges(n, np.concatenate([pairs[keep, 0], add[:, 0]]),
                      np.concatenate([pairs[keep, 1], add[:, 1]]), name="g")
    np.testing.assert_array_equal(new_g.indptr, want.indptr)
    np.testing.assert_array_equal(new_g.indices, want.indices)
    assert delta.added == 70 and delta.removed == 50
    assert delta.edges_changed == 120
    assert new_g.name == g.name


def test_apply_edge_delta_degree_accounting():
    rng = np.random.default_rng(1)
    n, m = 40, 160
    g = from_edges(n, rng.integers(0, n, m), rng.integers(0, n, m), name="g")
    add, remove = _random_delta(g, rng, 30, 25)
    new_g, delta = apply_edge_delta(g, add_edges=add, remove_edges=remove)
    # changed_vertices is a sorted id set; dense degree deltas match the
    # actual degree difference, and every listed vertex actually changed
    cv = delta.changed_vertices
    assert np.all(np.diff(cv) > 0)
    assert np.all(delta.degree_delta != 0)
    np.testing.assert_array_equal(
        delta.degree_delta, delta.out_degree_delta + delta.in_degree_delta)
    dense = np.zeros(n, dtype=np.int64)
    dense[cv] = delta.degree_delta
    np.testing.assert_array_equal(
        new_g.degree.astype(np.int64) - g.degree.astype(np.int64), dense)
    # per-direction deltas hold at the listed vertices (a vertex whose
    # out/in changes cancel has total 0 and is rightly absent)
    np.testing.assert_array_equal(
        delta.out_degree_delta,
        new_g.out_degree[cv].astype(np.int64)
        - g.out_degree[cv].astype(np.int64))
    np.testing.assert_array_equal(
        delta.in_degree_delta,
        new_g.in_degree[cv].astype(np.int64)
        - g.in_degree[cv].astype(np.int64))


def test_apply_edge_delta_transplants_degree_caches():
    rng = np.random.default_rng(2)
    n = 30
    g = from_edges(n, rng.integers(0, n, 90), rng.integers(0, n, 90),
                   name="g")
    add, remove = _random_delta(g, rng, 12, 10)
    new_g, _ = apply_edge_delta(g, add_edges=add, remove_edges=remove)
    # the O(V + delta) transplant pre-populates the cached_property slots
    for attr in ("out_degree", "in_degree", "degree"):
        assert attr in new_g.__dict__, f"{attr} cache not transplanted"
    scratch = from_edges(n, new_g.edge_src, new_g.indices, name="g")
    np.testing.assert_array_equal(new_g.out_degree, scratch.out_degree)
    np.testing.assert_array_equal(new_g.in_degree, scratch.in_degree)
    np.testing.assert_array_equal(new_g.degree, scratch.degree)
    assert new_g.out_degree.dtype == scratch.out_degree.dtype


def test_apply_edge_delta_multiset_removal():
    g = from_edges(3, [0, 0, 1], [1, 1, 2], name="m")  # 0->1 twice
    one, d1 = apply_edge_delta(g, remove_edges=[[0, 1]])
    assert one.num_edges == 2 and d1.removed == 1
    np.testing.assert_array_equal(one.indices[one.indptr[0]:one.indptr[1]],
                                  [1])  # one copy survives
    both, d2 = apply_edge_delta(g, remove_edges=[[0, 1], [0, 1]])
    assert both.num_edges == 1 and d2.removed == 2
    with pytest.raises(ValueError, match="does not hold"):
        apply_edge_delta(g, remove_edges=[[0, 1]] * 3)


def test_apply_edge_delta_validation():
    g = from_edges(4, [0, 1], [1, 2], name="v")
    with pytest.raises(ValueError):
        apply_edge_delta(g, remove_edges=[[2, 3]])        # absent edge
    with pytest.raises(ValueError, match="endpoints"):
        apply_edge_delta(g, add_edges=[[0, 4]])           # out of range
    with pytest.raises(ValueError, match="endpoints"):
        apply_edge_delta(g, add_edges=[[-1, 0]])
    with pytest.raises(ValueError, match=r"\(k, 2\)"):
        apply_edge_delta(g, add_edges=[[0, 1, 2]])        # bad shape


def test_apply_edge_delta_empty_is_identity():
    g = from_edges(4, [0, 1], [1, 2], name="v")
    same, delta = apply_edge_delta(g)
    assert same is g and delta.edges_changed == 0
    same, _ = apply_edge_delta(g, add_edges=np.empty((0, 2), dtype=np.int64))
    assert same is g


# -------------------------------------------------- core.patch_reorder tier
def test_patch_permutation_packs_hot_prefix_stably():
    rng = np.random.default_rng(3)
    n = 80
    g = from_edges(n, rng.integers(0, n, 400), rng.integers(0, n, 400),
                   name="p")
    perm = rng.permutation(n)
    hot = np.asarray(g.hot_mask(), dtype=bool)
    new_perm, new_inv, hot_len, info = patch_permutation(g, perm, 0)
    assert hot_len == int(hot.sum()) == info.hot_prefix_len
    # a valid bijection whose inverse matches
    np.testing.assert_array_equal(np.sort(new_perm), np.arange(n))
    np.testing.assert_array_equal(new_perm[new_inv], np.arange(n))
    # hot vertices fill exactly [0, hot_len)
    assert set(new_perm[hot].tolist()) == set(range(hot_len))
    # stability: relative served order preserved within each group
    for group in (hot, ~hot):
        ids = np.flatnonzero(group)
        np.testing.assert_array_equal(np.argsort(new_perm[ids]),
                                      np.argsort(perm[ids]))


def test_patch_permutation_identity_short_circuit():
    rng = np.random.default_rng(4)
    n = 50
    g = from_edges(n, rng.integers(0, n, 250), rng.integers(0, n, 250),
                   name="p")
    hot = np.asarray(g.hot_mask(), dtype=bool)
    # build a perm that already packs the hot set at the front
    order = np.concatenate([np.flatnonzero(hot), np.flatnonzero(~hot)])
    perm = np.empty(n, dtype=np.int64)
    perm[order] = np.arange(n)
    new_perm, _, hot_len, info = patch_permutation(g, perm, hot_len := int(
        hot.sum()))
    assert info.identity and info.moved == 0
    np.testing.assert_array_equal(new_perm, perm)


def test_patch_permutation_edge_cases():
    empty = from_edges(0, [], [], name="e")
    perm, inv, hot_len, info = patch_permutation(
        empty, np.empty(0, dtype=np.int64), 0)
    assert hot_len == 0 and info.identity and perm.size == inv.size == 0
    g = from_edges(3, [0], [1], name="s")
    with pytest.raises(ValueError, match="shape"):
        patch_permutation(g, np.arange(2), 0)


# ----------------------------------------- satellite: probe totality fixes
def test_probes_total_on_empty_and_edgeless_graphs():
    empty = from_edges(0, [], [], name="empty")
    assert two_sweep_diameter(empty) == 0
    assert estimate_diameter(empty) == 0
    assert empty.average_degree == 0.0
    p = probe_graph(empty)
    assert p.num_vertices == 0 and p.num_edges == 0
    assert p.avg_degree == 0.0 and p.hub_mass == 0.0

    edgeless = from_edges(5, [], [], name="edgeless")
    assert two_sweep_diameter(edgeless) == 0
    assert estimate_diameter(edgeless) == 0
    assert edgeless.average_degree == 0.0
    p = probe_graph(edgeless)
    assert p.num_edges == 0 and p.hub_fraction == 0.0
    assert np.isfinite(p.degree_gini)


# -------------------------------------------- incremental probe maintenance
def test_histogram_probes_match_direct_formulas():
    rng = np.random.default_rng(5)
    degrees = rng.integers(0, 40, size=500).astype(np.int64)
    hist = degree_histogram(degrees)
    assert int(hist.sum()) == 500
    np.testing.assert_allclose(gini_from_histogram(hist),
                               degree_gini(degrees), rtol=0, atol=1e-12)
    lam, hub_fraction, hub_mass = hub_stats_from_histogram(hist)
    np.testing.assert_allclose(lam, degrees.mean(), atol=1e-12)
    hot = degrees > lam
    np.testing.assert_allclose(hub_fraction, hot.mean(), atol=1e-12)
    np.testing.assert_allclose(hub_mass, degrees[hot].sum() / degrees.sum(),
                               atol=1e-12)


def test_registry_incremental_probes_match_full_reprobe():
    rng = np.random.default_rng(6)
    g = powerlaw_community(300, avg_degree=6.0, seed=9, name="probe")
    reg = GraphRegistry()
    entry = reg.add(g, expected_queries=64)
    diameter0 = entry.probes.diameter
    add, remove = _random_delta(g, rng, 15, 12)
    new_g, delta = apply_edge_delta(g, add_edges=add, remove_edges=remove)
    mode = reg.apply_mutation("probe", new_g, delta, drift_threshold=0.5)
    assert mode == "incremental"
    full = probe_graph(new_g)
    p = entry.probes
    assert p.num_edges == full.num_edges
    np.testing.assert_allclose(p.avg_degree, full.avg_degree, atol=1e-12)
    np.testing.assert_allclose(p.degree_gini, full.degree_gini, atol=1e-12)
    np.testing.assert_allclose(p.hub_fraction, full.hub_fraction, atol=1e-12)
    np.testing.assert_allclose(p.hub_mass, full.hub_mass, atol=1e-12)
    assert p.diameter == diameter0          # stale by design under patch
    assert entry.probe_drift > 0.0


def test_registry_drift_threshold_forces_full_reprobe():
    rng = np.random.default_rng(7)
    g = powerlaw_community(200, avg_degree=6.0, seed=10, name="drift")
    reg = GraphRegistry()
    entry = reg.add(g, expected_queries=64)
    add, remove = _random_delta(g, rng, 10, 10)
    new_g, delta = apply_edge_delta(g, add_edges=add, remove_edges=remove)
    mode = reg.apply_mutation("drift", new_g, delta, drift_threshold=0.0)
    assert mode == "full"
    assert entry.probe_drift == 0.0         # reset by the full re-probe
    assert entry.probes.diameter == probe_graph(new_g).diameter


# ------------------------------------------- satellite: registry empty ids
def test_registry_rejects_empty_graph_id():
    g = from_edges(4, [0, 1], [1, 2], name="ok")
    reg = GraphRegistry()
    with pytest.raises(ValueError, match="non-empty"):
        reg.add(g, graph_id="")
    unnamed = from_edges(4, [0, 1], [1, 2], name="")
    with pytest.raises(ValueError, match="empty name"):
        reg.add(unnamed)
    assert len(reg) == 0
    reg.add(unnamed, graph_id="explicit")   # explicit id still works
    assert "explicit" in reg


# ------------------------------------- satellite: pinned-refresh cache fix
def test_result_cache_pinned_refresh_at_capacity():
    cache = ResultCache(max_entries=8, max_pinned=1)
    row1, row2 = np.arange(3), np.arange(3) * 10
    cache.put("g", 0, "pr", -1, row1, pinned=True)
    # the pinned store is full; refreshing the SAME key must not be
    # dropped (the bug: the stale row stayed pinned forever)
    cache.put("g", 0, "pr", -1, row2, pinned=True)
    np.testing.assert_array_equal(cache.get("g", 0, "pr", -1), row2)
    assert cache.pinned_count == 1
    # a second distinct pinned key still demotes to the LRU (unchanged)
    cache.put("g", 0, "cc", -1, row1, pinned=True)
    assert cache.pinned_count == 1 and cache.entries == 2


# ------------------------------------------------------- policy re-decision
def test_decision_changed_compares_material_fields():
    d = PolicyDecision(scheme="lorder", kwargs={"kappa": 2}, reason="r",
                       predicted_gain=0.1)
    assert not decision_changed(None, None)
    assert decision_changed(None, d) and decision_changed(d, None)
    # reason / predicted gain churn on every decide; not material
    assert not decision_changed(d, dataclasses.replace(
        d, reason="other", predicted_gain=0.9))
    assert decision_changed(d, dataclasses.replace(d, scheme="hubsort"))
    assert decision_changed(d, dataclasses.replace(d, kwargs={"kappa": 3}))
    assert decision_changed(d, dataclasses.replace(d, backend="sharded"))
    assert decision_changed(d, dataclasses.replace(
        d, hot_prefix_fraction=0.25))


# ---------------------------------------------- update_graph: end to end
@pytest.mark.parametrize("config", ["exact", "bucketed", "sharded"])
def test_update_graph_matches_fresh_registration(config):
    rng = np.random.default_rng(8)
    g = powerlaw_community(400, avg_degree=8.0, seed=11, name="dyn")
    session = _make(config)
    gid = session.register(g, expected_queries=512)
    gen0 = session.registry.get(gid).generation
    for tier in ("patch", "patch", "full"):
        add, remove = _random_delta(session.registry.get(gid).graph,
                                    rng, 50, 40)
        summary = session.update_graph(gid, add_edges=add,
                                       remove_edges=remove, reorder=tier)
        assert summary["tier"] == tier
        assert summary["added"] == 50 and summary["removed"] == 40
    entry = session.registry.get(gid)
    assert entry.mutations == 3 and entry.generation >= gen0 + 3
    ref = _make(config)
    rid = ref.register(entry.graph, graph_id="fresh", expected_queries=512)
    for kernel in KERNELS:
        sources = [0, 17, 33] if kernel in ("bfs", "sssp", "bc") else None
        _assert_matches(kernel, session.submit(gid, kernel, sources),
                        ref.submit(rid, kernel, sources))
    tel = session.telemetry()["mutations"]
    assert tel["mutations"] == 3 and tel["patch_reorders"] == 2
    assert tel["edges_added"] == 150 and tel["edges_removed"] == 120


def test_update_graph_validation_and_noop():
    g = from_edges(8, [0, 1, 2, 3], [1, 2, 3, 4], name="v")
    session = _session()
    gid = session.register(g, expected_queries=8)
    gen0 = session.registry.get(gid).generation
    with pytest.raises(KeyError):
        session.update_graph("nope", add_edges=[[0, 1]])
    with pytest.raises(ValueError, match="tier"):
        session.update_graph(gid, add_edges=[[0, 1]], reorder="zap")
    with pytest.raises(ValueError):
        session.update_graph(gid, remove_edges=[[4, 0]])  # absent edge
    summary = session.update_graph(gid)                   # empty delta
    assert summary["tier"] == "noop"
    assert session.registry.get(gid).generation == gen0
    assert session.telemetry()["mutations"]["mutations"] == 0


def test_inflight_future_resolves_pre_mutation_generation(plc_graph):
    session = _session()
    gid = session.register(plc_graph, expected_queries=256)
    fut = session.enqueue(gid, "bfs", [2])
    gen0 = session.registry.get(gid).generation
    session.update_graph(gid, add_edges=[[2, 900]], reorder="patch")
    # the fence flushed the queue first: the future resolved under the
    # layout it was enqueued against, never the post-mutation one
    assert fut.done()
    assert fut.telemetry["generation"] == gen0
    assert session.registry.get(gid).generation == gen0 + 1
    ref = _session()
    rid = ref.register(plc_graph, graph_id="pre", expected_queries=256)
    _assert_matches("bfs", fut.result(), ref.submit(rid, "bfs", [2]))


def test_mutation_invalidates_result_cache(plc_graph):
    session = _session()
    gid = session.register(plc_graph, expected_queries=256)
    session.submit(gid, "pr")
    session.submit(gid, "pr")
    assert session.result_cache.hits >= 1
    session.update_graph(gid, add_edges=[[0, 1], [1, 0]], reorder="patch")
    assert session.result_cache.entries == 0   # every row invalidated
    got = session.submit(gid, "pr")
    ref = _session()
    rid = ref.register(session.registry.get(gid).graph, graph_id="post",
                       expected_queries=256)
    _assert_matches("pr", got, ref.submit(rid, "pr"))


def test_async_full_reorder_swaps_at_flush_boundary():
    rng = np.random.default_rng(9)
    g = powerlaw_community(300, avg_degree=8.0, seed=5, name="swap")
    session = _session()                       # inline reorder, fenced swap
    gid = session.register(g, expected_queries=512)
    add, remove = _random_delta(g, rng, 40, 30)
    summary = session.update_graph(gid, add_edges=add, remove_edges=remove,
                                   reorder="async")
    assert summary["full_reorder_scheduled"]
    assert gid in session._pending_swaps       # computed, awaiting a flush
    gen_patched = session.registry.get(gid).generation
    session.flush()
    entry = session.registry.get(gid)
    assert gid not in session._pending_swaps
    assert entry.generation == gen_patched + 1
    tel = session.telemetry()["mutations"]
    assert tel["layout_swaps"] == 1 and tel["layout_swaps_discarded"] == 0
    names = {ev["name"] for ev in session.tracer.events}
    assert {"mutate", "patch_reorder", "swap_layout"} <= names
    ref = _session()
    rid = ref.register(entry.graph, graph_id="fresh", expected_queries=512)
    for kernel in ("bfs", "cc"):
        sources = [1, 7] if kernel == "bfs" else None
        _assert_matches(kernel, session.submit(gid, kernel, sources),
                        ref.submit(rid, kernel, sources))


def test_stale_pending_swap_discarded_by_token():
    g = powerlaw_community(200, avg_degree=8.0, seed=12, name="stale")
    session = _session()
    gid = session.register(g, expected_queries=256)
    entry = session.registry.get(gid)
    gen0 = entry.generation
    session._pending_swaps[gid] = _PendingSwap(
        entry.decision, np.asarray(entry.perm).copy(), 0.0,
        token=entry.mutations - 1, trigger="stale")
    session.flush()
    assert gid not in session._pending_swaps
    assert entry.generation == gen0            # stale swap never applied
    tel = session.telemetry()["mutations"]
    assert tel["layout_swaps"] == 0 and tel["layout_swaps_discarded"] == 1


def test_threaded_async_reorders_all_accounted_for():
    rng = np.random.default_rng(10)
    g = powerlaw_community(300, avg_degree=8.0, seed=6, name="thr")
    session = _session(async_full_reorder=True)
    gid = session.register(g, expected_queries=512)
    scheduled = 0
    for _ in range(3):
        add, remove = _random_delta(session.registry.get(gid).graph,
                                    rng, 30, 20)
        summary = session.update_graph(gid, add_edges=add,
                                       remove_edges=remove, reorder="async")
        scheduled += int(summary["full_reorder_scheduled"])
        session.submit(gid, "bfs", [1])
    session.close()                            # joins workers, then drains
    tel = session.telemetry()["mutations"]
    assert tel["pending_swaps"] == []
    # the invariant: every scheduled reorder either swapped in at a flush
    # boundary or was discarded by the mutation-token fence — never lost,
    # never applied against a graph that no longer exists
    assert tel["layout_swaps"] + tel["layout_swaps_discarded"] == scheduled


@pytest.mark.parametrize("config", ["bucketed", "sharded"])
def test_drain_to_edgeless_and_regrow(config):
    g = powerlaw_community(120, avg_degree=4.0, seed=3, name="drain")
    session = _make(config)
    gid = session.register(g, expected_queries=128)
    pairs = _edge_pairs(session.registry.get(gid).graph)
    session.update_graph(gid, remove_edges=pairs, reorder="patch")
    assert session.registry.get(gid).graph.num_edges == 0
    ref = _make(config)
    rid = ref.register(from_edges(120, [], [], name="edgeless"),
                       expected_queries=128)
    _assert_matches("bfs", session.submit(gid, "bfs", [0]),
                    ref.submit(rid, "bfs", [0]))
    # regrow to the original multiset: results must match the original
    session.update_graph(gid, add_edges=pairs, reorder="full")
    ref2 = _make(config)
    rid2 = ref2.register(g, graph_id="orig", expected_queries=128)
    for kernel in ("bfs", "cc"):
        sources = [0, 5] if kernel == "bfs" else None
        _assert_matches(kernel, session.submit(gid, kernel, sources),
                        ref2.submit(rid2, kernel, sources))


# ----------------------------------------------------- property: sequences
def _run_mutation_sequence(config: str, seed: int, steps: int,
                           tiers, draws) -> None:
    """Shared driver: apply a random mutation sequence through the given
    tiers, then assert bit-identity (allclose for pr) against a fresh
    session registered with the final graph.

    ``draws(lo, hi, label)`` supplies the per-step delta sizes — an rng
    closure for the seeded leg, hypothesis draws for the property leg.
    """
    rng = np.random.default_rng(seed)
    n, m = 32, 96
    g = from_edges(n, rng.integers(0, n, m), rng.integers(0, n, m),
                   name="prop")
    session = _make(config)
    gid = session.register(g, graph_id="g", expected_queries=256)
    for step in range(steps):
        cur = session.registry.get(gid).graph
        k_rem = draws(0, min(cur.num_edges, 20), f"k_rem{step}")
        remove = None
        if k_rem:
            idx = rng.choice(cur.num_edges, size=k_rem, replace=False)
            remove = _edge_pairs(cur)[idx]
        k_add = draws(0, 20, f"k_add{step}")
        add = rng.integers(0, n, size=(k_add, 2)) if k_add else None
        session.update_graph(gid, add_edges=add, remove_edges=remove,
                             reorder=tiers[step % len(tiers)])
    final = session.registry.get(gid).graph
    ref = _make(config)
    rid = ref.register(final, graph_id="ref", expected_queries=256)
    for kernel, sources in (("bfs", [0, 5]), ("pr", None), ("cc", None)):
        _assert_matches(kernel, session.submit(gid, kernel, sources),
                        ref.submit(rid, kernel, sources))


@pytest.mark.parametrize("config", ["exact", "bucketed", "sharded"])
def test_update_graph_random_sequences_seeded(config):
    """Always-on leg of the sequence property: fixed seeds, mixed tiers."""
    for seed, tiers in ((13, ("patch", "full", "patch")),
                        (29, ("full", "patch"))):
        rng = np.random.default_rng(seed + 1000)
        _run_mutation_sequence(
            config, seed, steps=3, tiers=tiers,
            draws=lambda lo, hi, _label: int(rng.integers(lo, hi + 1)))


@pytest.mark.parametrize("config", ["exact", "bucketed", "sharded"])
def test_update_graph_property_random_sequences(config):
    """Random mutation sequences through random tiers stay bit-identical
    (allclose for pr) to registering the final graph fresh
    (hypothesis-driven when available)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=5, deadline=None)
    @given(data=st.data())
    def check(data):
        seed = data.draw(st.integers(0, 2**16), label="seed")
        steps = data.draw(st.integers(1, 3), label="steps")
        tiers = tuple(
            data.draw(st.sampled_from(["patch", "full"]), label=f"tier{i}")
            for i in range(steps))
        _run_mutation_sequence(
            config, seed, steps, tiers,
            draws=lambda lo, hi, label: data.draw(
                st.integers(lo, hi), label=label))

    check()


# -------------------------------------------------------- distributed leg
# ------------------------------------------------- vertex growth (v2)
def test_update_graph_add_vertices_grows_analytics_graph():
    g = powerlaw_community(200, avg_degree=6.0, seed=11, name="grow")
    session = _session()
    gid = session.register(g, expected_queries=256)
    n0 = g.num_vertices
    summary = session.update_graph(
        gid, add_edges=[[0, n0], [n0, 0], [n0, n0 + 1], [n0 + 1, n0]],
        add_vertices=2)
    assert summary["vertices_added"] == 2
    entry = session.registry.get(gid)
    assert entry.graph.num_vertices == n0 + 2
    # grown ids join the layout as a cold identity tail, perm stays valid
    assert len(entry.perm) == len(entry.inv_perm) == n0 + 2
    assert entry.perm[entry.inv_perm].tolist() == list(range(n0 + 2))
    # per-vertex metadata cannot extend to grown ids
    assert entry.graph.communities is None
    # grown vertices are served like any pre-existing source
    depth = session.submit(gid, "bfs", [n0])
    assert depth.shape == (1, n0 + 2)
    assert depth[0][n0] == 0 and depth[0][0] == 1 and depth[0][n0 + 1] == 1
    ref = _session()
    rid = ref.register(entry.graph, graph_id="fresh", expected_queries=256)
    _assert_matches("bfs", depth, ref.submit(rid, "bfs", [n0]))


def test_update_graph_add_vertices_validation():
    g = from_edges(6, [0, 1], [1, 2], name="vv")
    session = _session()
    gid = session.register(g, expected_queries=8)
    with pytest.raises(ValueError):
        apply_edge_delta(g, add_vertices=-1)
    with pytest.raises(ValueError):   # removals cannot touch grown ids
        session.update_graph(gid, add_edges=[[6, 0]],
                             remove_edges=[[6, 0]], add_vertices=1)
    with pytest.raises(ValueError):   # analytics graphs take no vectors
        session.update_graph(gid, add_edges=[[0, 2]],
                             vectors=np.zeros((1, 4), np.float32))
    # pure vertex growth with no edges is a real (non-noop) mutation
    gen0 = session.registry.get(gid).generation
    summary = session.update_graph(gid, add_vertices=1)
    assert summary["vertices_added"] == 1 and summary["tier"] != "noop"
    assert session.registry.get(gid).generation == gen0 + 1
    assert session.registry.get(gid).graph.num_vertices == 7


def test_mutations_four_forced_devices():
    """Re-run this module on 4 forced host devices so the sharded configs
    exercise a genuine mesh (same recipe as test_scheduler.py)."""
    res = run_forced_four_devices(
        ["-m", "pytest", "-q", os.path.abspath(__file__),
         "-k", "not four_forced"], timeout=900)
    assert res.returncode == 0, \
        f"stdout={res.stdout[-4000:]}\nstderr={res.stderr[-2000:]}"
