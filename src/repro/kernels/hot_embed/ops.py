"""Hot/cold embedding lookup: Pallas hot path + XLA cold overlay."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .hot_embed import ID_BLOCK, hot_gather_pallas
from .ref import embed_ref, hot_gather_ref


def hot_cold_lookup(ids, table, hot_size: int, *,
                    use_pallas: bool | None = None,
                    interpret: bool | None = None):
    """Embedding lookup where rows [0, hot_size) are served from the
    VMEM-resident hot slab and the Zipf tail from HBM."""
    on_tpu = jax.default_backend() == "tpu"
    use_pallas = on_tpu if use_pallas is None else use_pallas
    interpret = (not on_tpu) if interpret is None else interpret
    flat = ids.reshape(-1)
    pad = (-flat.shape[0]) % ID_BLOCK
    padded = jnp.pad(flat, (0, pad))
    if use_pallas:
        hot_rows = hot_gather_pallas(padded, table[:hot_size],
                                     interpret=interpret)
    else:
        hot_rows = hot_gather_ref(padded, table[:hot_size])
    is_cold = padded >= hot_size
    cold_rows = jnp.where(
        is_cold[:, None],
        jnp.take(table, jnp.where(is_cold, padded, hot_size), axis=0,
                 mode="clip"),
        0.0)
    out = (hot_rows + cold_rows)[: flat.shape[0]]
    return out.reshape(*ids.shape, table.shape[1])
