"""Graph registry: per-graph serving state + cheap structural probes.

The engine's reorder policy needs exactly the structural facts the paper
shows modulate reordering payoff — degree skew (§2.1 hotness) and diameter
(the κ = D/2 analysis) — but must obtain them at a cost far below a
reorder pass. The probes here are O(E) single passes: a degree Gini
coefficient, the hot-vertex fraction and hot edge mass (λ = avg degree,
the paper's threshold), and a single double-sweep BFS diameter bound.

Registry entries carry everything serving needs per graph: the original
layout (query ids stay in this space), the chosen permutation and its
inverse, the reordered ("served") layout, and the device arrays. Entries
also track *realized* query volume (``queries_observed``) independently
of the amortization ledger — the ledger resets on every re-decision, but
the volume history that triggers re-decisions must not.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from ..core.csr import Graph
from ..core.diameter import two_sweep_diameter


@dataclasses.dataclass(frozen=True)
class GraphProbes:
    """Cheap structural summary feeding the reorder policy."""

    num_vertices: int
    num_edges: int
    avg_degree: float
    degree_gini: float    # 0 = uniform degrees, →1 = extreme skew
    hub_fraction: float   # fraction of vertices with degree > λ (avg)
    hub_mass: float       # fraction of total degree held by hub vertices
    diameter: int         # double-sweep BFS lower bound
    probe_seconds: float


def degree_gini(degrees: np.ndarray) -> float:
    """Gini coefficient of the degree distribution (skew probe)."""
    d = np.sort(degrees.astype(np.float64))
    n = len(d)
    total = d.sum()
    if n == 0 or total == 0:
        return 0.0
    ranks = np.arange(1, n + 1, dtype=np.float64)
    return float(2.0 * (ranks * d).sum() / (n * total) - (n + 1) / n)


def probe_graph(g: Graph) -> GraphProbes:
    """Compute all policy probes in one pass over degrees + two BFS."""
    t0 = time.perf_counter()
    deg = g.degree
    hot = g.hot_mask()
    total = float(deg.sum())
    return GraphProbes(
        num_vertices=g.num_vertices,
        num_edges=g.num_edges,
        avg_degree=g.average_degree,
        degree_gini=degree_gini(deg),
        hub_fraction=float(hot.mean()) if g.num_vertices else 0.0,
        hub_mass=float(deg[hot].sum() / total) if total else 0.0,
        diameter=two_sweep_diameter(g),
        probe_seconds=time.perf_counter() - t0,
    )


@dataclasses.dataclass
class GraphEntry:
    """Per-graph serving state. Fields after ``expected_queries`` are
    populated by the session once the policy has run."""

    graph_id: str
    graph: Graph                      # original layout (query id space)
    probes: GraphProbes
    expected_queries: int             # volume hint; refreshed on re-decision
    perm: np.ndarray | None = None    # perm[old_id] = served_id
    inv_perm: np.ndarray | None = None
    served: Graph | None = None       # reordered layout actually executed
    arrays: object | None = None      # GraphArrays of `served` (single only)
    handle: object | None = None      # engine.backends.GraphHandle
    backend: str = "single"           # placement the policy chose
    bucket_shape: tuple | None = None  # padded (V_b, E_b) upload shape
    hot_prefix_fraction: float | None = None  # sharded exchange thinning
    # served-id prefix length considered "hot" under the current layout
    # (0 for identity/random layouts): result-cache entries whose source
    # permutes below this index are pinned (GRASP-style, result_cache.py)
    hot_prefix_len: int = 0
    reorder_seconds: float = 0.0
    decision: object | None = None    # engine.policy.PolicyDecision
    ledger: object | None = None      # engine.session.AmortizationLedger
    queries_observed: int = 0         # realized volume, survives re-decisions
    redecisions: int = 0
    # layout generation: bumped every time a policy decision is (re-)applied.
    # The scheduler translates each request through the generation current
    # at launch time and stamps it into the request's telemetry, so layout
    # replacements are observable and never straddle an in-flight future.
    generation: int = 0


class GraphRegistry:
    """Ingests graphs, probes them, and holds serving state by id."""

    def __init__(self):
        self._entries: dict[str, GraphEntry] = {}

    def add(self, graph: Graph, graph_id: str | None = None,
            expected_queries: int = 64) -> GraphEntry:
        gid = graph_id or graph.name
        if gid in self._entries:
            raise KeyError(f"graph id {gid!r} already registered")
        entry = GraphEntry(gid, graph, probe_graph(graph), expected_queries)
        self._entries[gid] = entry
        return entry

    def get(self, graph_id: str) -> GraphEntry:
        return self._entries[graph_id]

    def note_queries(self, graph_id: str, n: int = 1) -> int:
        """Count realized query batches against a graph; returns total."""
        entry = self._entries[graph_id]
        entry.queries_observed += n
        return entry.queries_observed

    def ids(self) -> list[str]:
        return list(self._entries)

    def __contains__(self, graph_id: str) -> bool:
        return graph_id in self._entries

    def __len__(self) -> int:
        return len(self._entries)
