"""k-NN search serving: builders, kernel parity, engine round-trips.

Covers the search subsystem end to end (docs/search.md):

- graph builders emit valid fixed-out-degree CSRs and the NSW insert
  path stays navigable across clusters (the diversity heuristic);
- the served `knn` kernel matches the host beam-search oracle
  bit-for-bit on integer-valued vectors (exact float32 sums), and holds
  recall >= 0.95 against the brute-force oracle on gaussian clusters;
- results are bit-identical across {kernel-vs-host, single/bucketed,
  sharded} execution and across {identity, full visitsort, patch}
  layouts — the composite (dist_bits, canonical_id) ranking key is the
  invariant under test;
- visit telemetry: per-vertex counts accumulate exactly (pad lanes
  excluded), flow into the registry EWMA, and drive the
  ``refresh_hotness`` full/patch tiers;
- vertex growth through ``update_graph(add_vertices=, vectors=)``.
"""
from __future__ import annotations

import os

import numpy as np
import pytest

from conftest import run_forced_four_devices
from repro.core.baselines import knn_search_baseline
from repro.core.generators import clustered_vectors
from repro.engine import EngineSession
from repro.search import (SearchParams, build_knn_graph, build_nsw_graph,
                          knn_brute_force, medoid_entry, nsw_insert_deltas,
                          pad_queries, query_digest, validate_search_graph,
                          visit_hot_mask, visit_order)

K_OUT = 8
K_RET = 10


@pytest.fixture(scope="module")
def corpus():
    vecs, labels = clustered_vectors(240, dim=8, num_clusters=5, seed=1)
    return vecs


@pytest.fixture(scope="module")
def nsw_graph(corpus):
    return build_nsw_graph(corpus, k=K_OUT)


def _queries(vecs, n=16, seed=0, jitter=0.01):
    rng = np.random.default_rng(seed)
    q = vecs[rng.integers(0, len(vecs), n)]
    return (q + rng.normal(0, jitter, q.shape)).astype(np.float32)


def _recall(got, oracle):
    k = oracle.shape[1]
    return float(np.mean([len(set(map(int, g)) & set(map(int, o))) / k
                          for g, o in zip(got, oracle)]))


# ---------------------------------------------------------------- builders
def test_builders_emit_valid_fixed_degree_csr(corpus, nsw_graph):
    for g in (build_knn_graph(corpus[:50], 4), nsw_graph):
        k = validate_search_graph(g)
        assert np.all(np.asarray(g.out_degree) == k)
    with pytest.raises(ValueError):
        build_knn_graph(corpus[:5], 5)     # k must be < n
    from repro.core.csr import from_edges
    ragged = from_edges(3, np.array([0, 0, 1]), np.array([1, 2, 0]))
    with pytest.raises(ValueError):        # ragged degrees rejected
        validate_search_graph(ragged)
    dup = from_edges(2, np.array([0, 0, 1, 1]), np.array([1, 1, 0, 0]))
    with pytest.raises(ValueError):        # duplicate non-self neighbors
        validate_search_graph(dup)


def test_nsw_graph_is_navigable_across_clusters(corpus, nsw_graph):
    """Cluster-sorted corpora are the failure mode: keep-the-nearest
    reverse links would converge to the (disconnected) exact k-NN graph.
    Every corpus point must find *itself* when queried exactly."""
    entry = medoid_entry(corpus)
    hits = 0
    probe = range(0, len(corpus), 7)
    for v in probe:
        ids, _ = knn_search_baseline(nsw_graph, corpus, corpus[v], entry,
                                     beam_width=32, k_return=1)
        hits += int(ids[0] == v)
    assert hits / len(list(probe)) >= 0.95


def test_medoid_entry_and_brute_force_tie_break(corpus):
    assert 0 <= medoid_entry(corpus) < len(corpus)
    dup = np.zeros((4, 3), np.float32)       # all-equal vectors: pure ties
    ids = knn_brute_force(dup, dup[:1], 3)
    assert ids.tolist() == [[0, 1, 2]]       # broken by id, deterministic


# ------------------------------------------------------------ serving glue
def test_query_digest_and_padding():
    q = np.arange(8, dtype=np.float32)
    assert query_digest(q) == query_digest(q.copy())
    assert query_digest(q) >= 0
    assert query_digest(q) != query_digest(q + 1)
    padded, valid, real = pad_queries(np.ones((5, 4), np.float32))
    assert padded.shape == (8, 4) and real == 5
    assert valid.sum() == 5 and valid[:5].all()
    padded, valid, real = pad_queries(np.ones((5, 4), np.float32),
                                      multiple=3)
    assert len(padded) % 3 == 0 and real == 5


def test_visit_order_is_a_hot_prefix_permutation():
    visits = np.array([0.0, 5.0, 1.0, 0.0, 9.0, 0.1])
    perm = visit_order(visits)
    assert sorted(perm) == list(range(6))
    hot = visit_hot_mask(visits)
    assert set(np.nonzero(hot)[0]) == {1, 4}
    assert perm[4] == 0 and perm[1] == 1     # hottest first
    cold = np.nonzero(~hot)[0]
    assert list(perm[cold]) == sorted(perm[cold])  # stable cold tail


# ------------------------------------------------------- kernel vs oracle
def test_kernel_matches_host_oracle_bit_for_bit_integer_vectors():
    """Integer-valued coordinates make float32 distance sums exact, so
    the device kernel and the host mirror must agree on every id —
    including tie-breaks, which the canonical-id key decides."""
    rng = np.random.default_rng(4)
    vecs = rng.integers(0, 12, (150, 6)).astype(np.float32)
    g = build_nsw_graph(vecs, k=6)
    entry = medoid_entry(vecs)
    queries = rng.integers(0, 12, (12, 6)).astype(np.float32)
    with EngineSession() as s:
        gid = s.register(g, "int-knn", vectors=vecs,
                         search_params=SearchParams(k_out=6, beam_width=16,
                                                    k_return=8))
        assert s.registry.get(gid).decision.scheme == "original"
        got = s.submit(gid, "knn", queries)
    for q, row in zip(queries, got):
        want, _ = knn_search_baseline(g, vecs, q, entry, beam_width=16,
                                      k_return=8)
        assert row.tolist() == want.tolist()


def test_visit_accounting_matches_host_and_masks_pad_lanes(corpus,
                                                           nsw_graph):
    entry = medoid_entry(corpus)
    queries = _queries(corpus, n=5, seed=3)   # pads 5 -> 8 device lanes
    with EngineSession() as s:
        gid = s.register(nsw_graph, "visits", vectors=corpus)
        s.submit(gid, "knn", queries)
        e = s.registry.get(gid)
    host_total = sum(int(knn_search_baseline(nsw_graph, corpus, q,
                                             entry)[1].sum())
                     for q in queries)
    assert e.visits_total == host_total       # pad lanes contribute 0
    assert e.visit_queries == 5
    assert e.visit_ewma is not None
    assert np.isclose(e.visit_ewma.sum(), host_total / 5)


# ----------------------------------------------------- engine round trips
def test_recall_at_10_through_engine(corpus, nsw_graph):
    queries = _queries(corpus, n=24, seed=0)
    oracle = knn_brute_force(corpus, queries, K_RET)
    with EngineSession() as s:
        gid = s.register(nsw_graph, "recall", vectors=corpus)
        got = s.submit(gid, "knn", queries)
    assert got.shape == (24, K_RET)
    assert _recall(got, oracle) >= 0.95


def test_bit_identity_across_layouts_and_backends(corpus, nsw_graph):
    """The acceptance invariant: identical ids from the identity layout,
    the full visitsort reorder, the patch-tier repack, a cache hit, and
    the sharded backend."""
    queries = _queries(corpus, n=16, seed=5)
    with EngineSession() as s:
        gid = s.register(nsw_graph, "bits", vectors=corpus)
        base = s.submit(gid, "knn", queries)

        r1 = s.refresh_hotness(gid)          # original -> visitsort
        assert r1["tier"] == "full"
        assert r1["scheme"] == "visitsort"
        assert r1["hotness_source"] == "visits"
        assert np.array_equal(s.submit(gid, "knn", queries), base)

        r2 = s.refresh_hotness(gid)          # same decision -> patch tier
        assert r2["tier"] == "patch"
        assert s._c_patches.value == 1
        assert np.array_equal(s.submit(gid, "knn", queries), base)

        hits0 = s.result_cache.hits          # repeat rides the cache
        assert np.array_equal(s.submit(gid, "knn", queries), base)
        assert s.result_cache.hits == hits0 + 16
        assert s.result_cache.pinned_count == 0   # digest keys never pin

    with EngineSession(device_budget_bytes=1024) as s2:   # force sharded
        gid2 = s2.register(nsw_graph, "bits-sh", vectors=corpus)
        assert s2.registry.get(gid2).backend == "sharded"
        assert np.array_equal(s2.submit(gid2, "knn", queries), base)


def test_refresh_hotness_sizes_prefix_from_visits(corpus, nsw_graph):
    with EngineSession() as s:
        gid = s.register(nsw_graph, "prefix", vectors=corpus)
        e = s.registry.get(gid)
        assert e.probes.family == "search"
        assert e.decision.scheme == "original"   # no telemetry yet
        s.submit(gid, "knn", _queries(corpus, n=16, seed=6))
        r = s.refresh_hotness(gid)
        assert r["tier"] == "full"
        assert e.decision.reason.startswith("search family")
        expected = int(round(e.probes.visit_hub_fraction
                             * e.graph.num_vertices))
        assert e.hot_prefix_len == expected > 0
        assert e.probes.visit_gini > 0
        rec = s.policy.history[-1]
        assert rec.family == "search"
        assert s.policy.calibrator.count("visitsort", family="search") == 1


def test_update_graph_grows_search_graph(corpus, nsw_graph):
    new_vecs, _ = clustered_vectors(30, dim=8, num_clusters=5, seed=9)
    nadd, add_e, rem_e = nsw_insert_deltas(nsw_graph, corpus, new_vecs)
    assert nadd == 30
    with EngineSession(async_full_reorder=False) as s:
        gid = s.register(nsw_graph, "grow", vectors=corpus)
        base_q = _queries(corpus, n=8, seed=7)
        s.submit(gid, "knn", base_q)
        info = s.update_graph(gid, add_edges=add_e, remove_edges=rem_e,
                              add_vertices=nadd, vectors=new_vecs)
        assert info["vertices_added"] == 30
        e = s.registry.get(gid)
        assert e.graph.num_vertices == len(corpus) + 30
        assert len(e.perm) == len(e.inv_perm) == len(e.vectors) \
            == len(corpus) + 30
        assert validate_search_graph(e.graph) == K_OUT
        # grown points are served and findable
        allv = np.concatenate([corpus, new_vecs])
        q2 = (new_vecs[:6] + 0.001).astype(np.float32)
        got = s.submit(gid, "knn", q2)
        assert _recall(got, knn_brute_force(allv, q2, K_RET)) >= 0.95
        # growth mismatches are rejected up front
        with pytest.raises(ValueError):
            s.update_graph(gid, add_vertices=2)          # vectors missing
        with pytest.raises(ValueError):
            s.update_graph(gid, add_vertices=2,
                           vectors=np.zeros((1, 8), np.float32))


def test_register_and_enqueue_validation(corpus, nsw_graph, tiny_graph):
    s = EngineSession()
    with pytest.raises(ValueError):
        s.register(nsw_graph, "bad-dim", vectors=corpus[:10])
    with pytest.raises(ValueError):          # k_out mismatch
        s.register(nsw_graph, "bad-k", vectors=corpus,
                   search_params=SearchParams(k_out=4))
    with pytest.raises(ValueError):          # search_params without vectors
        s.register(tiny_graph, "no-vecs",
                   search_params=SearchParams(k_out=2))
    gid = s.register(nsw_graph, "ok", vectors=corpus)
    with pytest.raises(ValueError):          # wrong query dimensionality
        s.enqueue(gid, "knn", np.ones((2, 3), np.float32))
    with pytest.raises(ValueError):          # empty batch
        s.enqueue(gid, "knn", np.empty((0, 8), np.float32))
    plain = s.register(tiny_graph, "plain")
    with pytest.raises(ValueError):          # knn needs a search graph
        s.enqueue(plain, "knn", np.ones((1, 8), np.float32))
    s.close()


# --------------------------------------------------------------- property
def test_random_clustered_corpora_property():
    """Hypothesis sweep: for random clustered vector sets the NSW build
    validates, stays navigable (exact-match queries find themselves),
    and the host oracle's ids are plain valid vertex ids."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(0, 2**16), n=st.integers(40, 90),
           dim=st.sampled_from([3, 6]), clusters=st.integers(2, 5))
    def check(seed, n, dim, clusters):
        vecs, _ = clustered_vectors(n, dim=dim, num_clusters=clusters,
                                    seed=seed)
        g = build_nsw_graph(vecs, k=4)
        assert validate_search_graph(g) == 4
        entry = medoid_entry(vecs)
        hits = 0
        probe = list(range(0, n, max(n // 10, 1)))
        for v in probe:
            ids, visited = knn_search_baseline(g, vecs, vecs[v], entry,
                                               beam_width=16, k_return=1)
            assert visited.shape == (n,) and 0 <= ids[0] < n
            hits += int(ids[0] == v)
        assert hits / len(probe) >= 0.8

    check()


# -------------------------------------------------------- distributed leg
def test_search_four_forced_devices():
    """Re-run this module on 4 forced host devices so the sharded knn
    path exercises a genuine mesh (same recipe as test_scheduler.py)."""
    res = run_forced_four_devices(
        ["-m", "pytest", "-q", os.path.abspath(__file__),
         "-k", "not four_forced"], timeout=900)
    assert res.returncode == 0, \
        f"stdout={res.stdout[-4000:]}\nstderr={res.stderr[-2000:]}"
