"""Distributed graph engine: 1-D edge-partitioned kernels via shard_map.

Scales the paper's workload to cluster meshes: edges are partitioned by
destination range (each shard owns a contiguous dst range = its slice of
the property array); a traversal step is

    local gather (remote props via all-gather) -> local segment-reduce

which is the pull-mode pattern of the paper mapped onto jax collectives.
After LOrder, hot vertices are concentrated in low id ranges, so the
all-gather payload that every shard actually *uses* is concentrated in a
small prefix — the cluster-level analogue of cache-line locality. The
`hot_prefix` variant exploits it by gathering only the hot prefix every
iteration and exchanging the cold remainder at lower frequency.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.6 exposes shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:  # 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

from .csr import Graph


def partition_edges(g: Graph, num_shards: int, edge_values=None):
    """Split COO edges by dst range; pad shards to equal edge counts.

    ``edge_values`` (optional, aligned with the graph's out-CSR edge
    order, e.g. SSSP weights) is partitioned identically and returned as
    a fifth array.
    """
    n = g.num_vertices
    per = -(-n // num_shards)  # dst ids [i*per, (i+1)*per)
    src = g.edge_src.astype(np.int32)
    dst = np.asarray(g.indices, dtype=np.int32)
    shard_of = dst // per
    order = np.argsort(shard_of, kind="stable")
    src, dst = src[order], dst[order]
    counts = np.bincount(shard_of, minlength=num_shards)
    emax = int(counts.max())
    s_pad = np.zeros((num_shards, emax), np.int32)
    d_pad = np.zeros((num_shards, emax), np.int32)
    valid = np.zeros((num_shards, emax), bool)
    if edge_values is not None:
        vals = np.asarray(edge_values)[order]
        v_pad = np.zeros((num_shards, emax), vals.dtype)
    off = 0
    for i, c in enumerate(counts):
        s_pad[i, :c] = src[off:off + c]
        d_pad[i, :c] = dst[off:off + c] - i * per  # local dst index
        valid[i, :c] = True
        if edge_values is not None:
            v_pad[i, :c] = vals[off:off + c]
        off += c
    if edge_values is not None:
        return s_pad, d_pad, valid, per, v_pad
    return s_pad, d_pad, valid, per


def make_distributed_pagerank(g: Graph, mesh: Mesh, axis: str = "data",
                              damping: float = 0.85, num_iters: int = 20):
    """Returns (step_fn, initial_rank) running PR over `axis` of `mesh`."""
    num_shards = mesh.shape[axis]
    s_pad, d_pad, valid, per = partition_edges(g, num_shards)
    n = g.num_vertices
    n_pad = per * num_shards
    outdeg = np.maximum(np.asarray(g.out_degree, np.float32), 1.0)
    outdeg_pad = np.ones(n_pad, np.float32)
    outdeg_pad[:n] = outdeg
    dangling_pad = np.zeros(n_pad, np.float32)
    dangling_pad[:n] = (np.asarray(g.out_degree) == 0).astype(np.float32)

    espec = NamedSharding(mesh, P(axis, None))
    vspec = NamedSharding(mesh, P(axis))
    s_sh = jax.device_put(s_pad, espec)
    d_sh = jax.device_put(d_pad, espec)
    v_sh = jax.device_put(valid, espec)
    deg_sh = jax.device_put(outdeg_pad, vspec)
    dang_sh = jax.device_put(dangling_pad, vspec)

    def step(rank, src_e, dst_e, val_e, deg, dang):
        # rank: (per,) local shard.  all-gather the full property array —
        # the collective whose *useful* payload LOrder concentrates.
        full = jax.lax.all_gather(rank, axis, tiled=True)       # (n_pad,)
        full_deg = jax.lax.all_gather(deg, axis, tiled=True)
        contrib = jnp.where(val_e[0], full[src_e[0]] / full_deg[src_e[0]], 0.0)
        summed = jax.ops.segment_sum(contrib, dst_e[0], num_segments=per)
        # dangling mass redistributed uniformly (GAP semantics)
        dangling = jax.lax.psum(jnp.sum(rank * dang), axis)
        out = (1.0 - damping) / n + damping * (summed + dangling / n)
        return out[None]

    sharded_step = jax.jit(_shard_map(
        step, mesh=mesh,
        in_specs=(P(axis), P(axis, None), P(axis, None), P(axis, None),
                  P(axis), P(axis)),
        out_specs=P(axis, None),
    ))

    def run(rank0=None):
        r = rank0 if rank0 is not None else jax.device_put(
            np.full(n_pad, 1.0 / n, np.float32), vspec)
        for _ in range(num_iters):
            r = sharded_step(r, s_sh, d_sh, v_sh, deg_sh,
                             dang_sh).reshape(n_pad)
        return r[:n]

    return run, vspec


def lower_distributed_pagerank(g: Graph, mesh: Mesh, axis: str = "data"):
    """Lower+compile one sharded PR step (dry-run hook for the graph engine)."""
    run, _ = make_distributed_pagerank(g, mesh, axis, num_iters=1)
    return run


# ------------------------------------------------- multi-source traversals
#
# Serving parity with the single-device engine: batched BFS / SSSP where
# the (S, V) property matrix is sharded along the *vertex* axis and each
# level/relaxation step all-gathers it. The outer iteration is a host
# loop with a device-side convergence flag (same structure as the PR
# driver above) — one sharded launch per level, bounded by eccentricity
# (BFS) or V (Bellman-Ford).

_INF_I32 = np.int32(2**31 - 1)


def _put_state(values: np.ndarray, mesh: Mesh, axis: str):
    """Upload an (S, n_pad) property matrix sharded over its vertex axis."""
    return jax.device_put(values, NamedSharding(mesh, P(None, axis)))


def make_distributed_bfs(g: Graph, mesh: Mesh, axis: str = "data"):
    """Returns run(sources) -> (S, V) BFS depths over `axis` of `mesh`."""
    num_shards = mesh.shape[axis]
    s_pad, d_pad, valid, per = partition_edges(g, num_shards)
    n, n_pad = g.num_vertices, per * num_shards
    espec = NamedSharding(mesh, P(axis, None))
    s_sh = jax.device_put(s_pad, espec)
    d_sh = jax.device_put(d_pad, espec)
    v_sh = jax.device_put(valid, espec)

    def step(depth, front, level, src_e, dst_e, val_e):
        # depth/front: (S, per) local vertex slices; edges: (1, e_local)
        full_front = jax.lax.all_gather(front, axis, axis=1, tiled=True)
        active = full_front[:, src_e[0]] & val_e[0]           # (S, e_local)
        touched = jax.vmap(
            lambda a: jax.ops.segment_max(a, dst_e[0], num_segments=per)
        )(active)
        new = touched & (depth < 0)
        depth = jnp.where(new, level + 1, depth)
        # replicated scalar per the P() out_spec: the host loop reads one
        # flag instead of reducing the whole sharded frontier each level
        alive = jax.lax.psum(new.any().astype(jnp.int32), axis)
        return depth, new, alive > 0

    sharded_step = jax.jit(_shard_map(
        step, mesh=mesh,
        in_specs=(P(None, axis), P(None, axis), P(),
                  P(axis, None), P(axis, None), P(axis, None)),
        out_specs=(P(None, axis), P(None, axis), P()),
    ))

    def run(sources):
        srcs = np.atleast_1d(np.asarray(sources, np.int64))
        s = srcs.size
        depth0 = np.full((s, n_pad), -1, np.int32)
        depth0[np.arange(s), srcs] = 0
        front0 = np.zeros((s, n_pad), bool)
        front0[np.arange(s), srcs] = True
        depth = _put_state(depth0, mesh, axis)
        front = _put_state(front0, mesh, axis)
        # do-while: the initial frontier is never empty (sources exist)
        for level in range(n):
            depth, front, alive = sharded_step(depth, front,
                                               jnp.int32(level),
                                               s_sh, d_sh, v_sh)
            if not bool(alive):
                break
        return depth[:, :n]

    return run


def make_distributed_sssp(g: Graph, mesh: Mesh, axis: str = "data",
                          canonical_ids=None):
    """Returns run(sources) -> (S, V) Bellman-Ford distances.

    Weights are the engine's canonical per-edge hash
    (`algos.graph_arrays.edge_weights`, relabel-invariant through
    ``canonical_ids``), so sharded distances match the single-device
    executor exactly.
    """
    from ..algos.graph_arrays import edge_weights

    num_shards = mesh.shape[axis]
    w = edge_weights(g.edge_src, g.indices, canonical_ids)
    s_pad, d_pad, valid, per, w_pad = partition_edges(g, num_shards,
                                                      edge_values=w)
    n, n_pad = g.num_vertices, per * num_shards
    espec = NamedSharding(mesh, P(axis, None))
    s_sh = jax.device_put(s_pad, espec)
    d_sh = jax.device_put(d_pad, espec)
    v_sh = jax.device_put(valid, espec)
    w_sh = jax.device_put(w_pad.astype(np.int32), espec)

    def step(dist, src_e, dst_e, val_e, w_e):
        full = jax.lax.all_gather(dist, axis, axis=1, tiled=True)
        du = full[:, src_e[0]]                                # (S, e_local)
        cand = jnp.where(val_e[0] & (du != _INF_I32),
                         du + w_e[0], _INF_I32)
        relaxed = jax.vmap(
            lambda c: jax.ops.segment_min(c, dst_e[0], num_segments=per)
        )(cand)
        new = jnp.minimum(dist, relaxed)
        # replicated convergence flag: psum makes it identical on every
        # shard, as the P() out_spec requires
        changed = jax.lax.psum((new != dist).any().astype(jnp.int32), axis)
        return new, changed > 0

    sharded_step = jax.jit(_shard_map(
        step, mesh=mesh,
        in_specs=(P(None, axis), P(axis, None), P(axis, None),
                  P(axis, None), P(axis, None)),
        out_specs=(P(None, axis), P()),
    ))

    def run(sources):
        srcs = np.atleast_1d(np.asarray(sources, np.int64))
        s = srcs.size
        dist0 = np.full((s, n_pad), _INF_I32, np.int32)
        dist0[np.arange(s), srcs] = 0
        dist = _put_state(dist0, mesh, axis)
        for _ in range(n):
            dist, changed = sharded_step(dist, s_sh, d_sh, v_sh, w_sh)
            if not bool(changed):
                break
        return dist[:, :n]

    return run
