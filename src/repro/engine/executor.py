"""Batched query executor: the routing facade over execution backends.

This is the serving-side answer to the paper's framing (section 4: the
traversal kernels whose cache behaviour reordering improves): the same
jitted kernels the benchmarks time, run behind caches so a query stream
pays compile and launch costs once, not per query. The mechanics live in
the backends (backends.py):

* **compile sharing** — `SingleDeviceBackend` caches jitted callables per
  ``(kernel, V_bucket, E_bucket)`` and pads CSR uploads to geometric
  shape buckets, so graphs of *different* sizes share compiled
  executables, not just exact (V, E) matches. Telemetry counts
  hits/misses so serving cost is attributable.
* **source batching** — multi-source queries run as one ``vmap``-batched
  device launch (`algos.kernels.bfs_multi`/`sssp_multi`/`bc_multi`)
  instead of a Python loop. Batches are padded to power-of-two buckets so
  a stream of ragged batch sizes hits a handful of compiled shapes.
* **sharding** — `ShardedBackend` routes queries through `core.dist`
  edge-partitioned kernels (all six: bfs/sssp/bc/pr/cc/ccsv) when a
  graph exceeds the per-device budget; the placement decision — and the
  `hot_prefix_fraction` governing the sharded exchange — is the
  policy's, see policy.py.

`BatchedExecutor.run` accepts either a `GraphHandle` from ``prepare``
(routed to the handle's backend) or raw `GraphArrays` (legacy
single-device path, exact shapes — what PR 1 callers and the benchmarks'
reference timings use).
"""
from __future__ import annotations

import jax.numpy as jnp

from ..algos.graph_arrays import GraphArrays
from ..core.csr import Graph
from .backends import (GLOBAL, MULTI_SOURCE, VECTOR_SOURCE, ExecutionBackend,
                       GraphHandle, ShardedBackend, SingleDeviceBackend,
                       build_kernel, source_bucket)

# Backwards-compatible aliases: PR 1 exposed these names here.
_build = build_kernel
_bucket = source_bucket


class BatchedExecutor:
    """Runs kernels against prepared graph handles through their backend."""

    def __init__(self, single: SingleDeviceBackend | None = None,
                 num_shards: int | None = None, bucketing: bool = True,
                 max_cached_executables: int | None = None,
                 metrics=None, fused: bool = True,
                 pallas_pr: bool | str = "auto"):
        self.single = single or SingleDeviceBackend(
            bucketing=bucketing,
            max_cached_executables=max_cached_executables,
            metrics=metrics, pallas_pr=pallas_pr)
        # one registry spans the facade and both backends — a session
        # adopts it so every engine metric shares a namespace (obs.py)
        self.metrics = self.single.metrics
        self._num_shards = num_shards
        self._fused = fused
        self._sharded: ShardedBackend | None = None
        self._tracer = None

    @property
    def sharded(self) -> ShardedBackend:
        """Lazy: building a mesh is pointless until a graph needs one."""
        if self._sharded is None:
            self._sharded = ShardedBackend(num_shards=self._num_shards,
                                           metrics=self.metrics,
                                           fused=self._fused)
            self._sharded.tracer = self._tracer
        return self._sharded

    @property
    def tracer(self):
        return self._tracer

    @tracer.setter
    def tracer(self, tracer) -> None:
        """Hand the session's tracer to both backends (for launch-internal
        spans: device_sync, compile misses, per-step exchanges)."""
        self._tracer = tracer
        self.single.tracer = tracer
        if self._sharded is not None:
            self._sharded.tracer = tracer

    def backend(self, name: str) -> ExecutionBackend:
        if name == "single":
            return self.single
        if name == "sharded":
            return self.sharded
        raise ValueError(f"unknown backend {name!r}; have single, sharded")

    # -------------------------------------------------------------- prepare
    def prepare(self, graph: Graph, backend: str = "single",
                canonical_ids=None,
                hot_prefix_fraction: float | None = None,
                search=None) -> GraphHandle:
        """Upload one graph through the named backend; returns its handle.

        ``hot_prefix_fraction`` only applies to the sharded backend (the
        single-device path has no per-step exchange to thin out).
        ``search`` (a `repro.search.SearchSpec`) attaches the served-order
        vector corpus that makes the handle servable by ``knn_search``.
        """
        if backend == "sharded":
            return self.sharded.prepare(
                graph, canonical_ids=canonical_ids,
                hot_prefix_fraction=hot_prefix_fraction, search=search)
        return self.backend(backend).prepare(graph,
                                             canonical_ids=canonical_ids,
                                             search=search)

    # ------------------------------------------------------------------ run
    def run(self, target, kernel: str, sources=None) -> jnp.ndarray:
        """Execute one query batch.

        Multi-source kernels return per-source rows ``(S, V)``; global
        kernels ignore ``sources`` and return ``(V,)``. Results are
        blocked on (serving latency = device latency) and sliced to the
        graph's real vertex count.
        """
        if isinstance(target, GraphHandle):
            return self.backend(target.backend).run(target, kernel, sources)
        if isinstance(target, GraphArrays):
            return self.single.run_arrays(target, kernel, sources)
        raise TypeError(f"expected GraphHandle or GraphArrays, "
                        f"got {type(target).__name__}")

    # ---------------------------------------------------- legacy telemetry
    @property
    def cache_hits(self) -> int:
        return self.single.cache_hits

    @property
    def cache_misses(self) -> int:
        return self.single.cache_misses

    @property
    def queries_run(self) -> int:
        sharded = self._sharded.queries_run if self._sharded else 0
        return self.single.queries_run + sharded

    @property
    def sources_run(self) -> int:
        sharded = self._sharded.sources_run if self._sharded else 0
        return self.single.sources_run + sharded

    def telemetry(self) -> dict:
        # legacy top-level keys + cross-backend totals; the detail
        # (cached keys, bucketing stats, shard counts) lives per backend
        return {
            "compile_cache_hits": self.cache_hits,
            "compile_cache_misses": self.cache_misses,
            "queries_run": self.queries_run,
            "sources_run": self.sources_run,
            "single": self.single.telemetry(),
            "sharded": self._sharded.telemetry() if self._sharded else None,
        }


__all__ = ["GLOBAL", "MULTI_SOURCE", "VECTOR_SOURCE", "BatchedExecutor",
           "GraphHandle", "ShardedBackend", "SingleDeviceBackend"]
