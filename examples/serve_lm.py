"""Serving example — continuous-batching decode with batched requests.

Loads a smoke-scale model (rwkv6 by default: O(1)/token state, the long-
context family), enqueues a burst of synthetic requests, and serves them
through the continuous-batching loop used by repro/launch/serve.py.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch mixtral-8x7b]
"""
import argparse
import time

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-3b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    from repro.configs import smoke_config
    from repro.launch.serve import serve_loop, synthetic_requests
    from repro.models.transformer import init_params

    cfg = smoke_config(args.arch, layers=2)
    if cfg.is_encoder:
        raise SystemExit("encoder-only arch has no decode step")
    print(f"[serve] {args.arch} (smoke scale), {args.slots} slots, "
          f"{args.requests} requests, T={args.temperature}")
    params = init_params(cfg, jax.random.PRNGKey(0))
    reqs = synthetic_requests(args.requests, cfg.vocab_size,
                              plen=(4, 16), gen=(8, 32))
    t0 = time.time()
    done = serve_loop(cfg, params, reqs, batch_slots=args.slots,
                      max_len=256, temperature=args.temperature)
    dt = time.time() - t0

    toks = sum(len(r.out) for r in done)
    lat = [r.t_done - r.t_enqueue for r in done]
    ttft = [r.t_first - r.t_enqueue for r in done if r.t_first]
    print(f"[serve] {len(done)} requests, {toks} tokens, {dt:.1f}s "
          f"({toks / dt:.1f} tok/s aggregate)")
    print(f"[serve] latency p50/p95 {np.percentile(lat, 50):.2f}/"
          f"{np.percentile(lat, 95):.2f}s; "
          f"ttft p50 {np.percentile(ttft, 50):.2f}s")
    sample = done[0]
    print(f"[serve] request 0: prompt {len(sample.prompt)} toks -> "
          f"{sample.out[:12]}...")


if __name__ == "__main__":
    main()
