"""Session front-end: register graphs, enqueue/submit queries, telemetry.

``EngineSession`` ties the subsystem together: registration probes the
graph (registry), picks and applies a reordering *and a placement*
(policy: single-device bucketed upload, or sharded across devices when
the CSR footprint exceeds the device budget — see backends.py), uploads
the served layout through the chosen backend, and opens an amortization
ledger.

The primary query API is the **request plane** (scheduler.py):
``enqueue(...)`` returns a `QueryFuture` and nothing launches until a
flush boundary, where the `MicroBatchScheduler` coalesces pending
multi-source requests into one vmapped launch, deduplicates concurrent
global-kernel requests, and drains in priority/deadline order.
``submit`` remains as enqueue + flush sugar — the exact blocking
behaviour it always had, one request riding a one-element micro-batch.
Either way sources are translated into the served id space at launch
time, results are translated back (component-label *values* are
canonicalized to original vertex ids too — scheduler.py's
`canonical_component_labels`), and callers never see the internal layout
or the placement.

A registration-time decision is **not final**. The session tracks
realized query volume per graph, and when it diverges from the
registration hint past ``redecide_factor`` — or the ledger shows the
chosen reorder will never amortize (realized gain <= 0) — it re-runs the
policy with the updated volume and the calibrator's fitted strengths,
re-reorders in place, and resets the ledger. Re-decisions are capped,
logged, and visible in ``telemetry()`` (docs/policy.md walks the
lifecycle).

The ledger is deliberately conservative: reorder cost is *measured*;
per-query savings are *estimated* from the cache simulator's realized
miss-rate reduction applied to measured query wall time (wall time on
this host includes XLA overheads that dilute cache effects, so the
simulator ratio is the paper-faithful signal). benchmarks/engine.py
measures both layouts directly for the honest wall-clock version.
"""
from __future__ import annotations

import dataclasses
import math
import threading

import numpy as np

from ..cache.sim import estimate_miss_rate, scaled_config
from ..core.csr import Graph
from ..core.mutate import apply_edge_delta
from ..core.patch_reorder import patch_permutation
from ..search.knn_graph import medoid_entry, validate_search_graph
from ..search.serve import SearchParams, SearchSpec, visit_hot_mask
from .executor import MULTI_SOURCE, VECTOR_SOURCE, BatchedExecutor
from .obs import Clock, MetricsRegistry, ProfilerHook, Tracer
from .policy import (AdmissionPolicy, PolicyDecision, ReorderPolicy,
                     decision_changed)
from .registry import GraphEntry, GraphRegistry
from .result_cache import ResultCache
from .scheduler import (LABEL_KERNELS, MicroBatchScheduler, QueryFuture,
                        canonical_component_labels)


@dataclasses.dataclass
class AmortizationLedger:
    """Tracks whether one reorder has paid for itself yet.

    Placement changes the break-even math: on the sharded backend each
    traversal step pays an all-gather whose cost locality does not
    remove, so the miss-rate gain only applies to the compute fraction of
    a launch. ``gain_discount`` (< 1 for sharded graphs) scales the gain
    before savings are booked — sharded reorders take proportionally more
    queries to amortize, which is exactly what the re-decision trigger
    should see. The hot-prefix exchange shrinks exactly that collective
    cost, so a sharded graph serving with ``hot_prefix_fraction`` gets a
    *milder* discount: the base discount scaled by the fraction of
    full-exchange bytes still paid (`EngineSession._gain_discount`).
    """

    reorder_seconds: float
    realized_gain: float          # fractional miss-rate reduction
    queries_served: int = 0
    sources_served: int = 0
    query_seconds: float = 0.0
    estimated_saved_seconds: float = 0.0
    estimated_lost_seconds: float = 0.0
    backend: str = "single"
    gain_discount: float = 1.0    # fraction of the gain that reaches wall

    def record_query(self, num_sources: int, wall_seconds: float) -> None:
        self.queries_served += 1
        self.sources_served += num_sources
        self.query_seconds += wall_seconds
        # time this query would have cost on the original layout, assuming
        # wall ∝ property misses: t_before = t_after / (1 - gain)
        gain = min(self.realized_gain * self.gain_discount, 0.95)
        if gain > 0:
            self.estimated_saved_seconds += wall_seconds * gain / (1 - gain)
        elif gain < 0:
            # a regressing reorder must not book negative "savings" that
            # silently shrink the total — surface the loss on its own line
            self.estimated_lost_seconds += wall_seconds * -gain / (1 - gain)

    @property
    def regressed(self) -> bool:
        """True when the reorder made cache behaviour worse."""
        return self.realized_gain < 0

    @property
    def amortized(self) -> bool:
        return self.estimated_saved_seconds >= self.reorder_seconds

    @property
    def break_even_queries(self) -> float:
        """Queries needed to repay the reorder at the observed rate."""
        if self.queries_served == 0 or self.estimated_saved_seconds <= 0:
            return float("inf")
        per_query = self.estimated_saved_seconds / self.queries_served
        return self.reorder_seconds / per_query

    def as_dict(self) -> dict:
        # strict-JSON shape: a never-amortizing reorder reports
        # break_even_queries=None plus an explicit flag, never the
        # non-standard Infinity literal json.dumps would otherwise emit
        be = self.break_even_queries
        never = math.isinf(be)
        return {**dataclasses.asdict(self),
                "regressed": self.regressed,
                "amortized": self.amortized,
                "break_even_queries": None if never else be,
                "break_even_never": never}


@dataclasses.dataclass(frozen=True)
class _PendingSwap:
    """A completed async full reorder waiting for a flush boundary.

    ``token`` is the entry's mutation count when the reorder was
    scheduled: if the graph mutated again while LOrder ran, the perm
    describes a graph that no longer exists and the swap is discarded.
    """

    decision: PolicyDecision
    perm: np.ndarray
    reorder_seconds: float
    token: int
    trigger: str


class EngineSession:
    """enqueue(...) -> QueryFuture / submit(...) -> results (original ids)."""

    def __init__(self, policy: ReorderPolicy | None = None,
                 registry: GraphRegistry | None = None,
                 executor: BatchedExecutor | None = None,
                 cache_cfg=None,
                 redecide_factor: float = 4.0,
                 redecide_min_queries: int = 8,
                 max_redecisions: int = 3,
                 device_budget_bytes: int | None = None,
                 num_shards: int | None = None,
                 sharded_gain_discount: float = 0.5,
                 max_batch_sources: int | None = None,
                 max_delay: float | None = 0.25,
                 auto_flush_interval: float | None = None,
                 admission: AdmissionPolicy | None = None,
                 result_cache: "ResultCache | bool" = True,
                 result_cache_entries: int = 4096,
                 result_cache_max_age_s: float | None = None,
                 result_cache_max_bytes: int | None = None,
                 clock: Clock | None = None,
                 tracer: Tracer | None = None,
                 profiler_dir: str | None = None,
                 fused: bool = True,
                 probe_drift_threshold: float = 0.5,
                 async_full_reorder: bool = True):
        # an explicitly supplied policy carries its own budget; the
        # session-level knob only configures the default policy
        self.policy = policy or ReorderPolicy(
            device_budget_bytes=device_budget_bytes)
        self.registry = registry or GraphRegistry()
        self.executor = executor or BatchedExecutor(num_shards=num_shards,
                                                    fused=fused)
        self.cache_cfg = cache_cfg  # None = scaled_config per graph
        self.redecide_factor = redecide_factor
        self.redecide_min_queries = redecide_min_queries
        self.max_redecisions = max_redecisions
        self.sharded_gain_discount = sharded_gain_discount
        self.redecision_log: list[dict] = []
        # observability plane (obs.py): ONE clock every latency number is
        # read from, ONE metrics registry (adopted from the executor so
        # backend counters land in the same namespace), ONE tracer the
        # executor's backends share for launch-internal spans
        self.clock = clock or Clock()
        self.metrics_registry: MetricsRegistry = self.executor.metrics
        self.tracer = tracer or Tracer(clock=self.clock)
        self.executor.tracer = self.tracer
        self.profiler = ProfilerHook(profiler_dir)
        m = self.metrics_registry
        self._c_registered = m.counter("engine_graphs_registered_total",
                                       "graphs registered with the session")
        self._c_reorders = m.counter("engine_reorders_total",
                                     "policy decisions applied (incl. "
                                     "registration)")
        self._c_redecisions = m.counter("engine_redecisions_total",
                                        "re-decisions that replaced a layout")
        # dynamic-graph plane (update_graph): counters + async-swap state
        self.probe_drift_threshold = probe_drift_threshold
        self.async_full_reorder = async_full_reorder
        self._pending_swaps: dict[str, _PendingSwap] = {}
        self._reorder_threads: list[threading.Thread] = []
        self._c_mutations = m.counter("engine_mutations_total",
                                      "edge deltas applied via update_graph")
        self._c_edges_added = m.counter("engine_edges_added_total",
                                        "edges added across all mutations")
        self._c_edges_removed = m.counter("engine_edges_removed_total",
                                          "edges removed across all mutations")
        self._c_patches = m.counter("engine_patch_reorders_total",
                                    "incremental hot-prefix patches applied")
        self._c_swaps = m.counter("engine_layout_swaps_total",
                                  "async full reorders swapped in at a "
                                  "flush boundary")
        self._c_swaps_discarded = m.counter(
            "engine_layout_swaps_discarded_total",
            "async full reorders invalidated by a newer mutation")
        # cross-request result cache (result_cache.py): True builds one in
        # the session's metrics namespace, False disables it, or pass a
        # pre-configured ResultCache (its own metrics registry is kept)
        if isinstance(result_cache, ResultCache):
            self.result_cache: ResultCache | None = result_cache
        elif result_cache:
            self.result_cache = ResultCache(max_entries=result_cache_entries,
                                            registry=m,
                                            max_age_s=result_cache_max_age_s,
                                            max_bytes=result_cache_max_bytes,
                                            clock=self.clock.now)
        else:
            self.result_cache = None
        self.scheduler = MicroBatchScheduler(
            self, max_batch_sources=max_batch_sources,
            max_delay=max_delay, admission=admission)
        if auto_flush_interval is not None:
            self.scheduler.start_auto_flush(auto_flush_interval)

    def metrics(self) -> MetricsRegistry:
        """The session-wide metrics registry (``.snapshot()`` /
        ``.to_prometheus()`` — docs/observability.md has the catalog)."""
        return self.metrics_registry

    def start_profiler(self) -> bool:
        """Begin a ``jax.profiler`` trace (needs ``profiler_dir``)."""
        return self.profiler.start()

    def stop_profiler(self) -> bool:
        return self.profiler.stop()

    # ----------------------------------------------------------- lifecycle
    def close(self, drain: bool = True) -> None:
        """Stop the background auto-flush thread (if any), wait for any
        in-flight async full reorders, and, by default, drain every
        pending request so no future is left dangling (the drain's final
        flush also applies any completed layout swap)."""
        self.scheduler.stop_auto_flush()
        for t in self._reorder_threads:
            t.join(timeout=120.0)
        self._reorder_threads.clear()
        if drain:
            self.scheduler.drain()

    def __enter__(self) -> "EngineSession":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # on an exception path still tear the thread down, but don't let a
        # drain launch shadow the original error
        self.close(drain=exc_type is None)

    # ----------------------------------------------------------- register
    def register(self, graph: Graph, graph_id: str | None = None,
                 expected_queries: int = 64, vectors=None,
                 search_params: SearchParams | None = None) -> str:
        """Register a graph for serving; returns its id.

        Passing ``vectors`` (one float32 row per vertex) registers the
        graph as a **search graph** (``family="search"``): the graph must
        be a valid fixed-out-degree k-NN graph (`search.knn_graph`), the
        ``knn`` kernel becomes enqueueable against it, and the policy
        decides from *visit* telemetry rather than degree skew (degrees
        are uniform by construction — docs/search.md). ``search_params``
        defaults to ``SearchParams(k_out=<graph degree>)``; its ``k_out``
        must match the graph's fixed out-degree.
        """
        family = "analytics"
        if vectors is not None:
            vecs = np.ascontiguousarray(vectors, dtype=np.float32)
            if vecs.ndim != 2 or len(vecs) != graph.num_vertices:
                raise ValueError(
                    f"vectors must be ({graph.num_vertices}, d); got "
                    f"shape {vecs.shape}")
            k_out = validate_search_graph(graph)
            if search_params is None:
                search_params = SearchParams(k_out=k_out)
            elif search_params.k_out != k_out:
                raise ValueError(
                    f"search_params.k_out={search_params.k_out} but the "
                    f"graph's fixed out-degree is {k_out}")
            family = "search"
        elif search_params is not None:
            raise ValueError("search_params requires vectors=")
        with self.tracer.span("register", graph_id=graph_id or graph.name):
            with self.tracer.span("probe", graph_id=graph_id or graph.name):
                entry = self.registry.add(graph, graph_id, expected_queries,
                                          family=family)
            if family == "search":
                entry.vectors = vecs
                entry.search_params = search_params
                entry.entry_point = medoid_entry(vecs)
            decision = self.policy.decide(entry.probes, expected_queries)
            self._apply_decision(entry, decision)
        self._c_registered.inc()
        return entry.graph_id

    def _search_spec(self, entry: GraphEntry) -> SearchSpec | None:
        """Layout-bound SearchSpec for the entry's *current* permutation
        (None for analytics graphs). Built fresh on every (re)prepare so
        the served-order vector matrix always matches the layout."""
        if entry.vectors is None:
            return None
        return SearchSpec(
            vectors=np.ascontiguousarray(entry.vectors[entry.inv_perm]),
            entry=int(entry.perm[entry.entry_point]),
            canon=np.asarray(entry.inv_perm, dtype=np.int32),
            params=entry.search_params)

    def _visits_for(self, entry: GraphEntry) -> np.ndarray | None:
        """Visit EWMA padded to the current vertex count (update_graph
        may have grown the vertex set since telemetry last arrived)."""
        v = entry.visit_ewma
        if v is not None and len(v) < entry.graph.num_vertices:
            v = np.pad(v, (0, entry.graph.num_vertices - len(v)))
        return v

    def _apply_decision(self, entry: GraphEntry, decision: PolicyDecision,
                        perm: np.ndarray | None = None,
                        reorder_seconds: float | None = None) -> None:
        """Reorder ``entry.graph`` per ``decision`` and (re)build serving
        state: permutations, served layout, device arrays, policy record,
        fresh ledger. Used at registration, on re-decision, and (with a
        ``perm`` precomputed off the request path) when an async full
        reorder swaps in at a flush boundary.

        Bumps the entry's layout ``generation``: the scheduler stamps
        every served request with the generation whose perm translated
        it, and only re-decides at flush boundaries, so no in-flight
        future ever straddles this replacement.
        """
        entry.decision = decision
        entry.generation += 1
        if self.result_cache is not None:
            # the generation key already makes the old layout's rows
            # unreachable; this reclaims exactly the stale graph's memory
            self.result_cache.invalidate_graph(entry.graph_id)
        if perm is None:
            t0 = self.clock.now()
            with self.tracer.span("reorder", graph_id=entry.graph_id,
                                  scheme=decision.scheme,
                                  generation=entry.generation):
                perm = np.asarray(
                    self.policy.reorder_fn(
                        decision,
                        visits=self._visits_for(entry))(entry.graph))
            entry.reorder_seconds = self.clock.now() - t0
        else:
            perm = np.asarray(perm)
            # the reorder wall was paid off the request path; book it so
            # the ledger still amortizes against the true cost
            entry.reorder_seconds = (reorder_seconds
                                     if reorder_seconds is not None else 0.0)
        self._c_reorders.inc()
        self.metrics_registry.histogram(
            "engine_reorder_seconds", "wall cost of applying one decision",
            scheme=decision.scheme).observe(entry.reorder_seconds)

        entry.perm = perm
        inv = np.empty_like(perm)
        inv[perm] = np.arange(len(perm))
        entry.inv_perm = inv
        if decision.scheme == "original":
            # fast path: no reorder, no benefit to measure — skip the
            # (graph-sized) cache simulation entirely
            entry.served = entry.graph
            before = after = 0.0
        else:
            entry.served = entry.graph.apply_permutation(perm)
            cfg = self.cache_cfg or scaled_config(entry.graph)
            before = estimate_miss_rate(entry.graph, cfg)
            after = estimate_miss_rate(entry.served, cfg)
        # canonical_ids = inverse perm keeps SSSP edge weights identical to
        # the original layout, so served results match original-layout runs
        with self.tracer.span("prepare", graph_id=entry.graph_id,
                              backend=decision.backend):
            entry.handle = self.executor.prepare(
                entry.served, backend=decision.backend, canonical_ids=inv,
                hot_prefix_fraction=decision.hot_prefix_fraction,
                search=self._search_spec(entry))
        entry.backend = decision.backend
        entry.bucket_shape = entry.handle.bucket
        entry.hot_prefix_fraction = decision.hot_prefix_fraction
        # locality layouts pack hubs into a low-id prefix; identity/random
        # layouts have no hot prefix to pin result-cache entries against.
        # Visit-ordered layouts size the prefix from the *observed* hot
        # set rather than the (uniform, for search graphs) degree one.
        hot_frac = (entry.probes.visit_hub_fraction
                    if decision.hotness_source == "visits"
                    else entry.probes.hub_fraction)
        entry.hot_prefix_len = (
            0 if decision.scheme in ("original", "random")
            else int(round(hot_frac * entry.graph.num_vertices)))
        entry.arrays = entry.handle.arrays  # None when served sharded

        rec = self.policy.record(entry.graph_id, decision, before, after,
                                 entry.reorder_seconds,
                                 family=entry.probes.family)
        entry.ledger = AmortizationLedger(entry.reorder_seconds,
                                          rec.realized_gain,
                                          backend=decision.backend,
                                          gain_discount=self._gain_discount(
                                              decision))

    def _gain_discount(self, decision: PolicyDecision) -> float:
        """Fraction of the miss-rate gain booked as wall savings.

        Single-device serving books the full gain. Sharded serving books
        ``sharded_gain_discount`` — collectives dilute locality savings —
        but the hot-prefix exchange removes part of that collective cost:
        with fraction f gathered per step and a full exchange every k
        steps, roughly ``f + (1 - f)/k`` of the full-exchange bytes are
        still paid, and only that share of the dilution applies.
        """
        if decision.backend != "sharded":
            return 1.0
        base = self.sharded_gain_discount
        f = decision.hot_prefix_fraction
        if not f:
            return base
        k = max(self.executor.sharded.cold_every, 1)
        exchange_ratio = min(f + (1.0 - f) / k, 1.0)
        return round(1.0 - (1.0 - base) * exchange_ratio, 4)

    # ------------------------------------------------------ dynamic graphs
    def update_graph(self, graph_id: str, add_edges=None, remove_edges=None,
                     *, reorder: str = "auto", add_vertices: int = 0,
                     vectors=None) -> dict:
        """Apply an edge delta to a registered graph (the mutation API).

        Edges are ``(k, 2)`` original-id pairs; removal is multiset
        (each pair removes one occurrence, missing edges raise). The
        mutation runs under a scheduler **fence**: every in-flight
        request for this graph is served under its pre-mutation
        generation first, then the plane's lock is held while the CSR is
        rebuilt (`core.mutate`), probes refresh incrementally or fully
        past the drift threshold (`registry.apply_mutation`), the layout
        is **patched** — a stable O(V) hot-prefix repack
        (`core.patch_reorder`) instead of a full reorder — and the
        mutated CSR is re-uploaded/re-bucketed through the backend under
        a bumped generation (every result-cache row invalidated).

        ``reorder`` picks the tier:

        - ``"patch"`` — incremental patch only (the request-path cost).
        - ``"auto"`` (default) — patch now; if the refreshed probes flip
          the policy decision (`policy.decision_changed`), additionally
          run the full reorder *asynchronously* off the request path and
          swap it in at a later flush boundary.
        - ``"async"`` — patch now, always schedule the async full reorder.
        - ``"full"`` — synchronous full reorder (blocks for LOrder).

        ``add_vertices`` grows the vertex set by that many ids, appended
        at the top of the original id range (``add_edges`` may reference
        them). New vertices join the layout as a cold identity tail —
        the next patch or full reorder places them properly. For search
        graphs, ``vectors`` must supply the ``(add_vertices, d)`` rows of
        the new vertices (`search.knn_graph.nsw_insert_deltas` produces
        both halves of that delta).

        Returns a summary dict (tier, probe mode, generation, walls).
        """
        if reorder not in ("auto", "patch", "async", "full"):
            raise ValueError(f"unknown reorder tier {reorder!r}")
        entry = self.registry.get(graph_id)  # KeyError on unknown id
        new_vecs = None
        if entry.vectors is not None:
            d = entry.vectors.shape[1]
            if (vectors is None) != (add_vertices == 0):
                raise ValueError(
                    "search graphs take add_vertices= and vectors= "
                    "together (one vector row per new vertex)")
            if vectors is not None:
                new_vecs = np.ascontiguousarray(vectors, dtype=np.float32)
                if new_vecs.shape != (int(add_vertices), d):
                    raise ValueError(
                        f"vectors must be ({int(add_vertices)}, {d}); "
                        f"got shape {new_vecs.shape}")
        elif vectors is not None:
            raise ValueError("vectors= requires a search graph "
                             "(registered with vectors=)")
        t0 = self.clock.now()
        with self.scheduler.fence(graph_id):
            with self.tracer.span("mutate", graph_id=graph_id,
                                  tier=reorder):
                n_old = entry.graph.num_vertices
                new_graph, delta = apply_edge_delta(
                    entry.graph, add_edges, remove_edges,
                    add_vertices=add_vertices)
                if delta.edges_changed == 0 and delta.vertices_added == 0:
                    return {"graph_id": graph_id, "added": 0, "removed": 0,
                            "vertices_added": 0,
                            "tier": "noop", "probe_mode": "none",
                            "generation": entry.generation,
                            "full_reorder_scheduled": False,
                            "mutate_seconds": 0.0}
                # a full reorder computed against the pre-mutation graph
                # describes a layout for a graph that no longer exists
                if self._pending_swaps.pop(graph_id, None) is not None:
                    self._c_swaps_discarded.inc()
                probe_mode = self.registry.apply_mutation(
                    graph_id, new_graph, delta,
                    drift_threshold=self.probe_drift_threshold)
                if delta.vertices_added:
                    # grown ids join the layout as a cold identity tail
                    # (served ids n_old..n-1); both tiers below rebuild
                    # the served CSR from this extended permutation
                    tail = np.arange(n_old, new_graph.num_vertices)
                    entry.perm = np.concatenate(
                        [np.asarray(entry.perm, dtype=np.int64), tail])
                    entry.inv_perm = np.concatenate(
                        [np.asarray(entry.inv_perm, dtype=np.int64), tail])
                    if new_vecs is not None:
                        entry.vectors = np.concatenate(
                            [entry.vectors, new_vecs])
                self._c_mutations.inc()
                self._c_edges_added.inc(delta.added)
                self._c_edges_removed.inc(delta.removed)
                schedule_full, trigger, fresh = False, None, None
                if reorder == "full":
                    tier = "full"
                    volume = max(entry.queries_observed,
                                 entry.expected_queries)
                    self._apply_decision(
                        entry, self.policy.decide(entry.probes, volume))
                else:
                    tier = "patch"
                    self._apply_patch(entry)
                    if reorder == "async":
                        schedule_full, trigger = True, "requested"
                    elif reorder == "auto":
                        volume = max(entry.queries_observed,
                                     entry.expected_queries)
                        fresh = self.policy.decide(entry.probes, volume)
                        if decision_changed(entry.decision, fresh):
                            schedule_full = True
                            trigger = "decision-changed"
                if schedule_full:
                    self._schedule_full_reorder(entry, trigger,
                                                decision=fresh)
            wall = self.clock.now() - t0
            self.metrics_registry.histogram(
                "engine_mutate_seconds",
                "wall cost of one update_graph call (fence to return)",
                tier=tier).observe(wall)
        return {"graph_id": graph_id,
                "added": delta.added, "removed": delta.removed,
                "vertices_added": delta.vertices_added,
                "tier": tier, "probe_mode": probe_mode,
                "generation": entry.generation,
                "full_reorder_scheduled": schedule_full,
                "reorder_seconds": entry.reorder_seconds,
                "mutate_seconds": wall}

    def _apply_patch(self, entry: GraphEntry,
                     hot_mask: np.ndarray | None = None) -> None:
        """Incremental patch tier: stable hot-prefix repack + re-upload.

        Keeps the current decision; bumps the generation (invalidating
        every cached row); re-packs the newly-hot vertices into the hot
        prefix with one stable O(V) pass — no graph traversal, no cache
        simulation — and re-uploads/re-buckets the mutated CSR through
        the entry's backend. Identity/random layouts have no hot prefix
        to maintain, so they keep their permutation and only re-upload.
        ``hot_mask`` overrides the degree-based hot set — the visit
        telemetry path (`refresh_hotness`) passes ``visit_hot_mask``.
        """
        decision = entry.decision
        entry.generation += 1
        if self.result_cache is not None:
            self.result_cache.invalidate_graph(entry.graph_id)
        # reorder_seconds keeps `_apply_decision`'s semantics — the cost
        # of *computing the permutation* (here the stable O(V) repack, vs
        # the full tier's LOrder pass); the served rebuild and re-upload
        # are paid by both tiers and land in engine_mutate_seconds
        t0 = self.clock.now()
        with self.tracer.span("patch_reorder", graph_id=entry.graph_id,
                              scheme=decision.scheme,
                              generation=entry.generation):
            if entry.hot_prefix_len > 0:
                perm, inv, hot_len, _info = patch_permutation(
                    entry.graph, entry.perm, entry.hot_prefix_len,
                    hot_mask=hot_mask)
                entry.perm, entry.inv_perm = perm, inv
                entry.hot_prefix_len = hot_len
        entry.reorder_seconds = self.clock.now() - t0
        if decision.scheme == "original":
            entry.served = entry.graph
        else:
            entry.served = entry.graph.apply_permutation(entry.perm)
        with self.tracer.span("prepare", graph_id=entry.graph_id,
                              backend=decision.backend):
            entry.handle = self.executor.prepare(
                entry.served, backend=decision.backend,
                canonical_ids=entry.inv_perm,
                hot_prefix_fraction=decision.hot_prefix_fraction,
                search=self._search_spec(entry))
        entry.bucket_shape = entry.handle.bucket
        entry.arrays = entry.handle.arrays
        self._c_patches.inc()
        self.metrics_registry.histogram(
            "engine_reorder_seconds", "wall cost of applying one decision",
            scheme="patch").observe(entry.reorder_seconds)
        # the stable repack preserves the locality structure the full
        # reorder built, so the realized gain carries forward — now
        # amortizing against the patch's (tiny) cost, with no
        # graph-sized cache simulation on the mutation path
        prev = entry.ledger
        entry.ledger = AmortizationLedger(
            entry.reorder_seconds,
            prev.realized_gain if prev else 0.0,
            backend=decision.backend,
            gain_discount=prev.gain_discount if prev else 1.0)

    def _schedule_full_reorder(self, entry: GraphEntry, trigger: str,
                               decision: PolicyDecision | None = None) -> None:
        """Run the full reorder off the request path; the result becomes a
        `_PendingSwap` applied at the next flush boundary — unless the
        graph mutates again first (the token check discards it)."""
        token = entry.mutations
        graph = entry.graph          # immutable snapshot: mutations replace
        gid = entry.graph_id         # entry.graph, never modify it in place
        if decision is None:
            volume = max(entry.queries_observed, entry.expected_queries)
            decision = self.policy.decide(entry.probes, volume)
        visits = self._visits_for(entry)  # snapshot, like `graph`

        def _work():
            t0 = self.clock.now()
            with self.tracer.span("reorder", graph_id=gid,
                                  scheme=decision.scheme, background=True):
                perm = np.asarray(
                    self.policy.reorder_fn(decision, visits=visits)(graph))
            secs = self.clock.now() - t0
            with self.scheduler._lock:
                if entry.mutations != token:
                    self._c_swaps_discarded.inc()
                    return
                self._pending_swaps[gid] = _PendingSwap(
                    decision, perm, secs, token, trigger)

        if self.async_full_reorder:
            t = threading.Thread(target=_work, daemon=True,
                                 name=f"engine-reorder-{gid}")
            self._reorder_threads.append(t)
            t.start()
        else:
            # inline mode for deterministic tests/benchmarks: the swap
            # still waits for a flush boundary, only the reorder blocks
            _work()

    def _swap_pending_ids(self) -> list[str]:
        """Graphs holding a completed full reorder awaiting a flush."""
        return list(self._pending_swaps)

    def _apply_pending_swap(self, entry: GraphEntry) -> bool:
        """Flush-boundary hook (scheduler): swap in a completed async full
        reorder, or discard it if a newer mutation invalidated it."""
        swap = self._pending_swaps.pop(entry.graph_id, None)
        if swap is None:
            return False
        if swap.token != entry.mutations:
            self._c_swaps_discarded.inc()
            return False
        with self.tracer.span("swap_layout", graph_id=entry.graph_id,
                              scheme=swap.decision.scheme,
                              trigger=swap.trigger):
            self._apply_decision(entry, swap.decision, perm=swap.perm,
                                 reorder_seconds=swap.reorder_seconds)
        self._c_swaps.inc()
        return True

    # ---------------------------------------------- visit-driven hotness
    def refresh_hotness(self, graph_id: str) -> dict:
        """Fold accumulated visit telemetry back into a search layout.

        Search graphs have uniform out-degree, so their skew lives in
        *observed visit frequency* (docs/search.md). Every ``knn`` launch
        folds per-vertex visit counts into the entry's EWMA; this call
        closes the loop: it recomputes the visit-skew probes
        (`registry.refresh_visit_probes`), re-runs the policy, and

        - applies the new decision when it changed (typically
          ``original`` -> ``visitsort`` once enough skew accumulates);
        - otherwise re-packs the hot prefix against the *observed* hot
          set via the patch tier (``patch_permutation`` with
          ``visit_hot_mask``) — the steady-state drift correction, one
          stable O(V) pass, no reorder;
        - does nothing without telemetry or a hot prefix.

        Runs under the scheduler fence so in-flight requests are served
        under their pre-refresh generation. Returns a summary dict.
        """
        entry = self.registry.get(graph_id)
        if entry.vectors is None:
            raise ValueError(f"{graph_id!r} is not a search graph "
                             "(register with vectors=)")
        with self.scheduler.fence(graph_id):
            probes = self.registry.refresh_visit_probes(graph_id)
            volume = max(entry.queries_observed, entry.expected_queries)
            decision = self.policy.decide(probes, volume)
            if decision_changed(entry.decision, decision):
                tier = "full"
                with self.tracer.span("refresh_hotness", graph_id=graph_id,
                                      tier=tier,
                                      new_scheme=decision.scheme):
                    self._apply_decision(entry, decision)
            elif entry.visit_ewma is not None and entry.hot_prefix_len > 0:
                tier = "patch"
                with self.tracer.span("refresh_hotness", graph_id=graph_id,
                                      tier=tier):
                    self._apply_patch(
                        entry,
                        hot_mask=visit_hot_mask(self._visits_for(entry)))
            else:
                tier = "noop"
        return {"graph_id": graph_id, "tier": tier,
                "scheme": entry.decision.scheme,
                "hotness_source": entry.decision.hotness_source,
                "generation": entry.generation,
                "hot_prefix_len": entry.hot_prefix_len,
                "visit_queries": entry.visit_queries,
                "visit_gini": entry.probes.visit_gini,
                "reason": entry.decision.reason}

    # -------------------------------------------------------- re-decision
    def _maybe_redecide(self, entry: GraphEntry) -> dict | None:
        """Re-run the policy when realized traffic contradicts the hint.

        Triggers: (a) realized volume exceeds the hint by
        ``redecide_factor``; (b) the ledger shows the reorder will never
        amortize (realized gain <= 0). The new decision uses the observed
        volume and the calibrator's current fitted strengths; if it only
        re-confirms a never-amortizing scheme, the graph is demoted to the
        original layout instead — a regressing reorder is strictly worse
        than serving the layout we already had.
        """
        if entry.redecisions >= self.max_redecisions:
            return None
        observed = entry.queries_observed
        if observed < self.redecide_min_queries:
            return None
        old = entry.decision
        if observed >= self.redecide_factor * max(entry.expected_queries, 1):
            trigger = "volume-divergence"
        elif old.scheme != "original" and entry.ledger.realized_gain <= 0:
            trigger = "never-amortize"
        else:
            return None

        new_volume = max(observed, entry.expected_queries)
        new = self.policy.decide(entry.probes, new_volume)
        if (trigger == "never-amortize"
                and (new.scheme, new.kwargs) == (old.scheme, old.kwargs)):
            new = PolicyDecision(
                "original", {},
                (f"re-decision demote: {old.scheme} realized gain "
                 f"{entry.ledger.realized_gain:.3f} <= 0 after "
                 f"{entry.ledger.queries_served} queries — it can never "
                 f"amortize, serving the original layout"),
                0.0, new.skew, new.backend,
                None)  # original layout has no packed prefix to exploit
        if (new.scheme, new.kwargs) == (old.scheme, old.kwargs):
            # same choice at the new volume: refresh the hint so the
            # divergence trigger re-arms at redecide_factor x observed
            entry.expected_queries = new_volume
            return None

        with self.tracer.span("redecide", graph_id=entry.graph_id,
                              trigger=trigger, old_scheme=old.scheme,
                              new_scheme=new.scheme):
            self._apply_decision(entry, new)
        self._c_redecisions.inc()
        entry.expected_queries = new_volume
        entry.redecisions += 1
        event = {
            "graph_id": entry.graph_id,
            "trigger": trigger,
            "old_scheme": old.scheme,
            "new_scheme": new.scheme,
            "observed_queries": observed,
            "new_expected_queries": new_volume,
            "reorder_seconds": entry.reorder_seconds,
            "reason": new.reason,
        }
        self.redecision_log.append(event)
        return event

    # ------------------------------------------------------ request plane
    def enqueue(self, graph_id: str, kernel: str, sources=None,
                priority: int = 0,
                deadline_seconds: float | None = None) -> QueryFuture:
        """Queue one query; returns a `QueryFuture` (the primary API).

        Nothing launches until ``flush()``/``drain()`` (or the future's
        own ``result()``, which flushes this graph). Pending requests on
        the same (graph, kernel) coalesce into shared device launches —
        see scheduler.py for the batching, dedup, and ordering rules.
        Sources and results use original vertex ids throughout.
        """
        return self.scheduler.enqueue(graph_id, kernel, sources,
                                      priority=priority,
                                      deadline_seconds=deadline_seconds)

    def flush(self, graph_id: str | None = None) -> int:
        """Serve everything pending (for one graph, or all); returns the
        number of requests served. Re-decisions happen here, per graph,
        after its pending requests are answered."""
        return self.scheduler.flush(graph_id)

    def drain(self) -> int:
        """Flush until no request is pending (lifecycle close)."""
        return self.scheduler.drain()

    def poll(self) -> int:
        """Auto-flush tick: serve any request past its deadline or older
        than ``max_delay``. Runs implicitly on every ``enqueue`` and
        ``QueryFuture.done()`` — call it directly from your own event
        loop, or let ``auto_flush_interval`` run it from a thread."""
        return self.scheduler.poll()

    def submit(self, graph_id: str, kernel: str,
               sources=None) -> np.ndarray:
        """Blocking sugar: enqueue + flush one query batch.

        Multi-source kernels (bfs/sssp/bc) return per-source rows
        ``(S, V)``; global kernels (pr/cc/ccsv) return ``(V,)``. Results
        use original vertex ids — including component-label *values* for
        cc/ccsv (min original id per component). Note: the flush serves
        *all* pending requests on this graph, so interleaving ``submit``
        with ``enqueue`` on one graph resolves the queued futures too.
        """
        future = self.enqueue(graph_id, kernel, sources)
        self.scheduler.flush(graph_id)
        return future.result()

    def bc_aggregate(self, graph_id: str, sources) -> np.ndarray:
        """GAP-style BC score: sum of per-source dependencies (V,)."""
        return self.submit(graph_id, "bc", sources).sum(axis=0)

    # ------------------------------------------------- scheduler internals
    def _launch(self, entry: GraphEntry, kernel: str,
                sources: np.ndarray | None) -> tuple[np.ndarray, float]:
        """One device launch against the entry's *current* layout.

        Sources arrive in original ids and are translated through
        ``entry.perm`` here — at launch time, not enqueue time — so a
        request enqueued before a re-decision is still translated and
        un-translated through one consistent generation. Returns the
        result already back in original id space plus the launch wall.
        """
        tracer = self.tracer
        is_vec = kernel in VECTOR_SOURCE
        served_sources = None
        if kernel in MULTI_SOURCE:
            with tracer.span("translate", graph_id=entry.graph_id,
                             kernel=kernel, generation=entry.generation):
                served_sources = entry.perm[sources].astype(np.int32)
        elif is_vec:
            # query vectors are not vertex ids — nothing to translate;
            # the handle's SearchSpec already binds the served layout
            served_sources = np.ascontiguousarray(sources, dtype=np.float32)
        # attribute the launch to compile vs cache hit through the
        # single backend's miss counter (sharded runners compile on
        # first use per kernel instead — annotated by the backend)
        misses0 = self.executor.single.cache_misses
        t0 = self.clock.now()
        with tracer.span("launch", graph_id=entry.graph_id, kernel=kernel,
                         backend=entry.backend) as span_args:
            with self.profiler.step(kernel,
                                    step_num=self.scheduler.launches):
                out = self.executor.run(entry.handle, kernel,
                                        served_sources)
                if is_vec:
                    ids, visits = np.asarray(out[0]), np.asarray(out[1])
                else:
                    out = np.asarray(out)
            if entry.backend == "single":
                hit = self.executor.single.cache_misses == misses0
                span_args["compile"] = "cache_hit" if hit else "compile"
        wall = self.clock.now() - t0
        self.metrics_registry.histogram(
            "engine_launch_wall_seconds", "device wall per launch",
            kernel=kernel, backend=entry.backend).observe(wall)
        if is_vec:
            # visit counts arrive per served vertex; fold them back to
            # original ids and into the registry's EWMA hotness estimate
            # (the telemetry refresh_hotness folds into the layout)
            self.registry.note_visits(entry.graph_id,
                                      np.asarray(visits)[entry.perm],
                                      num_queries=len(served_sources))
            # neighbor ids are served ids (-1 = unfilled beam slot; guard
            # the gather — a raw inv_perm[-1] would alias the last vertex)
            result = np.where(ids >= 0,
                              entry.inv_perm[np.maximum(ids, 0)],
                              -1).astype(np.int64)
            return result, wall
        # translate back: result for original vertex v lives at served
        # position perm[v]; component-label *values* (cc/ccsv) are served
        # ids and are canonicalized to min-original-id-per-component so
        # callers never see the internal layout (PR 4 leaked this)
        result = out[..., entry.perm]
        if kernel in LABEL_KERNELS:
            result = canonical_component_labels(result)
        return result, wall

    def _last_exchange(self, entry: GraphEntry) -> dict | None:
        """Per-run ExchangeStats delta of the launch that just returned
        (sharded placements only — the single-device path has no
        collective exchange to account)."""
        if entry.backend != "sharded":
            return None
        return self.executor.sharded.last_run_exchange

    # ---------------------------------------------------------- telemetry
    def telemetry(self) -> dict:
        return {
            "executor": self.executor.telemetry(),
            "scheduler": self.scheduler.telemetry(),
            "policy": [r.as_dict() for r in self.policy.history],
            "calibration": self.policy.calibrator.as_dict(),
            "redecisions": list(self.redecision_log),
            "mutations": {
                "mutations": self._c_mutations.value,
                "edges_added": self._c_edges_added.value,
                "edges_removed": self._c_edges_removed.value,
                "patch_reorders": self._c_patches.value,
                "layout_swaps": self._c_swaps.value,
                "layout_swaps_discarded": self._c_swaps_discarded.value,
                "pending_swaps": self._swap_pending_ids(),
            },
            "graphs": {
                gid: {
                    "scheme": e.decision.scheme if e.decision else None,
                    "generation": e.generation,
                    "backend": e.backend,
                    "hot_prefix_fraction": e.hot_prefix_fraction,
                    "bucket_shape": e.bucket_shape,
                    "device_bytes": (e.handle.device_bytes
                                     if e.handle else None),
                    "probes": dataclasses.asdict(e.probes),
                    "reorder_seconds": e.reorder_seconds,
                    "expected_queries": e.expected_queries,
                    "queries_observed": e.queries_observed,
                    "redecisions": e.redecisions,
                    "mutations": e.mutations,
                    "probe_drift": round(e.probe_drift, 6),
                    "hot_prefix_len": e.hot_prefix_len,
                    "visit_queries": e.visit_queries,
                    "ledger": e.ledger.as_dict() if e.ledger else None,
                }
                for gid, e in ((g, self.registry.get(g))
                               for g in self.registry.ids())
            },
        }


def _entry_repr(entry: GraphEntry) -> str:  # debugging convenience
    d = entry.decision
    return (f"<{entry.graph_id}: V={entry.probes.num_vertices} "
            f"E={entry.probes.num_edges} scheme={d.scheme if d else '?'}>")
