"""Pure-jnp oracle for the hot/cold embedding gather."""
from __future__ import annotations

import jax.numpy as jnp


def embed_ref(ids, table):
    return jnp.take(table, ids, axis=0)


def hot_gather_ref(ids, hot_slab):
    """Hot rows for ids < H, zeros otherwise (kernel contract)."""
    h = hot_slab.shape[0]
    is_hot = ids < h
    rows = jnp.take(hot_slab, jnp.where(is_hot, ids, 0), axis=0)
    return jnp.where(is_hot[:, None], rows, 0.0)
