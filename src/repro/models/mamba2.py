"""Mamba2 (SSD) block — zamba2's backbone.

Chunked state-space-duality algorithm: within a chunk the recurrence is a
masked attention-like matmul (MXU-friendly); across chunks a short scan
carries the (H, P, N) state. Single B/C group (ngroups=1), heads of size
``ssm_head_dim``, state size N = ``cfg.ssm_state``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import COMPUTE_DTYPE, _dense


def init_mamba(key, cfg: ModelConfig):
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 4)
    in_dim = 2 * di + 2 * n + h          # z, x, B, C, dt
    p = {
        "w_in": jax.random.normal(ks[0], (d, in_dim), jnp.float32) * d ** -0.5,
        "conv": jax.random.normal(ks[1], (cfg.conv_width, di + 2 * n),
                                  jnp.float32) * 0.2,
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "w_out": jax.random.normal(ks[2], (di, d), jnp.float32) * di ** -0.5,
    }
    return p


def _split_in(u, cfg: ModelConfig):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z, xbc, dt = jnp.split(u, [di, 2 * di + 2 * n], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, conv_w, state=None):
    """Depthwise causal conv, width W. state: (B, W-1, C) carry for decode."""
    w = conv_w.shape[0]
    if state is None:
        pad = jnp.zeros_like(xbc[:, : w - 1])
        buf = jnp.concatenate([pad, xbc], axis=1)
        new_state = buf[:, -(w - 1):]
    else:
        buf = jnp.concatenate([state.astype(xbc.dtype), xbc], axis=1)
        new_state = buf[:, -(w - 1):]
    out = sum(buf[:, i: i + xbc.shape[1]] * conv_w[i] for i in range(w))
    return jax.nn.silu(out), new_state


def _gated_rmsnorm(y, z, scale, eps=1e-5):
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = (y ** 2).mean(-1, keepdims=True)
    return (y * jax.lax.rsqrt(var + eps) * scale).astype(COMPUTE_DTYPE)


def ssd_chunked(x, dt, b, c, a_log, chunk: int):
    """SSD scan. x: (B,T,H,P); dt: (B,T,H); b,c: (B,T,N). Returns (B,T,H,P).

    Recurrence: S_t = exp(-exp(a_log)·dt_t)·S_{t-1} + dt_t·x_t⊗b_t,
    y_t = S_t·c_t (per head).
    """
    bs, t, h, pdim = x.shape
    n = b.shape[-1]
    nc = t // chunk
    A = -jnp.exp(a_log)                                     # (H,)
    la = (dt * A).astype(jnp.float32)                       # (B,T,H) log-decay
    xs = (x * dt[..., None]).astype(jnp.float32)            # dt-weighted input

    def reshape_c(v):
        return v.reshape(bs, nc, chunk, *v.shape[2:])

    la_c, xs_c = reshape_c(la), reshape_c(xs)
    b_c, c_c = reshape_c(b.astype(jnp.float32)), reshape_c(c.astype(jnp.float32))
    cums = jnp.cumsum(la_c, axis=2)                         # (B,NC,L,H)

    # ---- intra-chunk (attention-like, lower triangular)
    rel = cums[:, :, :, None, :] - cums[:, :, None, :, :]   # (B,NC,L,L,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask BEFORE exp: exp of the (positive) upper triangle overflows and
    # its cotangent would poison the gradient through jnp.where
    rel = jnp.where(tri[None, None, :, :, None], rel, -1e30)
    dec = jnp.exp(rel)
    cb = jnp.einsum("bgin,bgjn->bgij", c_c, b_c)            # (B,NC,L,L)
    m = cb[..., None] * dec                                 # (B,NC,L,L,H)
    y_intra = jnp.einsum("bgijh,bgjhp->bgihp", m, xs_c)

    # ---- chunk states: S_g = Σ_j exp(cums_last - cums_j) b_j ⊗ xs_j
    dec_last = jnp.exp(cums[:, :, -1:, :] - cums)           # (B,NC,L,H)
    s_chunk = jnp.einsum("bgjh,bgjn,bgjhp->bghnp", dec_last, b_c, xs_c)

    # ---- inter-chunk scan
    g_total = jnp.exp(cums[:, :, -1, :])                    # (B,NC,H)

    def scan_fn(s_prev, inp):
        g, s_c = inp                                        # (B,H), (B,H,N,P)
        s_new = s_prev * g[..., None, None] + s_c
        return s_new, s_prev

    s0 = jnp.zeros((bs, h, n, pdim), jnp.float32)
    _, s_before = jax.lax.scan(
        scan_fn, s0, (g_total.transpose(1, 0, 2), s_chunk.transpose(1, 0, 2, 3, 4)))
    s_before = s_before.transpose(1, 0, 2, 3, 4)            # (B,NC,H,N,P)

    dec_in = jnp.exp(cums)                                  # (B,NC,L,H)
    y_inter = jnp.einsum("bgin,bgih,bghnp->bgihp", c_c, dec_in, s_before)

    y = (y_intra + y_inter).reshape(bs, t, h, pdim)
    return y.astype(COMPUTE_DTYPE)


def apply_mamba(p, x, cfg: ModelConfig, cache=None):
    """x: (B,S,D). cache: dict(conv=(B,W-1,C), ssd=(B,H,N,P), pos) or None."""
    bsz, s, _ = x.shape
    h, pdim, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    u = _dense(x, p["w_in"])
    z, xbc, dt_raw = _split_in(u, cfg)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)

    if cache is None:
        xbc, _ = _causal_conv(xbc, p["conv"])
        xi, b, c = jnp.split(xbc, [cfg.d_inner, cfg.d_inner + n], axis=-1)
        xh = xi.reshape(bsz, s, h, pdim)
        # pad time to a chunk multiple (zero dt ⇒ padded steps are identity)
        pad = (-s) % cfg.ssm_chunk
        if pad:
            xh_p = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            b_p = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
            c_p = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
            y = ssd_chunked(xh_p, dt_p, b_p, c_p, p["a_log"],
                            cfg.ssm_chunk)[:, :s]
        else:
            y = ssd_chunked(xh, dt, b, c, p["a_log"], cfg.ssm_chunk)
        y = y + xh.astype(jnp.float32) * p["d_skip"][:, None]
        new_cache = None
    else:
        xbc, conv_state = _causal_conv(xbc, p["conv"], cache["conv"])
        xi, b, c = jnp.split(xbc, [cfg.d_inner, cfg.d_inner + n], axis=-1)
        xh = xi.reshape(bsz, s, h, pdim).astype(jnp.float32)
        a = jnp.exp(dt * -jnp.exp(p["a_log"]))[:, 0]        # (B,H)
        s_new = (cache["ssd"] * a[..., None, None]
                 + jnp.einsum("bn,bhp->bhnp", b[:, 0].astype(jnp.float32),
                              xh[:, 0] * dt[:, 0, :, None]))
        y = jnp.einsum("bn,bhnp->bhp", c[:, 0].astype(jnp.float32), s_new)
        y = (y + xh[:, 0] * p["d_skip"][:, None])[:, None]
        new_cache = {"conv": conv_state.astype(cache["conv"].dtype),
                     "ssd": s_new}

    y = _gated_rmsnorm(y.reshape(bsz, s, cfg.d_inner), z, p["norm_scale"])
    return _dense(y, p["w_out"]), new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=COMPUTE_DTYPE):
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1,
                           cfg.d_inner + 2 * cfg.ssm_state), dtype),
        "ssd": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state,
                          cfg.ssm_head_dim), jnp.float32),
    }
