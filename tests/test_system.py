"""End-to-end behaviour: train driver (loss goes down, resume bit-exact
continuation), serve driver (continuous batching), reorder end-to-end."""
from __future__ import annotations

import numpy as np
import pytest


def test_train_loop_loss_decreases(tmp_path):
    from repro.launch.train import main
    losses = main(["--arch", "qwen2.5-3b", "--steps", "25", "--smoke",
                   "--layers", "2", "--seq-len", "64", "--global-batch", "4",
                   "--ckpt-dir", str(tmp_path), "--ckpt-every", "0",
                   "--lr", "1e-3", "--log-every", "100"])
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_train_resume_continues(tmp_path):
    from repro.launch.train import main
    args = ["--arch", "qwen2.5-3b", "--smoke", "--layers", "2",
            "--seq-len", "32", "--global-batch", "2",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
            "--total-steps", "10",   # pin the LR schedule across runs
            "--no-vocab-reorder", "--log-every", "100"]
    full = main(["--steps", "10"] + args)
    # fresh process state: run 0-4, "crash", resume 5-9
    import shutil
    shutil.rmtree(tmp_path)
    part = main(["--steps", "5"] + args)
    cont = main(["--steps", "10", "--resume"] + args)
    np.testing.assert_allclose(part[:5], full[:5], rtol=1e-5)
    np.testing.assert_allclose(cont, full[5:], rtol=5e-3, atol=5e-3)


def test_serve_continuous_batching():
    import jax
    from repro.configs import smoke_config
    from repro.launch.serve import serve_loop, synthetic_requests
    from repro.models.transformer import init_params
    cfg = smoke_config("qwen2.5-3b", layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    reqs = synthetic_requests(5, cfg.vocab_size, plen=(4, 8), gen=(4, 10))
    done = serve_loop(cfg, params, reqs, batch_slots=2, max_len=64)
    assert len(done) == 5
    assert all(len(r.out) == r.max_new for r in done)
    assert all(0 <= t < cfg.vocab_size for r in done for t in r.out)


def test_serve_greedy_deterministic():
    import jax
    from repro.configs import smoke_config
    from repro.launch.serve import serve_loop, synthetic_requests
    from repro.models.transformer import init_params
    cfg = smoke_config("rwkv6-3b", layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    r1 = serve_loop(cfg, params, synthetic_requests(2, cfg.vocab_size),
                    batch_slots=2, max_len=96)
    r2 = serve_loop(cfg, params, synthetic_requests(2, cfg.vocab_size),
                    batch_slots=2, max_len=96)
    for a, b in zip(sorted(r1, key=lambda r: r.rid),
                    sorted(r2, key=lambda r: r.rid)):
        assert a.out == b.out


def test_reorder_end_to_end_graph_workload():
    """Full paper path: generate → reorder → run kernels → same results,
    lower simulated cache misses."""
    import jax.numpy as jnp
    from repro.algos.graph_arrays import to_device
    from repro.algos.kernels import pagerank
    from repro.cache.sim import CacheConfig, miss_rate
    from repro.core.generators import powerlaw_community
    from repro.core.lorder import lorder

    g = powerlaw_community(20_000, avg_degree=10, seed=11)
    perm = np.asarray(lorder(g))
    gp = g.apply_permutation(perm)
    cfg = CacheConfig(size_bytes=16 * 1024, ways=8, sample_rate=4)
    assert miss_rate(gp, cfg) < miss_rate(g, cfg)
    r1 = np.asarray(pagerank(to_device(g)))
    r2 = np.asarray(pagerank(to_device(gp)))
    np.testing.assert_allclose(r1, r2[perm], rtol=1e-4, atol=1e-8)
