"""End-to-end training driver: data → sharded train_step → checkpoints.

Production behaviours exercised even at laptop scale:
* checkpoint/restart (atomic, async, keep-k) via ckpt.CheckpointManager —
  `--resume` restores the latest committed step, including after a
  simulated crash mid-save;
* straggler monitor — per-step wall-time EWMA; steps slower than
  ``threshold × ewma`` are logged (on a fleet: feeds re-slicing);
* vocab-LOrder preprocessing — when the arch enables it, the permutation
  is computed from a corpus sample before step 0 and applied to the
  embedding rows + the host token stream (the paper's amortized-reorder
  deployment);
* elastic restart — restore re-shards onto whatever mesh is alive now.

Usage:
  python -m repro.launch.train --arch qwen2.5-3b --steps 200 --smoke
  python -m repro.launch.train --arch mixtral-8x7b --steps 50 --smoke --resume
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA step-time tracker; flags slow steps (fleet: triggers re-slice)."""
    alpha: float = 0.1
    threshold: float = 2.0
    ewma: float | None = None
    flagged: int = 0

    def observe(self, dt: float) -> bool:
        slow = self.ewma is not None and dt > self.threshold * self.ewma
        self.ewma = dt if self.ewma is None else \
            (1 - self.alpha) * self.ewma + self.alpha * dt
        if slow:
            self.flagged += 1
        return slow


def build_vocab_reorder(cfg, dc):
    """Paper preprocessing: LOrder over the corpus co-occurrence graph."""
    from ..data.pipeline import corpus_sample
    from ..locality.vocab import hot_coverage, vocab_permutation
    sample = corpus_sample(dc, num_batches=1)
    vr = vocab_permutation(sample, cfg.vocab_size,
                           hot_fraction=cfg.hot_vocab_fraction or 0.05)
    cov = hot_coverage(sample, vr)
    print(f"[vocab-lorder] hot slab {vr.hot_size} rows "
          f"({100 * vr.hot_size / cfg.vocab_size:.1f}% of vocab) covers "
          f"{100 * cov:.1f}% of corpus tokens")
    return vr


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-scale ~100M-class trunk)")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine",
                    choices=("cosine", "wsd", "const"))
    ap.add_argument("--total-steps", type=int, default=0,
                    help="schedule horizon (defaults to --steps); pin it "
                         "when resuming so the LR curve is invariant")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--no-vocab-reorder", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    from ..ckpt.manager import CheckpointManager
    from ..configs import get_config, smoke_config
    from ..data.pipeline import DataConfig, DataLoader
    from ..launch.mesh import make_host_mesh
    from ..models.transformer import init_params
    from ..train.optim import TrainConfig, init_opt_state
    from ..train.steps import make_train_step
    from ..locality import applies_to

    cfg = smoke_config(args.arch, layers=args.layers) if args.smoke \
        else get_config(args.arch)
    if cfg.input_mode != "tokens":
        raise SystemExit(f"{args.arch} is embedding-fed (stub frontend); "
                         "use examples/audio_encoder.py instead")
    mesh = make_host_mesh()
    total = args.total_steps or args.steps
    tc = TrainConfig(learning_rate=args.lr, total_steps=total,
                     warmup_steps=max(1, total // 10),
                     schedule=args.schedule,
                     microbatch=args.microbatch)

    seq = args.seq_len - (cfg.prefix_tokens or 0)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                    global_batch=args.global_batch)

    feats = applies_to(cfg)
    vocab_reorder = None
    if feats["vocab_reorder"] and not args.no_vocab_reorder:
        vocab_reorder = build_vocab_reorder(cfg, dc)

    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    if vocab_reorder is not None:
        params = vocab_reorder.apply_to_params(params)
    opt_state = init_opt_state(params)

    ckpt = CheckpointManager(args.ckpt_dir, keep=3)
    start_step = 0
    if args.resume:
        step_found, state = ckpt.restore()
        if state is not None:
            params, opt_state = state["params"], state["opt"]
            start_step = step_found + 1
            print(f"[ckpt] resumed from step {step_found}")

    step_fn, _ = make_train_step(cfg, tc, mesh)
    loader = DataLoader(dc, vocab_reorder, start_step=start_step)
    monitor = StragglerMonitor()

    import jax.numpy as jnp
    losses = []
    try:
        for step in range(start_step, args.steps):
            host = next(loader)
            batch = {"tokens": jnp.asarray(host["tokens"])}
            if cfg.prefix_tokens:
                batch["prefix"] = jnp.zeros(
                    (args.global_batch, cfg.prefix_tokens, cfg.d_model),
                    jnp.bfloat16)
            t0 = time.time()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            if monitor.observe(dt):
                print(f"[straggler] step {step} took {dt:.2f}s "
                      f"(ewma {monitor.ewma:.2f}s)")
            losses.append(loss)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} {dt:.2f}s", flush=True)
            if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step, {"params": params, "opt": opt_state})
    finally:
        loader.close()
        ckpt.wait()

    final = {"params": params, "opt": opt_state}
    ckpt.save(args.steps - 1, final, blocking=True)
    first = np.mean(losses[:5]) if len(losses) >= 5 else losses[0]
    last = np.mean(losses[-5:])
    print(f"[done] loss {first:.4f} -> {last:.4f} "
          f"({len(losses)} steps, {monitor.flagged} straggler flags)")
    return losses


if __name__ == "__main__":
    main()
