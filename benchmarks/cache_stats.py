"""Paper §5 cache statistics — simulated LLC miss rates per scheme,
pull- and push-mode traces.
"""
from __future__ import annotations

import numpy as np

from .common import bench_suite, fmt_table, save_json, schemes


def run(scale: float = 0.5) -> list[dict]:
    from repro.cache.sim import CacheConfig, property_trace, simulate_misses
    rows = []
    for dname, g in bench_suite(scale).items():
        cfg = CacheConfig(size_bytes=max(8 * 1024, g.num_vertices // 2),
                          ways=16, sample_rate=8)
        row = {"dataset": dname}
        for mode in ("pull", "push"):
            base = simulate_misses(property_trace(g, mode), cfg)
            row[f"original_{mode}"] = round(base["miss_rate"], 4)
        for sname, fn in schemes().items():
            gp = g.apply_permutation(np.asarray(fn(g)))
            for mode in ("pull", "push"):
                mr = simulate_misses(property_trace(gp, mode),
                                     cfg)["miss_rate"]
                row[f"{sname}_{mode}"] = round(mr, 4)
        rows.append(row)
        print(f"[cache_stats] {dname} done", flush=True)
    save_json("cache_stats", rows)
    return rows


def main(scale: float = 0.5):
    rows = run(scale)
    cols = ["dataset"] + [c for c in rows[0] if c != "dataset"
                          and c.endswith("_pull")]
    print(fmt_table(rows, cols))


if __name__ == "__main__":
    main()
