"""Engine demo — adaptive reordering + batched multi-source serving.

Registers two structurally opposite graphs with the serving engine:

* a power-law community graph (high degree skew, low diameter) — the
  regime where the paper's reordering pays, so the policy reorders;
* a high-diameter road mesh (uniform degrees) — no hub working set, so
  the policy serves the original layout.

Then submits batched multi-source BFS / SSSP / BC queries through the
session and verifies the answers match the single-source kernels on the
original layout, prints the telemetry (compile-cache hits, policy
predicted-vs-realized gains, amortization ledger), shows the closed
loop: realized outcomes calibrate the per-scheme strengths, and a graph
registered with a misleading volume hint is re-decided — and re-reordered
in place — once its realized traffic diverges. Finally it drives the
**request plane** (docs/scheduler.md): concurrent queries enqueued as
futures coalesce into shared device launches at a flush boundary —
identical answers, a fraction of the launches — and repeat traffic is
served straight from the result cache with zero launches (the plane is
always-on: auto-flush ticks and ``result()`` are flush boundaries, no
explicit ``flush()`` needed).

Run:  PYTHONPATH=src python examples/engine_demo.py
"""
import numpy as np
import jax.numpy as jnp

from repro.algos.graph_arrays import to_device
from repro.algos import kernels as K
from repro.core.generators import powerlaw_community, road_grid
from repro.engine import EngineSession


def main():
    print("== 1. register two structurally opposite graphs")
    session = EngineSession()
    g_pl = powerlaw_community(20_000, avg_degree=12.0, mixing=0.1,
                              seed=7, name="social")
    g_mesh = road_grid(100, shortcuts=32, seed=11, name="road")
    ids = [session.register(g_pl, expected_queries=256),
           session.register(g_mesh, expected_queries=256)]
    for gid in ids:
        e = session.registry.get(gid)
        p, d = e.probes, e.decision
        print(f"   {gid:8s} V={p.num_vertices:6d} gini={p.degree_gini:.3f} "
              f"hub_mass={p.hub_mass:.3f} D~{p.diameter:3d} "
              f"-> {d.scheme} {d.kwargs}")
    schemes = {session.registry.get(gid).decision.scheme for gid in ids}
    assert len(schemes) == 2, "policy should pick different reorderings"

    print("== 2. batched multi-source queries match single-source kernels")
    rng = np.random.default_rng(0)
    for gid, g in zip(ids, (g_pl, g_mesh)):
        srcs = rng.integers(0, g.num_vertices, size=5)
        ga = to_device(g)  # original layout, reference path
        depth = session.submit(gid, "bfs", srcs)
        dist = session.submit(gid, "sssp", srcs)
        for i, s in enumerate(srcs):
            assert np.array_equal(depth[i],
                                  np.asarray(K.bfs(ga, jnp.int32(s))))
            assert np.array_equal(dist[i],
                                  np.asarray(K.sssp(ga, jnp.int32(s))))
        bc = session.bc_aggregate(gid, srcs)
        np.testing.assert_allclose(bc, np.asarray(K.bc(ga, srcs)),
                                   rtol=1e-4, atol=1e-4)
        print(f"   {gid:8s} bfs/sssp/bc x{len(srcs)} sources: parity OK")

    print("== 3. serve a query stream (compile cache + amortization)")
    for _ in range(8):
        for gid, g in zip(ids, (g_pl, g_mesh)):
            srcs = rng.integers(0, g.num_vertices, size=4)
            session.submit(gid, "bfs", srcs)

    t = session.telemetry()
    ex = t["executor"]
    print(f"   compile cache: {ex['compile_cache_hits']} hits / "
          f"{ex['compile_cache_misses']} misses over "
          f"{ex['queries_run']} queries ({ex['sources_run']} sources)")
    for rec in t["policy"]:
        print(f"   policy {rec['graph_id']:8s} {rec['scheme']:10s} "
              f"predicted gain {rec['predicted_gain']:.3f} "
              f"realized {rec['realized_gain']:.3f}")
    for gid in ids:
        led = t["graphs"][gid]["ledger"]
        be = led["break_even_queries"]
        be_s = "never" if led["break_even_never"] else f"{be:.1f}"
        print(f"   ledger {gid:8s} reorder {led['reorder_seconds']:.3f}s, "
              f"{led['queries_served']} queries, "
              f"saved~{led['estimated_saved_seconds']:.3f}s, "
              f"break-even at {be_s} queries, "
              f"amortized={led['amortized']}")

    print("== 4. closed loop: calibration + online re-decision")
    cal = session.policy.calibrator
    fitted = {s: f"{v:.3f}" for s, v in cal.strengths().items()
              if cal.count(s)}
    print(f"   fitted strengths after recorded outcomes: {fitted}")
    # a bursty tenant: hint says 2 queries, reality delivers dozens
    g_burst = powerlaw_community(10_000, avg_degree=12.0, mixing=0.1,
                                 seed=23, name="burst")
    bid = session.register(g_burst, expected_queries=2)
    scheme0 = session.registry.get(bid).decision.scheme
    print(f"   {bid}: hint=2 queries -> {scheme0} (volume gate)")
    for _ in range(40):
        srcs = rng.integers(0, g_burst.num_vertices, size=4)
        session.submit(bid, "bfs", srcs)
    entry = session.registry.get(bid)
    events = [e for e in session.redecision_log if e["graph_id"] == bid]
    path = " -> ".join([scheme0] + [e["new_scheme"] for e in events])
    print(f"   served {entry.queries_observed} batches: "
          f"{entry.redecisions} re-decision(s), scheme path {path}")
    assert entry.redecisions >= 1, "divergent volume should re-decide"
    # results stay correct across the in-place re-reorder
    s = int(rng.integers(0, g_burst.num_vertices))
    depth = session.submit(bid, "bfs", [s])
    ref = np.asarray(K.bfs(to_device(g_burst), jnp.int32(s)))
    assert np.array_equal(depth[0], ref)
    print("   post-re-decision parity OK")

    print("== 5. request plane: enqueue futures, coalesce at the flush")
    gid = ids[0]  # the power-law graph
    launches_before = session.executor.queries_run
    # a burst of concurrent queries: 6 multi-source requests + 3 callers
    # all wanting PageRank; nothing launches until the flush boundary
    futs = [session.enqueue(gid, "bfs",
                            rng.integers(0, g_pl.num_vertices, size=3),
                            priority=i % 2)
            for i in range(6)]
    futs += [session.enqueue(gid, "pr") for _ in range(3)]
    assert not futs[0].done()
    served = session.flush()
    launches = session.executor.queries_run - launches_before
    print(f"   {served} requests served by {launches} device launches "
          f"(6 bfs coalesced into one vmapped batch, 3 pr deduplicated)")
    ga_pl = to_device(g_pl)
    for f in futs[:6]:
        srcs = f.request.sources
        for row, s in zip(f.result(), srcs):
            assert np.array_equal(
                row, np.asarray(K.bfs(ga_pl, jnp.int32(s))))
    t0 = futs[0].telemetry
    print(f"   per-request telemetry: launch shared with "
          f"{t0['coalesced_with']} others, generation {t0['generation']}, "
          f"wall share {t0['wall_share_seconds'] * 1e3:.1f}ms")
    sched = session.scheduler.telemetry()
    print(f"   scheduler: {sched['requests_served']} served / "
          f"{sched['launches']} launches, "
          f"{sched['dedup_hits']} dedup hit(s)")
    assert launches == 2 and sched["dedup_hits"] >= 2

    print("== 6. always-on: repeat traffic hits the result cache")
    launches_before = session.executor.queries_run
    # the same burst again — every row is already cached under the
    # current (graph, generation, kernel, source) key, and result() on a
    # pending future is itself a flush boundary: no flush() call, no
    # device launch
    repeats = [session.enqueue(gid, "bfs", f.request.sources)
               for f in futs[:6]] + [session.enqueue(gid, "pr")]
    for f, want in zip(repeats[:6], futs[:6]):
        assert np.array_equal(np.asarray(f.result()),
                              np.asarray(want.result()))
    assert all(f.telemetry["served_from_cache"] for f in repeats[:6])
    launches = session.executor.queries_run - launches_before
    cache = session.result_cache.stats()
    print(f"   {len(repeats)} repeat requests -> {launches} device "
          f"launches; cache: {cache['entries']} rows "
          f"({cache['pinned']} hot-prefix pinned), "
          f"hit rate {cache['hit_rate']:.2f}")
    assert launches == 0


if __name__ == "__main__":
    main()
