"""The §Perf optimization paths vs their exact references."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st


# ------------------------------------------------------------ chunked wkv
@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([16, 32, 64]),
       st.sampled_from([1, 2]), st.sampled_from([2, 4]))
def test_wkv_chunked_equals_scan(seed, t, b, h):
    from repro.models.rwkv6 import _wkv_chunked, _wkv_scan
    dh = 8
    rng = np.random.default_rng(seed)
    mk = lambda: jnp.asarray(rng.standard_normal((b, t, h * dh)),
                             jnp.float32)
    r, k, v = mk(), mk(), mk()
    dec = rng.standard_normal((b, t, h * dh)).astype(np.float32) - 1.5
    logw = jnp.asarray(-np.exp(dec))
    u = jnp.asarray(rng.standard_normal((h, dh)), jnp.float32)
    y1, s1 = _wkv_scan(r, k, v, jnp.exp(logw), u, h, dh)
    y2, s2 = _wkv_chunked(r, k, v, logw, u, h, dh)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


def test_wkv_chunked_extreme_decay_stable():
    """Strong decays (w→0) and weak decays (w→1) must not overflow."""
    from repro.models.rwkv6 import _wkv_chunked
    b, t, h, dh = 1, 32, 2, 8
    rng = np.random.default_rng(0)
    mk = lambda: jnp.asarray(rng.standard_normal((b, t, h * dh)), jnp.float32)
    for offset in (-8.0, +3.0):   # w ≈ 1 / w ≈ 0
        dec = np.full((b, t, h * dh), offset, np.float32)
        logw = jnp.asarray(-np.exp(dec))
        y, s = _wkv_chunked(mk(), mk(), mk(), logw,
                            jnp.zeros((h, dh)), h, dh)
        assert bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(s).all())


# ------------------------------------------------------- capacity dispatch
def _moe_setup(seed=0, e=4, d=32, f=64, t=64, k=2):
    from repro.configs import smoke_config
    import dataclasses
    cfg = dataclasses.replace(
        smoke_config("mixtral-8x7b", layers=2),
        num_experts=e, experts_per_token=k, d_model=d, d_ff=f)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    experts = jnp.asarray(rng.integers(0, e, (t, k)), jnp.int32)
    gates = jax.nn.softmax(jnp.asarray(rng.standard_normal((t, k)),
                                       jnp.float32), -1)
    w = lambda *s: jnp.asarray(0.1 * rng.standard_normal(s), jnp.float32)
    return cfg, x, experts, gates, w(e, d, f), w(e, d, f), w(e, f, d)


def test_capacity_dispatch_matches_ragged_when_capacity_suffices():
    from repro.models.moe import _dispatch_capacity, _dispatch_local
    cfg, x, ex, ga, wg, wu, wd = _moe_setup()
    t, k = ex.shape
    y_ragged = _dispatch_local(x, ex, ga, wg, wu, wd, cfg.num_experts, 0)
    y_cap = _dispatch_capacity(x, ex, ga, wg, wu, wd, cfg.num_experts,
                               capacity=t * k)   # no drops possible
    np.testing.assert_allclose(np.asarray(y_cap, np.float32),
                               np.asarray(y_ragged, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_capacity_dispatch_drops_overflow_only():
    """With capacity < max group, only overflow rows vanish; kept rows
    match the exact dispatch computed on the kept subset."""
    from repro.models.moe import _dispatch_capacity
    cfg, x, ex, ga, wg, wu, wd = _moe_setup(seed=3)
    # route EVERYTHING to expert 0 to force overflow
    ex0 = jnp.zeros_like(ex)
    cap = 16
    y = _dispatch_capacity(x, ex0, ga, wg, wu, wd, cfg.num_experts, cap)
    # tokens holding the first `cap` assignment slots keep output;
    # the rest are zero (both of each token's k=2 assignments overflow
    # or sit in slots; token rows beyond cap//k first tokens are zero)
    nz = np.abs(np.asarray(y)).sum(axis=1) > 0
    assert nz.sum() <= cap          # at most `cap` assignments served
    assert nz[: cap // ex.shape[1]].all()


def test_capacity_dispatch_empty_experts():
    from repro.models.moe import _dispatch_capacity
    cfg, x, ex, ga, wg, wu, wd = _moe_setup(seed=5)
    y = _dispatch_capacity(x, jnp.full_like(ex, 3), ga, wg, wu, wd,
                           cfg.num_experts, capacity=512)
    assert bool(jnp.isfinite(y).all())


# ----------------------------------------------------- loss-shift rolling
def test_loss_shift_roll_equals_slice_semantics():
    """The rolled-target loss equals the sliced-version loss exactly."""
    from repro.configs import smoke_config
    from repro.models.transformer import (chunked_xent, embed_tokens,
                                          init_params, loss_fn)
    cfg = smoke_config("qwen2.5-3b", layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    loss_rolled, _ = loss_fn(params, {"tokens": tokens}, cfg)

    # hand-computed sliced version through the same trunk
    from repro.models.transformer import _run_trunk, apply_norm, lm_logits
    x = embed_tokens(params["embed"], tokens, cfg)
    pos = jnp.arange(16, dtype=jnp.int32)
    x, _, _ = _run_trunk(params, x, cfg, pos, None, None)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = lm_logits(params["embed"], x, cfg).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits[:, :-1], axis=-1)
    gold = jnp.take_along_axis(logits[:, :-1],
                               tokens[:, 1:, None], axis=-1)[..., 0]
    ce = (lse - gold).mean()
    z = jnp.square(lse).mean()
    np.testing.assert_allclose(float(loss_rolled), float(ce + 1e-4 * z),
                               rtol=2e-3)
