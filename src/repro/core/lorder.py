"""LOrder — the paper's locality-based reordering (Algorithms 1 & 2).

Pass 1 (locality formation, Alg. 1): scan vertices in original id order;
every unassigned vertex seeds a κ-hop BFS over unassigned vertices; all
discovered vertices join that seed's locality. Localities are disjoint and
complete. Per-locality hotness = number of hot members (degree > λ,
λ = average degree by default).

Pass 2 (id assignment, Alg. 2): sort localities by hotness descending;
within a locality, emit the seed first, then the hot members, then the cold
members — each group in BFS-discovery order. Hot-first contiguous blocks
give the temporal-locality win; BFS order inside each block preserves the
spatial/community structure.

v2: localities are "ground-truth communities" — the generator's community
labels when available, otherwise connected components (κ = ∞). Higher
reorder cost, better post-reorder locality (paper §1).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .csr import Graph
from .diameter import default_kappa
from .traversal import bfs_order


@dataclasses.dataclass
class LocalityInfo:
    """Diagnostics from pass 1 (consumed by tests and benchmarks)."""
    seeds: np.ndarray          # (L,) seed vertex per locality, formation order
    hotness: np.ndarray        # (L,) hot-member count per locality
    sizes: np.ndarray          # (L,)
    locality_id: np.ndarray    # (V,) locality index (formation order) per vertex
    kappa: int


def form_localities(g: Graph, kappa: int,
                    hot: np.ndarray) -> tuple[list[np.ndarray], LocalityInfo]:
    """Algorithm 1. Returns member lists (BFS discovery order) + diagnostics."""
    n = g.num_vertices
    assigned = np.zeros(n, dtype=bool)
    members: list[np.ndarray] = []
    seeds: list[int] = []
    locality_id = np.empty(n, dtype=np.int64)
    for v in range(n):
        if assigned[v]:
            continue
        order = bfs_order(g, v, kappa, assigned)
        locality_id[order] = len(members)
        members.append(order)
        seeds.append(v)
    hotness = np.array([int(hot[m].sum()) for m in members], dtype=np.int64)
    sizes = np.array([len(m) for m in members], dtype=np.int64)
    info = LocalityInfo(np.array(seeds, dtype=np.int64), hotness, sizes,
                        locality_id, kappa)
    return members, info


def assign_ids(members: list[np.ndarray], info: LocalityInfo,
               hot: np.ndarray) -> np.ndarray:
    """Algorithm 2. Returns perm with perm[old_id] = new_id."""
    # sort localities by hotness descending; stable => ties keep formation
    # order (the order Alg. 1 discovered them in)
    order = np.argsort(-info.hotness, kind="stable")
    n = int(info.locality_id.shape[0])
    perm = np.empty(n, dtype=np.int64)
    index = 0
    for li in order:
        m = members[li]
        seed, rest = m[:1], m[1:]
        h = hot[rest]
        block = np.concatenate([seed, rest[h], rest[~h]])
        perm[block] = np.arange(index, index + len(block))
        index += len(block)
    assert index == n
    return perm


def lorder(g: Graph, kappa: int | None = None,
           hot_threshold: float | None = None,
           return_info: bool = False):
    """LOrder v1 — κ-hop BFS localities (κ defaults to ⌈diameter/2⌉)."""
    if kappa is None:
        kappa = default_kappa(g)
    hot = g.hot_mask(hot_threshold)
    members, info = form_localities(g, kappa, hot)
    perm = assign_ids(members, info, hot)
    return (perm, info) if return_info else perm


def lorder_v2(g: Graph, hot_threshold: float | None = None,
              return_info: bool = False):
    """LOrder v2 — localities are ground-truth communities.

    Uses the generator's community labels when the graph carries them;
    otherwise falls back to connected components (κ = ∞ BFS sweeps).
    """
    hot = g.hot_mask(hot_threshold)
    n = g.num_vertices
    if g.communities is not None:
        labels = np.asarray(g.communities, dtype=np.int64)
        # member lists per community, in ascending vertex id (CSR scan order)
        order = np.argsort(labels, kind="stable")
        lab_sorted = labels[order]
        cuts = np.nonzero(np.diff(lab_sorted))[0] + 1
        members = np.split(order, cuts)
        seeds = np.array([m[0] for m in members], dtype=np.int64)
        hotness = np.array([int(hot[m].sum()) for m in members], dtype=np.int64)
        sizes = np.array([len(m) for m in members], dtype=np.int64)
        locality_id = np.empty(n, dtype=np.int64)
        for i, m in enumerate(members):
            locality_id[m] = i
        info = LocalityInfo(seeds, hotness, sizes, locality_id, kappa=-1)
    else:
        members, info = form_localities(g.undirected, kappa=n, hot=hot)
    perm = assign_ids(members, info, hot)
    return (perm, info) if return_info else perm
