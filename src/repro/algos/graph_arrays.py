"""Device-side graph representation for the JAX graph kernels.

A `GraphArrays` pytree mirrors the GAP benchmark's working set: out-CSR,
in-CSR (transpose), COO views and degrees, all as jnp arrays. The six
kernels (BFS, PR, BC, SSSP, CC, CC-SV) consume this structure; vertex
relabeling (reordering) changes only the *content* of these arrays, never
the kernel code — exactly the paper's contract.

Shape bucketing (engine/backends.py) uploads graphs *padded* to a shared
(V_bucket, E_bucket) shape so XLA compiles once per bucket instead of
once per exact CSR shape. Padded uploads carry ``vertex_valid`` /
``edge_valid`` masks; the kernels consult them so results on the real
vertices are exact. Sentinel edges are self-loops on the last *padded*
vertex (padding edges forces at least one padded vertex), which keeps
them out of every real vertex's adjacency even before masking.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from ..core.csr import Graph


class GraphArrays(NamedTuple):
    indptr: jnp.ndarray     # (V+1,) int32 out-CSR
    indices: jnp.ndarray    # (E,)  int32 out-CSR neighbor (dst) ids
    src: jnp.ndarray        # (E,)  int32 COO source per out-edge
    t_indptr: jnp.ndarray   # (V+1,) int32 in-CSR
    t_indices: jnp.ndarray  # (E,)  int32 in-CSR neighbor (src) ids
    t_dst: jnp.ndarray      # (E,)  int32 COO dst per in-edge
    out_degree: jnp.ndarray  # (V,) int32
    in_degree: jnp.ndarray   # (V,) int32
    weights: jnp.ndarray     # (E,) int32 edge weights aligned with out-CSR
    # Bucket-padding masks. None (the default) means "all real": the
    # kernels then skip masking entirely, so unpadded uploads lower to the
    # exact same XLA programs as before bucketing existed.
    vertex_valid: jnp.ndarray | None = None  # (V,) bool, False = padding
    edge_valid: jnp.ndarray | None = None    # (E,) bool, False = sentinel

    @property
    def num_vertices(self) -> int:
        return self.indptr.shape[0] - 1

    @property
    def num_edges(self) -> int:
        return self.indices.shape[0]


def edge_weights(src: np.ndarray, dst: np.ndarray,
                 canonical_ids: np.ndarray | None = None) -> np.ndarray:
    """Deterministic int weights in [1, 255] per canonical edge identity.

    Weights are a pure function of the edge's (src, dst) in *canonical*
    ids — the graph's own ids, or ``canonical_ids[v]`` mapping back to the
    original layout for a relabeled graph — so they are relabel-invariant
    and identical across execution backends (single-device `to_device`
    and the sharded partitioner both call this).
    """
    h_src = np.asarray(src, dtype=np.int64)
    h_dst = np.asarray(dst, dtype=np.int64)
    if canonical_ids is not None:
        canon = np.asarray(canonical_ids, dtype=np.int64)
        h_src, h_dst = canon[h_src], canon[h_dst]
    # splitmix-style hash of canonical (src, dst) -> stable per-edge weight
    key = (h_src.astype(np.uint64) << np.uint64(32)) | h_dst.astype(np.uint64)
    key = (key ^ (key >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    key = (key ^ (key >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    key ^= key >> np.uint64(31)
    return (key % np.uint64(255)).astype(np.int32) + 1


def to_device(g: Graph, weight_seed: int = 17,
              canonical_ids: np.ndarray | None = None,
              pad_to: tuple[int, int] | None = None) -> GraphArrays:
    """Upload a host Graph; deterministic int weights in [1, 255] for SSSP.

    ``pad_to=(num_v, num_e)`` uploads the graph padded to that bucket
    shape: extra vertices are isolated (degree 0, ``vertex_valid`` False),
    extra edges are self-loops on the last padded vertex (``edge_valid``
    False, weight 1). Kernels mask them out, so results restricted to the
    real ``[:V]`` prefix equal the unpadded run. When edges are padded
    there must be at least one padded vertex to host the sentinels —
    `engine.backends.bucket_dims` guarantees that.
    """
    t = g.transpose
    src = g.edge_src.astype(np.int64)
    dst = g.indices.astype(np.int64)
    w = edge_weights(src, dst, canonical_ids)
    _ = weight_seed  # reserved; hash keeps weights relabel-invariant

    n, e = g.num_vertices, g.num_edges
    if pad_to is None:
        num_v, num_e = n, e
    else:
        num_v, num_e = pad_to
        if num_v < n or num_e < e:
            raise ValueError(f"pad_to {pad_to} smaller than graph ({n}, {e})")
        if num_e > e and num_v == n:
            raise ValueError("edge padding needs at least one padded vertex "
                             "to host sentinel self-loops")
    if (num_v, num_e) == (n, e):
        return GraphArrays(
            indptr=jnp.asarray(g.indptr, jnp.int32),
            indices=jnp.asarray(g.indices, jnp.int32),
            src=jnp.asarray(src, jnp.int32),
            t_indptr=jnp.asarray(t.indptr, jnp.int32),
            t_indices=jnp.asarray(t.indices, jnp.int32),
            t_dst=jnp.asarray(t.edge_src, jnp.int32),
            out_degree=jnp.asarray(g.out_degree, jnp.int32),
            in_degree=jnp.asarray(g.in_degree, jnp.int32),
            weights=jnp.asarray(w, jnp.int32),
        )

    sentinel = num_v - 1  # always a padded vertex when sentinel edges exist

    def pad_v(arr, fill=0):
        out = np.full(num_v, fill, np.int32)
        out[:n] = arr
        return out

    def pad_e(arr, fill):
        out = np.full(num_e, fill, np.int32)
        out[:e] = arr
        return out

    def pad_ptr(ptr):
        # padded vertices own no real edges; the whole sentinel tail is
        # booked to the last padded vertex so the CSR stays monotone.
        out = np.full(num_v + 1, e, np.int64)
        out[:n + 1] = ptr
        out[num_v] = num_e
        return out.astype(np.int32)

    vertex_valid = np.zeros(num_v, bool)
    vertex_valid[:n] = True
    edge_valid = np.zeros(num_e, bool)
    edge_valid[:e] = True
    return GraphArrays(
        indptr=jnp.asarray(pad_ptr(g.indptr)),
        indices=jnp.asarray(pad_e(g.indices, sentinel)),
        src=jnp.asarray(pad_e(src, sentinel)),
        t_indptr=jnp.asarray(pad_ptr(t.indptr)),
        t_indices=jnp.asarray(pad_e(t.indices, sentinel)),
        t_dst=jnp.asarray(pad_e(t.edge_src, sentinel)),
        out_degree=jnp.asarray(pad_v(g.out_degree)),
        in_degree=jnp.asarray(pad_v(g.in_degree)),
        weights=jnp.asarray(pad_e(w, 1)),
        vertex_valid=jnp.asarray(vertex_valid),
        edge_valid=jnp.asarray(edge_valid),
    )
