"""Paper Fig 5.2.2 / 5.2.3 — processing speed-ups per (dataset × kernel ×
scheme), plus per-kernel geometric means.

Two speed-up metrics per cell (original layout = 1.0):

* ``wall``  — measured JAX kernel wall-clock ratio on this host. Honest but
  noisy at laptop scale (XLA overheads flatten cache effects).
* ``cache`` — simulated LLC miss-count ratio on the property-access trace
  (the mechanism the paper credits; deterministic and host-independent).
  This is the primary reproduction metric; the cache model uses a
  capacity scaled to the graph so the working set exceeds it, as the
  paper's full-size graphs exceed a real LLC.

Kernels: BFS, PR, CC, CC-SV, BC (the five plotted in Fig 5.2.2); SSSP is
included for completeness (paper lists it in the setup).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .common import (bench_suite, fmt_table, geomean, save_json, schemes,
                     time_call)

KERNELS = ("bfs", "pr", "cc", "ccsv", "bc", "sssp")
PLOT_KERNELS = ("bfs", "pr", "cc", "ccsv", "bc")


def _cache_cfg(g):
    """LLC sized so the property array is ~8× capacity (paper regime)."""
    from repro.cache.sim import scaled_config
    return scaled_config(g)


def _run_kernel(name, ga):
    from repro.algos import kernels as K
    fns = {
        "bfs": lambda: K.bfs(ga, jnp.int32(0)),
        "pr": lambda: K.pagerank(ga),
        "cc": lambda: K.cc_labelprop(ga),
        "ccsv": lambda: K.cc_shiloach_vishkin(ga),
        "bc": lambda: K.bc(ga, sources=(0, 1)),
        "sssp": lambda: K.sssp(ga, jnp.int32(0)),
    }
    return fns[name]


def _tuned_lorder(g, cfg):
    """The paper's protocol (Table 5.2): κ is chosen per dataset to
    minimize post-reorder execution — swept here on the miss count."""
    from repro.cache.sim import property_trace, simulate_misses
    from repro.core.diameter import estimate_diameter
    from repro.core.lorder import lorder
    d = estimate_diameter(g)
    best, best_m = None, None
    for kappa in sorted({1, 2, max(1, d // 4), max(1, d // 2),
                         max(1, (3 * d) // 4)}):
        perm = np.asarray(lorder(g, kappa=int(kappa)))
        m = simulate_misses(property_trace(g.apply_permutation(perm)),
                            cfg)["misses"]
        if best_m is None or m < best_m:
            best, best_m = perm, m
    return best


def run(scale: float = 0.5, repeats: int = 5) -> list[dict]:
    from repro.algos.graph_arrays import to_device
    from repro.cache.sim import property_trace, simulate_misses

    suite = bench_suite(scale)
    sch = dict(schemes())
    rows = []
    for dname, g in suite.items():
        cfg = _cache_cfg(g)
        sch["lorder"] = lambda gg, _c=cfg: _tuned_lorder(gg, _c)
        base_misses = simulate_misses(property_trace(g), cfg)["misses"]
        ga = to_device(g)
        base_wall = {k: time_call(_run_kernel(k, ga), repeats=repeats)[0]
                     for k in KERNELS}
        del ga
        for sname, fn in sch.items():
            perm = np.asarray(fn(g))
            gp = g.apply_permutation(perm)
            misses = simulate_misses(property_trace(gp), cfg)["misses"]
            gpa = to_device(gp)
            for k in KERNELS:
                wall, _ = time_call(_run_kernel(k, gpa), repeats=repeats)
                rows.append({
                    "dataset": dname, "scheme": sname, "kernel": k,
                    "wall_speedup": round(base_wall[k] / wall, 4),
                    "cache_speedup": round(base_misses / max(misses, 1), 4),
                })
            del gpa
            print(f"[speedups] {dname}/{sname} done", flush=True)
    save_json("speedups", rows)
    return rows


def summarize(rows: list[dict], metric: str = "cache_speedup"):
    """Fig 5.2.3 (geomeans) + the DBG/SOrder win-rate claims."""
    datasets = sorted({r["dataset"] for r in rows})
    sch = sorted({r["scheme"] for r in rows})
    geo = []
    for s in sch:
        row = {"scheme": s}
        for k in PLOT_KERNELS:
            row[k] = round(geomean([r[metric] for r in rows
                                    if r["scheme"] == s
                                    and r["kernel"] == k]), 3)
        geo.append(row)

    def wins(a: str, b: str) -> tuple[int, int]:
        w = t = 0
        for d in datasets:
            for k in PLOT_KERNELS:
                ra = next(r[metric] for r in rows if r["dataset"] == d
                          and r["kernel"] == k and r["scheme"] == a)
                rb = next(r[metric] for r in rows if r["dataset"] == d
                          and r["kernel"] == k and r["scheme"] == b)
                t += 1
                w += ra > rb
        return w, t

    w_dbg = wins("lorder", "dbg")
    w_sorder = wins("lorder", "sorder")
    return geo, {"lorder_beats_dbg": w_dbg, "lorder_beats_sorder": w_sorder}


def main(scale: float = 0.5):
    rows = run(scale)
    for metric in ("cache_speedup", "wall_speedup"):
        geo, claims = summarize(rows, metric)
        print(f"\n=== geomean {metric} per kernel (Fig 5.2.3) ===")
        print(fmt_table(geo, ["scheme", *PLOT_KERNELS]))
        w, t = claims["lorder_beats_dbg"]
        print(f"LOrder beats DBG  {w}/{t} ({100 * w / t:.0f}%; paper: 77%)")
        w, t = claims["lorder_beats_sorder"]
        print(f"LOrder beats SOrder {w}/{t} ({100 * w / t:.0f}%; paper: 60%)")
        save_json(f"speedups_geomean_{metric}",
                  {"geomean": geo, "claims": claims})


if __name__ == "__main__":
    main()
