"""Beyond-paper table — vocab-LOrder embedding layout.

Per assigned token-fed architecture: hot-slab coverage of the corpus under
(a) the original tokenizer layout, (b) frequency sort (DBG-flavoured),
(c) LOrder on the co-occurrence graph; plus the simulated cache miss rate
of the embedding-row access trace (the paper's metric, applied to the
embedding table as the property array).
"""
from __future__ import annotations

import numpy as np

from .common import fmt_table, save_json


def run(sample_tokens: int = 200_000) -> list[dict]:
    from repro.cache.sim import CacheConfig, simulate_misses
    from repro.configs import ARCH_IDS, get_config
    from repro.data.pipeline import DataConfig, corpus_sample
    from repro.locality import applies_to
    from repro.locality.vocab import (degree_permutation, hot_coverage,
                                      vocab_permutation)

    rows = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        feats = applies_to(cfg)
        if not feats["vocab_reorder"]:
            rows.append({"arch": arch, "note": "inapplicable (DESIGN §4)"})
            continue
        v = min(cfg.vocab_size, 65536)       # cap corpus model for speed
        dc = DataConfig(vocab_size=v, seq_len=2048,
                        global_batch=max(1, sample_tokens // 2048))
        sample = corpus_sample(dc, 1)
        hot_frac = cfg.hot_vocab_fraction or 0.05
        lorder_vr = vocab_permutation(sample, v, hot_fraction=hot_frac)
        counts = np.bincount(sample, minlength=v)
        freq_vr = degree_permutation(counts, hot_fraction=hot_frac)

        # embedding-row cache trace: one row access per corpus token.
        # rows are d_model*4 bytes; model a 1/8-capacity LLC like §T6.
        row_bytes = cfg.d_model * 4
        cache = CacheConfig(size_bytes=max(64 * 1024, v * row_bytes // 256),
                            ways=16, line_bytes=row_bytes, prop_bytes=row_bytes,
                            sample_rate=16)
        def mr(tokens):
            return simulate_misses(tokens.astype(np.int64), cache)["miss_rate"]

        rows.append({
            "arch": arch,
            "vocab": v,
            "hot_slab_%": round(100 * hot_frac, 1),
            "cov_original_%": round(100 * float(
                (sample < int(v * hot_frac)).mean()), 1),
            "cov_freq_%": round(100 * hot_coverage(sample, freq_vr), 1),
            "cov_lorder_%": round(100 * hot_coverage(sample, lorder_vr), 1),
            "miss_original": round(mr(sample), 4),
            "miss_lorder": round(mr(lorder_vr.map_tokens(sample)), 4),
        })
        print(f"[vocab_locality] {arch} done", flush=True)
    save_json("vocab_locality", rows)
    return rows


def main():
    rows = run()
    cols = ["arch", "vocab", "hot_slab_%", "cov_original_%", "cov_freq_%",
            "cov_lorder_%", "miss_original", "miss_lorder", "note"]
    print(fmt_table(rows, cols))


if __name__ == "__main__":
    main()
