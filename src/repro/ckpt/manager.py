"""Fault-tolerant checkpoint manager (DESIGN.md §5).

Design for 1000+ node fleets:

* **Per-host shard files** — each host writes only the param/opt shards it
  owns (`.npz` per host per step); no host ever serializes the global
  state, so save cost is O(model/hosts) and scales flat with fleet size.
* **Atomic commit** — shards are written to ``step_<n>.tmp/`` and the
  directory is ``rename``d to ``step_<n>/`` only after all local writes
  fsync; a ``MANIFEST.json`` written last marks the step complete.
  Readers ignore uncommitted directories, so a node failure mid-save
  never corrupts the restore point.
* **Async save** — a background thread does the serialization from a
  jax.device_get'd snapshot, keeping step time flat (save overlaps the
  next steps; the train loop only blocks if a previous save is still
  in flight — one-deep pipeline).
* **Elastic restore** — shards store the *global* array pieces with their
  index ranges; restore concatenates whatever shard files exist and
  re-shards onto the *current* mesh, so a job restarted on a different
  topology (node loss ⇒ smaller mesh; expansion ⇒ larger) resumes
  bit-exactly. On this single-host container every save holds the full
  state, which is the degenerate case of the same format.
* **keep-k GC** — old committed steps beyond ``keep`` are deleted after a
  successful commit, never before.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
import time

import jax
import numpy as np


def _flatten(tree, prefix=""):
    """dict-of-dicts -> {path: leaf}; path uses '/' separators."""
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for path, leaf in flat.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return root


class CheckpointManager:
    def __init__(self, directory, *, keep: int = 3, host_id: int = 0,
                 num_hosts: int = 1, async_save: bool = True):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.async_save = async_save
        self._inflight: threading.Thread | None = None

    # ------------------------------------------------------------- save
    def save(self, step: int, state: dict, blocking: bool = False):
        """Snapshot ``state`` (pytree of jax/np arrays) at ``step``."""
        self.wait()  # one-deep async pipeline
        # snapshot on the caller thread (values may be donated next step)
        flat = {k: np.asarray(jax.device_get(v))
                for k, v in _flatten(state).items()}
        if self.async_save and not blocking:
            self._inflight = threading.Thread(
                target=self._write, args=(step, flat), daemon=True)
            self._inflight.start()
        else:
            self._write(step, flat)

    def _write(self, step: int, flat: dict):
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        shard_file = tmp / f"host_{self.host_id:05d}.npz"
        np.savez(shard_file, **{k.replace("/", "|"): v
                                for k, v in flat.items()})
        manifest = {
            "step": step,
            "num_hosts": self.num_hosts,
            "keys": sorted(flat.keys()),
            "time": time.time(),
        }
        (tmp / "MANIFEST.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)          # atomic commit
        self._gc()

    def wait(self):
        if self._inflight is not None:
            self._inflight.join()
            self._inflight = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for p in sorted(self.dir.glob("step_*")):
            if p.suffix == ".tmp" or not (p / "MANIFEST.json").exists():
                continue  # uncommitted — ignore (fault tolerance)
            out.append(int(p.name.split("_")[1]))
        return out

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None = None, shardings=None):
        """Load state; re-shard onto ``shardings`` (pytree of NamedSharding)
        if given — the elastic-restart path."""
        if step is None:
            step = self.latest_step()
            if step is None:
                return None, None
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "MANIFEST.json").read_text())
        flat: dict = {}
        for shard_file in sorted(d.glob("host_*.npz")):
            with np.load(shard_file) as z:
                for k in z.files:
                    flat[k.replace("|", "/")] = z[k]
        missing = set(manifest["keys"]) - set(flat)
        if missing:
            raise FileNotFoundError(
                f"checkpoint step {step} incomplete: missing {missing}")
        tree = _unflatten(flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return step, tree
