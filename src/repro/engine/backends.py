"""Pluggable execution backends: where and in what shape a graph runs.

The executor used to be a single code path — exact-shape compile cache on
one device. Two scale gaps (ROADMAP "Engine") break that at serving
volume:

* **compile sharing** — XLA specializes on shapes, so a stream of graphs
  with *distinct* (V, E) recompiles every kernel per graph even though
  the programs are identical. `SingleDeviceBackend` pads CSR uploads to
  geometric (V_bucket, E_bucket) shapes with masked sentinel edges
  (graph_arrays.to_device ``pad_to``; kernels consult the masks), so all
  graphs in a bucket share one compiled executable per kernel and results
  on the real ``[:V]`` prefix stay exact.
* **single-device memory** — a graph whose CSR working set exceeds the
  per-device budget has no serving path. `ShardedBackend` routes queries
  through `core.dist`'s edge-partitioned kernels — all six (multi-source
  BFS/SSSP/BC, PageRank, CC, CC-SV) — across every visible device, with
  an optional **hot-prefix exchange** (`hot_prefix_fraction`, a policy
  decision derived from the hub-mass probe) that all-gathers only the
  hot id prefix every step and the cold suffix every ``cold_every``
  steps on the monotone kernels, exactness-preserving (core/dist.py).

Both present the same surface (`ExecutionBackend`): ``prepare`` turns a
host graph into a `GraphHandle`, ``run`` executes one query batch against
a handle. `engine.executor.BatchedExecutor` is the routing facade; the
*choice* of backend is a policy decision (`ReorderPolicy` places a graph
by comparing `estimate_device_bytes` against its device budget) recorded
in the policy record and the amortization ledger. docs/backends.md has
the full picture.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import math
from collections import OrderedDict
from typing import Protocol, runtime_checkable

import numpy as np

import jax
import jax.numpy as jnp

from ..algos import kernels as K
from ..algos.graph_arrays import GraphArrays, to_device
from ..core.csr import Graph
from ..search.serve import SearchSpec, pad_queries
from .obs import MetricsRegistry, Tracer

# kernels taking a batch of sources -> (S, V) per-source rows
MULTI_SOURCE = ("bfs", "sssp", "bc")
# source-independent kernels -> (V,)
GLOBAL = ("pr", "cc", "ccsv")
# kernels whose "source" is a float32 vector, not a vertex id -> the
# per-source row is a (k_return,) id vector; runs also return (V,)
# visit counts (the reorder policy's hotness telemetry)
VECTOR_SOURCE = ("knn",)

# All entries are already jitted in algos.kernels; jax's own cache
# specializes per CSR shape. The backend's key-level dict on top exists
# to *attribute* compiles to serving traffic (hit/miss telemetry).
# knn is the exception: its static beam/step knobs force the per-key
# jit wrapper pattern (_run_knn), mirroring the pr@spmv path.
_FNS = {
    "bfs": K.bfs_multi,
    "sssp": K.sssp_multi,
    "bc": K.bc_multi,
    "pr": K.pagerank,
    "cc": K.cc_labelprop,
    "ccsv": K.cc_shiloach_vishkin,
    "knn": K.knn_search_multi,
}


def build_kernel(kernel: str):
    try:
        return _FNS[kernel]
    except KeyError:
        raise ValueError(
            f"unknown kernel {kernel!r}; "
            f"have {MULTI_SOURCE + GLOBAL + VECTOR_SOURCE}") from None


def source_bucket(n: int) -> int:
    """Next power-of-two source-batch bucket (>= 1)."""
    return 1 << max(0, (n - 1).bit_length())


def pad_sources(sources, kernel: str) -> tuple[np.ndarray, int]:
    """Validate + pad a source batch to its power-of-two bucket.

    Returns ``(padded_sources, real_count)``. Raises *before* any cache
    or device work for an empty batch — a zero-width vmap launch would
    still consult (and pollute) the compile-cache telemetry.
    """
    srcs = np.atleast_1d(np.asarray(sources, dtype=np.int32))
    if srcs.size == 0:
        raise ValueError(f"{kernel} needs at least one source")
    pad = source_bucket(srcs.size)
    padded = np.full(pad, srcs[0], np.int32)
    padded[:srcs.size] = srcs
    return padded, int(srcs.size)


# ------------------------------------------------------------------ buckets
def bucket_dims(num_vertices: int, num_edges: int, growth: float = 2.0,
                v_floor: int = 256, e_floor: int = 1024) -> tuple[int, int]:
    """Geometric (V_bucket, E_bucket) for compile sharing.

    Buckets grow by ``growth`` from the floors, so a stream of arbitrary
    graph sizes hits O(log V + log E) compiled shapes per kernel. When
    edges need padding the vertex bucket is forced strictly above V so
    sentinel self-loops land on a *padded* vertex — that keeps them out
    of every real adjacency list and off the real in-CSR rows.
    """
    if growth <= 1.0:
        raise ValueError(f"growth must be > 1, got {growth}")

    def up(x: int, floor: int) -> int:
        b = floor
        while b < x:
            b = int(math.ceil(b * growth))
        return b

    e_b = up(num_edges, e_floor)
    v_min = num_vertices + 1 if e_b > num_edges else num_vertices
    v_b = up(v_min, v_floor)
    return v_b, e_b


def estimate_device_bytes(num_vertices: int, num_edges: int,
                          batch_sources: int = 0) -> int:
    """Device footprint of serving one graph (the placement input).

    CSR upload: int32 fields — 2x indptr (V+1), 5x edge-sized (indices,
    src, t_indices, t_dst, weights), 2x vertex-sized degrees — plus
    1-byte bool masks.

    ``batch_sources`` adds the **query state** (placement v2): a
    multi-source launch of S sources holds an (S, V) int32 property
    matrix plus a same-shape relaxation/frontier buffer alive on the
    device, so at realistic batch sizes the working set is the CSR *and*
    ~8·S·V bytes. The policy feeds S from the micro-batch scheduler's
    observed launch sizes (`ReorderPolicy.observe_batch_sources`), so a
    graph that fits alone but not under its real traffic's batches is
    placed sharded.
    """
    return (4 * (2 * (num_vertices + 1) + 5 * num_edges + 2 * num_vertices)
            + num_vertices + num_edges
            + 8 * batch_sources * num_vertices)


# ------------------------------------------------------------------- handle
@dataclasses.dataclass
class PackedSpMV:
    """Pre-packed Pallas CSR-SpMV operands for one uploaded graph.

    `kernels.csr_spmv.pack_edges` output for the (possibly bucketed)
    in-CSR edge stream: dst-tiled edge blocks plus the static grid
    dimensions. ``val`` is 0 on sentinel edges, so bucketed uploads
    contribute nothing from padding. The grid dims are data-dependent
    (``blocks_per_tile`` follows the densest dst tile), so they are part
    of the compile-cache key — two graphs in the same (V, E) bucket may
    still need distinct pallas grids.
    """

    src: jnp.ndarray
    dst_local: jnp.ndarray
    val: jnp.ndarray
    blocks_per_tile: int
    num_tiles: int
    n_pad: int
    interpret: bool


@dataclasses.dataclass
class GraphHandle:
    """What ``prepare`` returns and ``run`` consumes — one served graph.

    ``num_vertices``/``num_edges`` are the *real* sizes; ``bucket`` is the
    padded upload shape (equal to the real sizes when bucketing is off or
    the graph already sits on a bucket boundary). ``arrays`` is the
    single-device upload; sharded handles carry backend state in
    ``shard_state`` instead.
    """

    backend: str
    num_vertices: int
    num_edges: int
    bucket: tuple[int, int]
    device_bytes: int
    arrays: GraphArrays | None = None
    shard_state: object | None = None
    hot_prefix_fraction: float | None = None  # sharded exchange policy
    spmv: PackedSpMV | None = None  # Pallas PR relaxation operands
    search: "DeviceSearch | None" = None  # knn operands (search graphs)


@dataclasses.dataclass
class DeviceSearch:
    """Device-resident knn operands for one uploaded search graph.

    ``vectors``/``canon`` are the `SearchSpec` payloads padded to the
    handle's vertex bucket (padded rows are unreachable: sentinel edges
    never land in a real adjacency list, so the kernel cannot gather
    them). ``params`` are the compile-static beam knobs.
    """

    vectors: jnp.ndarray   # (V_bucket, d) float32, served order
    canon: jnp.ndarray     # (V_bucket,) int32 served -> original
    entry: int             # served id of the entry vertex
    params: object         # search.serve.SearchParams
    dim: int


def _device_search(spec: SearchSpec, v_bucket: int) -> DeviceSearch:
    vecs = np.ascontiguousarray(spec.vectors, dtype=np.float32)
    canon = np.ascontiguousarray(spec.canon, dtype=np.int32)
    if v_bucket > len(vecs):
        vecs = np.concatenate(
            [vecs, np.zeros((v_bucket - len(vecs), vecs.shape[1]),
                            np.float32)])
        canon = np.concatenate(
            [canon, np.arange(len(canon), v_bucket, dtype=np.int32)])
    return DeviceSearch(jnp.asarray(vecs), jnp.asarray(canon),
                        int(spec.entry), spec.params, int(vecs.shape[1]))


@runtime_checkable
class ExecutionBackend(Protocol):
    """Uniform surface the executor routes through."""

    name: str

    def prepare(self, graph: Graph,
                canonical_ids: np.ndarray | None = None) -> GraphHandle: ...

    def run(self, handle: GraphHandle, kernel: str,
            sources=None) -> jnp.ndarray: ...

    def telemetry(self) -> dict: ...


def _backend_counters(metrics: MetricsRegistry, backend: str) -> dict:
    """The per-backend serving counters every backend keeps."""
    return {
        "queries": metrics.counter("engine_queries_total",
                                   "query batches executed",
                                   backend=backend),
        "sources": metrics.counter("engine_sources_total",
                                   "real (unpadded) sources executed",
                                   backend=backend),
        "prepared": metrics.counter("engine_graphs_prepared_total",
                                    "graphs uploaded/prepared",
                                    backend=backend),
        # host->device kernel launches. Single-device queries are one
        # launch each; sharded queries were one launch *per traversal
        # step* until the fused drivers (core/dist.py) collapsed them to
        # one per run — the collapse tests/test_fused_loops.py asserts
        # through this counter.
        "dispatches": metrics.counter("engine_dispatches_total",
                                      "host->device kernel launches",
                                      backend=backend),
    }


# ------------------------------------------------------------- single device
class SingleDeviceBackend:
    """Today's path plus shape bucketing: one device, shared compiles.

    The compiled-executable cache is **bounded**: with
    ``max_cached_executables`` set, entries are evicted LRU once the cap
    is hit. Each cache entry owns its own ``jax.jit`` wrapper (not the
    module-level jitted kernel), so evicting an entry genuinely releases
    its compiled executables — a long-lived session serving an unbounded
    stream of shapes stays bounded instead of accumulating one executable
    per (kernel, bucket) forever (the ROADMAP's eviction item). Evictions
    are counted in telemetry; an evicted shape that returns simply
    recompiles (a counted miss).
    """

    name = "single"

    def __init__(self, bucketing: bool = True, growth: float = 2.0,
                 v_floor: int = 256, e_floor: int = 1024,
                 max_cached_executables: int | None = None,
                 pallas_pr: bool | str = "auto",
                 metrics: MetricsRegistry | None = None):
        if max_cached_executables is not None and max_cached_executables < 1:
            raise ValueError("max_cached_executables must be >= 1 or None")
        self.bucketing = bucketing
        self.growth = growth
        self.v_floor = v_floor
        self.e_floor = e_floor
        self.max_cached_executables = max_cached_executables
        # Pallas PR relaxation: "auto" compiles the real kernel on TPU
        # and stays off elsewhere (the XLA segment-sum path is the CPU
        # production fallback); True forces it, falling back to the
        # pallas interpreter off-TPU so CI without TPUs still runs the
        # same kernel code (slow — validation, not serving).
        on_tpu = jax.default_backend() == "tpu"
        if pallas_pr == "auto":
            self.pallas_pr = on_tpu
        else:
            self.pallas_pr = bool(pallas_pr)
        self._pallas_interpret = not on_tpu
        self._cache: OrderedDict[tuple, object] = OrderedDict()
        # counters are registry instruments (obs.py); the legacy int
        # attributes below are read-through properties over them
        self.metrics = metrics or MetricsRegistry()
        self.tracer: Tracer | None = None   # set by the owning session
        self._counters = _backend_counters(self.metrics, self.name)
        self._c_hits = self.metrics.counter(
            "engine_compile_cache_hits_total",
            "executable cache hits", backend=self.name)
        self._c_misses = self.metrics.counter(
            "engine_compile_cache_misses_total",
            "executable cache misses (compiles)", backend=self.name)
        self._c_evictions = self.metrics.counter(
            "engine_cache_evictions_total",
            "LRU executable evictions", backend=self.name)
        self._bucket_counts: dict[tuple[int, int], int] = {}

    @property
    def cache_hits(self) -> int:
        return self._c_hits.value

    @property
    def cache_misses(self) -> int:
        return self._c_misses.value

    @property
    def cache_evictions(self) -> int:
        return self._c_evictions.value

    @property
    def queries_run(self) -> int:
        return self._counters["queries"].value

    @property
    def sources_run(self) -> int:
        return self._counters["sources"].value

    @property
    def graphs_prepared(self) -> int:
        return self._counters["prepared"].value

    def _span(self, name: str, **args):
        return (self.tracer.span(name, **args) if self.tracer is not None
                else contextlib.nullcontext(args))

    # -------------------------------------------------------------- prepare
    def prepare(self, graph: Graph,
                canonical_ids: np.ndarray | None = None,
                search: SearchSpec | None = None) -> GraphHandle:
        n, e = graph.num_vertices, graph.num_edges
        bucket = (bucket_dims(n, e, self.growth, self.v_floor, self.e_floor)
                  if self.bucketing else (n, e))
        arrays = to_device(graph, canonical_ids=canonical_ids,
                           pad_to=bucket if bucket != (n, e) else None)
        self._counters["prepared"].inc()
        self._bucket_counts[bucket] = self._bucket_counts.get(bucket, 0) + 1
        spmv = self._pack_spmv(arrays) if self.pallas_pr else None
        ds = _device_search(search, bucket[0]) if search is not None else None
        return GraphHandle(self.name, n, e, bucket,
                           estimate_device_bytes(*bucket), arrays=arrays,
                           spmv=spmv, search=ds)

    def _pack_spmv(self, arrays: GraphArrays) -> PackedSpMV:
        """Pack the (bucketed) in-CSR edge stream for the Pallas kernel.

        Edge values are the PR relaxation's coefficients: 1 for real
        edges, 0 for sentinels (`to_device` keeps real edges on the
        ``[:E]`` prefix of *both* CSR views, so ``edge_valid`` aligns
        with the in-CSR order too).
        """
        from ..kernels.csr_spmv.csr_spmv import pack_edges
        ev = arrays.edge_valid
        weights = None if ev is None else np.asarray(ev, np.float32)
        src, dst_local, val, bpt, ntiles, n_pad = pack_edges(
            np.asarray(arrays.t_indptr), np.asarray(arrays.t_indices),
            weights)
        return PackedSpMV(jnp.asarray(src), jnp.asarray(dst_local),
                          jnp.asarray(val), bpt, ntiles, n_pad,
                          self._pallas_interpret)

    # ------------------------------------------------------------------ run
    def _cache_get(self, key: tuple, build):
        """Hit/miss-counted LRU lookup; ``build()`` makes the jit wrapper."""
        cached = self._cache.get(key)
        if cached is not None:
            self._c_hits.inc()
            self._cache.move_to_end(key)     # LRU: refresh recency
            return cached
        self._c_misses.inc()
        if self.tracer is not None:
            self.tracer.instant("compile_cache_miss", kernel=key[0],
                                key=str(key))
        # a per-key jit wrapper owns this key's executables, so LRU
        # eviction below actually frees them (the module-level jitted
        # kernel would pin every shape it ever compiled)
        cached = build()
        self._cache[key] = cached
        if (self.max_cached_executables is not None
                and len(self._cache) > self.max_cached_executables):
            self._cache.popitem(last=False)  # least recently used
            self._c_evictions.inc()
        return cached

    def _compiled(self, kernel: str, ga: GraphArrays):
        # validate the kernel name before touching any telemetry counter
        fn = build_kernel(kernel)
        # mask presence changes the pytree structure, so jax recompiles
        # even at equal shapes — the telemetry key must not conflate them
        key = (kernel, ga.num_vertices, ga.num_edges,
               ga.vertex_valid is not None)
        return self._cache_get(key, lambda: jax.jit(fn))

    def run_arrays(self, ga: GraphArrays, kernel: str,
                   sources=None) -> jnp.ndarray:
        """Execute against raw device arrays (no real-prefix slicing)."""
        build_kernel(kernel)  # unknown kernel: raise before anything counts
        if kernel in GLOBAL:
            fn = self._compiled(kernel, ga)
            self._counters["queries"].inc()
            self._counters["dispatches"].inc()
            out = fn(ga)
            with self._span("device_sync", kernel=kernel):
                return jax.block_until_ready(out)
        padded, real = pad_sources(sources, kernel)
        fn = self._compiled(kernel, ga)
        self._counters["queries"].inc()
        self._counters["dispatches"].inc()
        self._counters["sources"].inc(real)
        out = fn(ga, jnp.asarray(padded))
        with self._span("device_sync", kernel=kernel):
            return jax.block_until_ready(out)[:real]

    def _run_pr_spmv(self, handle: GraphHandle) -> jnp.ndarray:
        """PR with the relaxation on the Pallas CSR kernel (still one
        ``while_loop`` jit, one dispatch — only the segment-sum inside
        the loop body changes). The cache key carries the pallas grid
        dims: ``blocks_per_tile`` follows the densest destination tile,
        so graphs sharing a (V, E) bucket may still need separate
        executables."""
        ga, sp = handle.arrays, handle.spmv
        key = ("pr@spmv", ga.num_vertices, ga.num_edges,
               ga.vertex_valid is not None, sp.num_tiles,
               sp.blocks_per_tile)
        fn = self._cache_get(key, lambda: jax.jit(functools.partial(
            K.pagerank_spmv, blocks_per_tile=sp.blocks_per_tile,
            num_tiles=sp.num_tiles, n_pad=sp.n_pad,
            interpret=sp.interpret)))
        self._counters["queries"].inc()
        self._counters["dispatches"].inc()
        out = fn(ga, sp.src, sp.dst_local, sp.val)
        with self._span("device_sync", kernel="pr"):
            return jax.block_until_ready(out)

    def _run_knn(self, handle: GraphHandle, queries) -> tuple:
        """Beam search over the uploaded search graph: (S, d) queries ->
        ``((S, k_return) served ids, (V,) visit counts)``. The beam knobs
        are compile-static, so (like pr@spmv) each parameterization owns
        a per-key jit wrapper in the bounded executable cache."""
        ds = handle.search
        if ds is None:
            raise ValueError("knn_search needs a graph prepared with "
                             "search= (a SearchSpec); this handle has none")
        ga = handle.arrays
        p = ds.params
        padded, valid, real = pad_queries(queries)
        key = ("knn", ga.num_vertices, ga.num_edges, ds.dim, len(padded),
               p.k_out, p.beam_width, p.k_return, p.max_steps)
        fn = self._cache_get(key, lambda: jax.jit(functools.partial(
            K.knn_search_multi, k_out=p.k_out, beam_width=p.beam_width,
            k_return=p.k_return, max_steps=p.max_steps)))
        self._counters["queries"].inc()
        self._counters["dispatches"].inc()
        self._counters["sources"].inc(real)
        ids, visits = fn(ga, ds.vectors, ds.canon, jnp.int32(ds.entry),
                         jnp.asarray(padded), jnp.asarray(valid))
        with self._span("device_sync", kernel="knn"):
            ids = jax.block_until_ready(ids)
        return ids[:real], visits[:handle.num_vertices]

    def run(self, handle: GraphHandle, kernel: str,
            sources=None) -> jnp.ndarray:
        if kernel in VECTOR_SOURCE:
            # knn returns (ids, visits), already sliced to real shapes
            return self._run_knn(handle, sources)
        if kernel == "pr" and handle.spmv is not None:
            out = self._run_pr_spmv(handle)
        else:
            out = self.run_arrays(handle.arrays, kernel, sources)
        # slice the bucket padding back off: results live on [:V]
        return out[..., :handle.num_vertices]

    # ------------------------------------------------------------ telemetry
    def telemetry(self) -> dict:
        return {
            "compile_cache_hits": self.cache_hits,
            "compile_cache_misses": self.cache_misses,
            "cache_evictions": self.cache_evictions,
            "max_cached_executables": self.max_cached_executables,
            "cached_keys": sorted(str(k) for k in self._cache),
            "queries_run": self.queries_run,
            "sources_run": self.sources_run,
            "dispatches": self._counters["dispatches"].value,
            "pallas_pr": self.pallas_pr,
            "bucketing": {
                "enabled": self.bucketing,
                "graphs_prepared": self.graphs_prepared,
                "distinct_buckets": len(self._bucket_counts),
                "bucket_counts": {str(k): v
                                  for k, v in sorted(self._bucket_counts.items())},
            },
        }


# ----------------------------------------------------------------- sharded
def _make_sharded_bfs(st):
    from ..core import dist
    return dist.make_distributed_bfs(
        st.graph, st.mesh, st.axis,
        hot_prefix_fraction=st.hot_prefix_fraction,
        cold_every=st.cold_every, stats=st.stats, fused=st.fused)


def _make_sharded_sssp(st):
    from ..core import dist
    return dist.make_distributed_sssp(
        st.graph, st.mesh, st.axis, canonical_ids=st.canonical_ids,
        hot_prefix_fraction=st.hot_prefix_fraction,
        cold_every=st.cold_every, stats=st.stats, fused=st.fused)


def _make_sharded_pr(st):
    from ..core import dist
    # synchronous power iteration: always a full exchange (core/dist.py)
    run, _ = dist.make_distributed_pagerank(st.graph, st.mesh, st.axis,
                                            stats=st.stats, fused=st.fused)
    return run


def _make_sharded_cc(st):
    from ..core import dist
    return dist.make_distributed_cc(
        st.graph, st.mesh, st.axis,
        hot_prefix_fraction=st.hot_prefix_fraction,
        cold_every=st.cold_every, stats=st.stats, fused=st.fused)


def _make_sharded_bc(st):
    from ..core import dist
    # level-synchronous float accumulation: always a full exchange
    return dist.make_distributed_bc(st.graph, st.mesh, st.axis,
                                    stats=st.stats, fused=st.fused)


# Every served kernel has a sharded runner factory — full six-kernel
# parity with the single-device backend. CC-SV shares the min-label
# runner: both converge to the min-id-per-component labeling, and the
# alias makes cc/ccsv share one cached runner (one edge partition, one
# compile) instead of building two identical ones.
_RUNNER_FACTORIES = {
    "bfs": _make_sharded_bfs,
    "sssp": _make_sharded_sssp,
    "bc": _make_sharded_bc,
    "pr": _make_sharded_pr,
    "cc": _make_sharded_cc,
    "ccsv": _make_sharded_cc,
}
_RUNNER_ALIASES = {"ccsv": "cc"}

SHARDED_KERNELS = tuple(_RUNNER_FACTORIES)


class _ShardedGraphState:
    """Per-graph device state for `ShardedBackend` (lazy kernel factories)."""

    def __init__(self, graph: Graph, mesh, axis: str,
                 canonical_ids: np.ndarray | None,
                 hot_prefix_fraction: float | None, cold_every: int,
                 stats, fused: bool = True,
                 search: SearchSpec | None = None):
        self.graph = graph
        self.mesh = mesh
        self.axis = axis
        self.canonical_ids = canonical_ids
        self.hot_prefix_fraction = hot_prefix_fraction
        self.cold_every = cold_every
        self.stats = stats
        self.fused = fused
        self._runners: dict[str, object] = {}
        # knn (query-parallel GSPMD) state: the host SearchSpec, the
        # replicated device operands (built lazily on first knn run),
        # and per-batch-shape jit wrappers
        self.search = search
        self.knn_operands: tuple | None = None
        self.knn_fns: dict[tuple, object] = {}

    def runner(self, kernel: str):
        kernel = _RUNNER_ALIASES.get(kernel, kernel)
        fn = self._runners.get(kernel)
        if fn is None:
            # unknown kernel names are rejected by build_kernel before we
            # get here, so a miss in the factory table is a parity bug
            assert kernel in _RUNNER_FACTORIES, (
                f"kernel {kernel!r} is served but has no sharded runner "
                f"factory; SHARDED_KERNELS = {SHARDED_KERNELS}")
            fn = _RUNNER_FACTORIES[kernel](self)
            self._runners[kernel] = fn
        return fn


class ShardedBackend:
    """Serve graphs beyond one device through core/dist edge partitions.

    Edges are 1-D partitioned by destination range over ``mesh[axis]``
    (every visible device by default); vertex property state lives sharded
    and each traversal step all-gathers it — see core/dist.py for why
    reordering concentrates the *useful* payload of that collective.
    ``prepare``'s ``hot_prefix_fraction`` (a policy decision) turns on the
    hot-prefix exchange for the monotone kernels: only that fraction of
    each shard's slice is gathered per step, the cold suffix every
    ``cold_every`` steps. `telemetry()["hot_prefix"]` reports the
    exchanged-vs-full byte ledger and static prefix hit rates.
    """

    name = "sharded"

    def __init__(self, num_shards: int | None = None, axis: str = "data",
                 mesh=None, cold_every: int = 4,
                 metrics: MetricsRegistry | None = None,
                 fused: bool = True):
        if mesh is None:
            n = num_shards or jax.device_count()
            mesh = jax.make_mesh((n,), (axis,))
        self.mesh = mesh
        self.axis = axis
        self.num_shards = mesh.shape[axis]
        self.cold_every = cold_every
        # fused=True runs each traversal as one on-device XLA While
        # (one dispatch per query); False keeps the host step loop — the
        # differential reference for tests/test_fused_loops.py
        self.fused = fused
        self.metrics = metrics or MetricsRegistry()
        self.tracer: Tracer | None = None   # set by the owning session
        self._counters = _backend_counters(self.metrics, self.name)
        self._c_ex_steps = self.metrics.counter(
            "engine_exchange_steps_total",
            "sharded per-step collective exchanges")
        self._c_ex_bytes = self.metrics.counter(
            "engine_exchange_bytes_total",
            "bytes received per device across exchanges")
        from ..core.dist import ExchangeStats
        self.exchange_stats = ExchangeStats()
        # exchange delta of the most recent run(): runs are serial, so a
        # snapshot/delta pair attributes collective bytes per query — the
        # scheduler copies this into each request's telemetry
        self.last_run_exchange: dict | None = None
        self._prefix_info: list[dict] = []

    @property
    def queries_run(self) -> int:
        return self._counters["queries"].value

    @property
    def sources_run(self) -> int:
        return self._counters["sources"].value

    @property
    def graphs_prepared(self) -> int:
        return self._counters["prepared"].value

    def prepare(self, graph: Graph,
                canonical_ids: np.ndarray | None = None,
                hot_prefix_fraction: float | None = None,
                search: SearchSpec | None = None) -> GraphHandle:
        n, e = graph.num_vertices, graph.num_edges
        state = _ShardedGraphState(graph, self.mesh, self.axis,
                                   canonical_ids, hot_prefix_fraction,
                                   self.cold_every, self.exchange_stats,
                                   fused=self.fused, search=search)
        self._counters["prepared"].inc()
        return GraphHandle(self.name, n, e, (n, e),
                           self._per_device_bytes(graph),
                           shard_state=state,
                           hot_prefix_fraction=hot_prefix_fraction)

    def _per_device_bytes(self, graph: Graph) -> int:
        """Resident graph bytes per device, from the *actual* partition.

        `partition_edges` splits by dst range and pads every shard to the
        fullest shard's edge count, so on skewed graphs the per-device
        footprint is set by the hub-heaviest range — the true histogram
        is O(E) on the host and cheap next to the upload. Counts the
        edge arrays (src, dst, valid, weights) and one int32 vertex
        property slice; per-query (S × per) state is not included.
        """
        per = -(-graph.num_vertices // self.num_shards)
        counts = np.bincount(np.asarray(graph.indices) // per,
                             minlength=self.num_shards)
        emax = int(counts.max()) if len(counts) else 0
        return emax * (4 + 4 + 1 + 4) + per * 4

    def _run_knn(self, handle: GraphHandle, queries) -> tuple:
        """Query-parallel knn through GSPMD: queries are row-sharded over
        ``mesh[axis]``, the CSR arrays / vector corpus / canonical-id map
        replicated, and the same jitted kernel the single-device path
        compiles partitions its ``vmap`` across devices — each shard
        beam-searches its query rows and the visit-count reduction over
        lanes lowers to one psum. No per-step exchange (the graph is
        replicated), so ``last_run_exchange`` stays None for knn runs;
        bit-identity with the single path holds because every lane runs
        the identical per-query program on identical operands."""
        from jax.sharding import NamedSharding, PartitionSpec
        st = handle.shard_state
        sp = st.search
        if sp is None:
            raise ValueError("knn_search needs a graph prepared with "
                             "search= (a SearchSpec); this handle has none")
        replicated = NamedSharding(self.mesh, PartitionSpec())
        if st.knn_operands is None:
            ga = to_device(st.graph, canonical_ids=st.canonical_ids)
            st.knn_operands = (
                jax.device_put(ga, replicated),
                jax.device_put(jnp.asarray(sp.vectors, jnp.float32),
                               replicated),
                jax.device_put(jnp.asarray(sp.canon, jnp.int32), replicated),
            )
        ga, vecs, canon = st.knn_operands
        padded, valid, real = pad_queries(queries, multiple=self.num_shards)
        q = jax.device_put(
            jnp.asarray(padded),
            NamedSharding(self.mesh, PartitionSpec(self.axis, None)))
        vmask = jax.device_put(
            jnp.asarray(valid),
            NamedSharding(self.mesh, PartitionSpec(self.axis)))
        p = sp.params
        key = (len(padded), p.k_out, p.beam_width, p.k_return, p.max_steps)
        fn = st.knn_fns.get(key)
        if fn is None:
            fn = jax.jit(functools.partial(
                K.knn_search_multi, k_out=p.k_out, beam_width=p.beam_width,
                k_return=p.k_return, max_steps=p.max_steps))
            st.knn_fns[key] = fn
        self._counters["queries"].inc()
        self._counters["dispatches"].inc()
        self._counters["sources"].inc(real)
        ids, visits = jax.block_until_ready(
            fn(ga, vecs, canon, jnp.int32(int(sp.entry)), q, vmask))
        self.last_run_exchange = None
        return ids[:real], visits[:handle.num_vertices]

    def run(self, handle: GraphHandle, kernel: str,
            sources=None) -> jnp.ndarray:
        build_kernel(kernel)  # unknown kernel: raise before anything counts
        if kernel in VECTOR_SOURCE:
            return self._run_knn(handle, sources)
        canon = _RUNNER_ALIASES.get(kernel, kernel)
        new_runner = canon not in handle.shard_state._runners
        runner = handle.shard_state.runner(kernel)
        if new_runner and getattr(runner, "hot_prefix_fraction",
                                  None) is not None:
            self._prefix_info.append({
                "kernel": canon,
                "hot_prefix_fraction": runner.hot_prefix_fraction,
                "h_local": runner.h_local,
                "per_shard_vertices": runner.per,
                "prefix_hit_rate": round(runner.prefix_hit_rate, 4),
            })
        self._counters["queries"].inc()
        before = self.exchange_stats.snapshot()
        # per-step exchange spans: while this run is live, every
        # ExchangeStats record emits one engine-track span covering the
        # step that ended at the collective — nested under the launch
        # span the session wraps around executor.run
        if self.tracer is not None:
            tracer = self.tracer
            last = {"t": tracer.clock.now()}

            def _exchange_span(mode: str, nbytes: int,
                               full_nbytes: int) -> None:
                now = tracer.clock.now()
                tracer.emit("exchange", last["t"], now,
                            args={"mode": mode, "bytes": nbytes,
                                  "bytes_full_equivalent": full_nbytes,
                                  "kernel": canon})
                last["t"] = now

            self.exchange_stats.span_sink = _exchange_span
        try:
            if kernel in GLOBAL:
                out = jax.block_until_ready(runner())[:handle.num_vertices]
            else:
                padded, real = pad_sources(sources, kernel)
                self._counters["sources"].inc(real)
                out = jax.block_until_ready(
                    runner(jnp.asarray(padded)))[:real,
                                                 :handle.num_vertices]
        finally:
            self.exchange_stats.span_sink = None
        delta = self.exchange_stats.delta(before)
        self._c_ex_steps.inc(delta.steps)
        self._c_ex_bytes.inc(delta.bytes_exchanged)
        self._counters["dispatches"].inc(delta.dispatches)
        self.last_run_exchange = delta.as_dict()
        return out

    def telemetry(self) -> dict:
        return {
            "num_shards": self.num_shards,
            "graphs_prepared": self.graphs_prepared,
            "queries_run": self.queries_run,
            "sources_run": self.sources_run,
            "fused": self.fused,
            "dispatches": self._counters["dispatches"].value,
            "hot_prefix": {
                **self.exchange_stats.as_dict(),
                "cold_every": self.cold_every,
                "runners": list(self._prefix_info),
            },
        }
