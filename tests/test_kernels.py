"""Pallas kernels (interpret mode) vs pure-jnp oracles: shape/dtype sweeps."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.generators import powerlaw_community, rmat


# ------------------------------------------------------------- csr_spmv
@pytest.mark.parametrize("gen,kw", [
    (powerlaw_community, dict(num_vertices=1500, avg_degree=6, seed=0)),
    (powerlaw_community, dict(num_vertices=700, avg_degree=20, seed=1)),
    (rmat, dict(scale=9, edge_factor=4, seed=2)),
])
def test_csr_spmv_matches_ref(gen, kw):
    from repro.kernels.csr_spmv.ops import SpMV
    from repro.kernels.csr_spmv.ref import csr_spmv_ref
    g = gen(**kw)
    t = g.transpose
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(g.num_vertices).astype(np.float32))
    w = rng.random(len(t.indices)).astype(np.float32)
    op = SpMV(t.indptr, t.indices, w, use_pallas=True, interpret=True)
    got = op(x)
    want = csr_spmv_ref(jnp.asarray(t.indptr), jnp.asarray(t.indices),
                        jnp.asarray(w), x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_csr_spmv_empty_rows():
    from repro.core.csr import from_edges
    from repro.kernels.csr_spmv.ops import SpMV
    g = from_edges(600, [0, 1], [5, 5])
    t = g.transpose
    x = jnp.arange(600, dtype=jnp.float32)
    op = SpMV(t.indptr, t.indices, use_pallas=True, interpret=True)
    y = np.asarray(op(x))
    assert y[5] == 1.0  # x[0] + x[1]
    assert y[np.arange(600) != 5].sum() == 0.0


def test_csr_spmv_pagerank_iteration_equivalence(plc_graph):
    """One PR pull step through the kernel == the algos path."""
    from repro.kernels.csr_spmv.ops import SpMV
    g = plc_graph
    t = g.transpose
    outdeg = np.maximum(np.asarray(g.out_degree, np.float32), 1.0)
    x = np.random.default_rng(1).random(g.num_vertices).astype(np.float32)
    op = SpMV(t.indptr, t.indices, use_pallas=True, interpret=True)
    got = np.asarray(op(jnp.asarray(x / outdeg)))
    want = np.zeros(g.num_vertices, np.float32)
    np.add.at(want, t.edge_src, (x / outdeg)[t.indices])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


# ------------------------------------------------------------ flash_attn
@pytest.mark.parametrize("bh,s,d", [(2, 256, 64), (1, 512, 128), (3, 256, 32)])
@pytest.mark.parametrize("window", [0, 128])
def test_flash_attention_matches_ref(bh, s, d, window):
    from repro.kernels.flash_attn.flash_attn import flash_attention_pallas
    from repro.kernels.flash_attn.ref import attention_ref
    rng = np.random.default_rng(42)
    q = jnp.asarray(rng.standard_normal((bh, s, d)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((bh, s, d)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((bh, s, d)).astype(np.float32))
    got = flash_attention_pallas(q, k, v, window=window, interpret=True)
    want = attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=2e-3)


def test_flash_attention_bf16():
    from repro.kernels.flash_attn.flash_attn import flash_attention_pallas
    from repro.kernels.flash_attn.ref import attention_ref
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.standard_normal((2, 256, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((2, 256, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((2, 256, 64)), jnp.bfloat16)
    got = flash_attention_pallas(q, k, v, interpret=True)
    want = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_flash_attention_causality():
    """Future tokens must not influence output."""
    from repro.kernels.flash_attn.flash_attn import flash_attention_pallas
    rng = np.random.default_rng(3)
    q = rng.standard_normal((1, 256, 32)).astype(np.float32)
    k = rng.standard_normal((1, 256, 32)).astype(np.float32)
    v = rng.standard_normal((1, 256, 32)).astype(np.float32)
    o1 = np.asarray(flash_attention_pallas(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), interpret=True))
    k2, v2 = k.copy(), v.copy()
    k2[:, 200:], v2[:, 200:] = 99.0, -99.0   # corrupt the future
    o2 = np.asarray(flash_attention_pallas(
        jnp.asarray(q), jnp.asarray(k2), jnp.asarray(v2), interpret=True))
    np.testing.assert_allclose(o1[:, :200], o2[:, :200], rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------- moe_gmm
@pytest.mark.parametrize("gs", [
    [128, 128, 128, 128],
    [100, 30, 0, 128],
    [0, 0, 5, 1],
    [512, 0, 0, 0],
])
def test_gmm_matches_ref(gs):
    from repro.kernels.moe_gmm.moe_gmm import TILE_M, gmm_pallas, pad_groups
    from repro.kernels.moe_gmm.ref import gmm_ref
    e, k, n = len(gs), 128, 256
    offs, tile_expert, total = pad_groups(np.array(gs))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((total, k)).astype(np.float32))
    w = jnp.asarray(0.1 * rng.standard_normal((e, k, n)).astype(np.float32))
    got = gmm_pallas(x, w, jnp.asarray(tile_expert), interpret=True)
    want = gmm_ref(x, w, jnp.asarray(np.repeat(tile_expert, TILE_M)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_gmm_k_accumulation():
    """K > TILE_K exercises the accumulate-over-k grid dimension."""
    from repro.kernels.moe_gmm.moe_gmm import gmm_pallas, pad_groups, TILE_M
    from repro.kernels.moe_gmm.ref import gmm_ref
    offs, tile_expert, total = pad_groups(np.array([128, 128]))
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((total, 384)).astype(np.float32))
    w = jnp.asarray(0.1 * rng.standard_normal((2, 384, 128)).astype(np.float32))
    got = gmm_pallas(x, w, jnp.asarray(tile_expert), interpret=True)
    want = gmm_ref(x, w, jnp.asarray(np.repeat(tile_expert, TILE_M)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------- hot_embed
@pytest.mark.parametrize("vocab,hot,ids_shape", [
    (1000, 128, (4, 100)), (4096, 512, (512,)), (600, 600, (2, 7)),
])
def test_hot_embed_matches_take(vocab, hot, ids_shape):
    from repro.kernels.hot_embed.ops import hot_cold_lookup
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.standard_normal((vocab, 32)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, vocab, ids_shape).astype(np.int32))
    got = hot_cold_lookup(ids, table, hot, use_pallas=True, interpret=True)
    want = jnp.take(table, ids, axis=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_hot_embed_all_hot_ids():
    from repro.kernels.hot_embed.ops import hot_cold_lookup
    table = jnp.asarray(np.arange(64 * 8, dtype=np.float32).reshape(64, 8))
    ids = jnp.asarray(np.arange(16, dtype=np.int32))
    got = hot_cold_lookup(ids, table, 32, use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(jnp.take(table, ids, axis=0)))
