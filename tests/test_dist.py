"""core/dist.py coverage: partition round-trips, hot-prefix exchange,
and true multi-shard parity.

The in-process suite runs on a single host device, so the genuinely
distributed checks (4 shards) run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` — the flag must be
set before jax initializes its backends.
"""
from __future__ import annotations

import textwrap

import numpy as np
import pytest

from conftest import run_forced_four_devices
from repro.core.dist import ExchangeStats, partition_edges


def _run_forced_four_devices(prog: str, timeout: int = 600):
    return run_forced_four_devices(["-c", prog], timeout=timeout)


@pytest.mark.parametrize("num_shards", [1, 2, 4, 7])
def test_partition_edges_round_trip(any_graph, num_shards):
    """No edge lost or invented; local dst indices reconstruct globals."""
    g = any_graph
    s_pad, d_pad, valid, per = partition_edges(g, num_shards)
    assert s_pad.shape == d_pad.shape == valid.shape
    assert valid.sum() == g.num_edges
    src_rt, dst_rt = [], []
    for i in range(num_shards):
        assert (0 <= d_pad[i][valid[i]]).all()
        assert (d_pad[i][valid[i]] < per).all()
        src_rt.append(s_pad[i][valid[i]])
        dst_rt.append(d_pad[i][valid[i]] + i * per)
    pairs_rt = np.stack([np.concatenate(src_rt).astype(np.int64),
                         np.concatenate(dst_rt).astype(np.int64)], 1)
    order = np.lexsort((pairs_rt[:, 1], pairs_rt[:, 0]))
    np.testing.assert_array_equal(pairs_rt[order], g.edge_multiset())


def test_partition_edges_empty_shards():
    """A graph whose edges all land in shard 0 still partitions cleanly."""
    from repro.core.csr import from_edges
    g = from_edges(40, [10, 11, 12], [0, 1, 2])  # dst < 10 => shard 0 of 4
    s_pad, d_pad, valid, per = partition_edges(g, 4)
    assert per == 10
    assert valid[0].sum() == 3 and valid[1:].sum() == 0


def test_partition_edges_weighted_round_trip_property():
    """Satellite: for random power-law graphs and shard counts,
    (src, dst, valid, edge_values) round-trips to the exact weighted edge
    multiset (hypothesis-driven when available)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    from repro.algos.graph_arrays import edge_weights
    from repro.core.generators import powerlaw_community

    @settings(max_examples=12, deadline=None)
    @given(n=st.integers(min_value=60, max_value=600),
           avg_degree=st.floats(min_value=2.0, max_value=10.0),
           seed=st.integers(min_value=0, max_value=2**16),
           num_shards=st.integers(min_value=1, max_value=7))
    def check(n, avg_degree, seed, num_shards):
        g = powerlaw_community(n, avg_degree=avg_degree, seed=seed)
        w = edge_weights(g.edge_src, g.indices)
        s_pad, d_pad, valid, per, w_pad = partition_edges(
            g, num_shards, edge_values=w)
        assert s_pad.shape == d_pad.shape == valid.shape == w_pad.shape
        assert int(valid.sum()) == g.num_edges
        trips = []
        for i in range(num_shards):
            v = valid[i]
            assert (0 <= d_pad[i][v]).all() and (d_pad[i][v] < per).all()
            trips.append(np.stack([s_pad[i][v].astype(np.int64),
                                   d_pad[i][v].astype(np.int64) + i * per,
                                   w_pad[i][v].astype(np.int64)], 1))
        got = np.concatenate(trips)
        got = got[np.lexsort((got[:, 2], got[:, 1], got[:, 0]))]
        want = np.stack([g.edge_src.astype(np.int64),
                         np.asarray(g.indices, np.int64),
                         w.astype(np.int64)], 1)
        want = want[np.lexsort((want[:, 2], want[:, 1], want[:, 0]))]
        np.testing.assert_array_equal(got, want)

    check()


# ------------------------------------------------------ hot-prefix driver
def test_exchange_stats_accounting():
    st = ExchangeStats()
    assert st.bytes_per_step == 0.0 and st.savings_fraction == 0.0
    st.record_full(100)
    st.record_hot(10, 100)
    st.record_hot(10, 100)
    assert st.steps == 3 and (st.steps_full, st.steps_hot) == (1, 2)
    assert st.bytes_exchanged == 120
    assert st.bytes_full_equivalent == 300
    assert st.bytes_per_step == pytest.approx(40.0)
    assert st.savings_fraction == pytest.approx(0.6)
    d = st.as_dict()
    assert d["bytes_exchanged"] == 120 and d["savings_fraction"] == 0.6
    assert d["steps"] == 3


def test_exchange_stats_snapshot_delta():
    """Satellite: snapshot/delta attributes the shared counter to one run
    — what the scheduler stamps into per-request telemetry."""
    st = ExchangeStats()
    st.record_full(100)
    before = st.snapshot()
    st.record_full(50)
    st.record_hot(10, 50)
    run = st.delta(before)
    assert run.steps == 2 and run.bytes_exchanged == 60
    assert run.bytes_full_equivalent == 100
    assert run.savings_fraction == pytest.approx(0.4)
    # the aggregate keeps everything; the delta saw only its slice
    assert st.steps == 3 and st.bytes_exchanged == 160
    assert st.delta(st.snapshot()).steps == 0


def test_hot_prefix_exact_and_saves_bytes_four_shards():
    """4 forced devices, hub-packed layout: hot-prefix BFS/SSSP/CC are
    bit-identical to the single-device kernels while exchanging fewer
    bytes per step than the full all-gather of the same state."""
    prog = textwrap.dedent("""
        import numpy as np
        import jax, jax.numpy as jnp
        assert jax.device_count() == 4, jax.devices()
        from repro.algos import kernels as K
        from repro.algos.graph_arrays import to_device
        from repro.core.baselines import dbg_order
        from repro.core.dist import (ExchangeStats, make_distributed_bfs,
                                     make_distributed_cc,
                                     make_distributed_sssp)
        from repro.core.generators import powerlaw_community

        g0 = powerlaw_community(2000, avg_degree=8.0, seed=3)
        perm = np.asarray(dbg_order(g0))
        g = g0.apply_permutation(perm)      # hubs packed into the prefix
        inv = np.empty_like(perm); inv[perm] = np.arange(len(perm))
        mesh = jax.make_mesh((4,), ("data",))
        ga = to_device(g, canonical_ids=inv)
        srcs = np.array([5, 321, 1500])

        hot = ExchangeStats()
        full = ExchangeStats()
        run_h = make_distributed_sssp(g, mesh, canonical_ids=inv,
                                      hot_prefix_fraction=0.15,
                                      cold_every=5, stats=hot)
        run_f = make_distributed_sssp(g, mesh, canonical_ids=inv,
                                      stats=full)
        want = np.stack([np.asarray(K.sssp(ga, jnp.int32(s)))
                         for s in srcs])
        np.testing.assert_array_equal(np.asarray(run_h(srcs)), want)
        np.testing.assert_array_equal(np.asarray(run_f(srcs)), want)
        assert hot.steps_hot > 0 and hot.steps_full > 0
        assert 0.0 < hot.savings_fraction < 1.0
        # a hot step moves h_local/per of a full step's payload
        assert hot.bytes_hot / hot.steps_hot \\
            < full.bytes_full / full.steps_full
        assert 0.0 < run_h.prefix_hit_rate <= 1.0
        assert run_h.h_local < run_h.per

        bfs_h = make_distributed_bfs(g, mesh, hot_prefix_fraction=0.15,
                                     cold_every=5)
        want = np.stack([np.asarray(K.bfs(ga, jnp.int32(s)))
                         for s in srcs])
        np.testing.assert_array_equal(np.asarray(bfs_h(srcs)), want)

        cc_h = make_distributed_cc(g, mesh, hot_prefix_fraction=0.15,
                                   cold_every=5)
        np.testing.assert_array_equal(np.asarray(cc_h()),
                                      np.asarray(K.cc_labelprop(ga)))
        print("HOT_PREFIX_OK")
    """)
    res = _run_forced_four_devices(prog)
    assert res.returncode == 0, \
        f"stdout={res.stdout}\nstderr={res.stderr}"
    assert "HOT_PREFIX_OK" in res.stdout


def test_distributed_pagerank_parity_four_shards():
    """Sharded PR on 4 forced host devices == single-device PR."""
    prog = textwrap.dedent("""
        import numpy as np
        import jax
        assert jax.device_count() == 4, jax.devices()
        from repro.algos.graph_arrays import to_device
        from repro.algos.kernels import pagerank
        from repro.core.dist import make_distributed_pagerank
        from repro.core.generators import powerlaw_community

        g = powerlaw_community(2000, avg_degree=8.0, seed=3)
        mesh = jax.make_mesh((4,), ("data",))
        run, _ = make_distributed_pagerank(g, mesh, axis="data",
                                           num_iters=20)
        got = np.asarray(run())
        want = np.asarray(pagerank(to_device(g), num_iters=20))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-7)
        print("PARITY_OK")
    """)
    res = _run_forced_four_devices(prog, timeout=300)
    assert res.returncode == 0, f"stdout={res.stdout}\nstderr={res.stderr}"
    assert "PARITY_OK" in res.stdout
