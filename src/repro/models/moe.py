"""Mixture-of-Experts with locality-sorted (dropless) dispatch.

This is LOrder's mechanism applied to expert routing (DESIGN.md §3.2):
token→expert assignments are a skewed bipartite access graph; sorting the
assignments by expert id produces contiguous per-expert blocks ("hot
groups first" falls out of load skew), so expert weights stream HBM→VMEM
once per group. Compute uses ``lax.ragged_dot`` on the XLA path and the
``moe_gmm`` Pallas kernel on TPU.

Two execution modes:
* single-shard (tests / CPU): plain ragged_dot over all experts;
* expert-parallel (``ep_axis``): inside ``shard_map``, each model shard
  owns E/|model| experts, computes its share of the sorted assignments and
  ``psum``s the combined output — the collective pattern a GShard-style
  all-to-all reduces to when activations are TP-replicated.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import COMPUTE_DTYPE, _dense


def init_moe(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    sc_in, sc_out = d ** -0.5, f ** -0.5
    p = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * sc_in,
        "w_gate": jax.random.normal(ks[1], (e, d, f), jnp.float32) * sc_in,
        "w_up": jax.random.normal(ks[2], (e, d, f), jnp.float32) * sc_in,
        "w_down": jax.random.normal(ks[3], (e, f, d), jnp.float32) * sc_out,
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": jax.random.normal(kss[0], (d, fs), jnp.float32) * sc_in,
            "w_up": jax.random.normal(kss[1], (d, fs), jnp.float32) * sc_in,
            "w_down": jax.random.normal(kss[2], (fs, d), jnp.float32) * sc_out,
        }
    return p


def _expert_ffn_ragged(xs, w_gate, w_up, w_down, group_sizes):
    """SwiGLU over expert-sorted rows via grouped matmuls."""
    dt = COMPUTE_DTYPE
    g = jax.lax.ragged_dot(xs.astype(dt), w_gate.astype(dt), group_sizes)
    u = jax.lax.ragged_dot(xs.astype(dt), w_up.astype(dt), group_sizes)
    h = jax.nn.silu(g) * u
    return jax.lax.ragged_dot(h.astype(dt), w_down.astype(dt), group_sizes)


def _route(p, x_flat, cfg: ModelConfig):
    """Top-k routing. Returns (experts (T,k), gates (T,k), aux_loss)."""
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, cfg.experts_per_token)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balancing auxiliary loss
    e = cfg.num_experts
    density = jnp.zeros((e,), jnp.float32).at[experts.reshape(-1)].add(
        1.0) / experts.size
    mean_prob = probs.mean(0)
    aux = e * jnp.sum(density * mean_prob)
    return experts, gates, aux


def _dispatch_capacity(x_flat, experts, gates, w_gate, w_up, w_down,
                       num_experts: int, capacity: int):
    """Locality-sorted capacity dispatch (§Perf iteration 4b).

    ragged_dot lowers to one dense (T·k × D × F) matmul PER EXPERT on this
    pipeline — E× the useful flops. Scattering the expert-sorted rows into
    an (E, capacity, D) buffer makes the compute a single batched matmul of
    exactly E·cap·D·F flops (cap·E/T·k ≈ the capacity factor, 1.5 here).
    Rows beyond an expert's capacity are dropped — standard GShard/Switch
    semantics for the production path; the exact ragged form remains the
    single-shard/test path.
    """
    t, k = experts.shape
    flat_e = experts.reshape(-1)
    order = jnp.argsort(flat_e)                     # ← the locality sort
    sorted_e = flat_e[order]
    tok = order // k
    counts = jnp.bincount(flat_e, length=num_experts)
    offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                               jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(t * k) - offsets[sorted_e]     # rank within group
    keep = pos < capacity
    slot = jnp.where(keep, sorted_e * capacity + pos, num_experts * capacity)
    buf = jnp.zeros((num_experts * capacity + 1, x_flat.shape[1]),
                    COMPUTE_DTYPE)
    buf = buf.at[slot].set(x_flat[tok].astype(COMPUTE_DTYPE))
    xe = buf[:-1].reshape(num_experts, capacity, -1)
    dt = COMPUTE_DTYPE
    g = jnp.einsum("ecd,edf->ecf", xe, w_gate.astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xe, w_up.astype(dt))
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, w_down.astype(dt))
    rows = ye.reshape(num_experts * capacity, -1)
    picked = jnp.where(keep[:, None], rows[jnp.clip(slot, 0,
                       num_experts * capacity - 1)], 0.0)
    w = (gates.reshape(-1)[order] * keep).astype(picked.dtype)
    return jax.ops.segment_sum(picked * w[:, None], tok,
                               num_segments=t).astype(COMPUTE_DTYPE)


def _dispatch_local(x_flat, experts, gates, w_gate, w_up, w_down,
                    num_local: int, base: int, replica=None):
    """Locality-sorted dispatch for experts [base, base+num_local).

    ``replica=(rep_id, reps)``: when several shards co-own the same expert
    set (E < |model|), each takes the assignment subset with
    index % reps == rep_id. Returns the combined output (T, D).
    """
    t, k = experts.shape
    flat_e = experts.reshape(-1) - base
    owned = (flat_e >= 0) & (flat_e < num_local)
    if replica is not None:
        rep_id, reps = replica
        owned &= (jnp.arange(t * k) % reps) == rep_id
    # route unowned assignments to a zero "parking" group at the end
    flat_e = jnp.where(owned, flat_e, num_local)
    order = jnp.argsort(flat_e)                      # ← the locality sort
    tok = order // k
    xs = x_flat[tok]
    group_sizes = jnp.bincount(flat_e, length=num_local + 1)[:num_local]
    ys = _expert_ffn_ragged(xs, w_gate, w_up, w_down,
                            group_sizes.astype(jnp.int32))
    w = (gates.reshape(-1)[order] * owned[order]).astype(ys.dtype)
    return jax.ops.segment_sum(ys * w[:, None], tok,
                               num_segments=t).astype(COMPUTE_DTYPE)


def apply_moe(p, x, cfg: ModelConfig, mesh=None, ep_axis: str = "model",
              dp_axes=("pod", "data")):
    """x: (B, S, D). Returns (y, aux_loss)."""
    b, s, d = x.shape
    x_flat = x.reshape(b * s, d)
    experts, gates, aux = _route(p, x_flat, cfg)

    if not cfg.moe_locality_sort:
        # unsorted baseline: dense per-token einsum over gathered experts —
        # the "no reordering" control for the MoE benchmarks
        dt = COMPUTE_DTYPE
        wg = p["w_gate"][experts]   # (T, k, D, F): skew-random HBM gathers
        wu = p["w_up"][experts]
        wd = p["w_down"][experts]
        g = jnp.einsum("td,tkdf->tkf", x_flat.astype(dt), wg.astype(dt))
        u = jnp.einsum("td,tkdf->tkf", x_flat.astype(dt), wu.astype(dt))
        yk = jnp.einsum("tkf,tkfd->tkd", jax.nn.silu(g) * u, wd.astype(dt))
        y = jnp.einsum("tkd,tk->td", yk, gates.astype(dt))
    elif mesh is not None and ep_axis in mesh.axis_names \
            and mesh.shape[ep_axis] > 1 \
            and cfg.d_ff % mesh.shape[ep_axis] == 0:
        # TP-within-expert dispatch (§Perf iteration 4). Each model shard
        # holds the F/|model| slice of EVERY expert and its data shard's
        # tokens; tokens are locality-sorted *locally* (the paper's hot-
        # first grouping, per shard), each expert's weight slab streams
        # once per contiguous group, and the down-projection partial sums
        # reduce over 'model'. Compared to the replicated-EP form this
        # removes (a) the per-layer expert-major weight re-layout
        # (all-gather of all expert weights), (b) the parked-row compute
        # (every shard used to process ALL T·k rows), (c) replica-group
        # tiling when E < |model|. Per-chip flops = 3·2·(Tk/|dp|)·D·F/|model|
        # — exactly the useful share.
        from jax.sharding import PartitionSpec as P

        e = cfg.num_experts
        nshard = mesh.shape[ep_axis]
        dp = tuple(a for a in dp_axes if a in mesh.axis_names)
        t = x_flat.shape[0]
        t_ok = dp and t % np.prod([mesh.shape[a] for a in dp]) == 0
        tspec = P(dp) if t_ok else P(None)

        t_local = max(1, t // (np.prod([mesh.shape[a] for a in dp])
                               if t_ok else 1))
        cap = int(np.ceil(1.5 * t_local * cfg.experts_per_token / e / 128)
                  ) * 128                       # MXU-aligned capacity

        def body(xf, ex, ga, wg, wu, wd):
            y = _dispatch_capacity(xf, ex, ga, wg, wu, wd, e, cap)
            return jax.lax.psum(y, ep_axis)

        y = jax.shard_map(
            body, mesh=mesh,
            in_specs=(tspec, tspec, tspec,
                      P(None, None, ep_axis), P(None, None, ep_axis),
                      P(None, ep_axis, None)),
            out_specs=tspec,
        )(x_flat, experts, gates, p["w_gate"], p["w_up"], p["w_down"])
    else:
        y = _dispatch_local(x_flat, experts, gates, p["w_gate"], p["w_up"],
                            p["w_down"], cfg.num_experts, 0)

    if cfg.num_shared_experts:
        sp = p["shared"]
        y = y + _dense(jax.nn.silu(_dense(x_flat, sp["w_gate"]))
                       * _dense(x_flat, sp["w_up"]), sp["w_down"])
    return y.reshape(b, s, d).astype(COMPUTE_DTYPE), aux
