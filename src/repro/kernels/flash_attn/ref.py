"""Pure-jnp oracle for blocked causal/SWA attention."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, sm_scale: float | None = None, window: int = 0):
    """q,k,v: (BH, S, d); causal; optional sliding window."""
    bh, s, d = q.shape
    scale = (d ** -0.5) if sm_scale is None else sm_scale
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = qpos >= kpos
    if window > 0:
        mask &= (qpos - kpos) < window
    logits = jnp.where(mask[None], logits, -1e30)
    p = jnp.exp(logits - logits.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
