"""Model trunk: scan-over-layers decoder/encoder covering all 10 archs.

Three statically-selected trunk variants share one entry point:
* ``attn``   — dense / MoE / VLM / audio stacks (attention + MLP|MoE);
* ``rwkv``   — RWKV6 stacks (time-mix + channel-mix);
* ``hybrid`` — Mamba2 stacks with optional *shared* attention blocks at
  flagged positions (zamba2).

`lax.scan` over stacked layer params keeps the XLA program O(1) in depth —
critical for 512-device dry-run compiles and real-fleet compile times.
Remat (`jax.checkpoint`) wraps the scanned body when ``cfg.remat``.

Modes: train (logits), prefill (logits + cache), decode (1 token + cache).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import ad_checkpoint

from .config import ModelConfig
from .layers import (COMPUTE_DTYPE, apply_attention, apply_mlp, apply_norm,
                     embed_tokens, init_attention, init_attn_cache, init_mlp,
                     init_norm, lm_logits)
from .mamba2 import apply_mamba, init_mamba, init_mamba_cache
from .moe import apply_moe, init_moe
from .rwkv6 import (apply_rwkv_channelmix, apply_rwkv_timemix, init_rwkv,
                    init_rwkv_cache)


def _shard_batch(x, mesh):
    """Constrain the leading (batch) dim onto the data axes — stops GSPMD
    from replicating large activations around the embedding gather."""
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n = 1
    for a in dp:
        n *= mesh.shape[a]
    if not dp or n <= 1 or x.shape[0] % n:
        return x
    spec = P(dp, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def trunk_kind(cfg: ModelConfig) -> str:
    if all(b == "rwkv" for b in cfg.block_pattern):
        return "rwkv"
    if any(b == "mamba" for b in cfg.block_pattern):
        return "hybrid"
    return "attn"


# =========================================================== initialization
def _init_layer(key, cfg: ModelConfig, kind: str):
    ks = jax.random.split(key, 4)
    if kind == "attn":
        p = {"norm1": init_norm(cfg), "norm2": init_norm(cfg),
             "attn": init_attention(ks[0], cfg)}
        p["ffn"] = init_moe(ks[1], cfg) if cfg.is_moe else init_mlp(ks[1], cfg)
        return p
    if kind == "rwkv":
        return {"norm1": init_norm(cfg), "norm2": init_norm(cfg),
                "rwkv": init_rwkv(ks[0], cfg)}
    if kind == "hybrid":
        return {"norm1": init_norm(cfg), "mamba": init_mamba(ks[0], cfg)}
    raise ValueError(kind)


def init_params(cfg: ModelConfig, key) -> dict:
    kind = trunk_kind(cfg)
    ks = jax.random.split(key, cfg.num_layers)
    layers = [_init_layer(ks[i], cfg, kind) for i in range(cfg.num_layers)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    from .layers import init_embedding
    params = {
        "embed": init_embedding(jax.random.fold_in(key, 10_001), cfg),
        "layers": stacked,
        "final_norm": init_norm(cfg),
    }
    if "shared_attn" in cfg.block_pattern:
        k2 = jax.random.fold_in(key, 10_002)
        params["shared_attn"] = {
            "norm1": init_norm(cfg), "norm2": init_norm(cfg),
            "attn": init_attention(k2, cfg),
            "ffn": init_mlp(jax.random.fold_in(key, 10_003), cfg),
        }
    return params


def param_shapes(cfg: ModelConfig, key=None):
    """ShapeDtypeStruct tree without allocation (dry-run path)."""
    k = jax.random.PRNGKey(0) if key is None else key
    return jax.eval_shape(lambda: init_params(cfg, k))


# ================================================================= caches
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    kind = trunk_kind(cfg)
    L = cfg.num_layers
    if kind == "attn":
        one = init_attn_cache(cfg, batch, max_len)
        layers = jax.tree.map(lambda x: jnp.broadcast_to(x, (L, *x.shape)), one)
    elif kind == "rwkv":
        one = init_rwkv_cache(cfg, batch)
        layers = jax.tree.map(lambda x: jnp.broadcast_to(x, (L, *x.shape)), one)
    else:  # hybrid
        one = init_mamba_cache(cfg, batch)
        layers = jax.tree.map(lambda x: jnp.broadcast_to(x, (L, *x.shape)), one)
    cache = {"layers": layers, "pos": jnp.zeros((), jnp.int32)}
    if "shared_attn" in cfg.block_pattern:
        napp = sum(b == "shared_attn" for b in cfg.block_pattern)
        one = init_attn_cache(cfg, batch, max_len)
        cache["shared"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (napp, *x.shape)), one)
    return cache


# ============================================================ block bodies
def _attn_block(p, x, cfg, positions, cache, mesh):
    h, new_c = apply_attention(p["attn"], apply_norm(p["norm1"], x, cfg), cfg,
                               positions, cache, mesh=mesh)
    x = x + h * cfg.residual_scale
    aux = jnp.zeros((), jnp.float32)
    y = apply_norm(p["norm2"], x, cfg)
    if cfg.is_moe:
        f, aux = apply_moe(p["ffn"], y, cfg, mesh)
    else:
        f = apply_mlp(p["ffn"], y, cfg)
    out = x + f * cfg.residual_scale
    # selective-remat anchor (§Perf iteration 6): naming the block output
    # lets save_only_these_names keep it (it is the scan carry — free) and
    # prune the attention replay from backward — ~2× on the train memory
    # term at unchanged peak HBM (measured; anchoring mid-block instead
    # raised peak temp 5 GB)
    out = ad_checkpoint.checkpoint_name(out, "attn_out")
    return out, new_c, aux


def _rwkv_block(p, x, cfg, cache):
    c_tm = None if cache is None else cache["tm"]
    c_cm = None if cache is None else cache["cm"]
    h, n_tm = apply_rwkv_timemix(p["rwkv"], apply_norm(p["norm1"], x, cfg),
                                 cfg, c_tm)
    x = x + h
    f, n_cm = apply_rwkv_channelmix(p["rwkv"], apply_norm(p["norm2"], x, cfg),
                                    cfg, c_cm)
    x = x + f
    new_c = None if cache is None else {"tm": n_tm, "cm": n_cm}
    return x, new_c


def _mamba_block(p, x, cfg, cache):
    h, new_c = apply_mamba(p["mamba"], apply_norm(p["norm1"], x, cfg), cfg,
                           cache)
    return x + h, new_c


# ================================================================= trunks
def _run_trunk(params, x, cfg: ModelConfig, positions, cache, mesh):
    """Returns (x, new_cache, aux_losses_sum)."""
    kind = trunk_kind(cfg)
    L = cfg.num_layers
    decode = cache is not None
    shared_p = params.get("shared_attn")
    is_shared = jnp.asarray(
        [b == "shared_attn" for b in cfg.block_pattern], jnp.bool_)
    app_idx = jnp.asarray(
        np.cumsum([b == "shared_attn" for b in cfg.block_pattern]) - 1,
        jnp.int32).clip(0)

    def body(carry, scanned):
        x, shared_cache = carry
        if decode:
            lp, lc, flag, ai = scanned
        else:
            lp, flag, ai = scanned
            lc = None
        aux = jnp.zeros((), jnp.float32)
        if kind == "attn":
            x, new_lc, aux = _attn_block(lp, x, cfg, positions, lc, mesh)
        elif kind == "rwkv":
            x, new_lc = _rwkv_block(lp, x, cfg, lc)
        else:  # hybrid: optional shared attention, then the mamba block
            if shared_p is not None:
                def with_attn(args):
                    x, sc = args
                    ci = (jax.tree.map(lambda c: c[ai], sc)
                          if decode else None)
                    xo, nc, _ = _attn_block(shared_p, x, cfg, positions, ci,
                                            mesh)
                    nsc = (jax.tree.map(
                        lambda c, n: c.at[ai].set(n.astype(c.dtype)), sc, nc)
                        if decode else sc)
                    return xo, nsc

                def no_attn(args):
                    return args

                x, shared_cache = jax.lax.cond(
                    flag, with_attn, no_attn, (x, shared_cache))
            x, new_lc = _mamba_block(lp, x, cfg, lc)
        return (x, shared_cache), (new_lc, aux) if decode else aux

    if cfg.remat and not decode:
        if cfg.remat_policy == "save_attn":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.save_only_these_names(
                    "attn_out"))
        else:
            body = jax.checkpoint(body)

    layers = params["layers"]
    if decode:
        xs = (layers, cache["layers"], is_shared, app_idx)
    else:
        xs = (layers, is_shared, app_idx)

    shared_cache0 = cache.get("shared") if decode else ()
    (x, shared_cache), ys = jax.lax.scan(body, (x, shared_cache0), xs)

    if decode:
        new_layer_cache, auxes = ys
        new_cache = {"layers": new_layer_cache,
                     "pos": cache["pos"] + positions.shape[-1]}
        if "shared" in cache:
            new_cache["shared"] = shared_cache
        return x, new_cache, auxes.sum()
    return x, None, ys.sum()


# ============================================================== public API
def forward(params, batch: dict, cfg: ModelConfig, mesh=None):
    """Training/prefill forward. batch: tokens (B,S) and/or embeds/prefix.

    Returns (logits (B,S,V), aux_loss)."""
    if cfg.input_mode == "embeddings":
        x = batch["embeds"].astype(COMPUTE_DTYPE)
    else:
        x = embed_tokens(params["embed"], batch["tokens"], cfg)
        if cfg.prefix_tokens > 0:
            x = jnp.concatenate(
                [batch["prefix"].astype(COMPUTE_DTYPE), x], axis=1)
    x = _shard_batch(x, mesh)
    s = x.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)
    x, _, aux = _run_trunk(params, x, cfg, positions, None, mesh)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = lm_logits(params["embed"], x, cfg)
    return logits, aux


def decode_step(params, cache, tokens, cfg: ModelConfig, mesh=None):
    """One decode step. tokens: (B, 1). Returns (logits (B,1,V), cache)."""
    x = _shard_batch(embed_tokens(params["embed"], tokens, cfg), mesh)
    positions = cache["pos"][None].astype(jnp.int32)
    x, new_cache, _ = _run_trunk(params, x, cfg, positions, cache, mesh)
    x = apply_norm(params["final_norm"], x, cfg)
    return lm_logits(params["embed"], x, cfg), new_cache


# ---------------------------------------------------------------- loss
def chunked_xent(params, x_final, targets, mask, cfg: ModelConfig):
    """Memory-bounded softmax cross-entropy: scan over sequence chunks with
    rematerialized logits (full (B,S,V) logits never live at once)."""
    b, s, d = x_final.shape
    c = min(cfg.loss_chunk, s)
    while s % c:
        c //= 2
    nc = s // c
    xc = x_final.reshape(b, nc, c, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(b, nc, c).transpose(1, 0, 2)
    mc = mask.reshape(b, nc, c).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, inp):
        xi, ti, mi = inp
        logits = lm_logits(params["embed"], xi, cfg).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ti[..., None], axis=-1)[..., 0]
        loss = ((lse - gold) * mi).sum()
        z = (jnp.square(lse) * mi).sum()           # z-loss term
        return (carry[0] + loss, carry[1] + z), ()

    (loss, zloss), _ = jax.lax.scan(body, (0.0, 0.0), (xc, tc, mc))
    denom = jnp.maximum(mask.sum(), 1.0)
    return loss / denom + 1e-4 * zloss / denom


def loss_fn(params, batch: dict, cfg: ModelConfig, mesh=None):
    """Next-token (or frame-label) CE + MoE aux. Returns (loss, metrics)."""
    if cfg.input_mode == "embeddings":
        x = batch["embeds"].astype(COMPUTE_DTYPE)
        targets = batch["targets"]
        mask = jnp.ones_like(targets, jnp.float32)
        shift = not cfg.is_encoder
    else:
        x = embed_tokens(params["embed"], batch["tokens"], cfg)
        if cfg.prefix_tokens > 0:
            x = jnp.concatenate(
                [batch["prefix"].astype(COMPUTE_DTYPE), x], axis=1)
            pad = jnp.zeros((x.shape[0], cfg.prefix_tokens), jnp.int32)
            targets = jnp.concatenate([pad, batch["tokens"]], axis=1)
            mask = jnp.concatenate(
                [jnp.zeros_like(pad, jnp.float32),
                 jnp.ones_like(batch["tokens"], jnp.float32)], axis=1)
        else:
            targets = batch["tokens"]
            mask = jnp.ones_like(targets, jnp.float32)
        shift = True

    x = _shard_batch(x, mesh)
    s = x.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)
    x, _, aux = _run_trunk(params, x, cfg, positions, None, mesh)
    x = apply_norm(params["final_norm"], x, cfg)

    if shift:
        # next-token shift WITHOUT slicing: x[:, :-1] would make the
        # sequence length odd (4095), collapsing chunked_xent's chunk to 1
        # and unrolling a 4095-step scan (found via §Perf HLO accounting).
        # Rolling targets keeps the length a power-of-two multiple.
        targets = jnp.roll(targets, -1, axis=1)
        mask = jnp.roll(mask, -1, axis=1).at[:, -1].set(0.0)
    ce = chunked_xent(params, x, targets, mask, cfg)
    loss = ce + cfg.router_aux_coef * aux
    return loss, {"ce": ce, "aux": aux}
