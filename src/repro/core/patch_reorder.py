"""Incremental hot-prefix patching of an existing permutation.

The patch tier of the dynamic-graph subsystem. After an edge delta,
re-running LOrder is O(V · κ-hop BFS) — far too expensive for the
request path. But Faldu et al. (*A Closer Look at Lightweight Graph
Reordering*) show hot sets are stable over time, and BOBA shows a
single-pass lightweight repack captures most of the locality win. So a
mutation *patches* the layout: one stable pass over the vertices in
their current served order, re-partitioned so the (possibly changed)
hot set is packed at the front of id space again.

Stability is the point — vertices keep their relative order within the
hot and cold groups, so the locality structure the full reorder built
(community blocks, hub clustering) survives the patch; only vertices
whose hotness flipped move across the boundary. O(V) time, no graph
traversal.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .csr import Graph


@dataclasses.dataclass(frozen=True)
class PatchInfo:
    """Account of one permutation patch."""

    promoted: int        # vertices newly packed into the hot prefix
    demoted: int         # vertices that fell out of the hot prefix
    moved: int           # vertices whose id changed at all
    hot_prefix_len: int  # new hot prefix length
    identity: bool       # True when the patch was a no-op


def patch_permutation(graph: Graph, perm: np.ndarray,
                      old_hot_prefix_len: int,
                      hot_mask: np.ndarray | None = None,
                      ) -> tuple[np.ndarray, np.ndarray, int, PatchInfo]:
    """Stable repack of ``perm`` so ``hot_mask`` fills the prefix.

    ``perm`` maps original vertex id -> served id (the engine's
    convention). ``hot_mask`` defaults to ``graph.hot_mask`` (degree >
    average degree λ) evaluated on the *mutated* graph. Returns
    ``(new_perm, new_inv_perm, hot_prefix_len, info)``; when the hot set
    already exactly fills the prefix the original ``perm`` is returned
    unchanged (``info.identity``), so callers can skip the re-upload
    decision on patches that turn out to be no-ops — though the engine
    still re-uploads because the *edges* changed even if ids did not.
    """
    n = graph.num_vertices
    perm = np.asarray(perm)
    if hot_mask is None:
        hot_mask = graph.hot_mask()
    hot_mask = np.asarray(hot_mask, dtype=bool)
    if perm.shape != (n,) or hot_mask.shape != (n,):
        raise ValueError(
            f"perm/hot_mask must have shape ({n},); got "
            f"{perm.shape} and {hot_mask.shape}")
    if n == 0:
        empty = np.empty(0, dtype=np.int32)
        return (perm.astype(np.int32), empty, 0,
                PatchInfo(0, 0, 0, 0, True))

    inv = np.empty(n, dtype=np.int64)           # served id -> original id
    inv[perm] = np.arange(n, dtype=np.int64)
    hot_in_order = hot_mask[inv]                # hotness along served order
    hot_len = int(hot_in_order.sum())
    if hot_in_order[:hot_len].all():
        # hot set already fills the prefix — stable repack is identity
        info = PatchInfo(0, 0, 0, hot_len, True)
        return perm.astype(np.int32), inv.astype(np.int32), hot_len, info

    new_order = np.concatenate([inv[hot_in_order], inv[~hot_in_order]])
    new_perm = np.empty(n, dtype=np.int32)
    new_perm[new_order] = np.arange(n, dtype=np.int32)
    new_inv = new_order.astype(np.int32)

    promoted = int((hot_mask & (perm >= old_hot_prefix_len)).sum())
    demoted = int((~hot_mask & (perm < old_hot_prefix_len)).sum())
    moved = int((new_perm != perm).sum())
    info = PatchInfo(promoted, demoted, moved, hot_len, False)
    return new_perm, new_inv, hot_len, info


__all__ = ["PatchInfo", "patch_permutation"]
