"""Set-associative LRU cache simulator (set-sampling) — paper's cache stats.

The paper attributes reordering speedups to LLC miss-rate reduction on the
*vertex property arrays* (§2.3: vertex/edge arrays stream; property arrays
have degree-proportional reuse). We reproduce those statistics exactly and
hardware-independently:

* The property-access trace of a pull-mode traversal over CSR is the
  in-edge array itself (for each destination in id order, the source ids
  whose property is read) — i.e. ``g.transpose.indices``. Push-mode uses
  ``g.indices``. Reordering changes the *content* of that trace, which is
  the entire effect being measured.
* Misses are counted with an exact per-set LRU model. For speed we use
  **set sampling** (simulate 1/R of the sets exactly; architectural
  standard, unbiased for index-hashed caches). ``sample_rate=1`` gives the
  exact full simulation.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np

from ..core.csr import Graph


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    size_bytes: int = 2 * 1024 * 1024   # per-core LLC slice
    ways: int = 16
    line_bytes: int = 64
    prop_bytes: int = 4                 # float32/int32 vertex property
    sample_rate: int = 16               # simulate 1/sample_rate of the sets

    @property
    def num_sets(self) -> int:
        return self.size_bytes // (self.line_bytes * self.ways)

    @property
    def props_per_line(self) -> int:
        return self.line_bytes // self.prop_bytes


LLC = CacheConfig()
L2 = CacheConfig(size_bytes=1024 * 1024, ways=8)


def property_trace(g: Graph, mode: str = "pull") -> np.ndarray:
    """Vertex-property access trace of one full traversal (paper §2.3)."""
    if mode == "pull":
        return np.asarray(g.transpose.indices, dtype=np.int64)
    if mode == "push":
        return np.asarray(g.indices, dtype=np.int64)
    raise ValueError(mode)


def simulate_misses(trace: np.ndarray, cfg: CacheConfig = LLC) -> dict:
    """Exact LRU simulation on sampled sets. Returns miss statistics."""
    lines = trace // cfg.props_per_line
    sets = lines % cfg.num_sets
    if cfg.sample_rate > 1:
        keep = (sets % cfg.sample_rate) == 0
        lines, sets = lines[keep], sets[keep]
    sampled = len(lines)
    if sampled == 0:
        return {"misses": 0, "accesses": 0, "miss_rate": 0.0, "sampled": 0}

    lru: dict[int, OrderedDict] = {}
    misses = 0
    for line, s in zip(lines.tolist(), sets.tolist()):
        od = lru.get(s)
        if od is None:
            od = OrderedDict()
            lru[s] = od
        if line in od:
            od.move_to_end(line)
        else:
            misses += 1
            od[line] = None
            if len(od) > cfg.ways:
                od.popitem(last=False)
    return {
        "misses": misses,
        "accesses": sampled,
        "miss_rate": misses / sampled,
        "sampled": sampled,
    }


def miss_rate(g: Graph, cfg: CacheConfig = LLC, mode: str = "pull") -> float:
    return simulate_misses(property_trace(g, mode), cfg)["miss_rate"]


def scaled_config(g: Graph, capacity_fraction: float = 1 / 8,
                  ways: int = 16, sample_rate: int = 8) -> CacheConfig:
    """Cache sized so the property array is ~1/capacity_fraction× capacity.

    Small benchmark graphs fit a real LLC outright, which would hide the
    reordering effect; scaling capacity to the graph restores the paper's
    working-set-exceeds-LLC regime (same trick as benchmarks/speedups.py).
    """
    prop_bytes = g.num_vertices * 4
    size = max(8 * 1024, int(prop_bytes * capacity_fraction))
    return CacheConfig(size_bytes=size, ways=ways, sample_rate=sample_rate)


def estimate_miss_rate(g: Graph, cfg: CacheConfig | None = None,
                       mode: str = "pull", max_accesses: int = 1 << 20) -> float:
    """Cheap miss-rate estimate for the engine's reorder policy.

    Large traces are cut down by raising the *set*-sampling rate, never by
    truncating the trace: set sampling stays unbiased across the whole
    traversal, whereas a trace prefix covers only low-id destinations —
    exactly the region reordering packs hubs into, which would bias
    before/after comparisons.
    """
    cfg = scaled_config(g) if cfg is None else cfg
    trace = property_trace(g, mode)
    if len(trace) > max_accesses * cfg.sample_rate:
        boost = -(-len(trace) // max_accesses)  # ceil
        cfg = dataclasses.replace(cfg, sample_rate=int(boost))
    return simulate_misses(trace, cfg)["miss_rate"]


def compare_orders(g: Graph, perms: dict[str, np.ndarray],
                   cfg: CacheConfig = LLC, mode: str = "pull") -> dict[str, float]:
    """Miss rate per reordering, including the original layout."""
    out = {"original": miss_rate(g, cfg, mode)}
    for name, perm in perms.items():
        out[name] = miss_rate(g.apply_permutation(perm), cfg, mode)
    return out
