"""zamba2-1.2b [hybrid]: 38L d2048 32H (MHA kv=32) ff8192, ssm_state=64 —
Mamba2 backbone + shared attention block applied periodically.
[arXiv:2411.15242; hf]"""
from ..models.config import ModelConfig

_L = 38
_PERIOD = 6
_PATTERN = tuple(
    "shared_attn" if (i % _PERIOD == _PERIOD - 1) else "mamba"
    for i in range(_L)
)

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=_L, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000,
    block_pattern=_PATTERN,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=64,
    shared_attn_period=_PERIOD,
    mlp_type="gelu",            # zamba2 shared block uses gelu MLP
    norm_type="rmsnorm",
    vocab_reorder=True, hot_vocab_fraction=0.05,
)
