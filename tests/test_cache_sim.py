"""Cache simulator: exact LRU semantics + the paper's qualitative claims."""
from __future__ import annotations

import numpy as np

from repro.cache.sim import (CacheConfig, compare_orders, miss_rate,
                             property_trace, simulate_misses)
from repro.core.baselines import hubcluster_order, sort_order
from repro.core.generators import powerlaw_community
from repro.core.lorder import lorder


def _tiny_cfg(sets=2, ways=2):
    # 2 sets × 2 ways × 1 prop/line  => line == property index
    return CacheConfig(size_bytes=sets * ways * 4, ways=ways, line_bytes=4,
                       prop_bytes=4, sample_rate=1)


def test_lru_hand_trace():
    cfg = _tiny_cfg()
    # set = line % 2. trace of evens -> all land in set 0 (2 ways)
    trace = np.array([0, 2, 0, 2, 4, 0])
    # 0:m 2:m 0:h 2:h 4:m(evict 0) 0:m
    out = simulate_misses(trace, cfg)
    assert out["accesses"] == 6
    assert out["misses"] == 4


def test_lru_associativity():
    cfg = _tiny_cfg(sets=1, ways=4)
    trace = np.array([0, 1, 2, 3, 0, 1, 2, 3])
    out = simulate_misses(trace, cfg)
    assert out["misses"] == 4            # all hits second round


def test_lru_eviction_order():
    cfg = _tiny_cfg(sets=1, ways=2)
    trace = np.array([0, 1, 0, 2, 1])
    # 0:m 1:m 0:h 2:m(evict LRU=1) 1:m
    assert simulate_misses(trace, cfg)["misses"] == 4


def test_spatial_locality_of_lines():
    cfg = CacheConfig(size_bytes=1024, ways=4, line_bytes=64, prop_bytes=4,
                      sample_rate=1)
    # 16 props per line: a sequential sweep misses once per line
    trace = np.arange(256)
    out = simulate_misses(trace, cfg)
    assert out["misses"] == 16


def test_set_sampling_close_to_exact():
    rng = np.random.default_rng(0)
    trace = rng.zipf(1.3, size=40_000) % 100_000
    exact = simulate_misses(trace, CacheConfig(sample_rate=1))["miss_rate"]
    sampled = simulate_misses(trace, CacheConfig(sample_rate=8))["miss_rate"]
    assert abs(exact - sampled) < 0.05


def test_property_trace_is_in_csr(plc_graph):
    g = plc_graph
    tr = property_trace(g, "pull")
    assert np.array_equal(tr, g.transpose.indices.astype(np.int64))
    tr_push = property_trace(g, "push")
    assert np.array_equal(tr_push, g.indices.astype(np.int64))


def test_reordering_reduces_misses_on_skewed_graph():
    """The paper's headline mechanism: hot-vertex grouping cuts misses.

    Uses a graph whose property array far exceeds the (shrunk) cache."""
    g = powerlaw_community(30_000, avg_degree=10, mixing=0.15, seed=9)
    cfg = CacheConfig(size_bytes=16 * 1024, ways=8, line_bytes=64,
                      prop_bytes=4, sample_rate=4)
    base = miss_rate(g, cfg)
    for name, fn in [("lorder", lambda: lorder(g, kappa=3)),
                     ("hubcluster", lambda: hubcluster_order(g)),
                     ("sort", lambda: sort_order(g))]:
        m = miss_rate(g.apply_permutation(np.asarray(fn())), cfg)
        assert m < base, f"{name} did not reduce miss rate ({m} vs {base})"


def test_compare_orders_includes_original(plc_graph):
    out = compare_orders(plc_graph, {"sort": sort_order(plc_graph)})
    assert set(out) == {"original", "sort"}
    assert 0.0 <= out["original"] <= 1.0
