"""Jitted train / prefill / serve steps with full sharding annotations.

`make_train_step` builds the donated, GSPMD-sharded update; microbatch
gradient accumulation (`TrainConfig.microbatch`) runs an inner scan so the
peak activation footprint is one microbatch. `make_serve_step` builds the
cache-donating decode step used by the decode/long dry-run cells.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..launch.shardings import (batch_specs, cache_specs, dp_axes,
                                param_specs, to_named)
from ..models.config import ModelConfig
from ..models.transformer import decode_step, forward, init_cache, loss_fn
from .optim import TrainConfig, adamw_update, init_opt_state


def opt_state_specs(pspecs):
    return {"mu": pspecs, "nu": pspecs, "step": P()}


def make_train_step(cfg: ModelConfig, tc: TrainConfig, mesh):
    """Returns (train_step, in_shardings, out_shardings)."""
    pspecs = param_specs(cfg, mesh)

    def compute_grads(params, batch):
        def lf(p):
            loss, metrics = loss_fn(p, batch, cfg, mesh)
            return loss, metrics
        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        if tc.microbatch and tc.microbatch > 0:
            # gradient accumulation: scan over microbatch slices
            def slice_mb(x, i, n):
                return x.reshape(n, -1, *x.shape[1:])[i]

            some = next(iter(batch.values()))
            n = some.shape[0] // tc.microbatch
            zeros = jax.tree.map(jnp.zeros_like, params)

            def body(acc, i):
                mb = jax.tree.map(lambda x: slice_mb(x, i, n), batch)
                loss, metrics, grads = compute_grads(params, mb)
                acc = jax.tree.map(jnp.add, acc, grads)
                return acc, (loss, metrics)

            grads, (losses, metricses) = jax.lax.scan(
                body, zeros, jnp.arange(n))
            grads = jax.tree.map(lambda g: g / n, grads)
            loss = losses.mean()
            metrics = jax.tree.map(lambda m: m.mean(), metricses)
        else:
            loss, metrics, grads = compute_grads(params, batch)

        params2, opt_state2, om = adamw_update(params, grads, opt_state, tc)
        metrics = dict(metrics, loss=loss, **om)
        return params2, opt_state2, metrics

    some_batch_spec = None  # resolved by caller via batch_specs
    in_shardings = (to_named(pspecs, mesh),
                    to_named(opt_state_specs(pspecs), mesh),
                    None)
    out_shardings = (to_named(pspecs, mesh),
                     to_named(opt_state_specs(pspecs), mesh),
                     None)
    step = jax.jit(train_step, in_shardings=in_shardings,
                   out_shardings=out_shardings,
                   donate_argnums=(0, 1))
    return step, pspecs


def make_forward(cfg: ModelConfig, mesh):
    """Prefill forward (logits + aux); inference param layout (no FSDP)."""
    pspecs = param_specs(cfg, mesh, serve=True)

    def fwd(params, batch):
        logits, aux = forward(params, batch, cfg, mesh)
        return logits, aux

    return jax.jit(fwd, in_shardings=(to_named(pspecs, mesh), None)), pspecs


def make_serve_step(cfg: ModelConfig, mesh, global_batch: int,
                    max_len: int):
    """One-token decode step; cache donated in-place; inference layout."""
    pspecs = param_specs(cfg, mesh, serve=True)
    cspecs = cache_specs(cfg, mesh, global_batch, max_len)

    def serve(params, cache, tokens):
        logits, new_cache = decode_step(params, cache, tokens, cfg, mesh)
        return logits, new_cache

    step = jax.jit(
        serve,
        in_shardings=(to_named(pspecs, mesh), to_named(cspecs, mesh), None),
        out_shardings=(None, to_named(cspecs, mesh)),
        donate_argnums=(1,),
    )
    return step, pspecs, cspecs
