"""Quickstart — the paper in 60 seconds.

Generates a power-law community graph, reorders it with LOrder (and the
baselines), and shows the three things the paper measures:
  1. reordering cost,
  2. post-reorder cache behaviour (simulated LLC),
  3. unchanged algorithm results (reordering is layout-only).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np
import jax.numpy as jnp

from repro.algos.graph_arrays import to_device
from repro.algos.kernels import bfs, pagerank
from repro.cache.sim import CacheConfig, miss_rate
from repro.core.baselines import dbg_order, hubcluster_order, sorder_order
from repro.core.diameter import default_kappa, estimate_diameter
from repro.core.generators import powerlaw_community
from repro.core.lorder import lorder, lorder_v2


def main():
    print("== 1. build a LiveJournal-flavoured graph")
    g = powerlaw_community(60_000, avg_degree=14, mixing=0.12, seed=7)
    d = estimate_diameter(g)
    print(f"   V={g.num_vertices:,} E={g.num_edges:,} "
          f"avg_deg={g.average_degree:.1f} diameter≈{d} "
          f"⇒ κ = D/2 = {default_kappa(g, d)}")

    print("== 2. reorder with LOrder + baselines (perm[old_id] = new_id)")
    schemes = {}
    for name, fn in [("lorder", lambda: lorder(g)),
                     ("lorder-v2", lambda: lorder_v2(g)),
                     ("dbg", lambda: dbg_order(g)),
                     ("sorder", lambda: sorder_order(g)),
                     ("hubcluster", lambda: hubcluster_order(g))]:
        t0 = time.time()
        schemes[name] = np.asarray(fn())
        print(f"   {name:12s} reorder time {time.time() - t0:6.2f}s")

    print("== 3. simulated LLC miss rate of one PR traversal (paper §2.3)")
    cache = CacheConfig(size_bytes=g.num_vertices // 2, ways=16,
                        sample_rate=8)
    base = miss_rate(g, cache)
    print(f"   {'original':12s} miss rate {base:.4f}")
    for name, perm in schemes.items():
        m = miss_rate(g.apply_permutation(perm), cache)
        print(f"   {name:12s} miss rate {m:.4f}  "
              f"({base / m:.2f}x fewer misses)" if m < base else
              f"   {name:12s} miss rate {m:.4f}")

    print("== 4. results are layout-invariant (the paper's contract)")
    perm = schemes["lorder"]
    gp = g.apply_permutation(perm)
    r_orig = np.asarray(pagerank(to_device(g)))
    r_perm = np.asarray(pagerank(to_device(gp)))
    ok = np.allclose(r_orig, r_perm[perm], rtol=1e-4, atol=1e-8)
    print(f"   PR(G) == perm^-1(PR(LOrder(G))): {ok}")
    d_orig = np.asarray(bfs(to_device(g), jnp.int32(0)))
    d_perm = np.asarray(bfs(to_device(gp), jnp.int32(int(perm[0]))))
    print(f"   BFS depths equivariant:          "
          f"{np.array_equal(d_orig, d_perm[perm])}")


if __name__ == "__main__":
    main()
