"""Paper Table 5.2 — the optimal locality radius κ vs graph diameter.

Claim under test: the κ minimizing post-reorder execution (≈ miss count)
sits at ~D/2 (the radius). Swept on graphs spanning the diameter axis:
the paper's social-network regime (D≈6-20) plus road/ring high-D regimes.
"""
from __future__ import annotations

import numpy as np

from .common import fmt_table, save_json


def sweep_graph(g, kappas, cfg):
    from repro.cache.sim import property_trace, simulate_misses
    from repro.core.lorder import lorder
    out = []
    base = simulate_misses(property_trace(g), cfg)["misses"]
    for k in kappas:
        perm = np.asarray(lorder(g, kappa=int(k)))
        misses = simulate_misses(property_trace(g.apply_permutation(perm)),
                                 cfg)["misses"]
        out.append({"kappa": int(k), "speedup": base / max(misses, 1)})
    return out


def run(scale: float = 0.25) -> list[dict]:
    from repro.cache.sim import CacheConfig
    from repro.core.diameter import estimate_diameter
    from repro.core.generators import (dataset_suite, road_grid, small_world)

    graphs = dict(dataset_suite(scale=scale))
    graphs["ring-sw"] = small_world(1 << 14, k=8, rewire=0.002, seed=3)
    graphs["road-96"] = road_grid(96, shortcuts=32, seed=3)

    rows = []
    for name, g in graphs.items():
        d = estimate_diameter(g)
        cfg = CacheConfig(size_bytes=max(8 * 1024, g.num_vertices // 2),
                          ways=16, sample_rate=8)
        kappas = sorted({1, 2, max(1, d // 4), max(1, d // 2),
                         max(1, (3 * d) // 4), max(1, d)})
        sweep = sweep_graph(g, kappas, cfg)
        best = max(sweep, key=lambda r: r["speedup"])
        rows.append({
            "dataset": name, "V": g.num_vertices, "diameter": d,
            "best_kappa": best["kappa"], "radius(D/2)": max(1, d // 2),
            "best_speedup": round(best["speedup"], 3),
            "speedup@D/2": round(next(r["speedup"] for r in sweep
                                      if r["kappa"] == max(1, d // 2)), 3),
            "sweep": sweep,
        })
        print(f"[kappa_sweep] {name}: D={d} best κ={best['kappa']}",
              flush=True)
    save_json("kappa_sweep", rows)
    return rows


def main(scale: float = 0.25):
    rows = run(scale)
    cols = ["dataset", "V", "diameter", "best_kappa", "radius(D/2)",
            "best_speedup", "speedup@D/2"]
    print(fmt_table(rows, cols))
    near = sum(1 for r in rows
               if r["speedup@D/2"] >= 0.95 * r["best_speedup"])
    print(f"\nκ=D/2 within 5% of the best κ on {near}/{len(rows)} graphs "
          f"(paper: best κ == D/2)")


if __name__ == "__main__":
    main()
