"""Attention wrapper with backend dispatch (Pallas on TPU, XLA elsewhere)."""
from __future__ import annotations

import jax

from .flash_attn import Q_TILE, flash_attention_pallas
from .ref import attention_ref


def causal_attention(q, k, v, *, sm_scale=None, window: int = 0,
                     use_pallas: bool | None = None,
                     interpret: bool | None = None):
    on_tpu = jax.default_backend() == "tpu"
    use_pallas = on_tpu if use_pallas is None else use_pallas
    interpret = (not on_tpu) if interpret is None else interpret
    if use_pallas and q.shape[1] % Q_TILE == 0:
        return flash_attention_pallas(q, k, v, sm_scale=sm_scale,
                                      window=window, interpret=interpret)
    return attention_ref(q, k, v, sm_scale=sm_scale, window=window)
