"""CSR container: construction, transpose, relabeling isomorphism."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.csr import (Graph, from_edges, ranges_to_indices,
                            validate_permutation)


def test_ranges_to_indices_basic():
    out = ranges_to_indices(np.array([0, 10, 5]), np.array([3, 2, 0]))
    assert out.tolist() == [0, 1, 2, 10, 11]


def test_ranges_to_indices_empty():
    assert ranges_to_indices(np.array([]), np.array([])).size == 0


def test_from_edges_sorted_rows(tiny_graph):
    g = tiny_graph
    for v in range(g.num_vertices):
        row = g.neighbors(v)
        assert np.all(np.diff(row) >= 0), f"row {v} not sorted"


def test_degrees(tiny_graph):
    g = tiny_graph
    assert g.out_degree.sum() == g.num_edges
    assert g.in_degree.sum() == g.num_edges
    assert np.array_equal(g.degree, g.out_degree + g.in_degree)


def test_transpose_involution(any_graph):
    g = any_graph
    tt = g.transpose.transpose
    assert np.array_equal(tt.indptr, g.indptr)
    assert np.array_equal(np.sort(tt.edge_multiset(), axis=0),
                          np.sort(g.edge_multiset(), axis=0))


def test_transpose_edge_count(any_graph):
    assert any_graph.transpose.num_edges == any_graph.num_edges


def test_apply_permutation_isomorphism(any_graph):
    g = any_graph
    rng = np.random.default_rng(0)
    perm = rng.permutation(g.num_vertices)
    gp = g.apply_permutation(perm)
    # edge multiset maps through the permutation
    orig = g.edge_multiset()
    mapped = np.stack([perm[orig[:, 0]], perm[orig[:, 1]]], 1)
    order = np.lexsort((mapped[:, 1], mapped[:, 0]))
    assert np.array_equal(mapped[order], gp.edge_multiset())


def test_apply_identity_is_noop(tiny_graph):
    g = tiny_graph
    gp = g.apply_permutation(np.arange(g.num_vertices))
    assert np.array_equal(gp.indptr, g.indptr)
    assert np.array_equal(gp.indices, g.indices)


def test_permutation_degree_preserved(plc_graph):
    g = plc_graph
    rng = np.random.default_rng(1)
    perm = rng.permutation(g.num_vertices)
    gp = g.apply_permutation(perm)
    assert np.array_equal(gp.out_degree[perm], g.out_degree)
    assert np.array_equal(gp.in_degree[perm], g.in_degree)


def test_undirected_symmetric(plc_graph):
    und = plc_graph.undirected
    em = und.edge_multiset()
    fwd = set(map(tuple, em))
    assert all((b, a) in fwd for a, b in fwd)


def test_validate_permutation():
    assert validate_permutation(np.array([2, 0, 1]), 3)
    assert not validate_permutation(np.array([0, 0, 1]), 3)
    assert not validate_permutation(np.array([0, 1]), 3)


def test_frontier_neighbors(tiny_graph):
    g = tiny_graph
    nbrs = g.frontier_neighbors(np.array([0, 3]))
    expect = np.concatenate([g.neighbors(0), g.neighbors(3)])
    assert np.array_equal(nbrs, expect)
