"""Observability plane: registry mechanics, deterministic latency math,
trace export validation, failure counters, and the telemetry facades.

Latency/deadline tests advance a `ManualClock` instead of sleeping, so
the asserted numbers are exact, not approximate.
"""
import json

import numpy as np
import pytest

from repro.core.generators import powerlaw_community
from repro.engine import (EngineSession, ManualClock, MetricsRegistry,
                          ProfilerHook, SingleDeviceBackend, Tracer,
                          validate_chrome_trace)
from repro.engine.obs import (Histogram, log_boundaries,
                              merge_histogram_snapshots,
                              signed_log_boundaries)

HIST_SNAPSHOT_KEYS = {"count", "sum", "min", "max", "p50", "p90", "p99",
                      "boundaries", "bucket_counts"}
SCHEDULER_TELEMETRY_KEYS = [
    "requests_enqueued", "requests_served", "pending", "launches",
    "coalesced_requests", "dedup_hits", "flushes", "deadlines_missed",
    "launches_failed", "requests_failed", "max_batch_sources",
    "max_delay", "auto_flushes", "requests_expired", "admission",
    "admission_rejected", "admission_degraded", "admission_shed",
    "deadline_miss_rate", "result_cache"]


@pytest.fixture(scope="module")
def obs_graph():
    return powerlaw_community(600, avg_degree=8.0, seed=11, name="obsg")


# ---------------------------------------------------------------- registry
def test_counter_and_gauge_mechanics():
    m = MetricsRegistry()
    c = m.counter("hits_total", "help text")
    c.inc()
    c.inc(3)
    assert c.value == 4
    with pytest.raises(ValueError):
        c.inc(-1)
    assert m.counter("hits_total") is c        # same (name, labels) = same
    assert m.counter("hits_total", x="1") is not c
    g = m.gauge("pending")
    g.inc(5)
    g.dec(2)
    assert g.value == 3
    g.set(0)
    assert g.value == 0
    with pytest.raises(ValueError):            # kind drift must be loud
        m.gauge("hits_total")


def test_histogram_observe_and_quantiles():
    h = Histogram("lat", boundaries=log_boundaries(1e-3, 1.0))
    for v in (0.002, 0.002, 0.004, 0.5):
        h.observe(v)
    snap = h.snapshot()
    assert snap["count"] == 4
    assert snap["sum"] == pytest.approx(0.508)
    assert snap["min"] == 0.002 and snap["max"] == 0.5
    assert 0.001 <= snap["p50"] <= 0.008       # within the winning buckets
    assert snap["p99"] <= 0.5
    with pytest.raises(ValueError):
        h.quantile(1.5)
    empty = Histogram("e").snapshot()
    assert empty["count"] == 0 and empty["p50"] is None


def test_merge_histogram_snapshots():
    a = Histogram("x", boundaries=(1.0, 2.0))
    b = Histogram("x", boundaries=(1.0, 2.0))
    a.observe(0.5)
    b.observe(1.5)
    b.observe(10.0)
    merged = merge_histogram_snapshots([a.snapshot(), b.snapshot()])
    assert merged["count"] == 3
    assert merged["min"] == 0.5 and merged["max"] == 10.0
    other = Histogram("y", boundaries=(5.0,)).snapshot()
    with pytest.raises(ValueError):
        merge_histogram_snapshots([a.snapshot(), other])


def test_signed_log_boundaries_mirrored():
    b = signed_log_boundaries(1e-3, 8.0)
    assert list(b) == sorted(b)
    assert 0.0 in b
    assert b[0] == -b[-1]


def test_snapshot_and_prometheus_shapes():
    m = MetricsRegistry()
    m.counter("jobs_total").inc(2)
    m.counter("served_total", graph="g1", kernel="bfs").inc()
    m.histogram("wait_seconds", kernel="bfs").observe(0.25)
    snap = m.snapshot()
    assert set(snap) == {"counters", "gauges", "histograms"}
    assert snap["counters"]["jobs_total"] == 2          # unlabelled: bare
    assert snap["counters"]["served_total"] == {"graph=g1,kernel=bfs": 1}
    hist = snap["histograms"]["wait_seconds"]["kernel=bfs"]
    assert set(hist) == HIST_SNAPSHOT_KEYS
    json.dumps(snap, allow_nan=False)                   # strict-JSON safe
    text = m.to_prometheus()
    assert "# TYPE jobs_total counter" in text
    assert "# TYPE wait_seconds histogram" in text
    assert 'served_total{graph="g1",kernel="bfs"} 1' in text
    assert 'wait_seconds_bucket{kernel="bfs",le="+Inf"} 1' in text
    assert 'wait_seconds_count{kernel="bfs"} 1' in text


def test_manual_clock_is_monotonic():
    clk = ManualClock()
    assert clk.now() == 0.0
    clk.advance(1.5)
    assert clk.now() == 1.5
    with pytest.raises(ValueError):
        clk.advance(-1)


# ------------------------------------------------------------------ tracer
def test_tracer_spans_nest_and_export(tmp_path):
    clk = ManualClock()
    tr = Tracer(clock=clk)
    with tr.span("outer", graph_id="g"):
        clk.advance(0.5)
        with tr.span("inner") as args:
            clk.advance(0.25)
            args["fact"] = "learned-inside"
    tr.instant("tick", note="hi")
    p = tr.export(tmp_path / "trace.json")
    trace = json.loads(p.read_text())
    stats = validate_chrome_trace(trace)
    assert stats["complete_spans"] == 2
    assert stats["span_names"] == ["inner", "outer"]
    inner = next(e for e in trace["traceEvents"] if e["name"] == "inner")
    assert inner["args"]["fact"] == "learned-inside"
    assert inner["dur"] == pytest.approx(0.25e6)        # µs
    assert trace["otherData"]["dropped_events"] == 0


def test_tracer_bounded_buffer_counts_drops():
    tr = Tracer(clock=ManualClock(), max_events=2)
    for i in range(5):
        tr.instant(f"e{i}")
    assert len(tr.events) == 2
    assert tr.dropped == 3
    assert tr.to_chrome()["otherData"]["dropped_events"] == 3


def test_validate_rejects_overlapping_spans():
    tr = Tracer(clock=ManualClock())
    tr.emit("a", 0.0, 2.0)
    tr.emit("b", 1.0, 3.0)        # overlaps a without nesting
    with pytest.raises(AssertionError):
        validate_chrome_trace(tr.to_chrome())


# --------------------------------------------- deterministic latency math
def test_queue_wait_and_serve_histograms_exact(obs_graph):
    clk = ManualClock()
    session = EngineSession(clock=clk)
    gid = session.register(obs_graph, "g")
    fut = session.enqueue(gid, "bfs", [0, 1])
    clk.advance(0.5)                       # the request waits half a second
    session.flush()
    assert fut.telemetry["queue_seconds"] == pytest.approx(0.5)
    fam = session.metrics().family("engine_queue_wait_seconds")
    hist = fam["graph_id=g,kernel=bfs"]
    assert hist.count == 1
    assert hist.min == pytest.approx(0.5) and hist.max == pytest.approx(0.5)
    serve = session.metrics().family("engine_serve_seconds")
    assert serve["graph_id=g,kernel=bfs"].min == pytest.approx(0.5)


def test_deadline_slack_histogram_exact(obs_graph):
    clk = ManualClock()
    session = EngineSession(clock=clk)
    gid = session.register(obs_graph, "g")
    missed = session.enqueue(gid, "bfs", [0], deadline_seconds=0.2)
    met = session.enqueue(gid, "bfs", [1], deadline_seconds=2.0)
    clk.advance(0.5)
    session.flush()
    assert session.scheduler.deadlines_missed == 1
    assert missed.telemetry["deadline_missed"] is True
    assert met.telemetry["deadline_missed"] is False
    fam = session.metrics().family("engine_deadline_slack_seconds")
    slack = fam["graph_id=g,kernel=bfs"]
    assert slack.count == 2
    assert slack.min == pytest.approx(-0.3)     # missed by 0.3s
    assert slack.max == pytest.approx(1.5)      # met with 1.5s of room


# ------------------------------------------------------- failure counting
def test_launch_failure_counters_and_recovery(obs_graph):
    session = EngineSession(clock=ManualClock())
    gid = session.register(obs_graph, "g")
    real_launch = session._launch

    def boom(entry, kernel, sources):
        raise RuntimeError("device on fire")

    session._launch = boom
    f1 = session.enqueue(gid, "bfs", [0])
    f2 = session.enqueue(gid, "bfs", [1])
    with pytest.raises(RuntimeError, match="device on fire"):
        session.flush()
    assert f1.done() and f2.done()
    assert isinstance(f1.exception(), RuntimeError)
    with pytest.raises(RuntimeError, match="device on fire"):
        f2.result()
    t = session.scheduler.telemetry()
    assert t["launches_failed"] == 1      # one coalesced launch raised...
    assert t["requests_failed"] == 2      # ...failing both riders
    assert t["requests_served"] == 0
    assert t["pending"] == 0              # nothing stranded in the queues
    session._launch = real_launch         # the session serves again
    out = session.submit(gid, "bfs", [0])
    assert out.shape == (1, obs_graph.num_vertices)
    assert session.scheduler.telemetry()["requests_served"] == 1


# ------------------------------------------------- end-to-end trace + burst
def test_burst_trace_and_histogram_counts(obs_graph, tmp_path):
    session = EngineSession()
    gid = session.register(obs_graph, "g")
    rng = np.random.default_rng(3)
    kernels = ("bfs", "sssp", "bc", "pr", "cc", "ccsv")
    futs = []
    for i in range(64):
        k = kernels[i % len(kernels)]
        srcs = (rng.integers(0, obs_graph.num_vertices, size=2)
                if k in ("bfs", "sssp", "bc") else None)
        futs.append(session.enqueue(gid, k, srcs))
    session.drain()
    for f in futs:
        np.asarray(f.result())

    snap = session.metrics().snapshot()
    for name in ("engine_queue_wait_seconds", "engine_serve_seconds"):
        per_label = snap["histograms"][name]
        assert sum(s["count"] for s in per_label.values()) == 64
        merged = merge_histogram_snapshots(list(per_label.values()))
        assert merged["p50"] is not None and merged["p99"] >= merged["p50"]
    assert snap["counters"]["engine_requests_served_total"] == 64

    p = session.tracer.export(tmp_path / "burst_trace.json")
    trace = json.loads(p.read_text())
    stats = validate_chrome_trace(trace)
    for must in ("flush", "coalesce", "translate", "launch", "device_sync",
                 "queue_wait", "serve", "reorder", "register"):
        assert must in stats["span_names"], must
    served = {e["args"]["trace_id"] for e in trace["traceEvents"]
              if e.get("ph") == "X" and e["name"] == "serve"}
    assert served == {f.trace_id for f in futs}   # every future is traced
    assert all(f.trace_id == f.telemetry["trace_id"] for f in futs)


def test_launch_span_marks_compile_then_cache_hit(obs_graph):
    session = EngineSession()
    gid = session.register(obs_graph, "g")
    session.submit(gid, "bfs", [0])
    session.submit(gid, "bfs", [1])       # same shape: second is a hit
    launches = [e for e in session.tracer.to_chrome()["traceEvents"]
                if e.get("ph") == "X" and e["name"] == "launch"]
    assert [e["args"]["compile"] for e in launches] == \
        ["compile", "cache_hit"]


def test_sharded_run_emits_exchange_spans(obs_graph):
    session = EngineSession(device_budget_bytes=1024)   # force sharded
    gid = session.register(obs_graph, "g")
    entry = session.registry.get(gid)
    assert entry.backend == "sharded"
    fut = session.enqueue(gid, "bfs", [0, 1])
    session.flush()
    np.asarray(fut.result())
    assert fut.telemetry["exchange"] is not None
    trace = session.tracer.to_chrome()
    validate_chrome_trace(trace)
    exchanges = [e for e in trace["traceEvents"]
                 if e.get("ph") == "X" and e["name"] == "exchange"]
    assert len(exchanges) >= 1            # one span per traversal step
    launch = next(e for e in trace["traceEvents"]
                  if e.get("ph") == "X" and e["name"] == "launch")
    lo, hi = launch["ts"], launch["ts"] + launch["dur"]
    for ex in exchanges:                  # nested inside their launch
        assert lo - 1e-2 <= ex["ts"] <= ex["ts"] + ex["dur"] <= hi + 1e-2
        assert ex["args"]["mode"] in ("full", "hot")
    snap = session.metrics().snapshot()
    assert snap["counters"]["engine_exchange_steps_total"] == len(exchanges)


# ------------------------------------------------------------ golden schema
def test_scheduler_telemetry_golden_schema(obs_graph):
    session = EngineSession()
    gid = session.register(obs_graph, "g")
    session.submit(gid, "bfs", [0])
    t = session.scheduler.telemetry()
    assert list(t) == SCHEDULER_TELEMETRY_KEYS
    assert t["admission"] is None          # none configured by default
    assert set(t["result_cache"]) == {"entries", "pinned", "max_entries",
                                      "bytes", "max_bytes", "max_age_s",
                                      "hits", "misses", "evictions",
                                      "expired", "hit_rate"}
    top = session.telemetry()
    assert set(top) == {"executor", "scheduler", "policy", "calibration",
                        "redecisions", "mutations", "graphs"}
    assert set(top["mutations"]) == {"mutations", "edges_added",
                                     "edges_removed", "patch_reorders",
                                     "layout_swaps",
                                     "layout_swaps_discarded",
                                     "pending_swaps"}
    led = top["graphs"]["g"]["ledger"]
    assert "break_even_never" in led
    assert led["break_even_queries"] is None or \
        isinstance(led["break_even_queries"], float)
    json.dumps(top, allow_nan=False, default=float)     # strict-JSON safe

    snap = session.metrics().snapshot()
    for name in ("engine_requests_enqueued_total",
                 "engine_requests_served_total", "engine_launches_total",
                 "engine_flushes_total", "engine_graphs_registered_total",
                 "engine_reorders_total", "engine_queries_total",
                 "engine_compile_cache_misses_total",
                 "engine_auto_flushes_total",
                 "engine_requests_expired_total",
                 "engine_admission_rejected_total",
                 "engine_admission_degraded_total",
                 "engine_admission_shed_total",
                 "engine_result_cache_hits_total",
                 "engine_result_cache_misses_total",
                 "engine_result_cache_evictions_total"):
        assert name in snap["counters"], name
    assert "engine_pending_requests" in snap["gauges"]
    assert "engine_result_cache_entries" in snap["gauges"]
    assert "engine_result_cache_pinned" in snap["gauges"]
    for name in ("engine_queue_wait_seconds", "engine_serve_seconds",
                 "engine_launch_wall_seconds", "engine_reorder_seconds"):
        assert name in snap["histograms"], name
        for child in snap["histograms"][name].values():
            assert set(child) == HIST_SNAPSHOT_KEYS


def test_registry_adoption_chain(obs_graph):
    session = EngineSession()
    assert session.metrics() is session.executor.metrics
    assert session.metrics() is session.executor.single.metrics
    gid = session.register(obs_graph, "g")
    session.submit(gid, "bfs", [0])
    # backend-side counters land in the session's namespace
    assert session.metrics().snapshot()["counters"][
        "engine_queries_total"] == {"backend=single": 1}
    standalone = SingleDeviceBackend()    # built alone: private registry
    assert standalone.metrics is not session.metrics()


# ---------------------------------------------------------------- profiler
def test_profiler_hook_inert_without_log_dir():
    hook = ProfilerHook(None)
    assert hook.enabled is False
    assert hook.start() is False
    with hook.step("bfs"):                # nullcontext, never raises
        pass
    assert hook.stop() is False
    session = EngineSession()
    assert session.start_profiler() is False


def test_profiler_hook_records_errors_not_raises(monkeypatch, tmp_path):
    hook = ProfilerHook(str(tmp_path / "prof"))
    assert hook.enabled is True
    import jax

    def blow_up(*a, **k):
        raise RuntimeError("profiler unavailable")

    monkeypatch.setattr(jax.profiler, "start_trace", blow_up)
    assert hook.start() is False          # swallowed, not raised
    assert "profiler unavailable" in hook.error
