"""Request plane: futures + micro-batch scheduling over the serving engine.

The paper's economic argument is *amortization* — a reorder pays off only
across many traversals — yet a blocking one-caller ``submit`` launches one
device program per call, so concurrent traffic can never share a vmapped
launch and the policy never observes real batch shapes. This module turns
the front door into a request plane:

* ``EngineSession.enqueue(...)`` returns a `QueryFuture` immediately;
  nothing touches a device until a **flush boundary**.
* `MicroBatchScheduler` queues requests per ``(graph_id, kernel)`` and, at
  ``flush()``/``drain()``:

  - **coalesces** pending multi-source requests (bfs/sssp/bc) into one
    vmapped launch whose concatenated sources fill a power-of-two
    `source_bucket`, then slices each request's rows back out of the
    ``(S, V)`` result — N requests, one device program;
  - **deduplicates** concurrent global-kernel requests (pr/cc/ccsv) into
    a single run fanned out to every waiter — the result is
    source-independent, so running it twice is pure waste;
  - drains queues in **priority / deadline order** (higher ``priority``
    first, then earlier absolute deadline, then FIFO), so a latency-bound
    request is never stuck behind a bulk scan that arrived first.

* **generations** — every (re-)applied policy decision bumps the graph
  entry's ``generation``; a request's sources are translated through the
  layout *at launch time* and its result translated back before the
  flush-boundary re-decision check runs, so an in-flight future is never
  served half from a layout that was just replaced. Re-decision moves
  from per-submit to per-flush: one check per graph per flush, after all
  of its pending requests were served.

* **telemetry** — every future carries per-request serving facts: the
  launch it rode, how many requests shared it, its wall share, the
  generation that served it, whether its deadline was met, and (sharded
  placements) the per-run `ExchangeStats` delta from ``core/dist.py``.

``EngineSession.submit`` is reimplemented as enqueue + flush sugar, so
the blocking API is exactly one request riding a one-element batch —
bit-identical results, same id translation, same ledger accounting.
docs/scheduler.md documents the lifecycle and the migration path.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import TYPE_CHECKING

import numpy as np

from .backends import GLOBAL, MULTI_SOURCE, build_kernel, source_bucket

if TYPE_CHECKING:  # import cycle: session builds the scheduler
    from .session import EngineSession

# component-label kernels whose *values* (not just positions) are vertex
# ids and must be canonicalized back to original id space at the boundary
LABEL_KERNELS = ("cc", "ccsv")


def canonical_component_labels(labels: np.ndarray) -> np.ndarray:
    """Relabel component ids to the **minimum original vertex id** of each
    component.

    ``labels[v]`` must be a consistent per-component representative (any
    id space — the engine's served layout uses served ids). The output is
    layout-independent: bit-identical to `core.baselines.cc_baseline`
    whatever permutation the graph was served under, which is what lets
    the parity matrix demand cross-backend bit-identity for cc/ccsv.
    """
    labels = np.asarray(labels)
    n = labels.shape[-1]
    flat = labels.reshape(-1, n).astype(np.int64, copy=False)
    out = np.empty_like(flat)
    for i, row in enumerate(flat):
        rep_min = np.full(int(row.max()) + 1, n, dtype=np.int64)
        np.minimum.at(rep_min, row, np.arange(n, dtype=np.int64))
        out[i] = rep_min[row]
    return out.reshape(labels.shape)


@dataclasses.dataclass
class Request:
    """One enqueued query: what to run, how urgently, and for whom."""

    seq: int                       # FIFO tiebreak, assigned at enqueue
    graph_id: str
    kernel: str
    sources: np.ndarray | None     # original-id space; None for GLOBAL
    priority: int                  # higher drains first
    deadline: float | None         # absolute perf_counter() time, or None
    enqueued_at: float
    future: "QueryFuture"
    generation: int | None = None  # layout generation that served it

    @property
    def num_sources(self) -> int:
        return 0 if self.sources is None else int(self.sources.size)

    def order_key(self) -> tuple:
        """Drain order: priority desc, earliest deadline, FIFO."""
        return (-self.priority,
                self.deadline if self.deadline is not None else float("inf"),
                self.seq)


class QueryFuture:
    """Handle to a pending (or served) request.

    ``result()`` is the blocking read: if the request has not been served
    yet it flushes the owning scheduler for this request's graph first,
    so a lone ``enqueue(...).result()`` behaves exactly like the old
    blocking ``submit``. ``telemetry`` is populated at serve time (see
    `MicroBatchScheduler._account`).
    """

    def __init__(self, scheduler: "MicroBatchScheduler", request: Request):
        self._scheduler = scheduler
        self._result: np.ndarray | None = None
        self._exception: BaseException | None = None
        self._done = False
        self.request = request
        self.telemetry: dict = {}

    # ------------------------------------------------------------ protocol
    def done(self) -> bool:
        return self._done

    def result(self) -> np.ndarray:
        if not self._done:
            self._scheduler.flush(self.request.graph_id)
        if not self._done:  # defensive: flush must have served us
            raise RuntimeError(
                f"flush did not serve request {self.request.seq} "
                f"({self.request.graph_id}/{self.request.kernel})")
        if self._exception is not None:
            raise self._exception
        return self._result

    def exception(self) -> BaseException | None:
        """The launch failure, if any (None while pending or on success)."""
        return self._exception

    # ------------------------------------------------------------ internal
    def _set_result(self, value: np.ndarray) -> None:
        self._result = value
        self._done = True

    def _set_exception(self, exc: BaseException) -> None:
        self._exception = exc
        self._done = True


class MicroBatchScheduler:
    """Per-(graph, kernel) request queues drained as micro-batches.

    One scheduler fronts one `EngineSession`; the session owns the
    registry/policy/executor and exposes the launch internals the
    scheduler drives (`EngineSession._launch` / ``_finalize`` /
    ``_maybe_redecide``). ``max_batch_sources`` caps how many concatenated
    sources one coalesced launch may carry (None = coalesce everything
    pending into a single launch; the executor still pads the batch to
    its power-of-two `source_bucket`).
    """

    def __init__(self, session: "EngineSession",
                 max_batch_sources: int | None = None):
        if max_batch_sources is not None and max_batch_sources < 1:
            raise ValueError("max_batch_sources must be >= 1 or None")
        self.session = session
        self.max_batch_sources = max_batch_sources
        self._queues: dict[tuple[str, str], list[Request]] = {}
        self._seq = itertools.count()
        # counters: the coalescing story in numbers
        self.requests_enqueued = 0
        self.requests_served = 0
        self.launches = 0
        self.coalesced_requests = 0   # requests that shared a launch
        self.dedup_hits = 0           # global requests served without a run
        self.flushes = 0
        self.deadlines_missed = 0

    # ------------------------------------------------------------- enqueue
    def enqueue(self, graph_id: str, kernel: str, sources=None,
                priority: int = 0,
                deadline_seconds: float | None = None) -> QueryFuture:
        """Queue one request; returns its future. Validation is eager —
        unknown kernel/graph and empty source batches raise *here*, not at
        flush time where they would poison a coalesced batch."""
        build_kernel(kernel)                    # ValueError on unknown
        entry = self.session.registry.get(graph_id)  # KeyError on unknown
        srcs = None
        if kernel in MULTI_SOURCE:
            srcs = np.atleast_1d(np.asarray(sources, dtype=np.int64))
            if srcs.size == 0:
                raise ValueError(f"{kernel} needs at least one source")
            n = entry.graph.num_vertices
            if int(srcs.min()) < 0 or int(srcs.max()) >= n:
                # out-of-range ids must fail *this* caller now — at launch
                # time they would poison every request coalesced alongside
                raise ValueError(
                    f"{kernel} sources must be in [0, {n}); got "
                    f"[{int(srcs.min())}, {int(srcs.max())}]")
        now = time.perf_counter()
        req = Request(
            seq=next(self._seq), graph_id=graph_id, kernel=kernel,
            sources=srcs, priority=priority,
            deadline=(now + deadline_seconds
                      if deadline_seconds is not None else None),
            enqueued_at=now, future=None)  # type: ignore[arg-type]
        req.future = QueryFuture(self, req)
        self._queues.setdefault((graph_id, kernel), []).append(req)
        self.requests_enqueued += 1
        return req.future

    def pending(self, graph_id: str | None = None) -> int:
        return sum(len(reqs) for (gid, _), reqs in self._queues.items()
                   if graph_id is None or gid == graph_id)

    # --------------------------------------------------------------- flush
    def flush(self, graph_id: str | None = None) -> int:
        """Serve everything currently pending (for one graph, or all).

        Queues drain in priority/deadline order; each graph gets exactly
        one re-decision check *after* all of its pending requests were
        served — the flush boundary — so no in-flight future straddles a
        layout replacement.
        """
        graphs: list[str] = []
        for (gid, _), reqs in self._queues.items():
            if reqs and (graph_id is None or gid == graph_id):
                if gid not in graphs:
                    graphs.append(gid)
        served = 0
        self.flushes += 1
        for gid in graphs:
            served += self._flush_graph(gid)
        return served

    def drain(self) -> int:
        """Flush until no request is pending anywhere (lifecycle close)."""
        served = 0
        while self.pending():
            served += self.flush()
        return served

    # ------------------------------------------------------ flush internals
    def _take_queues(self, graph_id: str) -> list[tuple[str, list[Request]]]:
        """Pop this graph's non-empty queues, ordered by their most urgent
        request (so a high-priority sssp drains before a bulk bfs)."""
        taken = []
        for (gid, kernel), reqs in list(self._queues.items()):
            if gid == graph_id and reqs:
                taken.append((kernel, reqs))
                del self._queues[(gid, kernel)]
        taken.sort(key=lambda kv: min(r.order_key() for r in kv[1]))
        return taken

    def _flush_graph(self, graph_id: str) -> int:
        session = self.session
        entry = session.registry.get(graph_id)
        served = 0
        taken = self._take_queues(graph_id)
        try:
            for kernel, reqs in taken:
                reqs.sort(key=Request.order_key)
                if kernel in GLOBAL:
                    self._serve_global(entry, kernel, reqs)
                else:
                    for chunk in self._chunks(reqs):
                        self._serve_multi(entry, kernel, chunk)
                served += len(reqs)
        except Exception as exc:
            # a failed launch must not strand the rest of the flush set:
            # every taken-but-unserved future fails with the same cause
            for _, reqs in taken:
                for r in reqs:
                    if not r.future.done():
                        r.future._set_exception(exc)
            raise
        finally:
            # requests resolved before a mid-flush failure were genuinely
            # served: keep the counter consistent with their futures
            self.requests_served += served
        # flush boundary: all pending requests for this graph are answered
        # and translated under the generation that served them — only now
        # may the layout be replaced (skipped if the flush aborted above)
        session._maybe_redecide(entry)
        return served

    def _chunks(self, reqs: list[Request]) -> list[list[Request]]:
        """Greedy coalescing under the source cap, in drain order."""
        if self.max_batch_sources is None:
            return [reqs]
        chunks: list[list[Request]] = []
        cur: list[Request] = []
        total = 0
        for r in reqs:
            if cur and total + r.num_sources > self.max_batch_sources:
                chunks.append(cur)
                cur, total = [], 0
            cur.append(r)
            total += r.num_sources
        if cur:
            chunks.append(cur)
        return chunks

    def _serve_multi(self, entry, kernel: str, reqs: list[Request]) -> None:
        """One vmapped launch for every request in ``reqs``; per-request
        rows sliced back out of the (S, V) result."""
        session = self.session
        all_sources = np.concatenate([r.sources for r in reqs])
        try:
            out, wall = session._launch(entry, kernel, all_sources)
        except Exception as exc:
            for r in reqs:
                r.future._set_exception(exc)
            raise
        exchange = session._last_exchange(entry)
        total = int(all_sources.size)
        session.policy.observe_batch_sources(total)
        self.launches += 1
        if len(reqs) > 1:
            self.coalesced_requests += len(reqs)
        offset = 0
        for r in reqs:
            # copy: a slice view would pin the whole (S_total, V) launch
            # array for as long as any one future's result is retained
            rows = out[offset:offset + r.num_sources].copy()
            offset += r.num_sources
            share = wall * (r.num_sources / max(total, 1))
            self._account(entry, r, rows, wall, share, len(reqs), total,
                          exchange)

    def _serve_global(self, entry, kernel: str, reqs: list[Request]) -> None:
        """One run, fanned out to every waiter (the result is
        source-independent, so concurrent requests are duplicates)."""
        session = self.session
        try:
            out, wall = session._launch(entry, kernel, None)
        except Exception as exc:
            for r in reqs:
                r.future._set_exception(exc)
            raise
        exchange = session._last_exchange(entry)
        self.launches += 1
        if len(reqs) > 1:
            self.coalesced_requests += len(reqs)
            self.dedup_hits += len(reqs) - 1
        for r in reqs:
            self._account(entry, r, out, wall, wall / len(reqs), len(reqs),
                          0, exchange)

    def _account(self, entry, req: Request, result: np.ndarray, wall: float,
                 wall_share: float, sharing: int, batch_sources: int,
                 exchange: dict | None) -> None:
        """Resolve one future: ledger, realized-volume, telemetry."""
        session = self.session
        req.generation = entry.generation
        entry.ledger.record_query(req.num_sources, wall_share)
        session.registry.note_queries(entry.graph_id)
        served_at = time.perf_counter()
        missed = req.deadline is not None and served_at > req.deadline
        if missed:
            self.deadlines_missed += 1
        req.future.telemetry = {
            "kernel": req.kernel,
            "graph_id": req.graph_id,
            "priority": req.priority,
            "generation": req.generation,
            "launch_index": self.launches,  # 1-based, in launch order
            "launch_wall_seconds": wall,
            "wall_share_seconds": wall_share,
            "coalesced_with": sharing - 1,
            "launch_batch_sources": batch_sources,
            "queue_seconds": served_at - req.enqueued_at,
            "deadline_missed": missed,
            "exchange": exchange,
        }
        req.future._set_result(result)

    # ----------------------------------------------------------- telemetry
    def telemetry(self) -> dict:
        return {
            "requests_enqueued": self.requests_enqueued,
            "requests_served": self.requests_served,
            "pending": self.pending(),
            "launches": self.launches,
            "coalesced_requests": self.coalesced_requests,
            "dedup_hits": self.dedup_hits,
            "flushes": self.flushes,
            "deadlines_missed": self.deadlines_missed,
            "max_batch_sources": self.max_batch_sources,
        }


__all__ = ["LABEL_KERNELS", "MicroBatchScheduler", "QueryFuture", "Request",
           "canonical_component_labels", "source_bucket"]
