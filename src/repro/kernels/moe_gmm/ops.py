"""Public grouped-matmul wrapper with backend dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .moe_gmm import TILE_M, gmm_pallas, pad_groups
from .ref import gmm_ref


def grouped_matmul(x, w, tile_expert, *, use_pallas: bool | None = None,
                   interpret: bool | None = None):
    """x (M,K) expert-sorted rows (M multiple of TILE_M), w (E,K,N),
    tile_expert (M//TILE_M,)."""
    on_tpu = jax.default_backend() == "tpu"
    use_pallas = on_tpu if use_pallas is None else use_pallas
    interpret = (not on_tpu) if interpret is None else interpret
    if use_pallas:
        return gmm_pallas(x, w, tile_expert, interpret=interpret)
    row_expert = jnp.repeat(tile_expert, TILE_M)
    return gmm_ref(x, w, row_expert)
