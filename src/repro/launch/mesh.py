"""Production mesh builders (functions — importing never touches jax
device state; jax is only queried when a mesh is actually constructed)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh():
    """Degenerate 1×1 mesh on whatever single device is present (tests)."""
    n = len(jax.devices())
    return jax.make_mesh((1, min(n, 1)), ("data", "model"))
