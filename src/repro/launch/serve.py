"""Serving driver: continuous-batching decode loop over the sharded model.

A small production-shaped server core (no network layer — requests come
from a synthetic queue, matching the offline container):

* **continuous batching** — fixed B decode slots; finished sequences are
  immediately replaced by queued requests (per-slot KV/state reset), so
  the batch never drains;
* **prefill/decode split** — new requests run one prefill forward, then
  enter the decode batch (the two dry-run shape kinds);
* **greedy/temperature sampling** with per-slot RNG;
* the decode step is the same jitted ``make_serve_step`` the dry-run
  lowers, so what is served is what was compiled.

Usage:
  python -m repro.launch.serve --arch qwen2.5-3b --smoke --requests 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    t_enqueue: float = 0.0
    t_first: float | None = None
    t_done: float | None = None


def synthetic_requests(n: int, vocab: int, seed: int = 0,
                       plen: tuple[int, int] = (8, 32),
                       gen: tuple[int, int] = (8, 48)) -> list[Request]:
    rng = np.random.default_rng(seed)
    now = time.time()
    return [
        Request(rid=i,
                prompt=rng.integers(0, vocab,
                                    rng.integers(*plen)).astype(np.int32),
                max_new=int(rng.integers(*gen)), t_enqueue=now)
        for i in range(n)
    ]


def _reset_slot(cache, slot: int, kind: str):
    """Zero one batch slot of the cache pytree (new request admission)."""
    def z(x):
        if x.ndim >= 2 and x.shape[0] != 1:  # (L, B, ...) layered entries
            return x.at[:, slot].set(jnp.zeros_like(x[:, slot]))
        return x
    layers = jax.tree.map(z, cache["layers"])
    out = dict(cache, layers=layers)
    if "shared" in cache:
        out["shared"] = jax.tree.map(z, cache["shared"])
    return out


def serve_loop(cfg, params, requests: list[Request], batch_slots: int = 4,
               max_len: int = 512, temperature: float = 0.0, seed: int = 0):
    """Continuous-batching loop. Returns the completed requests."""
    from ..launch.mesh import make_host_mesh
    from ..models.transformer import decode_step, forward, init_cache

    mesh = make_host_mesh()
    queue = list(requests)[::-1]           # pop() takes the oldest
    active: list[Request | None] = [None] * batch_slots
    remaining = [0] * batch_slots
    done: list[Request] = []

    cache = init_cache(cfg, batch_slots, max_len)
    tokens = jnp.zeros((batch_slots, 1), jnp.int32)
    key = jax.random.PRNGKey(seed)

    step_fn = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg))
    prefill_fn = jax.jit(lambda p, b: forward(p, b, cfg))

    # NOTE on prefill: slots decode independently, but the KV write offset
    # (cache["pos"]) is shared across slots in this compact server; we
    # therefore prefill token-by-token through the decode path for
    # correctness on all trunk kinds (attn/rwkv/hybrid). A per-slot
    # position cache is the documented production extension.
    def admit(slot: int):
        nonlocal cache, tokens
        req = queue.pop()
        cache = _reset_slot(cache, slot, "any")
        ids = jnp.asarray(req.prompt)[None, :]
        # feed prompt through decode steps for this slot only
        for i in range(ids.shape[1]):
            tokens = tokens.at[slot, 0].set(ids[0, i])
            _, cache = step_fn(params, cache, tokens)
        active[slot] = req
        remaining[slot] = req.max_new
        req.t_first = None

    steps = 0
    while queue or any(a is not None for a in active):
        for s in range(batch_slots):
            if active[s] is None and queue:
                admit(s)
        logits, cache = step_fn(params, cache, tokens)
        lg = logits[:, -1].astype(jnp.float32)
        if temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, lg / temperature, axis=-1)
        else:
            nxt = jnp.argmax(lg, axis=-1)
        tokens = nxt[:, None].astype(jnp.int32)
        now = time.time()
        for s in range(batch_slots):
            req = active[s]
            if req is None:
                continue
            if req.t_first is None:
                req.t_first = now
            req.out.append(int(nxt[s]))
            remaining[s] -= 1
            if remaining[s] <= 0:
                req.t_done = now
                done.append(req)
                active[s] = None
        steps += 1
        if steps * batch_slots > 100_000:
            raise RuntimeError("serve loop runaway")
    return done


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    from ..configs import get_config, smoke_config
    from ..models.transformer import init_params

    cfg = smoke_config(args.arch, layers=args.layers) if args.smoke \
        else get_config(args.arch)
    if cfg.is_encoder:
        raise SystemExit("encoder-only arch has no decode step")
    params = init_params(cfg, jax.random.PRNGKey(0))
    reqs = synthetic_requests(args.requests, cfg.vocab_size)
    t0 = time.time()
    done = serve_loop(cfg, params, reqs, batch_slots=args.slots,
                      temperature=args.temperature)
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s aggregate)")
    lat = [r.t_done - r.t_enqueue for r in done]
    print(f"[serve] latency p50 {np.percentile(lat, 50):.2f}s "
          f"p95 {np.percentile(lat, 95):.2f}s")
    return done


if __name__ == "__main__":
    main()
