"""RWKV6 ("Finch") block — attention-free, data-dependent decay.

Time-mix: per-head wkv state S ∈ (H, K, V) with per-channel, per-token
decay w_t = exp(-exp(ŵ_t)) where ŵ_t is data-dependent via a low-rank MLP
(the Finch contribution); token-shift interpolation is likewise
data-dependent (ddlerp). Channel-mix is the standard squared-ReLU FFN.

Training uses a time scan (sequential, correct); decoding is O(1)/token.
The chunked block-parallel form is a documented TPU perf follow-up.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import COMPUTE_DTYPE, _dense

LORA_DIM = 32
DDLERP_DIM = 32


def init_rwkv(key, cfg: ModelConfig):
    d = cfg.d_model
    h = cfg.num_heads if cfg.num_heads > 0 else d // 64
    dh = d // h
    ks = jax.random.split(key, 12)
    sc = d ** -0.5
    p = {
        # ddlerp token-shift: base mus + low-rank data-dependent deltas
        "mu_base": jnp.zeros((5, d), jnp.float32),
        "ddl_w1": jax.random.normal(ks[0], (d, 5 * DDLERP_DIM), jnp.float32) * sc,
        "ddl_w2": jax.random.normal(ks[1], (5, DDLERP_DIM, d), jnp.float32) * 0.01,
        # projections r,k,v,g + output
        "wr": jax.random.normal(ks[2], (d, d), jnp.float32) * sc,
        "wk": jax.random.normal(ks[3], (d, d), jnp.float32) * sc,
        "wv": jax.random.normal(ks[4], (d, d), jnp.float32) * sc,
        "wg": jax.random.normal(ks[5], (d, d), jnp.float32) * sc,
        "wo": jax.random.normal(ks[6], (d, d), jnp.float32) * sc,
        # decay: base + low-rank data-dependent (the v6 feature)
        "w_base": jnp.full((d,), -6.0, jnp.float32),
        "dec_w1": jax.random.normal(ks[7], (d, LORA_DIM), jnp.float32) * sc,
        "dec_w2": jax.random.normal(ks[8], (LORA_DIM, d), jnp.float32) * 0.01,
        "u_bonus": jnp.zeros((h, dh), jnp.float32),
        "ln_scale": jnp.ones((d,), jnp.float32),
        # channel mix
        "cm_mu": jnp.zeros((2, d), jnp.float32),
        "cm_k": jax.random.normal(ks[9], (d, cfg.d_ff), jnp.float32) * sc,
        "cm_v": jax.random.normal(ks[10], (cfg.d_ff, d), jnp.float32)
                * cfg.d_ff ** -0.5,
        "cm_r": jax.random.normal(ks[11], (d, d), jnp.float32) * sc,
    }
    return p


def _ddlerp(p, x, x_prev):
    """Data-dependent token-shift for the 5 streams (r,k,v,g,w)."""
    base = x + (x_prev - x) * p["mu_base"][0]  # shared pre-mix
    lo = jnp.tanh(_dense(base, p["ddl_w1"]))
    lo = lo.reshape(*lo.shape[:-1], 5, DDLERP_DIM)
    delta = jnp.einsum("...sr,srd->...sd", lo.astype(jnp.float32), p["ddl_w2"])
    mus = p["mu_base"][None, None] + delta          # (B,T,5,D)
    xx = x_prev - x
    return tuple(x + xx * mus[..., i, :].astype(x.dtype) for i in range(5))


def _wkv_scan(r, k, v, w, u, h, dh):
    """Sequential wkv: S_t = diag(w_t)·S_{t-1} + k_t⊗v_t;
    y_t = r_t·(S_{t-1} + u·k_t⊗v_t)."""
    bsz, t, _ = r.shape

    def to_heads(x):
        return x.reshape(bsz, t, h, dh).transpose(1, 0, 2, 3)  # (T,B,H,dh)

    rh, kh, vh, wh = map(to_heads, (r, k, v, w))

    def step(s, inp):
        rt, kt, vt, wt = inp                               # (B,H,dh)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s_new = s * wt[..., None] + kv
        return s_new, y

    s0 = jnp.zeros((bsz, h, dh, dh), jnp.float32)
    s_fin, ys = jax.lax.scan(step, s0, (rh, kh, vh, wh))
    return ys.transpose(1, 0, 2, 3).reshape(bsz, t, h * dh), s_fin


WKV_CHUNK = 16


def _wkv_chunked(r, k, v, logw, u, h, dh, chunk: int = WKV_CHUNK):
    """Block-parallel wkv (§Perf iteration: rwkv6 train was memory-bound on
    the 4096-step token scan — state re-read/written every token).

    Scan over T/chunk chunks carrying S ∈ (B,H,dh,dh); within a chunk the
    recurrence is closed-form:

      y_t = Σ_{j<t} (r_t ⊙ e^{c_{t-1}-c_j}) · k_j v_j
            + (r_t ⊙ u ⊙ k_t)·1 v_t + (r_t ⊙ e^{c_{t-1}}) · S_in

    with c = intra-chunk cumulative log-decay (c = Σ log w ≤ 0). Every
    exponent is a *suffix sum of log-decays* and hence ≤ 0 — no overflow,
    no renormalization needed. State traffic drops by the chunk length and
    the per-token outer products become (L×dh)·(dh×dh) MXU matmuls.
    """
    bsz, t, _ = r.shape
    nc = t // chunk

    def to_chunks(x):  # (B,T,D) -> (NC, B, H, L, dh)
        return (x.reshape(bsz, nc, chunk, h, dh)
                 .transpose(1, 0, 3, 2, 4))

    rc, kc, vc, lwc = map(to_chunks, (r, k, v, logw))
    tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)     # strict lower

    def chunk_step(s, inp):
        rr, kk, vv, lw = inp                  # (B,H,L,dh)
        cc = jnp.cumsum(lw, axis=2)           # inclusive cumulative log-w
        cm1 = cc - lw                         # exclusive (c_{t-1})
        # ---- intra-chunk pairwise (j < t): exponent = c_{t-1} - c_j <= 0
        rel = cm1[:, :, :, None, :] - cc[:, :, None, :, :]   # (B,H,L,L,dh)
        dec = jnp.where(tri[None, None, :, :, None], jnp.exp(rel), 0.0)
        att = jnp.einsum("bhtd,bhjd,bhtjd->bhtj", rr, kk, dec)
        y = jnp.einsum("bhtj,bhjd->bhtd", att, vv)
        # ---- current-token bonus (u term)
        coeff = jnp.einsum("bhtd,bhtd->bht", rr, u[None, :, None, :] * kk)
        y += coeff[..., None] * vv
        # ---- contribution of the carried state
        y += jnp.einsum("bhtd,bhdv->bhtv", rr * jnp.exp(cm1), s)
        # ---- state update: S' = S·e^{c_L} + Σ_j (k_j e^{c_L - c_j}) v_j
        k_dec = kk * jnp.exp(cc[:, :, -1:, :] - cc)
        s_new = (s * jnp.exp(cc[:, :, -1])[..., :, None]
                 + jnp.einsum("bhld,bhlv->bhdv", k_dec, vv))
        return s_new, y

    s0 = jnp.zeros((bsz, h, dh, dh), jnp.float32)
    s_fin, ys = jax.lax.scan(chunk_step, s0, (rc, kc, vc, lwc))
    # (NC,B,H,L,dh) -> (B,T,H*dh)
    y = ys.transpose(1, 0, 3, 2, 4).reshape(bsz, t, h * dh)
    return y, s_fin


def apply_rwkv_timemix(p, x, cfg: ModelConfig, cache=None):
    """x: (B,S,D). cache: dict(shift=(B,D), wkv=(B,H,dh,dh)) or None."""
    bsz, s, d = x.shape
    h = cfg.num_heads if cfg.num_heads > 0 else d // 64
    dh = d // h
    if cache is None:
        x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        x_prev = jnp.concatenate([cache["shift"][:, None].astype(x.dtype),
                                  x[:, :-1]], axis=1)
    xr, xk, xv, xg, xw = _ddlerp(p, x, x_prev)
    r = _dense(xr, p["wr"]).astype(jnp.float32)
    k = _dense(xk, p["wk"]).astype(jnp.float32)
    v = _dense(xv, p["wv"]).astype(jnp.float32)
    g = jax.nn.silu(_dense(xg, p["wg"]))
    # data-dependent decay (Finch): w = exp(-exp(w_base + lora(xw)))
    dec = p["w_base"] + _dense(jnp.tanh(_dense(xw, p["dec_w1"])),
                               p["dec_w2"]).astype(jnp.float32)
    logw = -jnp.exp(dec)          # log decay, always <= 0
    w = jnp.exp(logw)
    u = p["u_bonus"]

    if cache is None:
        if s % WKV_CHUNK == 0:
            y, s_fin = _wkv_chunked(r, k, v, logw, u, h, dh)
        else:       # ragged tails fall back to the token scan
            y, s_fin = _wkv_scan(r, k, v, w, u, h, dh)
        new_cache = None
    else:
        rt = r.reshape(bsz, h, dh)
        kt = k.reshape(bsz, h, dh)
        vt = v.reshape(bsz, h, dh)
        wt = w.reshape(bsz, h, dh)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt,
                       cache["wkv"] + u[None, :, :, None] * kv)
        s_fin = cache["wkv"] * wt[..., None] + kv
        y = y.reshape(bsz, 1, d)
        new_cache = {"shift": x[:, -1], "wkv": s_fin}

    # per-head groupnorm (RWKV uses GroupNorm over heads), then gate
    yh = y.reshape(bsz, s, h, dh).astype(jnp.float32)
    mu = yh.mean(-1, keepdims=True)
    var = ((yh - mu) ** 2).mean(-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + 64e-5)
    y = (yh.reshape(bsz, s, d) * p["ln_scale"]).astype(COMPUTE_DTYPE) * g
    out = _dense(y, p["wo"])
    if cache is None:
        return out, None
    return out, new_cache


def apply_rwkv_channelmix(p, x, cfg: ModelConfig, cache=None):
    bsz, s, d = x.shape
    if cache is None:
        x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        new_cache = None
    else:
        x_prev = jnp.concatenate([cache["shift"][:, None].astype(x.dtype),
                                  x[:, :-1]], axis=1)
        new_cache = {"shift": x[:, -1]}
    xx = x_prev - x
    xk = x + xx * p["cm_mu"][0].astype(x.dtype)
    xr = x + xx * p["cm_mu"][1].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(_dense(xk, p["cm_k"])))
    out = jax.nn.sigmoid(_dense(xr, p["cm_r"])) * _dense(kk, p["cm_v"])
    return out, new_cache


def init_rwkv_cache(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    h = cfg.num_heads if cfg.num_heads > 0 else d // 64
    dh = d // h
    return {
        "tm": {"shift": jnp.zeros((batch, d), jnp.float32),
               "wkv": jnp.zeros((batch, h, dh, dh), jnp.float32)},
        "cm": {"shift": jnp.zeros((batch, d), jnp.float32)},
    }
