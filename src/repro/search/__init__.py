"""Cache-efficient k-NN search serving (ROADMAP item 4).

Coleman et al. ("Graph Reordering for Cache-Efficient Near Neighbor
Search", PAPERS.md) show the paper's hot-prefix packing speeds greedy
beam search on k-NN graphs — except on search graphs out-degree is fixed
by construction, so the skew the reorder exploits lives in *visit
frequency*, observed from serving telemetry rather than read off the
degree distribution.

- ``knn_graph``: exact and NSW-style incremental search-graph builders
  (fixed out-degree CSR, rides the existing ``GraphArrays`` path).
- ``serve``: query digests, query padding, the served-order
  ``SearchSpec`` handed to backends, and the visit-ordered permutation
  used when ``hotness_source == "visits"``.
"""
from .knn_graph import (build_knn_graph, build_nsw_graph, knn_brute_force,
                        medoid_entry, nsw_insert_deltas, validate_search_graph)
from .serve import (SearchParams, SearchSpec, default_max_steps, pad_queries,
                    query_digest, visit_hot_mask, visit_order)

__all__ = [
    "build_knn_graph", "build_nsw_graph", "knn_brute_force", "medoid_entry",
    "nsw_insert_deltas", "validate_search_graph",
    "SearchParams", "SearchSpec", "default_max_steps", "pad_queries",
    "query_digest", "visit_hot_mask", "visit_order",
]
