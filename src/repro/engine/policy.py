"""Adaptive reorder policy: probes + expected query volume -> scheme.

The paper's result is a trade-off, not a recommendation: reordering buys
per-traversal speedup proportional to degree skew, at a one-time cost that
only amortizes over enough traversals (Faldu et al. make the same point
for the lightweight schemes). The policy encodes that trade-off:

* **volume gate** — below ``min_queries`` expected traversals nothing can
  amortize, serve the original layout;
* **skew gate** — low degree Gini (meshes, roads, rings) means no hub
  working set to pack; reordering moves nothing, serve original;
* **cheap tier** — skewed graph but modest volume: a single O(V) pass
  (HubCluster below ``dbg_gini``, DBG above) captures most of the win;
* **expensive tier** — skewed graph and high volume: LOrder with
  κ = ⌈D/2⌉ derived from the registry's diameter probe (paper Table 5.2).

Every decision carries a *predicted* fractional miss-rate reduction from a
probe-only model; the session later records the *realized* reduction from
the cache simulator, so mispredictions are visible in telemetry.
"""
from __future__ import annotations

import dataclasses

from ..core.baselines import reordering_registry
from .registry import GraphProbes

# Relative strength of each scheme at converting skew into miss reduction,
# calibrated against benchmarks/speedups.py geomeans (original = 0).
_SCHEME_STRENGTH = {
    "original": 0.0,
    "hubcluster": 0.35,
    "dbg": 0.5,
    "lorder": 0.75,
}


@dataclasses.dataclass(frozen=True)
class PolicyDecision:
    scheme: str              # key into reordering_registry()
    kwargs: dict             # scheme arguments (e.g. probe-derived kappa)
    reason: str              # human-readable rule that fired
    predicted_gain: float    # predicted fractional miss-rate reduction


@dataclasses.dataclass
class PolicyRecord:
    """Predicted vs realized benefit for one policy decision."""

    graph_id: str
    decision: PolicyDecision
    miss_rate_before: float
    miss_rate_after: float
    reorder_seconds: float

    @property
    def realized_gain(self) -> float:
        if self.miss_rate_before <= 0:
            return 0.0
        return 1.0 - self.miss_rate_after / self.miss_rate_before

    @property
    def prediction_error(self) -> float:
        return self.decision.predicted_gain - self.realized_gain

    def as_dict(self) -> dict:
        return {
            "graph_id": self.graph_id,
            "scheme": self.decision.scheme,
            "kwargs": self.decision.kwargs,
            "reason": self.decision.reason,
            "predicted_gain": self.decision.predicted_gain,
            "realized_gain": self.realized_gain,
            "miss_rate_before": self.miss_rate_before,
            "miss_rate_after": self.miss_rate_after,
            "reorder_seconds": self.reorder_seconds,
        }


class ReorderPolicy:
    """Threshold policy over (probes, expected query volume)."""

    def __init__(self, min_queries: int = 4, high_volume: int = 32,
                 min_gini: float = 0.25, dbg_gini: float = 0.45):
        self.min_queries = min_queries
        self.high_volume = high_volume
        self.min_gini = min_gini
        self.dbg_gini = dbg_gini
        self.history: list[PolicyRecord] = []

    # ------------------------------------------------------------- decide
    def _predict_gain(self, probes: GraphProbes, scheme: str) -> float:
        """Probe-only payoff model: skew × hub mass × scheme strength."""
        skew = min(probes.degree_gini * (0.5 + probes.hub_mass), 1.0)
        return round(skew * _SCHEME_STRENGTH[scheme], 4)

    def decide(self, probes: GraphProbes,
               expected_queries: int) -> PolicyDecision:
        if expected_queries < self.min_queries:
            scheme, kwargs = "original", {}
            reason = (f"volume gate: {expected_queries} expected queries "
                      f"< {self.min_queries}, reorder cannot amortize")
        elif probes.degree_gini < self.min_gini:
            scheme, kwargs = "original", {}
            reason = (f"skew gate: degree gini {probes.degree_gini:.3f} "
                      f"< {self.min_gini}, no hub working set to pack")
        elif expected_queries < self.high_volume:
            if probes.degree_gini < self.dbg_gini:
                scheme, kwargs = "hubcluster", {}
                reason = (f"cheap tier: moderate skew "
                          f"(gini {probes.degree_gini:.3f}), single-pass "
                          f"hub clustering")
            else:
                scheme, kwargs = "dbg", {}
                reason = (f"cheap tier: high skew "
                          f"(gini {probes.degree_gini:.3f}), degree-based "
                          f"grouping")
        else:
            kappa = max(1, (probes.diameter + 1) // 2)
            scheme, kwargs = "lorder", {"kappa": kappa}
            reason = (f"high volume ({expected_queries} >= "
                      f"{self.high_volume}) + skew "
                      f"(gini {probes.degree_gini:.3f}): LOrder with "
                      f"probe-derived kappa = ceil(D/2) = {kappa} "
                      f"(D ~ {probes.diameter})")
        return PolicyDecision(scheme, kwargs, reason,
                              self._predict_gain(probes, scheme))

    # -------------------------------------------------------------- apply
    def reorder_fn(self, decision: PolicyDecision):
        """Resolve the decision to a callable(graph) -> perm."""
        fn = reordering_registry()[decision.scheme]
        return lambda g: fn(g, **decision.kwargs)

    def record(self, graph_id: str, decision: PolicyDecision,
               miss_rate_before: float, miss_rate_after: float,
               reorder_seconds: float) -> PolicyRecord:
        rec = PolicyRecord(graph_id, decision, miss_rate_before,
                           miss_rate_after, reorder_seconds)
        self.history.append(rec)
        return rec
