"""Graph analytics serving engine (docs/engine.md).

Turns the one-shot reproduction benchmarks into a serving system: a
registry of probed graphs, an adaptive reorder policy that decides *when*
and *how* to reorder from cheap structural probes plus expected query
volume, a compile-cached batched executor, and a session front-end with
an amortization ledger.
"""
from .executor import BatchedExecutor
from .policy import PolicyDecision, PolicyRecord, ReorderPolicy
from .registry import GraphProbes, GraphRegistry, probe_graph
from .session import AmortizationLedger, EngineSession

__all__ = [
    "AmortizationLedger", "BatchedExecutor", "EngineSession",
    "GraphProbes", "GraphRegistry", "PolicyDecision", "PolicyRecord",
    "ReorderPolicy", "probe_graph",
]
