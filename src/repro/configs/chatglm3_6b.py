"""chatglm3-6b [dense]: 28L d4096 32H (GQA kv=2) ff13696 v65024 — RoPE 2d
(partial rotary on half the head dims), GQA. [arXiv:2406.12793; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    num_layers=28, d_model=4096, num_heads=32, num_kv_heads=2,
    d_ff=13696, vocab_size=65024,
    rotary_pct=0.5,            # chatglm's 2-D RoPE: rotate half the dims
    rope_theta=10_000.0,
    qkv_bias=True,             # chatglm: add_qkv_bias
    mlp_type="swiglu", norm_type="rmsnorm",
    vocab_reorder=True, hot_vocab_fraction=0.05,
)
