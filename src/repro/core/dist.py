"""Distributed graph engine: 1-D edge-partitioned kernels via shard_map.

Scales the paper's workload to cluster meshes: edges are partitioned by
destination range (each shard owns a contiguous dst range = its slice of
the property array); a traversal step is

    local gather (remote props via all-gather) -> local segment-reduce

which is the pull-mode pattern of the paper mapped onto jax collectives.
After LOrder, hot vertices are concentrated in low id ranges, so the
all-gather payload that every shard actually *uses* is concentrated in a
small prefix — the cluster-level analogue of cache-line locality.

The **hot-prefix exchange** (`hot_prefix_fraction` on the traversal
factories) exploits it: every step all-gathers only the first
``h_local = ceil(fraction * per)`` entries of each shard's property
slice; the cold remainder is refreshed by a full exchange every
``cold_every`` steps and read from a per-shard stale cache in between.
This is only applied to the *monotone min-relaxation* kernels (BFS as
unit-weight Bellman-Ford, SSSP, CC label propagation): their state only
ever decreases, so relaxing against stale — i.e. older, hence larger —
remote values can never commit a wrong result, only delay convergence.
Termination requires a **full** exchange step that changes nothing, so
the returned fixed point is exactly the single-device result. PageRank
and BC are level/iteration-synchronous and always exchange in full.
`ExchangeStats` accounts the per-step exchanged bytes either way.

**Fused drivers** (``fused=True``, the default): the whole traversal —
step loop, per-step collective, hot/cold cadence and the convergence
test — runs as one ``jax.lax.while_loop`` inside a single
``shard_map``-ped jit, so an entire BFS/SSSP/CC/PR/BC run compiles to
one ``XLA::While`` and costs **one** host→device dispatch instead of
one per step. Step counts come back in the loop carry and are replayed
into `ExchangeStats` on the host after the launch, so per-step byte
accounting and trace spans are unchanged. ``fused=False`` keeps the
original host-orchestrated loop (one jitted step per iteration) as the
differential reference — tests/test_fused_loops.py asserts the two are
bit-identical for all six kernels.

All six serving kernels have distributed entry points here: PR
(`make_distributed_pagerank`), multi-source BFS/SSSP
(`make_distributed_bfs` / `make_distributed_sssp`), CC by min-label
propagation (`make_distributed_cc`, also serving CC-SV: both converge to
the min-id-per-component labeling), and multi-source BC
(`make_distributed_bc`: BFS forward + sharded path counting + a
src-partitioned dependency-accumulation backward pass).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.6 exposes shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:  # 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

from .csr import Graph


def _shard_map_norep(f, mesh, in_specs, out_specs):
    """shard_map with the replication check off — for steps returning an
    all-gathered (hence genuinely replicated) array under a P(None, ...)
    out_spec, which the static checker cannot infer. The fused drivers
    need it too: their while-carries mix sharded state with replicated
    caches/counters. The kwarg was renamed check_rep -> check_vma across
    jax versions."""
    try:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
    except TypeError:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)


def _partition_coo(src, dst, num_vertices: int, num_shards: int,
                   edge_values=None):
    """Split raw COO edges by dst range; pad shards to equal edge counts.

    Returns ``(src_pad, dst_pad, valid, per[, values_pad])`` where
    ``src_pad`` keeps *global* ids, ``dst_pad`` is localized to each
    shard's ``[i*per, (i+1)*per)`` range, and ``valid`` masks padding.
    Swapping the ``src``/``dst`` arguments partitions by source instead
    (used by the BC backward pass, which accumulates at src).
    """
    per = -(-num_vertices // num_shards)  # dst ids [i*per, (i+1)*per)
    src = np.asarray(src, dtype=np.int32)
    dst = np.asarray(dst, dtype=np.int32)
    shard_of = dst // per
    order = np.argsort(shard_of, kind="stable")
    src, dst = src[order], dst[order]
    counts = np.bincount(shard_of[order], minlength=num_shards)
    emax = int(counts.max()) if counts.size else 0
    s_pad = np.zeros((num_shards, emax), np.int32)
    d_pad = np.zeros((num_shards, emax), np.int32)
    valid = np.zeros((num_shards, emax), bool)
    if edge_values is not None:
        vals = np.asarray(edge_values)[order]
        v_pad = np.zeros((num_shards, emax), vals.dtype)
    off = 0
    for i, c in enumerate(counts):
        s_pad[i, :c] = src[off:off + c]
        d_pad[i, :c] = dst[off:off + c] - i * per  # local dst index
        valid[i, :c] = True
        if edge_values is not None:
            v_pad[i, :c] = vals[off:off + c]
        off += c
    if edge_values is not None:
        return s_pad, d_pad, valid, per, v_pad
    return s_pad, d_pad, valid, per


def partition_edges(g: Graph, num_shards: int, edge_values=None):
    """Split a graph's COO edges by dst range; pad shards equally.

    ``edge_values`` (optional, aligned with the graph's out-CSR edge
    order, e.g. SSSP weights) is partitioned identically and returned as
    a fifth array.
    """
    return _partition_coo(g.edge_src, g.indices, g.num_vertices, num_shards,
                          edge_values=edge_values)


# ---------------------------------------------------------- exchange stats
@dataclasses.dataclass
class ExchangeStats:
    """Per-step collective payload accounting for the sharded kernels.

    A "step" is one traversal iteration that all-gathers vertex property
    state. Bytes count what one device *receives* per step:
    ``(num_shards - 1) * slab_bytes`` — the remote share of the gathered
    array. ``bytes_full_equivalent`` books what the same step would have
    cost with a full exchange, so the hot-prefix saving is
    ``1 - bytes_exchanged / bytes_full_equivalent``.

    ``dispatches`` counts host→device launches: with host-loop drivers
    that is one per step (plus prep launches), with fused drivers one per
    run — the collapse the fused benchmark phase demonstrates.
    """

    steps_full: int = 0
    steps_hot: int = 0
    bytes_full: int = 0
    bytes_hot: int = 0
    bytes_full_equivalent: int = 0
    dispatches: int = 0
    # optional per-step observer ``(mode, nbytes, full_nbytes) -> None``:
    # the engine's sharded backend points this at its tracer while a run
    # is live, so every exchange becomes one trace span (engine/obs.py)
    # without dist growing an engine dependency. Fused runs replay their
    # device-side step counts through here right after the launch.
    span_sink: object = dataclasses.field(default=None, compare=False,
                                          repr=False)

    def record_full(self, nbytes: int) -> None:
        self.steps_full += 1
        self.bytes_full += nbytes
        self.bytes_full_equivalent += nbytes
        if self.span_sink is not None:
            self.span_sink("full", nbytes, nbytes)

    def record_hot(self, nbytes: int, full_nbytes: int) -> None:
        self.steps_hot += 1
        self.bytes_hot += nbytes
        self.bytes_full_equivalent += full_nbytes
        if self.span_sink is not None:
            self.span_sink("hot", nbytes, full_nbytes)

    def record_dispatch(self, n: int = 1) -> None:
        self.dispatches += n

    def record_run(self, steps_full: int, steps_hot: int,
                   full_nbytes: int, hot_nbytes: int) -> None:
        """Replay a fused run's device-side step counts one step at a
        time, so per-step accounting (and the span_sink) see the same
        sequence of records the host-loop driver would have produced."""
        for _ in range(int(steps_full)):
            self.record_full(full_nbytes)
        for _ in range(int(steps_hot)):
            self.record_hot(hot_nbytes, full_nbytes)

    def snapshot(self) -> tuple:
        """Counter tuple for per-run attribution (see ``delta``)."""
        return (self.steps_full, self.steps_hot, self.bytes_full,
                self.bytes_hot, self.bytes_full_equivalent, self.dispatches)

    def delta(self, since: tuple) -> "ExchangeStats":
        """Stats accumulated since ``snapshot()`` — the exchange cost of
        exactly one runner invocation when runs are serial, which is how
        the scheduler attributes collective bytes to individual requests
        instead of only the backend-level aggregate."""
        now = self.snapshot()
        return ExchangeStats(*(a - b for a, b in zip(now, since)))

    @property
    def steps(self) -> int:
        return self.steps_full + self.steps_hot

    @property
    def bytes_exchanged(self) -> int:
        return self.bytes_full + self.bytes_hot

    @property
    def bytes_per_step(self) -> float:
        return self.bytes_exchanged / max(self.steps, 1)

    @property
    def savings_fraction(self) -> float:
        if self.bytes_full_equivalent <= 0:
            return 0.0
        return 1.0 - self.bytes_exchanged / self.bytes_full_equivalent

    def as_dict(self) -> dict:
        return {
            "steps": self.steps,
            "steps_full": self.steps_full,
            "steps_hot": self.steps_hot,
            "bytes_full": self.bytes_full,
            "bytes_hot": self.bytes_hot,
            "bytes_exchanged": self.bytes_exchanged,
            "bytes_full_equivalent": self.bytes_full_equivalent,
            "bytes_per_step": round(self.bytes_per_step, 1),
            "savings_fraction": round(self.savings_fraction, 4),
            "dispatches": self.dispatches,
        }


def make_distributed_pagerank(g: Graph, mesh: Mesh, axis: str = "data",
                              damping: float = 0.85, num_iters: int = 20,
                              stats: ExchangeStats | None = None,
                              fused: bool = True):
    """Returns (step_fn, initial_rank) running PR over `axis` of `mesh`.

    ``fused=True`` runs all ``num_iters`` power iterations inside one
    ``lax.fori_loop`` under a single shard_map'd jit (one dispatch);
    ``fused=False`` is the host-loop reference (one dispatch per
    iteration).
    """
    num_shards = mesh.shape[axis]
    s_pad, d_pad, valid, per = partition_edges(g, num_shards)
    n = g.num_vertices
    n_pad = per * num_shards
    outdeg = np.maximum(np.asarray(g.out_degree, np.float32), 1.0)
    outdeg_pad = np.ones(n_pad, np.float32)
    outdeg_pad[:n] = outdeg
    dangling_pad = np.zeros(n_pad, np.float32)
    dangling_pad[:n] = (np.asarray(g.out_degree) == 0).astype(np.float32)

    espec = NamedSharding(mesh, P(axis, None))
    vspec = NamedSharding(mesh, P(axis))
    s_sh = jax.device_put(s_pad, espec)
    d_sh = jax.device_put(d_pad, espec)
    v_sh = jax.device_put(valid, espec)
    deg_sh = jax.device_put(outdeg_pad, vspec)
    dang_sh = jax.device_put(dangling_pad, vspec)

    def _iterate(rank, src_e, dst_e, val_e, deg, dang):
        # rank: (per,) local shard.  all-gather the full property array —
        # the collective whose *useful* payload LOrder concentrates.
        full = jax.lax.all_gather(rank, axis, tiled=True)       # (n_pad,)
        full_deg = jax.lax.all_gather(deg, axis, tiled=True)
        contrib = jnp.where(val_e[0], full[src_e[0]] / full_deg[src_e[0]], 0.0)
        summed = jax.ops.segment_sum(contrib, dst_e[0], num_segments=per)
        # dangling mass redistributed uniformly (GAP semantics)
        dangling = jax.lax.psum(jnp.sum(rank * dang), axis)
        return (1.0 - damping) / n + damping * (summed + dangling / n)

    def step(rank, src_e, dst_e, val_e, deg, dang):
        return _iterate(rank, src_e, dst_e, val_e, deg, dang)[None]

    sharded_step = jax.jit(_shard_map(
        step, mesh=mesh,
        in_specs=(P(axis), P(axis, None), P(axis, None), P(axis, None),
                  P(axis), P(axis)),
        out_specs=P(axis, None),
    ))

    def fused_run_fn(rank, src_e, dst_e, val_e, deg, dang):
        def body(_, r):
            return _iterate(r, src_e, dst_e, val_e, deg, dang)
        return jax.lax.fori_loop(0, num_iters, body, rank)

    sharded_fused = jax.jit(_shard_map_norep(
        fused_run_fn, mesh=mesh,
        in_specs=(P(axis), P(axis, None), P(axis, None), P(axis, None),
                  P(axis), P(axis)),
        out_specs=P(axis),
    ))

    # PR's power iteration is synchronous: every step needs a consistent
    # full view, so there is no hot-prefix variant — two f32 gathers
    # (rank + outdeg) per iteration, accounted in full.
    iter_bytes = 2 * (num_shards - 1) * per * 4

    def run(rank0=None):
        r = rank0 if rank0 is not None else jax.device_put(
            np.full(n_pad, 1.0 / n, np.float32), vspec)
        if fused:
            r = sharded_fused(r, s_sh, d_sh, v_sh, deg_sh, dang_sh)
            if stats is not None:
                stats.record_dispatch()
                stats.record_run(num_iters, 0, iter_bytes, 0)
            return r[:n]
        for _ in range(num_iters):
            r = sharded_step(r, s_sh, d_sh, v_sh, deg_sh,
                             dang_sh).reshape(n_pad)
            if stats is not None:
                stats.record_dispatch()
                stats.record_full(iter_bytes)
        return r[:n]

    return run, vspec


def lower_distributed_pagerank(g: Graph, mesh: Mesh, axis: str = "data"):
    """Lower+compile one sharded PR step (dry-run hook for the graph engine)."""
    run, _ = make_distributed_pagerank(g, mesh, axis, num_iters=1)
    return run


# ------------------------------------------------- multi-source traversals
#
# Serving parity with the single-device engine: batched BFS / SSSP / CC /
# BC where the (S, V) property matrix is sharded along the *vertex* axis
# and each level/relaxation step all-gathers it. The outer iteration is
# either a single on-device `lax.while_loop` (fused, one launch per run)
# or a host loop with a device-side convergence flag (the reference) —
# bounded by eccentricity (BFS) or V (Bellman-Ford) either way.

_INF_I32 = np.int32(2**31 - 1)


def _put_state(values: np.ndarray, mesh: Mesh, axis: str):
    """Upload an (S, n_pad) property matrix sharded over its vertex axis."""
    return jax.device_put(values, NamedSharding(mesh, P(None, axis)))


# ------------------------------------------- hot-prefix min-relaxation core
def _make_minrelax_runner(coo_src, coo_dst, edge_w, num_vertices: int,
                          mesh: Mesh, axis: str,
                          hot_prefix_fraction: float | None = None,
                          cold_every: int = 4,
                          stats: ExchangeStats | None = None,
                          fused: bool = True):
    """Generic monotone min-relaxation to a fixed point over shard_map.

    State is an int32 ``(S, n_pad)`` matrix sharded on the vertex axis;
    one step relaxes ``state[dst] = min(state[dst], state[src] + w)`` over
    the dst-partitioned edge set. With ``hot_prefix_fraction`` set, hot
    steps gather only each shard's first ``h_local`` entries and read the
    cold remainder from the cache left by the last full exchange; the
    shard's *own* slice is always read live. Because state is monotone
    non-increasing, stale (older = larger) remote values can only delay a
    relaxation, never commit a wrong one — and the loop terminates only
    when a **full**-exchange step changes nothing, i.e. at the exact
    global fixed point.

    ``fused=True`` puts the whole loop — including the full/hot cadence
    (``lax.cond`` over the two gather shapes) and the termination test —
    inside one ``lax.while_loop`` under a single shard_map'd jit: one
    XLA::While, one dispatch per run. The step sequence is identical to
    the ``fused=False`` host loop, so results are bit-identical.

    Returns ``run(state0) -> (S, n_pad) final state`` with
    ``run.h_local``, ``run.per``, ``run.hot_prefix_fraction`` and the
    static ``run.prefix_hit_rate`` (fraction of edge-source reads served
    fresh: local to the shard, or inside the gathered hot prefix).
    """
    num_shards = mesh.shape[axis]
    cold_every = max(int(cold_every), 1)
    s_pad, d_pad, valid, per, w_pad = _partition_coo(
        coo_src, coo_dst, num_vertices, num_shards,
        edge_values=np.asarray(edge_w, np.int32))
    n_pad = per * num_shards
    f = hot_prefix_fraction
    h_local = per if f is None else min(per, max(1, int(np.ceil(f * per))))
    # distance info crosses at least one hop per full exchange even in
    # the worst case, so the fixed point is reached well inside
    # V * cold_every steps; the bound is a backstop, not the driver
    max_iters = num_vertices * cold_every + cold_every + 2

    espec = NamedSharding(mesh, P(axis, None))
    s_sh = jax.device_put(s_pad, espec)
    d_sh = jax.device_put(d_pad, espec)
    v_sh = jax.device_put(valid, espec)
    w_sh = jax.device_put(w_pad, espec)

    def _relax(state, view, src_e, dst_e, val_e, w_e):
        du = view[:, src_e[0]]                               # (S, e_local)
        cand = jnp.where(val_e[0] & (du != _INF_I32), du + w_e[0], _INF_I32)
        relaxed = jax.vmap(
            lambda c: jax.ops.segment_min(c, dst_e[0], num_segments=per)
        )(cand)
        new = jnp.minimum(state, relaxed)
        # replicated convergence flag, as the P() out_spec requires
        changed = jax.lax.psum((new != state).any().astype(jnp.int32), axis)
        return new, changed > 0

    def _gather_full(state):
        return jax.lax.all_gather(state, axis, axis=1, tiled=True)

    def _hot_view(state, cache):
        # gather only the hot prefix of every shard's slice ...
        fresh = jax.lax.all_gather(state[:, :h_local], axis,
                                   axis=0, tiled=False)  # (shards, S, h)
        view = cache.reshape(cache.shape[0], num_shards, per)
        view = view.at[:, :, :h_local].set(jnp.transpose(fresh, (1, 0, 2)))
        # ... and read the shard's own slice live, not from the cache
        view = jax.lax.dynamic_update_slice_in_dim(
            view, state[:, None, :], jax.lax.axis_index(axis), axis=1)
        return view.reshape(cache.shape[0], n_pad)

    def step_full(state, src_e, dst_e, val_e, w_e):
        full = _gather_full(state)
        new, changed = _relax(state, full, src_e, dst_e, val_e, w_e)
        # the gathered view doubles as the cold cache until the next full
        # exchange; identical on every shard, hence the replicated spec
        return new, full, changed

    sharded_full = jax.jit(_shard_map_norep(
        step_full, mesh=mesh,
        in_specs=(P(None, axis), P(axis, None), P(axis, None),
                  P(axis, None), P(axis, None)),
        out_specs=(P(None, axis), P(None, None), P()),
    ))

    def step_hot(state, cache, src_e, dst_e, val_e, w_e):
        return _relax(state, _hot_view(state, cache),
                      src_e, dst_e, val_e, w_e)

    sharded_hot = jax.jit(_shard_map(
        step_hot, mesh=mesh,
        in_specs=(P(None, axis), P(None, None), P(axis, None),
                  P(axis, None), P(axis, None), P(axis, None)),
        out_specs=(P(None, axis), P()),
    ))

    # ---------------------------------------------------- fused driver
    def fused_fn(state, src_e, dst_e, val_e, w_e):
        # carry: (state, cache, it, full_due, done, steps_full, steps_hot)
        # — the exact control variables of the host loop below, moved
        # into the While carry so the cadence and the termination test
        # compile into the loop. `is_full`/`done` derive from psum'd
        # flags, hence replicated, so lax.cond may hold a collective in
        # each branch. With no hot prefix configured the cadence is
        # static — every step is full — so that case compiles without
        # the cond or the (S, n_pad) cache in the carry.
        if f is None:
            def cond(c):
                _, done, it, _ = c
                return ~done & (it < max_iters)

            def body(c):
                st, _, it, sf = c
                new, _, changed = step_full(st, src_e, dst_e, val_e, w_e)
                return new, ~changed, it + 1, sf + 1

            state, _, _, sf = jax.lax.while_loop(
                cond, body,
                (state, jnp.bool_(False), jnp.int32(0), jnp.int32(0)))
            return state, sf, jnp.int32(0)

        s_rows = state.shape[0]
        cache0 = jnp.zeros((s_rows, n_pad), jnp.int32)

        def full_branch(st, cache):
            new, full, changed = step_full(st, src_e, dst_e, val_e, w_e)
            return new, full, changed

        def hot_branch(st, cache):
            new, changed = step_hot(st, cache, src_e, dst_e, val_e, w_e)
            return new, cache, changed

        def cond(c):
            _, _, it, _, done, _, _ = c
            return ~done & (it < max_iters)

        def body(c):
            st, cache, it, full_due, _, sf, sh = c
            is_full = full_due | (it % cold_every == 0)
            st, cache, changed = jax.lax.cond(
                is_full, full_branch, hot_branch, st, cache)
            done = is_full & ~changed
            full_due = jnp.where(is_full, False, ~changed)
            return (st, cache, it + 1, full_due, done,
                    sf + is_full.astype(jnp.int32),
                    sh + (~is_full).astype(jnp.int32))

        init = (state, cache0, jnp.int32(0), jnp.bool_(True),
                jnp.bool_(False), jnp.int32(0), jnp.int32(0))
        state, _, _, _, _, sf, sh = jax.lax.while_loop(cond, body, init)
        return state, sf, sh

    sharded_fused = jax.jit(_shard_map_norep(
        fused_fn, mesh=mesh,
        in_specs=(P(None, axis), P(axis, None), P(axis, None),
                  P(axis, None), P(axis, None)),
        out_specs=(P(None, axis), P(), P()),
    ))

    def run(state0):
        s = int(np.asarray(state0).shape[0])
        state = _put_state(np.asarray(state0, np.int32), mesh, axis)
        full_b = (num_shards - 1) * per * 4 * s
        hot_b = (num_shards - 1) * h_local * 4 * s
        if fused:
            state, sf, sh = sharded_fused(state, s_sh, d_sh, v_sh, w_sh)
            if stats is not None:
                stats.record_dispatch()
                stats.record_run(int(sf), int(sh), full_b, hot_b)
            return state
        cache = None
        full_due = True
        for it in range(max_iters):
            if f is None or full_due or it % cold_every == 0:
                state, cache, changed = sharded_full(state, s_sh, d_sh,
                                                     v_sh, w_sh)
                if stats is not None:
                    stats.record_dispatch()
                    stats.record_full(full_b)
                full_due = False
                if not bool(changed):
                    break  # fixed point certified against the full view
            else:
                state, changed = sharded_hot(state, cache, s_sh, d_sh,
                                             v_sh, w_sh)
                if stats is not None:
                    stats.record_dispatch()
                    stats.record_hot(hot_b, full_b)
                if not bool(changed):
                    full_due = True  # locally quiesced: verify in full
        return state

    if f is None:
        run.prefix_hit_rate = 1.0
    else:
        own = (s_pad // per) == np.arange(num_shards)[:, None]
        hit = (own | ((s_pad % per) < h_local)) & valid
        nvalid = int(valid.sum())
        run.prefix_hit_rate = float(hit.sum() / nvalid) if nvalid else 1.0
    run.h_local, run.per, run.hot_prefix_fraction = h_local, per, f
    return run


def _copy_prefix_attrs(run, relax) -> None:
    run.prefix_hit_rate = relax.prefix_hit_rate
    run.h_local, run.per = relax.h_local, relax.per
    run.hot_prefix_fraction = relax.hot_prefix_fraction


# ------------------------------------------------------------------- BFS
def _make_bfs_frontier(g: Graph, mesh: Mesh, axis: str,
                       stats: ExchangeStats | None, fused: bool = True):
    """Level-synchronous frontier BFS; returns run(sources) -> sharded
    (S, n_pad) depth (the full-exchange path, also BC's forward pass)."""
    num_shards = mesh.shape[axis]
    s_pad, d_pad, valid, per = partition_edges(g, num_shards)
    n, n_pad = g.num_vertices, per * num_shards
    espec = NamedSharding(mesh, P(axis, None))
    s_sh = jax.device_put(s_pad, espec)
    d_sh = jax.device_put(d_pad, espec)
    v_sh = jax.device_put(valid, espec)

    def step(depth, front, level, src_e, dst_e, val_e):
        # depth/front: (S, per) local vertex slices; edges: (1, e_local)
        full_front = jax.lax.all_gather(front, axis, axis=1, tiled=True)
        active = full_front[:, src_e[0]] & val_e[0]           # (S, e_local)
        touched = jax.vmap(
            lambda a: jax.ops.segment_max(a, dst_e[0], num_segments=per)
        )(active)
        new = touched & (depth < 0)
        depth = jnp.where(new, level + 1, depth)
        # replicated scalar per the P() out_spec: the loop predicate (or
        # the host loop) reads one flag instead of reducing the whole
        # sharded frontier each level
        alive = jax.lax.psum(new.any().astype(jnp.int32), axis)
        return depth, new, alive > 0

    sharded_step = jax.jit(_shard_map(
        step, mesh=mesh,
        in_specs=(P(None, axis), P(None, axis), P(),
                  P(axis, None), P(axis, None), P(axis, None)),
        out_specs=(P(None, axis), P(None, axis), P()),
    ))

    def fused_fn(depth, front, src_e, dst_e, val_e):
        def cond(c):
            _, _, level, alive = c
            return alive & (level < n)

        def body(c):
            depth, front, level, _ = c
            depth, front, alive = step(depth, front, level,
                                       src_e, dst_e, val_e)
            return depth, front, level + 1, alive

        # do-while: the initial frontier is never empty (sources exist)
        depth, _, steps, _ = jax.lax.while_loop(
            cond, body, (depth, front, jnp.int32(0), jnp.bool_(True)))
        return depth, steps

    sharded_fused = jax.jit(_shard_map_norep(
        fused_fn, mesh=mesh,
        in_specs=(P(None, axis), P(None, axis),
                  P(axis, None), P(axis, None), P(axis, None)),
        out_specs=(P(None, axis), P()),
    ))

    def run_full(sources):
        srcs = np.atleast_1d(np.asarray(sources, np.int64))
        s = srcs.size
        depth0 = np.full((s, n_pad), -1, np.int32)
        depth0[np.arange(s), srcs] = 0
        front0 = np.zeros((s, n_pad), bool)
        front0[np.arange(s), srcs] = True
        depth = _put_state(depth0, mesh, axis)
        front = _put_state(front0, mesh, axis)
        level_bytes = (num_shards - 1) * per * 1 * s  # bool frontier
        if fused:
            depth, steps = sharded_fused(depth, front, s_sh, d_sh, v_sh)
            if stats is not None:
                stats.record_dispatch()
                stats.record_run(int(steps), 0, level_bytes, 0)
            return depth
        # do-while: the initial frontier is never empty (sources exist)
        for level in range(n):
            depth, front, alive = sharded_step(depth, front,
                                               jnp.int32(level),
                                               s_sh, d_sh, v_sh)
            if stats is not None:
                stats.record_dispatch()
                stats.record_full(level_bytes)
            if not bool(alive):
                break
        return depth

    run_full.per = per
    # the dst-partitioned edge uploads and the raw per-shard step body,
    # reusable by passes that share the same partition (BC's forward σ
    # pass, and BC's fully-fused driver) — one partition, one upload
    run_full.edge_shards = (s_sh, d_sh, v_sh)
    run_full.step_fn = step
    return run_full


def make_distributed_bfs(g: Graph, mesh: Mesh, axis: str = "data",
                         hot_prefix_fraction: float | None = None,
                         cold_every: int = 4,
                         stats: ExchangeStats | None = None,
                         fused: bool = True):
    """Returns run(sources) -> (S, V) BFS depths over `axis` of `mesh`.

    With ``hot_prefix_fraction`` set, BFS runs as unit-weight Bellman-Ford
    through the hot-prefix min-relaxation driver (exact depths; the level
    counter of the frontier formulation cannot tolerate stale frontiers,
    min-relaxation can). Without it, the level-synchronous frontier path
    exchanges the full frontier every step.
    """
    n = g.num_vertices
    if hot_prefix_fraction is None:
        run_full = _make_bfs_frontier(g, mesh, axis, stats, fused=fused)

        def run(sources):
            return run_full(sources)[:, :n]

        run.prefix_hit_rate, run.hot_prefix_fraction = 1.0, None
        run.per = run_full.per
        run.h_local = run_full.per
        return run

    unit = np.ones(g.num_edges, np.int32)
    relax = _make_minrelax_runner(g.edge_src, g.indices, unit, n, mesh, axis,
                                  hot_prefix_fraction, cold_every, stats,
                                  fused=fused)
    n_pad = relax.per * mesh.shape[axis]

    def run(sources):
        srcs = np.atleast_1d(np.asarray(sources, np.int64))
        state0 = np.full((srcs.size, n_pad), _INF_I32, np.int32)
        state0[np.arange(srcs.size), srcs] = 0
        dist = relax(state0)
        return jnp.where(dist == _INF_I32, -1, dist)[:, :n]

    _copy_prefix_attrs(run, relax)
    return run


def make_distributed_sssp(g: Graph, mesh: Mesh, axis: str = "data",
                          canonical_ids=None,
                          hot_prefix_fraction: float | None = None,
                          cold_every: int = 4,
                          stats: ExchangeStats | None = None,
                          fused: bool = True):
    """Returns run(sources) -> (S, V) Bellman-Ford distances.

    Weights are the engine's canonical per-edge hash
    (`algos.graph_arrays.edge_weights`, relabel-invariant through
    ``canonical_ids``), so sharded distances match the single-device
    executor exactly — with or without the hot-prefix exchange
    (Bellman-Ford is monotone, see `_make_minrelax_runner`). Both the
    full-exchange and hot-prefix paths run through the min-relaxation
    driver (with ``hot_prefix_fraction=None`` every step is a full
    exchange), so SSSP gets the fused single-dispatch loop for free.
    """
    from ..algos.graph_arrays import edge_weights

    n = g.num_vertices
    w = edge_weights(g.edge_src, g.indices, canonical_ids)
    relax = _make_minrelax_runner(g.edge_src, g.indices, w, n, mesh, axis,
                                  hot_prefix_fraction, cold_every, stats,
                                  fused=fused)
    n_pad = relax.per * mesh.shape[axis]

    def run(sources):
        srcs = np.atleast_1d(np.asarray(sources, np.int64))
        state0 = np.full((srcs.size, n_pad), _INF_I32, np.int32)
        state0[np.arange(srcs.size), srcs] = 0
        return relax(state0)[:, :n]

    _copy_prefix_attrs(run, relax)
    return run


# -------------------------------------------------- Connected Components
def make_distributed_cc(g: Graph, mesh: Mesh, axis: str = "data",
                        hot_prefix_fraction: float | None = None,
                        cold_every: int = 4,
                        stats: ExchangeStats | None = None,
                        fused: bool = True):
    """Returns run() -> (V,) min-label CC over the symmetrized edges.

    Min-label propagation is a monotone min-relaxation (weight 0 over the
    symmetrized edge set), so it runs through the same driver as the
    hot-prefix traversals — with ``hot_prefix_fraction`` unset every step
    is a full exchange. Converges to the min-vertex-id-per-component
    labeling, bit-identical to `algos.kernels.cc_labelprop`; CC-SV
    reaches the same labeling, so this runner serves both cc and ccsv.
    """
    n = g.num_vertices
    src = np.concatenate([np.asarray(g.edge_src), np.asarray(g.indices)])
    dst = np.concatenate([np.asarray(g.indices), np.asarray(g.edge_src)])
    relax = _make_minrelax_runner(src, dst, np.zeros(src.size, np.int32), n,
                                  mesh, axis, hot_prefix_fraction,
                                  cold_every, stats, fused=fused)
    n_pad = relax.per * mesh.shape[axis]

    def run():
        lab0 = np.arange(n_pad, dtype=np.int32)[None, :]
        return relax(lab0)[0, :n]

    _copy_prefix_attrs(run, relax)
    return run


# -------------------------------------------- Betweenness Centrality (BC)
def make_distributed_bc(g: Graph, mesh: Mesh, axis: str = "data",
                        stats: ExchangeStats | None = None,
                        fused: bool = True):
    """Returns run(sources) -> (S, V) per-source Brandes dependencies.

    Three sharded passes, mirroring `algos.kernels.bc_single_source`:

    1. **forward depths** — the frontier BFS above, kept sharded;
    2. **path counts** — per level, all-gather sigma and segment-sum the
       tree-edge contributions into local dst (edges partitioned by dst);
    3. **dependency accumulation** — per level backwards, all-gather
       delta and accumulate ``sigma[u]/sigma[v] * (1 + delta[v])`` into
       local src over a *source-partitioned* copy of the edges (the
       backward pass scatters to src, so dst-partitioned edges would
       need a cross-shard scatter).

    ``fused=True`` compiles all three passes — BFS While, σ While, δ
    While, with ``max_level`` carried as a traced pmax instead of a host
    round-trip — into **one** shard_map'd jit: a whole multi-source BC
    run is a single dispatch. ``fused=False`` keeps the per-level host
    loops as the reference.

    Level-synchronous float accumulation: no hot-prefix variant (the
    per-level sums need a consistent view), and results are numerically
    close — not bit-identical — to the single-device kernel because the
    segment-sum order differs.
    """
    num_shards = mesh.shape[axis]
    n = g.num_vertices
    bfs_full = _make_bfs_frontier(g, mesh, axis, stats, fused=fused)
    per = bfs_full.per
    n_pad = per * num_shards

    espec = NamedSharding(mesh, P(axis, None))
    # forward: dst-partitioned (sigma accumulates at dst) — the exact
    # partition the frontier BFS already uploaded, so reuse it
    s_sh, d_sh, v_sh = bfs_full.edge_shards
    bfs_step = bfs_full.step_fn
    # backward: src-partitioned (delta accumulates at src); swapping the
    # COO roles localizes src and keeps dst global
    bd_pad, bs_pad, bvalid, per_b = _partition_coo(g.indices, g.edge_src, n,
                                                   num_shards)
    assert per_b == per
    bd_sh = jax.device_put(bd_pad, espec)   # global dst ids
    bs_sh = jax.device_put(bs_pad, espec)   # local src indices
    bv_sh = jax.device_put(bvalid, espec)

    def fwd_prep(depth, src_e, dst_e, val_e):
        full_depth = jax.lax.all_gather(depth, axis, axis=1, tiled=True)
        du = full_depth[:, src_e[0]]                      # (S, e_local)
        dv = depth[:, dst_e[0]]                           # dst is local
        tree = (dv == du + 1) & (du >= 0) & val_e[0]
        return du, tree

    sharded_fwd_prep = jax.jit(_shard_map(
        fwd_prep, mesh=mesh,
        in_specs=(P(None, axis), P(axis, None), P(axis, None),
                  P(axis, None)),
        out_specs=(P(None, axis), P(None, axis)),
    ))

    def fwd_step(sigma, du, tree, src_e, dst_e, level):
        full_sigma = jax.lax.all_gather(sigma, axis, axis=1, tiled=True)
        add_e = jnp.where(tree & (du == level),
                          full_sigma[:, src_e[0]], 0.0)
        add = jax.vmap(
            lambda c: jax.ops.segment_sum(c, dst_e[0], num_segments=per)
        )(add_e)
        return sigma + add

    sharded_fwd_step = jax.jit(_shard_map(
        fwd_step, mesh=mesh,
        in_specs=(P(None, axis), P(None, axis), P(None, axis),
                  P(axis, None), P(axis, None), P()),
        out_specs=P(None, axis),
    ))

    def bwd_prep(depth, sigma, bsrc_e, bdst_e, bval_e):
        full_depth = jax.lax.all_gather(depth, axis, axis=1, tiled=True)
        du = depth[:, bsrc_e[0]]                          # src is local
        dv = full_depth[:, bdst_e[0]]
        tree = (dv == du + 1) & (du >= 0) & bval_e[0]
        # sigma is fixed during the backward pass: gather it once and
        # keep the replicated copy instead of re-gathering per level
        sig_full = jax.lax.all_gather(sigma, axis, axis=1, tiled=True)
        return du, tree, sig_full

    sharded_bwd_prep = jax.jit(_shard_map_norep(
        bwd_prep, mesh=mesh,
        in_specs=(P(None, axis), P(None, axis), P(axis, None),
                  P(axis, None), P(axis, None)),
        out_specs=(P(None, axis), P(None, axis), P(None, None)),
    ))

    def bwd_step(delta, sig_full, du, tree, bsrc_e, bdst_e, level):
        full_delta = jax.lax.all_gather(delta, axis, axis=1, tiled=True)
        mask = tree & (du == level)
        base = jax.lax.axis_index(axis) * per
        sig_u = sig_full[:, base + bsrc_e[0]]
        sig_v = jnp.maximum(sig_full[:, bdst_e[0]], 1e-30)
        contrib = jnp.where(
            mask, sig_u / sig_v * (1.0 + full_delta[:, bdst_e[0]]), 0.0)
        add = jax.vmap(
            lambda c: jax.ops.segment_sum(c, bsrc_e[0], num_segments=per)
        )(contrib)
        return delta + add

    sharded_bwd_step = jax.jit(_shard_map(
        bwd_step, mesh=mesh,
        in_specs=(P(None, axis), P(None, None), P(None, axis),
                  P(None, axis), P(axis, None), P(axis, None), P()),
        out_specs=P(None, axis),
    ))

    # ---------------------------------------------------- fused driver
    def fused_fn(depth, front, sigma, src_e, dst_e, val_e,
                 bsrc_e, bdst_e, bval_e):
        # pass 1: forward BFS — the same While as _make_bfs_frontier's
        def bfs_cond(c):
            _, _, level, alive = c
            return alive & (level < n)

        def bfs_body(c):
            depth, front, level, _ = c
            depth, front, alive = bfs_step(depth, front, level,
                                           src_e, dst_e, val_e)
            return depth, front, level + 1, alive

        depth, _, bfs_steps, _ = jax.lax.while_loop(
            bfs_cond, bfs_body, (depth, front, jnp.int32(0),
                                 jnp.bool_(True)))
        # the host reference reads max_level back between passes; fused,
        # it is a traced replicated scalar (padded vertices sit at -1, and
        # the source row guarantees a max >= 0)
        max_level = jax.lax.pmax(jnp.max(depth), axis)

        # pass 2: path counts, level-synchronous up to max_level
        du_f, tree_f = fwd_prep(depth, src_e, dst_e, val_e)

        def fwd_body(c):
            sigma, level = c
            return (fwd_step(sigma, du_f, tree_f, src_e, dst_e, level),
                    level + 1)

        sigma, _ = jax.lax.while_loop(
            lambda c: c[1] <= max_level, fwd_body, (sigma, jnp.int32(0)))

        # pass 3: dependency accumulation, levels max_level-1 .. 0
        du_b, tree_b, sig_full = bwd_prep(depth, sigma, bsrc_e, bdst_e,
                                          bval_e)

        def bwd_body(c):
            delta, level = c
            return (bwd_step(delta, sig_full, du_b, tree_b, bsrc_e,
                             bdst_e, level), level - 1)

        delta, _ = jax.lax.while_loop(
            lambda c: c[1] >= 0, bwd_body,
            (jnp.zeros_like(sigma), max_level - 1))
        return delta, bfs_steps, max_level

    sharded_fused = jax.jit(_shard_map_norep(
        fused_fn, mesh=mesh,
        in_specs=(P(None, axis), P(None, axis), P(None, axis),
                  P(axis, None), P(axis, None), P(axis, None),
                  P(axis, None), P(axis, None), P(axis, None)),
        out_specs=(P(None, axis), P(), P()),
    ))

    def run(sources):
        srcs = np.atleast_1d(np.asarray(sources, np.int64))
        s = srcs.size
        step_bytes = (num_shards - 1) * per * 4 * s
        level_bytes = (num_shards - 1) * per * 1 * s  # bool frontier
        sigma0 = np.zeros((s, n_pad), np.float32)
        sigma0[np.arange(s), srcs] = 1.0
        if fused:
            depth0 = np.full((s, n_pad), -1, np.int32)
            depth0[np.arange(s), srcs] = 0
            front0 = np.zeros((s, n_pad), bool)
            front0[np.arange(s), srcs] = True
            delta, bfs_steps, max_level = sharded_fused(
                _put_state(depth0, mesh, axis),
                _put_state(front0, mesh, axis),
                _put_state(sigma0, mesh, axis),
                s_sh, d_sh, v_sh, bs_sh, bd_sh, bv_sh)
            max_level = int(max_level)
            if stats is not None:
                # replay the host reference's per-step accounting from
                # the device-side counters: BFS frontier gathers, one
                # fwd_prep, max_level+1 σ gathers, depth+sigma bwd_prep,
                # max_level δ gathers — all in one dispatch
                stats.record_dispatch()
                stats.record_run(int(bfs_steps), 0, level_bytes, 0)
                stats.record_full(step_bytes)
                stats.record_run(max_level + 1, 0, step_bytes, 0)
                stats.record_full(2 * step_bytes)
                stats.record_run(max_level, 0, step_bytes, 0)
        else:
            depth = bfs_full(srcs)                    # (S, n_pad) sharded
            max_level = int(np.asarray(depth[:, :n]).max())
            du_f, tree_f = sharded_fwd_prep(depth, s_sh, d_sh, v_sh)
            sigma = _put_state(sigma0, mesh, axis)
            if stats is not None:
                stats.record_dispatch()
                stats.record_full(step_bytes)         # fwd_prep depth gather
            for level in range(max_level + 1):
                sigma = sharded_fwd_step(sigma, du_f, tree_f, s_sh, d_sh,
                                         jnp.int32(level))
                if stats is not None:
                    stats.record_dispatch()
                    stats.record_full(step_bytes)
            du_b, tree_b, sig_full = sharded_bwd_prep(depth, sigma, bs_sh,
                                                      bd_sh, bv_sh)
            if stats is not None:
                stats.record_dispatch()
                stats.record_full(2 * step_bytes)     # depth + sigma gathers
            delta = _put_state(np.zeros((s, n_pad), np.float32), mesh, axis)
            for level in range(max_level - 1, -1, -1):
                delta = sharded_bwd_step(delta, sig_full, du_b, tree_b,
                                         bs_sh, bd_sh, jnp.int32(level))
                if stats is not None:
                    stats.record_dispatch()
                    stats.record_full(step_bytes)
        out = np.array(delta)[:, :n]
        out[np.arange(s), srcs] = 0.0
        return jnp.asarray(out)

    run.prefix_hit_rate, run.hot_prefix_fraction = 1.0, None
    run.per = per
    run.h_local = per
    return run
