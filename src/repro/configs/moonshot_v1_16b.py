"""moonshot-v1-16b-a3b [moe]: 48L d2048 16H (MHA kv=16) ff1408 v163840 —
64 experts top-6 + shared experts (moonlight/kimi-style fine-grained MoE).
[hf:moonshotai/Moonlight-16B-A3B; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=163_840,
    rope_theta=5e4,
    num_experts=64, experts_per_token=6,
    num_shared_experts=2,
    mlp_type="swiglu", norm_type="rmsnorm",
    vocab_reorder=True, hot_vocab_fraction=0.03,
    moe_locality_sort=True,
)
