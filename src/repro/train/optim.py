"""Optimizer substrate: AdamW + schedules (cosine, minicpm's WSD),
global-norm clipping, and int8 error-feedback gradient compression for the
cross-pod all-reduce (DESIGN.md §5).

No optax dependency — the optimizer is a pure pytree transform so its
state shards exactly like the params under GSPMD.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    schedule: str = "cosine"          # "cosine" | "wsd" | "const"
    wsd_decay_frac: float = 0.1       # WSD: last 10% decays
    microbatch: int = 0               # >0: grad accumulation chunk size
    grad_compress_pod: bool = False   # int8 EF compression on "pod" axis


def schedule_lr(tc: TrainConfig, step):
    """LR at `step` (traced ok)."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(tc.warmup_steps, 1), 1.0)
    if tc.schedule == "cosine":
        t = jnp.clip((step - tc.warmup_steps)
                     / jnp.maximum(tc.total_steps - tc.warmup_steps, 1), 0, 1)
        mult = 0.5 * (1 + jnp.cos(jnp.pi * t))
    elif tc.schedule == "wsd":   # warmup-stable-decay (minicpm)
        decay_start = tc.total_steps * (1 - tc.wsd_decay_frac)
        t = jnp.clip((step - decay_start)
                     / jnp.maximum(tc.total_steps - decay_start, 1), 0, 1)
        mult = jnp.where(step < decay_start, 1.0, 0.5 ** (t * 10))
    else:
        mult = 1.0
    return tc.learning_rate * warm * mult


def init_opt_state(params):
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


def adamw_update(params, grads, opt_state, tc: TrainConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
    step = opt_state["step"] + 1
    lr = schedule_lr(tc, step)
    b1, b2 = tc.beta1, tc.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        update = (mu / bc1) / (jnp.sqrt(nu / bc2) + tc.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            update = update + tc.weight_decay * p
        return p - lr * update, mu, nu

    flat = jax.tree.map(upd, params, grads, opt_state["mu"], opt_state["nu"])
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}


# ------------------------------------------------- gradient compression
def compress_int8(g):
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    scale = jnp.maximum(jnp.abs(g).max(), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


def ef_compressed_psum(grads, errors, axis: str):
    """Error-feedback int8 psum over ``axis`` (the low-bandwidth cross-pod
    link). Residuals accumulate locally so compression noise is unbiased
    over steps. Returns (mean_grads, new_errors). Use inside shard_map."""
    npods = jax.lax.axis_size(axis)

    def one(g, e):
        gc = g.astype(jnp.float32) + e
        q, scale = compress_int8(gc)
        new_e = gc - decompress_int8(q, scale)
        # int8 payload summed over the slow axis (XLA upcasts to wider
        # accumulation as needed); scale summed alongside.
        total = jax.lax.psum(decompress_int8(q, scale), axis)
        return total / npods, new_e

    out = jax.tree.map(one, grads, errors)
    mean = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    errs = jax.tree.map(lambda t: t[1], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    return mean, errs
