"""Adaptive reorder policy: probes + expected query volume -> scheme.

The paper's result (section 5) is a trade-off, not a recommendation:
reordering buys per-traversal speedup proportional to degree skew, at a
one-time cost that only amortizes over enough traversals (Faldu et al.
make the same point for the lightweight schemes). The policy encodes that
trade-off:

* **volume gate** — below ``min_queries`` expected traversals nothing can
  amortize, serve the original layout;
* **skew gate** — low degree Gini (meshes, roads, rings) means no hub
  working set to pack; reordering moves nothing, serve original;
* **cheap tier** — skewed graph but modest volume: a single O(V) pass
  (HubCluster below ``dbg_gini``, DBG above) captures most of the win;
* **expensive tier** — skewed graph and high volume: LOrder with
  κ = ⌈D/2⌉ derived from the registry's diameter probe (paper Table 5.2).

Every decision carries a *predicted* fractional miss-rate reduction,
``skew x strength[scheme]``. The strengths are **calibrated, not
static**: the session records the *realized* reduction from the cache
simulator into a ``StrengthCalibrator`` (see calibration.py), and later
decisions consult the fitted strengths. Once a scheme has enough
observations, a tier's default choice can be overridden by a candidate
whose fitted predicted gain is higher by ``override_margin`` — so a
scheme that consistently mispredicts loses decisions to the one that
actually delivers (the top Engine item in ROADMAP.md).
"""
from __future__ import annotations

import dataclasses

from ..core.baselines import reordering_registry
from .backends import bucket_dims, estimate_device_bytes
from .calibration import DEFAULT_PRIORS, StrengthCalibrator
from .registry import GraphProbes

# Backwards-compatible alias: PR 1 exposed the static strengths here.
# They are now the *priors* of the calibration model (calibration.py).
_SCHEME_STRENGTH = DEFAULT_PRIORS


@dataclasses.dataclass(frozen=True)
class PolicyDecision:
    scheme: str              # key into reordering_registry(), or "visitsort"
    kwargs: dict             # scheme arguments (e.g. probe-derived kappa)
    reason: str              # human-readable rule that fired
    predicted_gain: float    # predicted fractional miss-rate reduction
    skew: float = 0.0        # probe composite the prediction was based on
    backend: str = "single"  # placement: engine.backends name
    # sharded placement only: fraction of each shard's property slice
    # all-gathered every step (None = full exchange every step)
    hot_prefix_fraction: float | None = None
    # what "hot" means for this layout: "degree" (structural probes) or
    # "visits" (serving telemetry, the search-family signal) — determines
    # which skew axis the prediction used and how the hot prefix is kept
    # fresh (session.refresh_hotness patches by visit mask)
    hotness_source: str = "degree"


def decision_changed(old: PolicyDecision | None,
                     new: PolicyDecision | None) -> bool:
    """Whether a fresh decision is materially different from the applied
    one — i.e. whether a mutation warrants an async full reorder. Reasons
    and predicted gains differ on every re-decide; what matters is the
    layout recipe: scheme, its kwargs, placement, exchange fraction, and
    which hotness axis the layout is ordered by.
    """
    if old is None or new is None:
        return old is not new
    return (old.scheme != new.scheme
            or old.kwargs != new.kwargs
            or old.backend != new.backend
            or old.hot_prefix_fraction != new.hot_prefix_fraction
            or old.hotness_source != new.hotness_source)


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """Backpressure contract for the request plane (scheduler.py).

    ``max_pending`` bounds the queue: at the cap an arrival is either
    rejected with `scheduler.AdmissionRejected` (``overload="reject"``)
    or *degraded* — admitted as best-effort with its priority clamped to
    ``degraded_priority`` and its deadline dropped (``"degrade"``).
    Below the cap a *shed* band starts at ``soft_fraction`` of it: when
    the queue is that deep AND the recent deadline-miss rate (a
    `RateWindow` over the last ``miss_window`` deadline outcomes, armed
    after ``min_miss_samples``) is at least ``shed_miss_rate``, new
    best-effort arrivals (no deadline, priority <= 0) are shed so the
    latency-sensitive traffic that is already missing deadlines stops
    queueing behind them.
    """

    max_pending: int = 1024
    overload: str = "reject"      # "reject" | "degrade"
    soft_fraction: float = 0.5
    shed_miss_rate: float = 0.5
    miss_window: int = 64
    min_miss_samples: int = 8
    degraded_priority: int = -1

    def __post_init__(self):
        if self.max_pending < 1:
            raise ValueError("max_pending must be >= 1")
        if self.overload not in ("reject", "degrade"):
            raise ValueError("overload must be 'reject' or 'degrade'")
        if not 0.0 <= self.soft_fraction <= 1.0:
            raise ValueError("soft_fraction must be in [0, 1]")
        if not 0.0 <= self.shed_miss_rate <= 1.0:
            raise ValueError("shed_miss_rate must be in [0, 1]")
        if self.miss_window < 1:
            raise ValueError("miss_window must be >= 1")

    @property
    def soft_limit(self) -> int:
        return max(int(self.max_pending * self.soft_fraction), 1)

    def as_dict(self) -> dict:
        return {
            "max_pending": self.max_pending,
            "overload": self.overload,
            "soft_limit": self.soft_limit,
            "shed_miss_rate": self.shed_miss_rate,
        }


@dataclasses.dataclass
class PolicyRecord:
    """Predicted vs realized benefit for one policy decision."""

    graph_id: str
    decision: PolicyDecision
    miss_rate_before: float
    miss_rate_after: float
    reorder_seconds: float
    family: str = "analytics"   # graph family the outcome belongs to

    @property
    def realized_gain(self) -> float:
        if self.miss_rate_before <= 0:
            return 0.0
        return 1.0 - self.miss_rate_after / self.miss_rate_before

    @property
    def prediction_error(self) -> float:
        return self.decision.predicted_gain - self.realized_gain

    def as_dict(self) -> dict:
        return {
            "graph_id": self.graph_id,
            "family": self.family,
            "scheme": self.decision.scheme,
            "backend": self.decision.backend,
            "hot_prefix_fraction": self.decision.hot_prefix_fraction,
            "kwargs": self.decision.kwargs,
            "reason": self.decision.reason,
            "skew": self.decision.skew,
            "predicted_gain": self.decision.predicted_gain,
            "realized_gain": self.realized_gain,
            "miss_rate_before": self.miss_rate_before,
            "miss_rate_after": self.miss_rate_after,
            "reorder_seconds": self.reorder_seconds,
        }


class ReorderPolicy:
    """Threshold policy over (probes, volume) with calibrated strengths."""

    def __init__(self, min_queries: int = 4, high_volume: int = 32,
                 min_gini: float = 0.25, dbg_gini: float = 0.45,
                 calibrator: StrengthCalibrator | None = None,
                 min_calibration_samples: int = 5,
                 override_margin: float = 0.05,
                 device_budget_bytes: int | None = None,
                 hot_prefix_hub_mass_min: float = 0.5,
                 hot_prefix_margin: float = 2.0,
                 hot_prefix_bounds: tuple[float, float] = (0.05, 0.5)):
        self.min_queries = min_queries
        self.high_volume = high_volume
        self.min_gini = min_gini
        self.dbg_gini = dbg_gini
        self.calibrator = calibrator or StrengthCalibrator()
        self.min_calibration_samples = min_calibration_samples
        self.override_margin = override_margin
        # None = everything fits one device; a byte budget turns placement
        # on and routes oversized graphs to the sharded backend
        self.device_budget_bytes = device_budget_bytes
        # sharded placement: hub mass above the threshold means a hub-
        # packing reorder concentrates most property reads in the first
        # ~hub_fraction of ids, so the per-step all-gather can be thinned
        # to that prefix (margin x for the cold vertices interleaved by
        # imperfect packing), clamped to the bounds
        self.hot_prefix_hub_mass_min = hot_prefix_hub_mass_min
        self.hot_prefix_margin = hot_prefix_margin
        self.hot_prefix_bounds = hot_prefix_bounds
        # placement v2: the S term of estimate_device_bytes. Starts at 1
        # (a lone query's state) and tracks the micro-batch scheduler's
        # *observed* coalesced launch sizes via an EWMA, so re-decisions
        # place graphs against the batch shapes traffic actually produces
        self.batch_sources_ewma = 1.0
        self.batch_sources_decay = 0.2
        self.batches_observed = 0
        self.history: list[PolicyRecord] = []

    # ------------------------------------------------------------- decide
    @staticmethod
    def _skew(probes: GraphProbes) -> float:
        """Probe composite: how much hot working set there is to pack."""
        return min(probes.degree_gini * (0.5 + probes.hub_mass), 1.0)

    @staticmethod
    def _visit_skew(probes: GraphProbes) -> float:
        """The same composite over observed visit frequency — the skew
        axis for search graphs, whose fixed out-degree makes the degree
        composite read ~0 (docs/search.md)."""
        return min(probes.visit_gini * (0.5 + probes.visit_hub_mass), 1.0)

    def _predict_gain(self, probes: GraphProbes, scheme: str) -> float:
        """Payoff model: skew x fitted scheme strength (family-keyed)."""
        skew = (self._visit_skew(probes) if scheme == "visitsort"
                else self._skew(probes))
        return round(skew * self.calibrator.strength(
            scheme, family=probes.family), 4)

    def _scheme_kwargs(self, scheme: str, probes: GraphProbes) -> dict:
        if scheme == "lorder":
            return {"kappa": max(1, (probes.diameter + 1) // 2)}
        return {}

    def observe_batch_sources(self, num_sources: int) -> None:
        """Feed one coalesced launch's source count into the S estimate.

        Called by the micro-batch scheduler after every multi-source
        launch; `_placement` sizes query state from the EWMA of these
        observations, closing the loop between the request plane's real
        batch shapes and where graphs are placed (ROADMAP placement v2).
        """
        n = max(int(num_sources), 1)
        if self.batches_observed == 0:
            self.batch_sources_ewma = float(n)
        else:
            d = self.batch_sources_decay
            self.batch_sources_ewma = ((1.0 - d) * self.batch_sources_ewma
                                       + d * n)
        self.batches_observed += 1

    @property
    def batch_sources_hint(self) -> int:
        """S for placement: the vmapped launch the executor would build
        for the typical observed batch (its power-of-two source bucket)."""
        from .backends import source_bucket
        return source_bucket(max(int(round(self.batch_sources_ewma)), 1))

    def _placement(self, probes: GraphProbes) -> tuple[str, str | None]:
        """Pick the execution backend from the working set vs budget.

        Placement changes the amortization math, not just the launch
        path: a sharded traversal pays an all-gather per step, so the
        session discounts booked reorder savings on sharded graphs
        (`AmortizationLedger.gain_discount`).
        """
        if self.device_budget_bytes is None:
            return "single", None
        # what the single-device backend would actually hold live: the
        # graph padded to its geometric bucket (default bucketing params,
        # not the raw (V, E) footprint — a graph just under budget raw
        # can be nearly growth x over it once padded) plus the (S, V)
        # query state of the typical observed micro-batch
        v_b, e_b = bucket_dims(probes.num_vertices, probes.num_edges)
        s = self.batch_sources_hint
        csr_only = estimate_device_bytes(v_b, e_b)
        need = estimate_device_bytes(v_b, e_b, batch_sources=s)
        if need > self.device_budget_bytes:
            batch_note = ""
            if csr_only <= self.device_budget_bytes:
                batch_note = (f" (the CSR alone fits; S={s} observed "
                              f"batch state tips it over)")
            note = (f"placement: working set ~{need / 1e6:.1f} MB "
                    f"(CSR + S={s} query state) exceeds device budget "
                    f"{self.device_budget_bytes / 1e6:.1f} MB — serving "
                    f"sharded across devices{batch_note}")
            return "sharded", note
        return "single", None

    def _hot_prefix(self, probes: GraphProbes,
                    scheme: str) -> tuple[float | None, str | None]:
        """Derive the sharded hot-prefix fraction from the hub-mass probe.

        Only meaningful when a hub-packing reorder concentrates the hot
        working set toward low ids: the original/random layouts scatter
        hubs across every shard's slice, so thinning the exchange would
        just delay convergence for nothing. The exchange gathers the
        first ``fraction`` of *each shard's* slice — under a
        degree-monotone packing that is each shard's locally-hottest
        range, while the absolute hubs sit on the first shard(s), so
        this is a heuristic, not a coverage guarantee (covering the
        global hub prefix exactly would need ``fraction ~ hub_fraction x
        num_shards``; the realized coverage is what the backend's
        ``prefix_hit_rate`` telemetry measures). ``margin x
        hub_fraction`` clamped to the bounds is a serviceable default
        either way: results stay exact regardless, only convergence
        speed rides on the estimate.
        """
        if scheme in ("original", "random"):
            return None, None
        if probes.hub_mass < self.hot_prefix_hub_mass_min:
            return None, None
        lo, hi = self.hot_prefix_bounds
        frac = round(min(max(probes.hub_fraction * self.hot_prefix_margin,
                             lo), hi), 4)
        note = (f"hot-prefix exchange: hub mass {probes.hub_mass:.2f} >= "
                f"{self.hot_prefix_hub_mass_min} concentrated on "
                f"{probes.hub_fraction:.1%} of vertices — gathering the "
                f"first {frac:.1%} of each shard per step")
        return frac, note

    def _calibrated_override(self, default: str, candidates: list[str],
                             probes: GraphProbes) -> tuple[str, str | None]:
        """Swap the tier default for a candidate with higher fitted gain.

        Only fires once there is evidence to act on — the default or the
        challenger has ``min_calibration_samples`` observations — so an
        uncalibrated policy reproduces the static PR 1 decision tree
        exactly. The margin keeps noise from flapping decisions.
        """
        cal, n_min = self.calibrator, self.min_calibration_samples
        best, best_gain = default, self._predict_gain(probes, default)
        for cand in candidates:
            if cand == default:
                continue
            if cal.count(cand) < n_min and cal.count(default) < n_min:
                continue
            gain = self._predict_gain(probes, cand)
            if gain > best_gain + self.override_margin:
                best, best_gain = cand, gain
        if best == default:
            return default, None
        note = (f"calibration override: fitted strength favours {best} "
                f"({best_gain:.3f}) over {default} "
                f"({self._predict_gain(probes, default):.3f}) by more than "
                f"{self.override_margin}")
        return best, note

    def _decide_search(self, probes: GraphProbes,
                       expected_queries: int) -> PolicyDecision:
        """Search-family tree: degree probes are blind here (fixed
        out-degree), so the only skew worth packing is *observed* visit
        frequency — populated by `GraphRegistry.note_visits` as knn
        traffic flows and refreshed via ``refresh_visit_probes``. Until
        telemetry shows skew, serve the original layout."""
        if expected_queries < self.min_queries:
            scheme, source = "original", "degree"
            reason = (f"volume gate: {expected_queries} expected queries "
                      f"< {self.min_queries}, reorder cannot amortize")
        elif probes.visit_gini < self.min_gini:
            scheme, source = "original", "degree"
            reason = (f"search skew gate: visit gini "
                      f"{probes.visit_gini:.3f} < {self.min_gini} — no "
                      f"observed hot set to pack (degree gini "
                      f"{probes.degree_gini:.3f} is structurally "
                      f"uninformative on fixed out-degree graphs)")
        else:
            scheme, source = "visitsort", "visits"
            reason = (f"search family: observed visit gini "
                      f"{probes.visit_gini:.3f} >= {self.min_gini} with "
                      f"{probes.visit_hub_mass:.1%} of visits on "
                      f"{probes.visit_hub_fraction:.1%} of vertices — "
                      f"packing the hot prefix by visit telemetry")
        backend, placement_note = self._placement(probes)
        if placement_note:
            reason = f"{reason}; {placement_note}"
        skew = (self._visit_skew(probes) if source == "visits"
                else self._skew(probes))
        return PolicyDecision(scheme, {}, reason,
                              self._predict_gain(probes, scheme),
                              skew, backend, None, hotness_source=source)

    def decide(self, probes: GraphProbes,
               expected_queries: int) -> PolicyDecision:
        if probes.family == "search":
            return self._decide_search(probes, expected_queries)
        candidates: list[str] = []
        if expected_queries < self.min_queries:
            scheme = "original"
            reason = (f"volume gate: {expected_queries} expected queries "
                      f"< {self.min_queries}, reorder cannot amortize")
        elif probes.degree_gini < self.min_gini:
            scheme = "original"
            reason = (f"skew gate: degree gini {probes.degree_gini:.3f} "
                      f"< {self.min_gini}, no hub working set to pack")
        elif expected_queries < self.high_volume:
            candidates = ["hubcluster", "dbg"]
            if probes.degree_gini < self.dbg_gini:
                scheme = "hubcluster"
                reason = (f"cheap tier: moderate skew "
                          f"(gini {probes.degree_gini:.3f}), single-pass "
                          f"hub clustering")
            else:
                scheme = "dbg"
                reason = (f"cheap tier: high skew "
                          f"(gini {probes.degree_gini:.3f}), degree-based "
                          f"grouping")
        else:
            candidates = ["hubcluster", "dbg", "lorder"]
            scheme = "lorder"
            kappa = self._scheme_kwargs("lorder", probes)["kappa"]
            reason = (f"high volume ({expected_queries} >= "
                      f"{self.high_volume}) + skew "
                      f"(gini {probes.degree_gini:.3f}): LOrder with "
                      f"probe-derived kappa = ceil(D/2) = {kappa} "
                      f"(D ~ {probes.diameter})")
        if candidates:
            scheme, note = self._calibrated_override(scheme, candidates,
                                                     probes)
            if note:
                reason = f"{reason}; {note}"
        backend, placement_note = self._placement(probes)
        if placement_note:
            reason = f"{reason}; {placement_note}"
        hot_prefix = None
        if backend == "sharded":
            hot_prefix, prefix_note = self._hot_prefix(probes, scheme)
            if prefix_note:
                reason = f"{reason}; {prefix_note}"
        return PolicyDecision(scheme, self._scheme_kwargs(scheme, probes),
                              reason, self._predict_gain(probes, scheme),
                              self._skew(probes), backend, hot_prefix)

    # -------------------------------------------------------------- apply
    def reorder_fn(self, decision: PolicyDecision, visits=None):
        """Resolve the decision to a callable(graph) -> perm.

        ``visits`` carries the observed per-vertex visit EWMA (original-id
        space) that the ``visitsort`` scheme orders by — it is serving
        telemetry, not graph structure, so it rides in from the session
        rather than the registry of structural schemes.
        """
        if decision.scheme == "visitsort":
            if visits is None:
                raise ValueError(
                    "visitsort orders by observed visits; pass visits=")
            from ..search.serve import visit_order
            return lambda g: visit_order(visits)
        fn = reordering_registry()[decision.scheme]
        return lambda g: fn(g, **decision.kwargs)

    def record(self, graph_id: str, decision: PolicyDecision,
               miss_rate_before: float, miss_rate_after: float,
               reorder_seconds: float,
               family: str = "analytics") -> PolicyRecord:
        """Log an outcome and feed it to the calibrator (the closed loop)."""
        rec = PolicyRecord(graph_id, decision, miss_rate_before,
                           miss_rate_after, reorder_seconds, family=family)
        self.history.append(rec)
        self.calibrator.observe_record(rec)
        return rec

    # ----------------------------------------------------------- persist
    def save_calibration(self, path):
        """Persist fitted strengths so calibration survives sessions."""
        return self.calibrator.save(path)

    def load_calibration(self, path) -> None:
        self.calibrator = StrengthCalibrator.load(path)
