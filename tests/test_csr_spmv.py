"""Pallas CSR-SpMV pack/kernel correctness vs the pure-jnp oracle.

This is the relaxation the fused single-device PageRank routes through
(`algos.kernels.pagerank_spmv`, served by
``SingleDeviceBackend(pallas_pr=...)``): `pack_edges` tiles the in-CSR
edge stream by destination and `csr_spmv_pallas` accumulates one
destination tile per grid row. Everything here runs in interpreter mode
so CI without TPUs executes the same kernel body.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.csr import from_edges
from repro.core.generators import powerlaw_community, rmat
from repro.kernels.csr_spmv.csr_spmv import (DST_TILE, csr_spmv_pallas,
                                             pack_edges)
from repro.kernels.csr_spmv.ref import csr_spmv_ref


def _pallas_vs_ref(t_indptr, t_indices, weights, x):
    src, dst_local, val, bpt, ntiles, n_pad = pack_edges(
        np.asarray(t_indptr), np.asarray(t_indices), weights)
    got = csr_spmv_pallas(jnp.asarray(src), jnp.asarray(dst_local),
                          jnp.asarray(val), jnp.asarray(x),
                          blocks_per_tile=bpt, num_tiles=ntiles,
                          n_pad=n_pad, interpret=True)
    w = (np.ones(len(t_indices), np.float32) if weights is None
         else np.asarray(weights, np.float32))
    want = csr_spmv_ref(jnp.asarray(t_indptr), jnp.asarray(t_indices),
                        jnp.asarray(w), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)
    return np.asarray(got)


@pytest.mark.parametrize("gen,kw", [
    (powerlaw_community, dict(num_vertices=1500, avg_degree=6, seed=0)),
    (powerlaw_community, dict(num_vertices=700, avg_degree=20, seed=1)),
    (rmat, dict(scale=9, edge_factor=4, seed=2)),
])
def test_packed_spmv_matches_ref_ragged(gen, kw):
    """Ragged degree distributions (power-law + RMAT skew) spanning
    multiple destination tiles and blocks_per_tile > 1."""
    g = gen(**kw)
    t = g.transpose
    rng = np.random.default_rng(0)
    x = rng.standard_normal(g.num_vertices).astype(np.float32)
    w = rng.random(len(t.indices)).astype(np.float32)
    _pallas_vs_ref(t.indptr, t.indices, w, x)


def test_packed_spmv_empty_rows_and_dangling_dst():
    """Vertices with no in-edges must come out exactly zero, including
    a dangling destination tile (rows past the last edge)."""
    g = from_edges(DST_TILE + 88, [0, 1, 2], [5, 5, DST_TILE + 3])
    t = g.transpose
    x = np.arange(g.num_vertices, dtype=np.float32) + 1.0
    y = _pallas_vs_ref(t.indptr, t.indices, None, x)
    assert y[5] == x[0] + x[1]
    assert y[DST_TILE + 3] == x[2]
    mask = np.ones(g.num_vertices, bool)
    mask[[5, DST_TILE + 3]] = False
    assert np.abs(y[mask]).sum() == 0.0


def test_packed_spmv_no_edges():
    """The degenerate pack (0 edges) still emits a well-formed grid."""
    g = from_edges(17, np.array([], np.int64), np.array([], np.int64))
    t = g.transpose
    y = _pallas_vs_ref(t.indptr, t.indices, None,
                       np.ones(g.num_vertices, np.float32))
    assert np.abs(y).sum() == 0.0


def test_packed_spmv_sub_tile_graph():
    """n << DST_TILE: single-tile grid with the x slab zero-padded."""
    g = from_edges(7, [0, 1, 2, 6, 6], [3, 3, 3, 0, 0])
    t = g.transpose
    x = np.array([1, 2, 3, 4, 5, 6, 7], np.float32)
    y = _pallas_vs_ref(t.indptr, t.indices, None, x)
    assert y[3] == 6.0 and y[0] == 14.0  # parallel edges both counted


def test_packed_sentinel_edges_contribute_zero():
    """The bucketed serving path pads the CSR views with sentinel edges
    and marks them invalid; packed with val=edge_valid they must not
    perturb the result — compare a padded graph against its exact self."""
    from repro.algos.graph_arrays import to_device
    g = powerlaw_community(600, avg_degree=8.0, seed=11)
    exact = to_device(g)
    padded = to_device(g, pad_to=(1024, 8192))
    assert padded.edge_valid is not None
    rng = np.random.default_rng(3)
    x = rng.random(1024).astype(np.float32)  # junk beyond V must be masked

    def run(arrays, x_n):
        ev = arrays.edge_valid
        w = None if ev is None else np.asarray(ev, np.float32)
        src, dst_local, val, bpt, ntiles, n_pad = pack_edges(
            np.asarray(arrays.t_indptr), np.asarray(arrays.t_indices), w)
        return np.asarray(csr_spmv_pallas(
            jnp.asarray(src), jnp.asarray(dst_local), jnp.asarray(val),
            jnp.asarray(x_n), blocks_per_tile=bpt, num_tiles=ntiles,
            n_pad=n_pad, interpret=True))

    got = run(padded, x)[:g.num_vertices]
    want = run(exact, x[:g.num_vertices])
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_pagerank_spmv_matches_segment_sum_kernel():
    """The fused-loop PR that routes its relaxation through the Pallas
    kernel == the segment-sum PR, on exact and bucketed arrays."""
    from repro.algos import kernels as K
    from repro.algos.graph_arrays import to_device
    g = powerlaw_community(800, avg_degree=8.0, seed=5)
    for pad_to in (None, (1024, 16384)):
        ga = to_device(g, pad_to=pad_to)
        ev = ga.edge_valid
        w = None if ev is None else np.asarray(ev, np.float32)
        src, dst_local, val, bpt, ntiles, n_pad = pack_edges(
            np.asarray(ga.t_indptr), np.asarray(ga.t_indices), w)
        got = np.asarray(K.pagerank_spmv(
            ga, jnp.asarray(src), jnp.asarray(dst_local), jnp.asarray(val),
            blocks_per_tile=bpt, num_tiles=ntiles, n_pad=n_pad,
            interpret=True))
        want = np.asarray(K.pagerank(ga))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-8)


def test_engine_pallas_pr_backend_parity():
    """`SingleDeviceBackend(pallas_pr=True)` serves PR through the packed
    kernel (one launch per query, `pr@spmv` cache key) and matches the
    default backend bit-for-bit up to float tolerance."""
    from repro.engine.backends import SingleDeviceBackend
    g = powerlaw_community(500, avg_degree=6.0, seed=7)
    ref = SingleDeviceBackend()
    pal = SingleDeviceBackend(pallas_pr=True)
    assert ref.telemetry()["pallas_pr"] is False  # auto -> off on CPU
    h_ref, h_pal = ref.prepare(g), pal.prepare(g)
    assert h_ref.spmv is None and h_pal.spmv is not None
    out_ref = np.asarray(ref.run(h_ref, "pr"))
    out_pal = np.asarray(pal.run(h_pal, "pr"))
    np.testing.assert_allclose(out_pal, out_ref, rtol=1e-5, atol=1e-8)
    assert any(k[0] == "pr@spmv" for k in pal._cache)
    assert pal.telemetry()["dispatches"] == 1
