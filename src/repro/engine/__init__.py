"""Graph analytics serving engine (docs/engine.md, docs/policy.md).

Turns the one-shot reproduction benchmarks into a serving system: a
registry of probed graphs, an adaptive reorder policy that decides *when*
and *how* to reorder from cheap structural probes plus expected query
volume, a compile-cached batched executor, and a session front-end with
an amortization ledger. The loop is closed: realized outcomes calibrate
the policy's per-scheme strengths (calibration.py), and the session
re-decides — re-reordering in place — when realized traffic diverges
from the registration hint or a reorder provably cannot amortize.
"""
from .backends import (SHARDED_KERNELS, ExecutionBackend, GraphHandle,
                       ShardedBackend, SingleDeviceBackend, bucket_dims,
                       estimate_device_bytes)
from .calibration import DEFAULT_PRIORS, SchemeStats, StrengthCalibrator
from .executor import BatchedExecutor
from .policy import PolicyDecision, PolicyRecord, ReorderPolicy
from .registry import GraphProbes, GraphRegistry, probe_graph
from .session import AmortizationLedger, EngineSession

__all__ = [
    "AmortizationLedger", "BatchedExecutor", "DEFAULT_PRIORS",
    "EngineSession", "ExecutionBackend", "GraphHandle", "GraphProbes",
    "GraphRegistry", "PolicyDecision", "PolicyRecord", "ReorderPolicy",
    "SHARDED_KERNELS", "SchemeStats", "ShardedBackend",
    "SingleDeviceBackend",
    "StrengthCalibrator", "bucket_dims", "estimate_device_bytes",
    "probe_graph",
]
