"""Distributed graph engine: 1-D edge-partitioned kernels via shard_map.

Scales the paper's workload to cluster meshes: edges are partitioned by
destination range (each shard owns a contiguous dst range = its slice of
the property array); a traversal step is

    local gather (remote props via all-gather) -> local segment-reduce

which is the pull-mode pattern of the paper mapped onto jax collectives.
After LOrder, hot vertices are concentrated in low id ranges, so the
all-gather payload that every shard actually *uses* is concentrated in a
small prefix — the cluster-level analogue of cache-line locality. The
`hot_prefix` variant exploits it by gathering only the hot prefix every
iteration and exchanging the cold remainder at lower frequency.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.6 exposes shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:  # 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

from .csr import Graph


def partition_edges(g: Graph, num_shards: int):
    """Split COO edges by dst range; pad shards to equal edge counts."""
    n = g.num_vertices
    per = -(-n // num_shards)  # dst ids [i*per, (i+1)*per)
    src = g.edge_src.astype(np.int32)
    dst = np.asarray(g.indices, dtype=np.int32)
    shard_of = dst // per
    order = np.argsort(shard_of, kind="stable")
    src, dst = src[order], dst[order]
    counts = np.bincount(shard_of, minlength=num_shards)
    emax = int(counts.max())
    s_pad = np.zeros((num_shards, emax), np.int32)
    d_pad = np.zeros((num_shards, emax), np.int32)
    valid = np.zeros((num_shards, emax), bool)
    off = 0
    for i, c in enumerate(counts):
        s_pad[i, :c] = src[off:off + c]
        d_pad[i, :c] = dst[off:off + c] - i * per  # local dst index
        valid[i, :c] = True
        off += c
    return s_pad, d_pad, valid, per


def make_distributed_pagerank(g: Graph, mesh: Mesh, axis: str = "data",
                              damping: float = 0.85, num_iters: int = 20):
    """Returns (step_fn, initial_rank) running PR over `axis` of `mesh`."""
    num_shards = mesh.shape[axis]
    s_pad, d_pad, valid, per = partition_edges(g, num_shards)
    n = g.num_vertices
    n_pad = per * num_shards
    outdeg = np.maximum(np.asarray(g.out_degree, np.float32), 1.0)
    outdeg_pad = np.ones(n_pad, np.float32)
    outdeg_pad[:n] = outdeg
    dangling_pad = np.zeros(n_pad, np.float32)
    dangling_pad[:n] = (np.asarray(g.out_degree) == 0).astype(np.float32)

    espec = NamedSharding(mesh, P(axis, None))
    vspec = NamedSharding(mesh, P(axis))
    s_sh = jax.device_put(s_pad, espec)
    d_sh = jax.device_put(d_pad, espec)
    v_sh = jax.device_put(valid, espec)
    deg_sh = jax.device_put(outdeg_pad, vspec)
    dang_sh = jax.device_put(dangling_pad, vspec)

    def step(rank, src_e, dst_e, val_e, deg, dang):
        # rank: (per,) local shard.  all-gather the full property array —
        # the collective whose *useful* payload LOrder concentrates.
        full = jax.lax.all_gather(rank, axis, tiled=True)       # (n_pad,)
        full_deg = jax.lax.all_gather(deg, axis, tiled=True)
        contrib = jnp.where(val_e[0], full[src_e[0]] / full_deg[src_e[0]], 0.0)
        summed = jax.ops.segment_sum(contrib, dst_e[0], num_segments=per)
        # dangling mass redistributed uniformly (GAP semantics)
        dangling = jax.lax.psum(jnp.sum(rank * dang), axis)
        out = (1.0 - damping) / n + damping * (summed + dangling / n)
        return out[None]

    sharded_step = jax.jit(_shard_map(
        step, mesh=mesh,
        in_specs=(P(axis), P(axis, None), P(axis, None), P(axis, None),
                  P(axis), P(axis)),
        out_specs=P(axis, None),
    ))

    def run(rank0=None):
        r = rank0 if rank0 is not None else jax.device_put(
            np.full(n_pad, 1.0 / n, np.float32), vspec)
        for _ in range(num_iters):
            r = sharded_step(r, s_sh, d_sh, v_sh, deg_sh,
                             dang_sh).reshape(n_pad)
        return r[:n]

    return run, vspec


def lower_distributed_pagerank(g: Graph, mesh: Mesh, axis: str = "data"):
    """Lower+compile one sharded PR step (dry-run hook for the graph engine)."""
    run, _ = make_distributed_pagerank(g, mesh, axis, num_iters=1)
    return run
