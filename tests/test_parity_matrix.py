"""Cross-backend parity matrix: six kernels x serving configs vs numpy.

Every served kernel (bfs, sssp, bc, pr, cc, ccsv) runs end-to-end
through ``EngineSession.submit`` — policy reorder, id translation and
all — under each serving configuration:

* **exact** — single-device backend, bucketing off (exact CSR shapes);
* **bucketed** — single-device backend, geometric shape buckets;
* **sharded** — a tiny device budget forces the sharded backend (one
  shard in the plain suite; every visible device when the process runs
  under ``--xla_force_host_platform_device_count=4``).

Results are checked against the host numpy baselines in
`core/baselines.py` (bit-identical for the integer kernels — including
the component labelings, whose values the session now canonicalizes to
min-original-id per component at the boundary — allclose for PR/BC), and
connected components are additionally checked **bit-identical across
backends**: canonical labels are layout-independent, so every serving
config must produce the same bits whatever reorder or placement it
picked.

The genuinely distributed leg re-runs this whole module in a subprocess
with 4 forced host devices (the XLA flag must be set before jax picks
its backends), so the matrix is literally the same suite at both shard
counts.
"""
from __future__ import annotations

import os

import numpy as np
import pytest

from conftest import run_forced_four_devices
from repro.algos.graph_arrays import to_device
from repro.core.baselines import (bc_baseline, bfs_baseline, cc_baseline,
                                  pagerank_baseline, sssp_baseline)
from repro.engine import BatchedExecutor, EngineSession

CONFIGS = ("exact", "bucketed", "sharded")
GRAPHS = ("plc_graph", "tiny_graph")  # power-law + floor-bucket edge case
SOURCES = {"plc_graph": np.array([5, 321, 1500]),
           "tiny_graph": np.array([0, 3])}


def _make_session(config: str) -> EngineSession:
    # re-decision disabled: the matrix probes serving parity, not the
    # online policy loop (tests/test_calibration.py covers that)
    if config == "exact":
        return EngineSession(executor=BatchedExecutor(bucketing=False),
                             redecide_min_queries=10**6)
    if config == "bucketed":
        return EngineSession(redecide_min_queries=10**6)
    return EngineSession(device_budget_bytes=1024,
                         redecide_min_queries=10**6)


@pytest.fixture(scope="module",
                params=[(c, g) for g in GRAPHS for c in CONFIGS],
                ids=[f"{g.split('_')[0]}-{c}"
                     for g in GRAPHS for c in CONFIGS])
def served(request):
    """(config, graph_key, graph, session, graph_id) — registered once."""
    config, graph_key = request.param
    graph = request.getfixturevalue(graph_key)
    session = _make_session(config)
    gid = session.register(graph, graph_id=f"matrix-{config}-{graph_key}",
                           expected_queries=256)
    return config, graph_key, graph, session, gid


# cc labels per (graph, config), for the cross-backend bit-identity check
_CC_ACROSS: dict[tuple[str, str], np.ndarray] = {}


def test_placement_matches_config(served):
    config, _, _, session, gid = served
    entry = session.registry.get(gid)
    assert entry.backend == ("sharded" if config == "sharded" else "single")
    if config == "sharded":
        assert entry.ledger.gain_discount < 1.0


def test_matrix_bfs(served):
    _, graph_key, g, session, gid = served
    srcs = SOURCES[graph_key]
    out = np.asarray(session.submit(gid, "bfs", srcs))
    assert out.shape == (len(srcs), g.num_vertices)
    for i, s in enumerate(srcs):
        np.testing.assert_array_equal(out[i], bfs_baseline(g, int(s)))


def test_matrix_sssp(served):
    _, graph_key, g, session, gid = served
    srcs = SOURCES[graph_key]
    out = np.asarray(session.submit(gid, "sssp", srcs), dtype=np.int64)
    weights = np.asarray(to_device(g).weights)
    for i, s in enumerate(srcs):
        np.testing.assert_array_equal(out[i],
                                      sssp_baseline(g, weights, int(s)))


def test_matrix_bc(served):
    _, graph_key, g, session, gid = served
    srcs = SOURCES[graph_key]
    out = np.asarray(session.submit(gid, "bc", srcs)).sum(axis=0)
    np.testing.assert_allclose(out, bc_baseline(g, srcs),
                               rtol=1e-3, atol=1e-3)


def test_matrix_pr(served):
    _, _, g, session, gid = served
    out = np.asarray(session.submit(gid, "pr"))
    np.testing.assert_allclose(out, pagerank_baseline(g),
                               rtol=1e-4, atol=1e-7)


@pytest.mark.parametrize("kernel", ["cc", "ccsv"])
def test_matrix_components(served, kernel):
    config, graph_key, g, session, gid = served
    out = np.asarray(session.submit(gid, kernel))
    # label values are canonicalized to original id space at the session
    # boundary (min original id per component) — bit-identical to numpy
    np.testing.assert_array_equal(out, cc_baseline(g))
    if kernel == "cc":
        _CC_ACROSS[(graph_key, config)] = out


def test_matrix_cc_bit_identical_across_backends(served):
    """Canonical labels are layout-independent, so every backend — and
    every reorder — must produce the same bits for the same graph."""
    config, graph_key, _, session, gid = served
    if (graph_key, config) not in _CC_ACROSS:
        # selective runs (-k) may skip test_matrix_components: collect here
        _CC_ACROSS[(graph_key, config)] = np.asarray(
            session.submit(gid, "cc"))
    mine = _CC_ACROSS[(graph_key, config)]
    for (gk, other), labels in _CC_ACROSS.items():
        if gk == graph_key and other != config:
            np.testing.assert_array_equal(mine, labels)


def test_matrix_four_forced_devices():
    """Re-run this whole module on 4 forced host devices: the sharded
    config becomes a genuine 4-shard mesh (with the policy's hot-prefix
    exchange active on the power-law graph) against the same baselines."""
    res = run_forced_four_devices(
        ["-m", "pytest", "-q", os.path.abspath(__file__),
         "-k", "not four_forced"], timeout=900)
    assert res.returncode == 0, \
        f"stdout={res.stdout[-4000:]}\nstderr={res.stderr[-2000:]}"
