"""Diameter estimation (double-sweep BFS) and the paper's κ = D/2 rule.

The paper's headline structural observation (Table 5.2) is that the
optimal locality radius κ equals half the graph diameter ("κ is also
referred to as the radius"). Diameter is estimated with the standard iterated double-sweep
lower bound on the symmetrized graph — the same figure SNAP reports
(longest shortest path, effective on the largest component).
"""
from __future__ import annotations

import numpy as np

from .csr import Graph
from .traversal import bfs_levels, farthest_vertex


def estimate_diameter(g: Graph, sweeps: int = 4, seed: int = 0) -> int:
    """Iterated double-sweep BFS diameter lower bound (exact on trees)."""
    und = g.undirected
    if und.num_vertices == 0:
        return 0
    rng = np.random.default_rng(seed)
    # start from the highest-degree vertex (lands in the giant component)
    start = int(np.argmax(und.out_degree))
    best = 0
    for s in range(sweeps):
        far, ecc = farthest_vertex(und, start)
        best = max(best, ecc)
        if ecc == 0:
            break
        start = far
        if s >= 1:  # extra restarts from random vertices sharpen the bound
            dist = bfs_levels(und, int(rng.integers(und.num_vertices)))
            best = max(best, int(dist.max()))
    return int(best)


def two_sweep_diameter(g: Graph) -> int:
    """Single double-sweep diameter lower bound — the engine's cheap probe.

    Two BFS passes total (vs ``estimate_diameter``'s iterated sweeps plus
    random restarts): BFS from the highest-degree vertex, then BFS from the
    farthest vertex found. Within a few percent of the iterated bound on
    the paper's graph families at a fraction of the probe cost.
    """
    und = g.undirected
    if und.num_vertices == 0:
        return 0
    start = int(np.argmax(und.out_degree))
    far, ecc = farthest_vertex(und, start)
    if ecc == 0:
        return 0
    _, ecc2 = farthest_vertex(und, far)
    return int(max(ecc, ecc2))


def default_kappa(g: Graph, diameter: int | None = None) -> int:
    """κ = ⌈D / 2⌉ — the radius (paper Table 5.2)."""
    d = estimate_diameter(g) if diameter is None else diameter
    return max(1, (d + 1) // 2)
