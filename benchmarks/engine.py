"""Engine harness — policy decisions, reorder cost, and amortization.

For each dataset: register with the serving engine (policy decides a
scheme from probes + volume hint), then measure batched multi-source BFS
latency on the *original* layout vs the *served* layout directly, and
report the wall-clock break-even query count next to the ledger's
cache-model estimate. Emits benchmarks/results/engine.json.
"""
from __future__ import annotations

import numpy as np

from .common import bench_suite, fmt_table, save_json, time_call


def run(scale: float = 0.5, batch: int = 8, repeats: int = 5) -> list[dict]:
    from repro.algos.graph_arrays import to_device
    from repro.engine import EngineSession

    session = EngineSession()
    suite = dict(bench_suite(scale))
    from repro.core.generators import road_grid
    side = max(32, int(128 * np.sqrt(scale)))
    suite["road-sim"] = road_grid(side, shortcuts=64, seed=13,
                                  name="road-sim")

    rng = np.random.default_rng(0)
    rows = []
    for dname, g in suite.items():
        gid = session.register(g, graph_id=dname, expected_queries=256)
        entry = session.registry.get(gid)
        srcs = rng.integers(0, g.num_vertices, size=batch).astype(np.int32)

        ga_orig = to_device(g)
        srcs_served = entry.perm[srcs].astype(np.int32)
        t_before, _ = time_call(session.executor.run, ga_orig, "bfs", srcs,
                                repeats=repeats)
        t_after, _ = time_call(session.executor.run, entry.arrays, "bfs",
                               srcs_served, repeats=repeats)
        saving = t_before - t_after
        wall_break_even = (entry.reorder_seconds / saving
                           if saving > 1e-9 else float("inf"))
        rec = next(r for r in session.policy.history if r.graph_id == gid)
        rows.append({
            "dataset": dname,
            "scheme": entry.decision.scheme,
            "kwargs": entry.decision.kwargs,
            "reason": entry.decision.reason,
            "reorder_seconds": round(entry.reorder_seconds, 4),
            "predicted_gain": rec.decision.predicted_gain,
            "realized_gain": round(rec.realized_gain, 4),
            "batch": int(batch),
            "query_seconds_before": round(t_before, 5),
            "query_seconds_after": round(t_after, 5),
            "wall_break_even_queries": (round(wall_break_even, 1)
                                        if np.isfinite(wall_break_even)
                                        else "inf"),
        })
        print(f"[engine] {dname}: {entry.decision.scheme} "
              f"{entry.decision.kwargs}, reorder "
              f"{entry.reorder_seconds:.2f}s, query "
              f"{t_before * 1e3:.1f}ms -> {t_after * 1e3:.1f}ms", flush=True)

    out = {"rows": rows, "executor": session.executor.telemetry()}
    save_json("engine", out)
    return rows


def main(scale: float = 0.5):
    rows = run(scale)
    cols = ["dataset", "scheme", "reorder_seconds", "predicted_gain",
            "realized_gain", "query_seconds_before", "query_seconds_after",
            "wall_break_even_queries"]
    print("\n=== engine policy + amortization ===")
    print(fmt_table(rows, cols))


if __name__ == "__main__":
    main()
