"""Shared fixtures: small graphs spanning the structural regimes the paper
cares about (power-law community, RMAT skew, high-diameter grid, ring) —
plus the forced-4-device subprocess runner the distributed tests share
(re-exported from benchmarks/common.py, the single copy of that recipe)."""
from __future__ import annotations

import os
import sys

import numpy as np
import pytest

from repro.core.csr import Graph, from_edges
from repro.core.generators import powerlaw_community, rmat, road_grid, small_world

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:  # `pytest` without -m: repo root may be absent
    sys.path.insert(0, _ROOT)
from benchmarks.common import run_forced_four_devices  # noqa: E402,F401


def pytest_sessionstart(session):
    """Child-side guard for `run_forced_four_devices`: if the parent
    demanded a forced device count, fail the whole session up front when
    jax didn't honor it (e.g. XLA_FLAGS was clobbered) rather than
    silently running the 4-shard matrix on one device."""
    expect = os.environ.get("REPRO_EXPECT_DEVICE_COUNT")
    if expect:
        import jax
        got = jax.device_count()
        assert got == int(expect), (
            f"forced-device subprocess expected {expect} devices, jax "
            f"initialized {got}; XLA_FLAGS={os.environ.get('XLA_FLAGS')!r}")


@pytest.fixture(scope="session")
def plc_graph() -> Graph:
    return powerlaw_community(2000, avg_degree=8.0, seed=3)


@pytest.fixture(scope="session")
def rmat_graph() -> Graph:
    return rmat(10, edge_factor=8, seed=4)


@pytest.fixture(scope="session")
def grid_graph() -> Graph:
    return road_grid(20, shortcuts=8, seed=5)


@pytest.fixture(scope="session")
def ring_graph() -> Graph:
    return small_world(512, k=4, rewire=0.02, seed=6)


@pytest.fixture(scope="session")
def tiny_graph() -> Graph:
    """Hand-checkable 8-vertex graph (paper Fig 2.2.1 style)."""
    edges = [(0, 1), (0, 2), (1, 2), (2, 3), (3, 0), (3, 4),
             (4, 5), (5, 6), (6, 4), (6, 7), (7, 0), (1, 4)]
    src, dst = zip(*edges)
    return from_edges(8, src, dst)


GRAPH_FIXTURES = ["plc_graph", "rmat_graph", "grid_graph", "ring_graph",
                  "tiny_graph"]


@pytest.fixture(params=GRAPH_FIXTURES)
def any_graph(request) -> Graph:
    return request.getfixturevalue(request.param)
