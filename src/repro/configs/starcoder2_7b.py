"""starcoder2-7b [dense]: 32L d4608 36H (GQA kv=4) ff18432 v49152 — GQA,
RoPE, layernorm + biased GELU MLP. [arXiv:2402.19173; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b", family="dense",
    num_layers=32, d_model=4608, num_heads=36, num_kv_heads=4,
    d_ff=18432, vocab_size=49152,
    rope_theta=1e5,
    qkv_bias=True, attn_out_bias=True,
    mlp_type="gelu", mlp_bias=True, norm_type="layernorm",
    vocab_reorder=True, hot_vocab_fraction=0.08,   # code token skew is strong
)
