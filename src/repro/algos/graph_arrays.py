"""Device-side graph representation for the JAX graph kernels.

A `GraphArrays` pytree mirrors the GAP benchmark's working set: out-CSR,
in-CSR (transpose), COO views and degrees, all as jnp arrays. The six
kernels (BFS, PR, BC, SSSP, CC, CC-SV) consume this structure; vertex
relabeling (reordering) changes only the *content* of these arrays, never
the kernel code — exactly the paper's contract.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from ..core.csr import Graph


class GraphArrays(NamedTuple):
    indptr: jnp.ndarray     # (V+1,) int32 out-CSR
    indices: jnp.ndarray    # (E,)  int32 out-CSR neighbor (dst) ids
    src: jnp.ndarray        # (E,)  int32 COO source per out-edge
    t_indptr: jnp.ndarray   # (V+1,) int32 in-CSR
    t_indices: jnp.ndarray  # (E,)  int32 in-CSR neighbor (src) ids
    t_dst: jnp.ndarray      # (E,)  int32 COO dst per in-edge
    out_degree: jnp.ndarray  # (V,) int32
    in_degree: jnp.ndarray   # (V,) int32
    weights: jnp.ndarray     # (E,) int32 edge weights aligned with out-CSR

    @property
    def num_vertices(self) -> int:
        return self.indptr.shape[0] - 1

    @property
    def num_edges(self) -> int:
        return self.indices.shape[0]


def to_device(g: Graph, weight_seed: int = 17,
              canonical_ids: np.ndarray | None = None) -> GraphArrays:
    """Upload a host Graph; deterministic int weights in [1, 255] for SSSP.

    Weights are a pure function of the *canonical edge identity*: by
    default the graph's own (src, dst) ids, or — for a relabeled graph —
    ``canonical_ids[v]`` giving each vertex's id in the original layout.
    Passing the inverse permutation makes weights relabel-invariant, which
    is what fair pre/post-reorder SSSP comparisons (and the equivariance
    tests) require.
    """
    t = g.transpose
    src = g.edge_src.astype(np.int64)
    dst = g.indices.astype(np.int64)
    h_src, h_dst = src, dst
    if canonical_ids is not None:
        canon = np.asarray(canonical_ids, dtype=np.int64)
        h_src, h_dst = canon[src], canon[dst]
    # splitmix-style hash of canonical (src, dst) -> stable per-edge weight
    key = (h_src.astype(np.uint64) << np.uint64(32)) | h_dst.astype(np.uint64)
    key = (key ^ (key >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    key = (key ^ (key >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    key ^= key >> np.uint64(31)
    w = (key % np.uint64(255)).astype(np.int32) + 1
    _ = weight_seed  # reserved; hash keeps weights relabel-invariant
    return GraphArrays(
        indptr=jnp.asarray(g.indptr, jnp.int32),
        indices=jnp.asarray(g.indices, jnp.int32),
        src=jnp.asarray(src, jnp.int32),
        t_indptr=jnp.asarray(t.indptr, jnp.int32),
        t_indices=jnp.asarray(t.indices, jnp.int32),
        t_dst=jnp.asarray(t.edge_src, jnp.int32),
        out_degree=jnp.asarray(g.out_degree, jnp.int32),
        in_degree=jnp.asarray(g.in_degree, jnp.int32),
        weights=jnp.asarray(w, jnp.int32),
    )
