"""Substrate layers: data pipeline, checkpoints, optimizer, locality feats."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import (DataConfig, DataLoader, ZipfCommunityCorpus,
                                 corpus_sample, token_histogram)


# ----------------------------------------------------------------- data
def test_corpus_deterministic():
    dc = DataConfig(vocab_size=512, seq_len=32, global_batch=4, seed=7)
    c1, c2 = ZipfCommunityCorpus(dc), ZipfCommunityCorpus(dc)
    assert np.array_equal(c1.batch(3), c2.batch(3))
    assert not np.array_equal(c1.batch(3), c1.batch(4))


def test_corpus_host_sharding_disjoint():
    kw = dict(vocab_size=512, seq_len=16, global_batch=8, seed=7,
              num_hosts=2)
    a = ZipfCommunityCorpus(DataConfig(host_id=0, **kw)).batch(0)
    b = ZipfCommunityCorpus(DataConfig(host_id=1, **kw)).batch(0)
    assert a.shape == (4, 16)
    assert not np.array_equal(a, b)


def test_corpus_zipf_skew():
    dc = DataConfig(vocab_size=1024, seq_len=256, global_batch=8)
    counts = token_histogram(dc, num_batches=2)
    top = np.sort(counts)[::-1]
    # top 10% of tokens should carry well over half the mass
    assert top[:102].sum() > 0.5 * counts.sum()


def test_loader_prefetch_and_restart():
    dc = DataConfig(vocab_size=256, seq_len=16, global_batch=2)
    l1 = DataLoader(dc, start_step=0)
    b0, b1 = next(l1), next(l1)
    l1.close()
    l2 = DataLoader(dc, start_step=1)
    b1b = next(l2)
    l2.close()
    assert b0["step"] == 0 and b1["step"] == 1
    assert np.array_equal(b1["tokens"], b1b["tokens"])


def test_loader_applies_vocab_reorder():
    from repro.locality.vocab import degree_permutation
    dc = DataConfig(vocab_size=256, seq_len=16, global_batch=2)
    counts = token_histogram(dc, 1)
    vr = degree_permutation(counts, hot_fraction=0.1)
    plain = DataLoader(dc)
    mapped = DataLoader(dc, vocab_reorder=vr)
    a, b = next(plain), next(mapped)
    plain.close()
    mapped.close()
    assert np.array_equal(vr.perm[a["tokens"]], b["tokens"])


# ------------------------------------------------------------- locality
def test_vocab_permutation_valid_and_hot():
    from repro.core.csr import validate_permutation
    from repro.locality.vocab import hot_coverage, vocab_permutation
    dc = DataConfig(vocab_size=512, seq_len=128, global_batch=4)
    sample = corpus_sample(dc, 1)
    vr = vocab_permutation(sample, 512, hot_fraction=0.1)
    assert validate_permutation(vr.perm, 512)
    cov = hot_coverage(sample, vr)
    assert cov > 0.3, f"hot slab coverage too low: {cov}"
    # reordering must beat the identity layout's coverage
    ident_cov = float((sample < vr.hot_size).mean())
    assert cov > ident_cov


def test_vocab_reorder_apply_to_params_consistent():
    from repro.configs import smoke_config
    from repro.locality.vocab import degree_permutation
    from repro.models.transformer import forward, init_params
    cfg = smoke_config("qwen2.5-3b", layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    counts = np.random.default_rng(0).integers(1, 100, cfg.vocab_size)
    vr = degree_permutation(counts)
    params2 = vr.apply_to_params(params)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    l1, _ = forward(params, {"tokens": tokens}, cfg)
    mapped = jnp.asarray(vr.map_tokens(np.asarray(tokens)))
    l2, _ = forward(params2, {"tokens": mapped}, cfg)
    # logits permuted over the vocab axis (tied embeddings ⇒ head permutes)
    np.testing.assert_allclose(
        np.asarray(l1, np.float32),
        np.asarray(l2, np.float32)[..., :][..., np.argsort(vr.perm)][...,
            np.arange(cfg.vocab_size)] if False else
        np.asarray(jnp.take(l2, jnp.asarray(vr.perm), axis=-1), np.float32),
        rtol=2e-2, atol=2e-2)


def test_moe_dispatch_stats():
    from repro.locality.moe import (cross_shard_traffic, dispatch_stats,
                                    expert_affinity_permutation,
                                    routing_graph)
    rng = np.random.default_rng(0)
    # skewed routing: a few hot experts
    p = 1.0 / (1 + np.arange(16)) ** 1.2
    p /= p.sum()
    experts = rng.choice(16, size=(4096, 2), p=p)
    stats = dispatch_stats(experts, 16)
    assert stats["weight_stream_reduction"] > 10
    g = routing_graph(experts, 16)
    assert g.num_edges == 4096 * 2
    perm = expert_affinity_permutation(experts, 16)
    assert sorted(perm.tolist()) == list(range(16))
    base = cross_shard_traffic(experts, 16, 4)
    assert 1.0 <= base <= 2.0


# ----------------------------------------------------------------- ckpt
def test_ckpt_roundtrip(tmp_path):
    from repro.ckpt.manager import CheckpointManager
    m = CheckpointManager(tmp_path, keep=2, async_save=False)
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "opt": {"mu": jnp.zeros((2, 3)), "step": jnp.int32(5)}}
    m.save(3, state, blocking=True)
    step, got = m.restore()
    assert step == 3
    np.testing.assert_array_equal(got["params"]["w"],
                                  np.arange(6.0).reshape(2, 3))
    assert int(got["opt"]["step"]) == 5


def test_ckpt_keep_k_gc(tmp_path):
    from repro.ckpt.manager import CheckpointManager
    m = CheckpointManager(tmp_path, keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        m.save(s, {"x": jnp.ones(3) * s}, blocking=True)
    assert m.all_steps() == [3, 4]


def test_ckpt_ignores_uncommitted(tmp_path):
    from repro.ckpt.manager import CheckpointManager
    m = CheckpointManager(tmp_path, keep=3, async_save=False)
    m.save(1, {"x": jnp.ones(2)}, blocking=True)
    # simulate crash mid-save: a .tmp directory and a dir w/o manifest
    (tmp_path / "step_00000002.tmp").mkdir()
    (tmp_path / "step_00000003").mkdir()
    assert m.all_steps() == [1]
    step, got = m.restore()
    assert step == 1


def test_ckpt_async(tmp_path):
    from repro.ckpt.manager import CheckpointManager
    m = CheckpointManager(tmp_path, keep=3, async_save=True)
    m.save(7, {"x": jnp.full((4,), 7.0)})
    m.wait()
    step, got = m.restore()
    assert step == 7 and float(got["x"][0]) == 7.0


def test_ckpt_elastic_restore_resharding(tmp_path):
    """Restore onto explicit (degenerate-mesh) shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.ckpt.manager import CheckpointManager
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    m = CheckpointManager(tmp_path, async_save=False)
    m.save(1, {"w": jnp.arange(8.0)}, blocking=True)
    shard = {"w": NamedSharding(mesh, P())}
    step, got = m.restore(shardings=shard)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(got["w"]), np.arange(8.0))


# ---------------------------------------------------------------- optim
def test_adamw_reduces_quadratic_loss():
    from repro.train.optim import TrainConfig, adamw_update, init_opt_state
    tc = TrainConfig(learning_rate=0.1, warmup_steps=0, total_steps=100,
                     schedule="const", weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    opt = init_opt_state(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(params, grads, opt, tc)
    assert float(jnp.abs(params["w"]).max()) < 1.0


def test_schedules():
    from repro.train.optim import TrainConfig, schedule_lr
    tc = TrainConfig(learning_rate=1.0, warmup_steps=10, total_steps=100,
                     schedule="cosine")
    assert float(schedule_lr(tc, 0)) == 0.0
    assert abs(float(schedule_lr(tc, 10)) - 1.0) < 1e-6
    assert float(schedule_lr(tc, 100)) < 1e-6
    wsd = TrainConfig(learning_rate=1.0, warmup_steps=10, total_steps=100,
                      schedule="wsd")
    assert abs(float(schedule_lr(wsd, 50)) - 1.0) < 1e-6   # stable phase
    assert float(schedule_lr(wsd, 99)) < 0.01              # decay phase


def test_grad_clip():
    from repro.train.optim import clip_by_global_norm
    g = {"a": jnp.array([3.0, 4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-6
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5


def test_int8_compression_error_feedback():
    from repro.train.optim import compress_int8, decompress_int8
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(1000).astype(np.float32))
    q, s = compress_int8(g)
    err = g - decompress_int8(q, s)
    assert float(jnp.abs(err).max()) <= float(s) + 1e-6
    # error feedback: accumulated residual keeps the long-run mean unbiased
    acc = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    for _ in range(50):
        q, s = compress_int8(g + acc)
        sent = decompress_int8(q, s)
        acc = (g + acc) - sent
        total = total + sent
    np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g),
                               atol=5e-3)


def test_train_step_microbatch_equivalence():
    """Grad accumulation (microbatch) == full-batch step."""
    from repro.configs import smoke_config
    from repro.launch.mesh import make_host_mesh
    from repro.models.transformer import init_params
    from repro.train.optim import TrainConfig, init_opt_state
    from repro.train.steps import make_train_step
    cfg = smoke_config("qwen2.5-3b", layers=2)
    mesh = make_host_mesh()
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                          cfg.vocab_size)}
    tc_full = TrainConfig(microbatch=0, warmup_steps=0, schedule="const")
    tc_mb = TrainConfig(microbatch=2, warmup_steps=0, schedule="const")
    s1, _ = make_train_step(cfg, tc_full, mesh)
    s2, _ = make_train_step(cfg, tc_mb, mesh)
    copy = lambda t: jax.tree.map(jnp.copy, t)   # steps donate their inputs
    p1, _, m1 = s1(copy(params), copy(opt), batch)
    p2, _, m2 = s2(copy(params), copy(opt), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-2)
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), p1, p2)
    assert max(jax.tree.leaves(d)) < 2e-2
