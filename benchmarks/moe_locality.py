"""Beyond-paper table — LOrder's mechanism on MoE expert dispatch.

For the two assigned MoE architectures, measures on a real routed batch:
* weight-stream reduction of locality-sorted vs unsorted dispatch
  (the MoE analogue of Fig 5.2.2's cache speedups);
* cross-shard traffic with and without the expert-affinity permutation
  (LOrder on the expert co-activation graph);
* wall-clock of the sorted vs dense dispatch path at smoke scale.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .common import fmt_table, save_json, time_call


def route_real_batch(arch: str, tokens: int = 8192, seed: int = 0):
    """Run the actual router of a smoke-scaled arch on Zipf data."""
    from repro.configs import smoke_config
    from repro.data.pipeline import DataConfig, ZipfCommunityCorpus
    from repro.models.moe import _route, init_moe
    from repro.models.transformer import init_params

    cfg = smoke_config(arch)
    # full expert count at smoke width so routing skew is realistic
    from repro.configs import get_config
    e_full = get_config(arch).num_experts
    cfg = dataclasses.replace(cfg, num_experts=e_full,
                              experts_per_token=get_config(
                                  arch).experts_per_token)
    p = init_moe(jax.random.PRNGKey(seed), cfg)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=tokens,
                    global_batch=1, seed=seed)
    toks = ZipfCommunityCorpus(dc).batch(0)
    params = init_params(cfg, jax.random.PRNGKey(seed + 1))
    emb = np.asarray(jax.device_get(params["embed"]["table"]))[toks[0]]
    experts, gates, aux = _route(p, jnp.asarray(emb), cfg)
    return cfg, p, np.asarray(experts), np.asarray(emb)


def run() -> list[dict]:
    from repro.locality.moe import (cross_shard_traffic, dispatch_stats,
                                    expert_affinity_permutation)
    from repro.models.moe import apply_moe
    from repro.configs import get_config

    rows = []
    for arch in ("mixtral-8x7b", "moonshot-v1-16b-a3b"):
        full = get_config(arch)
        cfg, p, experts, emb = route_real_batch(arch)
        st = dispatch_stats(experts, cfg.num_experts,
                            d_model=full.d_model, d_ff=full.d_ff)
        shards = min(cfg.num_experts, 16)
        base_traffic = cross_shard_traffic(experts, cfg.num_experts, shards)
        perm = expert_affinity_permutation(experts, cfg.num_experts)
        opt_traffic = cross_shard_traffic(experts, cfg.num_experts, shards,
                                          perm)

        x = jnp.asarray(emb, jnp.bfloat16).reshape(1, -1, cfg.d_model)
        sorted_fn = jax.jit(lambda xx: apply_moe(p, xx, cfg)[0])
        dense_cfg = dataclasses.replace(cfg, moe_locality_sort=False)
        dense_fn = jax.jit(lambda xx: apply_moe(p, xx, dense_cfg)[0])
        t_sorted, _ = time_call(sorted_fn, x, repeats=3)
        t_dense, _ = time_call(dense_fn, x, repeats=3)

        rows.append({
            "arch": arch,
            "experts": f"{cfg.num_experts}top{cfg.experts_per_token}",
            "load_cv": round(st["load_cv"], 3),
            "stream_reduction_x": round(st["weight_stream_reduction"], 1),
            "pad_frac_%": round(100 * st["pad_fraction"], 1),
            "xshard_base": round(base_traffic, 3),
            "xshard_lorder": round(opt_traffic, 3),
            "wall_sorted_ms": round(1e3 * t_sorted, 1),
            "wall_dense_ms": round(1e3 * t_dense, 1),
            "wall_speedup": round(t_dense / t_sorted, 2),
        })
        print(f"[moe_locality] {arch} done", flush=True)
    save_json("moe_locality", rows)
    return rows


def main():
    rows = run()
    print(fmt_table(rows, list(rows[0].keys())))


if __name__ == "__main__":
    main()
