"""Synthetic graph suite — offline substitutes for the paper's datasets.

The container has no network access, so the SNAP graphs the paper uses
(LiveJournal, Orkut, Youtube, Pokec, PLD-arc) are replaced by generators
matched on the properties the paper's mechanism depends on:

* power-law degree skew (hot-vertex fraction, Table 1 analogue),
* community structure (planted partition, ground-truth labels retained so
  LOrder-v2 can consume them),
* a diameter range spanning "small-world social" (D≈8-20) to "road-like"
  (D≈O(√V)) for the κ = D/2 analysis.

`kron` mirrors the paper's Graph500 Kronecker dataset in-kind (RMAT).
"""
from __future__ import annotations

import numpy as np

from .csr import Graph, from_edges


def _rng(seed):
    return np.random.default_rng(seed)


def rmat(scale: int, edge_factor: int = 16, a: float = 0.57, b: float = 0.19,
         c: float = 0.19, seed: int = 0, name: str | None = None) -> Graph:
    """Graph500-style RMAT/Kronecker generator (paper's kron dataset)."""
    n = 1 << scale
    m = n * edge_factor
    rng = _rng(seed)
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab, abc = a + b, a + b + c
    for bit in range(scale):
        r = rng.random(m)
        # quadrant choice per Graph500 reference
        go_right = (r >= a) & (r < ab) | (r >= abc)
        go_down = r >= ab
        src = (src << 1) | go_down.astype(np.int64)
        dst = (dst << 1) | go_right.astype(np.int64)
    # permute vertex labels so generation order carries no information
    relab = rng.permutation(n)
    return from_edges(n, relab[src], relab[dst], name=name or f"kron{scale}")


def _chung_lu_edges(weights: np.ndarray, m: int, rng) -> tuple[np.ndarray, np.ndarray]:
    """Sample m edges with endpoint probability ∝ weights (power-law degrees)."""
    p = weights / weights.sum()
    src = rng.choice(len(weights), size=m, p=p)
    dst = rng.choice(len(weights), size=m, p=p)
    return src, dst


def powerlaw_community(num_vertices: int, avg_degree: float = 16.0,
                       num_communities: int | None = None,
                       mixing: float = 0.1, alpha: float = 2.2,
                       seed: int = 0, name: str = "plc") -> Graph:
    """Planted-partition graph with Zipf community sizes and power-law degrees.

    ``mixing`` is the fraction of edges crossing community boundaries
    (LFR-style µ). Ground-truth community labels are retained on the Graph.
    """
    rng = _rng(seed)
    n = num_vertices
    k = num_communities or max(8, int(np.sqrt(n) / 4))
    # Zipf community sizes
    sizes = 1.0 / np.arange(1, k + 1) ** 1.2
    sizes = np.maximum((sizes / sizes.sum() * n).astype(np.int64), 4)
    sizes[0] += n - sizes.sum()  # absorb rounding in the largest community
    labels = np.repeat(np.arange(k), sizes)[:n]
    rng.shuffle(labels)

    # power-law vertex weights (degree propensity)
    w = (1.0 - rng.random(n)) ** (-1.0 / (alpha - 1.0))
    w = np.minimum(w, n ** 0.5)  # cap to avoid absurd hubs

    m = int(n * avg_degree)
    m_inter = int(m * mixing)
    m_intra = m - m_inter

    # intra-community edges: sample community ∝ total weight, endpoints within
    order = np.argsort(labels, kind="stable")
    lab_sorted = labels[order]
    starts = np.searchsorted(lab_sorted, np.arange(k))
    ends = np.searchsorted(lab_sorted, np.arange(k), side="right")
    comm_w = np.bincount(labels, weights=w, minlength=k)
    comm_p = comm_w / comm_w.sum()
    counts = rng.multinomial(m_intra, comm_p)
    src_parts, dst_parts = [], []
    for ci in np.nonzero(counts)[0]:
        members = order[starts[ci]:ends[ci]]
        pw = w[members] / w[members].sum()
        src_parts.append(members[rng.choice(len(members), counts[ci], p=pw)])
        dst_parts.append(members[rng.choice(len(members), counts[ci], p=pw)])
    s_i, d_i = _chung_lu_edges(w, m_inter, rng)
    src = np.concatenate(src_parts + [s_i])
    dst = np.concatenate(dst_parts + [d_i])
    return from_edges(n, src, dst, dedup=True, communities=labels, name=name)


def small_world(num_vertices: int, k: int = 8, rewire: float = 0.05,
                seed: int = 0, name: str = "smallworld") -> Graph:
    """Watts-Strogatz ring: moderate diameter, strong local structure."""
    rng = _rng(seed)
    n = num_vertices
    offsets = np.arange(1, k // 2 + 1)
    src = np.repeat(np.arange(n), len(offsets))
    dst = (src + np.tile(offsets, n)) % n
    flip = rng.random(len(dst)) < rewire
    dst[flip] = rng.integers(0, n, flip.sum())
    return from_edges(n, np.concatenate([src, dst]),
                      np.concatenate([dst, src]), dedup=True, name=name)


def road_grid(side: int, shortcuts: int = 0, seed: int = 0,
              name: str = "road") -> Graph:
    """2-D grid ('road network'): diameter ≈ 2·side — the high-D regime."""
    rng = _rng(seed)
    n = side * side
    idx = np.arange(n).reshape(side, side)
    right = np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()])
    down = np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()])
    src = np.concatenate([right[0], down[0]])
    dst = np.concatenate([right[1], down[1]])
    if shortcuts:
        s = rng.integers(0, n, shortcuts)
        d = rng.integers(0, n, shortcuts)
        src, dst = np.concatenate([src, s]), np.concatenate([dst, d])
    return from_edges(n, np.concatenate([src, dst]),
                      np.concatenate([dst, src]), dedup=True, name=name)


def clustered_vectors(num_vectors: int, dim: int = 16,
                      num_clusters: int = 8, spread: float = 0.15,
                      zipf: float = 1.1, seed: int = 0
                      ) -> tuple[np.ndarray, np.ndarray]:
    """Synthetic vector corpus for the k-NN search workload (search/).

    Gaussian blobs around ``num_clusters`` random centers with Zipf-skewed
    cluster sizes — the skew is what makes a *query* mix concentrate visits
    on the popular clusters' vertices, mirroring the visit-frequency skew
    Coleman et al. exploit on search graphs. Returns ``(vectors, labels)``
    with float32 ``(N, dim)`` vectors and int64 cluster labels.
    """
    rng = _rng(seed)
    n, k = num_vectors, max(1, num_clusters)
    sizes = 1.0 / np.arange(1, k + 1) ** zipf
    sizes = np.maximum((sizes / sizes.sum() * n).astype(np.int64), 1)
    sizes[0] += n - sizes.sum()  # absorb rounding in the largest cluster
    labels = np.repeat(np.arange(k), sizes)[:n]
    rng.shuffle(labels)
    centers = rng.standard_normal((k, dim))
    vecs = centers[labels] + spread * rng.standard_normal((n, dim))
    return vecs.astype(np.float32), labels


# --------------------------------------------------------------------------
# Dataset registry: the paper's six datasets, regenerated in-kind.
# scale=1.0 is the default benchmark size; tests use smaller scales.
# --------------------------------------------------------------------------
def dataset_suite(scale: float = 1.0, seed: int = 7) -> dict[str, Graph]:
    def sz(x):
        return max(1024, int(x * scale))

    return {
        # LiveJournal-like: large social network, D≈16
        "lj-sim": powerlaw_community(sz(1 << 17), avg_degree=14.0, mixing=0.12,
                                     seed=seed, name="lj-sim"),
        # Orkut-like: dense community graph, D≈9
        "orkut-sim": powerlaw_community(sz(1 << 16), avg_degree=38.0, mixing=0.25,
                                        num_communities=64, seed=seed + 1,
                                        name="orkut-sim"),
        # PLD-arc-like: hyperlink graph, extreme skew
        "pld-sim": rmat(max(10, int(np.log2(sz(1 << 17)))), edge_factor=8,
                        a=0.65, b=0.15, c=0.15, seed=seed + 2, name="pld-sim"),
        # the paper's kron dataset (scaled from kron23)
        "kron-sim": rmat(max(10, int(np.log2(sz(1 << 16)))), edge_factor=16,
                         seed=seed + 3, name="kron-sim"),
        # Youtube-like: sparse community graph, high diameter (D≈20)
        "youtube-sim": powerlaw_community(sz(1 << 17), avg_degree=5.0,
                                          mixing=0.05, seed=seed + 4,
                                          name="youtube-sim"),
        # Pokec-like: social network, D≈11
        "pokec-sim": powerlaw_community(sz(1 << 16), avg_degree=18.0,
                                        mixing=0.15, seed=seed + 5,
                                        name="pokec-sim"),
    }


def diameter_suite(seed: int = 11) -> dict[str, Graph]:
    """Extra graphs spanning the diameter axis (paper's κ=D/2 analysis)."""
    return {
        "ring-sw": small_world(1 << 15, k=8, rewire=0.01, seed=seed),
        "road-256": road_grid(128, shortcuts=64, seed=seed),
        "kron-lowD": rmat(14, edge_factor=16, seed=seed),
    }
