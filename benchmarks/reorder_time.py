"""Paper Fig 5.2.1 — time to reorder each dataset with each scheme.

Claim under test: DBG and SOrder (single traversal) reorder ~2× faster
than NOrder and LOrder (double traversal); GOrder ≫ everything.
"""
from __future__ import annotations

import time

import numpy as np

from .common import bench_suite, fmt_table, save_json, schemes


def run(scale: float = 0.5, include_gorder: bool = True,
        gorder_cap: int = 1 << 15) -> list[dict]:
    suite = bench_suite(scale)
    sch = schemes()
    rows = []
    for dname, g in suite.items():
        row = {"dataset": dname, "V": g.num_vertices, "E": g.num_edges}
        for sname, fn in sch.items():
            t0 = time.perf_counter()
            fn(g)
            row[sname] = round(time.perf_counter() - t0, 3)
        if include_gorder and g.num_vertices <= gorder_cap:
            from repro.core.baselines import gorder_order
            t0 = time.perf_counter()
            gorder_order(g, max_vertices=gorder_cap)
            row["gorder"] = round(time.perf_counter() - t0, 3)
        rows.append(row)
        print(f"[reorder_time] {dname} done", flush=True)
    save_json("reorder_time", rows)
    return rows


def main(scale: float = 0.5, include_gorder: bool = False):
    # GOrder costs ~40 min/graph at this scale; the recorded full run
    # (incl. GOrder) lives in results/reorder_time_gorder.json
    rows = run(scale, include_gorder=include_gorder)
    cols = ["dataset", "V", "E", "dbg", "sorder", "norder", "hubcluster",
            "lorder", "lorder-v2", "gorder"]
    print(fmt_table(rows, cols))
    # claim check: single-traversal schemes faster than double-traversal
    ok = sum(r["dbg"] <= r["lorder"] for r in rows)
    print(f"\nDBG <= LOrder reorder time on {ok}/{len(rows)} datasets "
          f"(paper: single- vs double-traversal)")


if __name__ == "__main__":
    main()
