"""Calibration loop: strength fitting, persistence, online re-decision."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.generators import powerlaw_community
from repro.engine import (DEFAULT_PRIORS, EngineSession, ReorderPolicy,
                          StrengthCalibrator)
from repro.engine.registry import GraphProbes
from repro.engine.session import AmortizationLedger


def _probes(gini=0.55, hub_mass=0.6, diameter=8) -> GraphProbes:
    return GraphProbes(num_vertices=1000, num_edges=8000, avg_degree=8.0,
                       degree_gini=gini, hub_fraction=0.2,
                       hub_mass=hub_mass, diameter=diameter,
                       probe_seconds=0.0)


# -------------------------------------------------------------- fitting
def test_calibrator_starts_at_priors():
    cal = StrengthCalibrator()
    for scheme, prior in DEFAULT_PRIORS.items():
        assert cal.strength(scheme) == pytest.approx(prior)


def test_calibrator_converges_to_generating_strength():
    true_strength = 0.2   # far below the 0.75 prior
    cal = StrengthCalibrator()
    rng = np.random.default_rng(0)
    for _ in range(300):
        skew = rng.uniform(0.3, 0.9)
        gain = true_strength * skew + rng.normal(0, 0.02)
        cal.observe("lorder", skew, gain)
    assert cal.strength("lorder") == pytest.approx(true_strength, abs=0.05)
    assert cal.count("lorder") == 300


def test_calibrator_shrinks_toward_prior_with_few_samples():
    cal = StrengthCalibrator(shrinkage=2.0)
    cal.observe("dbg", skew=0.5, realized_gain=0.0)  # one bad outcome
    # one sample (skew^2 = 0.25) barely moves a shrinkage-2 estimate
    assert cal.strength("dbg") > 0.8 * DEFAULT_PRIORS["dbg"]


def test_calibrator_strength_clamped_and_original_pinned():
    cal = StrengthCalibrator()
    for _ in range(50):
        cal.observe("dbg", 0.9, -5.0)
    assert cal.strength("dbg") == 0.0
    cal.observe("original", 0.9, 0.7)
    assert cal.strength("original") == 0.0


def test_calibrator_save_load_round_trip(tmp_path):
    cal = StrengthCalibrator(shrinkage=3.5)
    rng = np.random.default_rng(1)
    for _ in range(20):
        cal.observe("lorder", rng.uniform(0.2, 0.9), rng.uniform(0, 0.5))
        cal.observe("hubcluster", rng.uniform(0.2, 0.9), rng.uniform(0, 0.3))
    path = cal.save(tmp_path / "cal.json")
    loaded = StrengthCalibrator.load(path)
    assert loaded.shrinkage == cal.shrinkage
    assert loaded.strengths() == cal.strengths()
    assert loaded.count("lorder") == 20
    # loaded state keeps accumulating identically
    loaded.observe("lorder", 0.5, 0.1)
    cal.observe("lorder", 0.5, 0.1)
    assert loaded.strength("lorder") == pytest.approx(cal.strength("lorder"))
    # custom priors round-trip without picking up default schemes
    custom = StrengthCalibrator(priors={"lorder": 0.6})
    reloaded = StrengthCalibrator.load(custom.save(tmp_path / "custom.json"))
    assert set(reloaded.strengths()) == {"lorder"}
    assert reloaded.strength("lorder") == pytest.approx(0.6)


# ---------------------------------------------- policy consults the fit
def test_policy_record_feeds_calibrator():
    pol = ReorderPolicy()
    d = pol.decide(_probes(), expected_queries=500)
    assert d.scheme == "lorder" and d.skew > 0
    pol.record("g", d, miss_rate_before=0.5, miss_rate_after=0.45,
               reorder_seconds=1.0)
    assert pol.calibrator.count("lorder") == 1
    # "original" decisions and unmeasured records are not samples
    d0 = pol.decide(_probes(), expected_queries=1)
    pol.record("g0", d0, 0.0, 0.0, 0.0)
    assert pol.calibrator.count("original") == 0


def test_uncalibrated_policy_matches_static_tree():
    pol = ReorderPolicy()
    assert pol.decide(_probes(gini=0.35), 8).scheme == "hubcluster"
    assert pol.decide(_probes(gini=0.55), 8).scheme == "dbg"
    assert pol.decide(_probes(gini=0.55), 500).scheme == "lorder"


def test_decision_changes_after_calibrating_on_outcomes():
    pol = ReorderPolicy()
    probes = _probes()
    assert pol.decide(probes, 500).scheme == "lorder"
    # recorded outcomes: lorder keeps realizing ~nothing on this workload
    for i in range(12):
        d = pol.decide(probes, 500)
        pol.record(f"g{i}", d, miss_rate_before=0.5,
                   miss_rate_after=0.49, reorder_seconds=1.0)
    after = pol.decide(probes, 500)
    assert after.scheme == "dbg"
    assert "calibration override" in after.reason
    assert pol.calibrator.strength("lorder") < 0.3


def test_override_needs_margin_not_noise():
    pol = ReorderPolicy()
    probes = _probes()
    # outcomes that roughly confirm the prior must not flip the decision
    for i in range(12):
        d = pol.decide(probes, 500)
        pol.record(f"g{i}", d, miss_rate_before=0.5,
                   miss_rate_after=0.5 * (1 - 0.7 * d.skew),
                   reorder_seconds=1.0)
    assert pol.decide(probes, 500).scheme == "lorder"


# ------------------------------------------------------------ ledger fix
def test_ledger_negative_gain_clamped_and_surfaced():
    led = AmortizationLedger(reorder_seconds=1.0, realized_gain=-0.5)
    led.record_query(num_sources=2, wall_seconds=0.3)
    assert led.estimated_saved_seconds == 0.0
    assert led.estimated_lost_seconds == pytest.approx(0.3 * 0.5 / 1.5)
    d = led.as_dict()
    assert d["regressed"] is True and d["amortized"] is False
    good = AmortizationLedger(reorder_seconds=1.0, realized_gain=0.4)
    good.record_query(1, 0.3)
    assert good.estimated_saved_seconds == pytest.approx(0.3 * 0.4 / 0.6)
    assert good.as_dict()["regressed"] is False


# --------------------------------------------------------- re-decision
@pytest.fixture(scope="module")
def skewed_graph():
    return powerlaw_community(1200, avg_degree=10.0, seed=3, name="plc")


def test_redecision_fires_on_volume_divergence(skewed_graph):
    session = EngineSession(redecide_min_queries=6, redecide_factor=3.0)
    gid = session.register(skewed_graph, expected_queries=2)
    entry = session.registry.get(gid)
    assert entry.decision.scheme == "original"   # volume gate
    rng = np.random.default_rng(0)
    for _ in range(8):
        session.submit(gid, "bfs", rng.integers(0, 1200, size=2))
    assert entry.redecisions >= 1
    ev = session.redecision_log[0]
    assert ev["trigger"] == "volume-divergence"
    assert ev["old_scheme"] == "original" and ev["new_scheme"] != "original"
    # ledger was reset for the new layout
    assert entry.ledger.queries_served < entry.queries_observed
    assert entry.expected_queries >= 6
    # served results remain correct post-re-reorder
    import jax.numpy as jnp
    from repro.algos import kernels as K
    from repro.algos.graph_arrays import to_device
    depth = session.submit(gid, "bfs", [17])
    ref = np.asarray(K.bfs(to_device(skewed_graph), jnp.int32(17)))
    np.testing.assert_array_equal(depth[0], ref)
    assert session.telemetry()["redecisions"]


def test_no_redecision_on_accurate_hint(skewed_graph):
    session = EngineSession(redecide_min_queries=6)
    gid = session.register(skewed_graph, expected_queries=256)
    rng = np.random.default_rng(1)
    for _ in range(10):
        session.submit(gid, "bfs", rng.integers(0, 1200, size=2))
    assert session.registry.get(gid).redecisions == 0
    assert session.redecision_log == []


def test_redecision_demotes_never_amortizing_reorder(skewed_graph):
    session = EngineSession(redecide_min_queries=4)
    gid = session.register(skewed_graph, expected_queries=64)
    entry = session.registry.get(gid)
    assert entry.decision.scheme != "original"
    # simulate a regressing reorder: the cache model says it lost ground
    entry.ledger.realized_gain = -0.2
    rng = np.random.default_rng(2)
    for _ in range(5):
        session.submit(gid, "bfs", rng.integers(0, 1200, size=2))
    assert entry.decision.scheme == "original"
    ev = session.redecision_log[0]
    assert ev["trigger"] == "never-amortize"
    assert "demote" in ev["reason"]


def test_redecision_count_is_capped(skewed_graph):
    session = EngineSession(redecide_min_queries=2, redecide_factor=1.5,
                            max_redecisions=1)
    gid = session.register(skewed_graph, expected_queries=1)
    rng = np.random.default_rng(3)
    for _ in range(30):
        session.submit(gid, "bfs", rng.integers(0, 1200, size=2))
    assert session.registry.get(gid).redecisions == 1


# ------------------------------------------------- family-keyed fits (v2)
def test_family_fit_matches_global_when_one_family_owns_the_data():
    cal = StrengthCalibrator()
    rng = np.random.default_rng(3)
    for _ in range(40):
        skew = rng.uniform(0.3, 0.9)
        cal.observe("lorder", skew, 0.25 * skew, family="analytics")
    # leave-one-family-out shrinkage: with a single family in play the
    # family fit reduces *exactly* to the legacy global fit
    assert cal.strength("lorder", family="analytics") == pytest.approx(
        cal.strength("lorder"))
    # a family with no observations inherits the global fit wholesale
    assert cal.strength("lorder", family="search") == pytest.approx(
        cal.strength("lorder"))


def test_family_fits_diverge_with_mixed_evidence():
    cal = StrengthCalibrator()
    rng = np.random.default_rng(4)
    for _ in range(60):   # visitsort converts skew well on search graphs,
        skew = rng.uniform(0.3, 0.9)   # poorly on analytics ones
        cal.observe("visitsort", skew, 0.7 * skew, family="search")
        cal.observe("visitsort", skew, 0.1 * skew, family="analytics")
    s_search = cal.strength("visitsort", family="search")
    s_analytics = cal.strength("visitsort", family="analytics")
    assert s_search > cal.strength("visitsort") > s_analytics
    assert cal.count("visitsort", family="search") == 60
    assert cal.count("visitsort") == 120
    blob = cal.as_dict()
    assert blob["families"]["search/visitsort"]["count"] == 60


def test_family_calibration_save_load_round_trip(tmp_path):
    cal = StrengthCalibrator()
    cal.observe("visitsort", 0.6, 0.3, family="search")
    cal.observe("dbg", 0.5, 0.2, family="analytics")
    cal.observe("dbg", 0.5, 0.2)            # global-only sample
    path = cal.save(tmp_path / "cal.json")
    back = StrengthCalibrator.load(path)
    for scheme, fam in (("visitsort", "search"), ("dbg", "analytics")):
        assert back.strength(scheme, family=fam) == pytest.approx(
            cal.strength(scheme, family=fam))
        assert back.count(scheme, family=fam) == 1
    assert back.count("dbg") == 2


def test_load_pre_v2_blob_without_families(tmp_path):
    import json
    cal = StrengthCalibrator()
    cal.observe("lorder", 0.5, 0.3, family="analytics")
    path = cal.save(tmp_path / "cal.json")
    blob = json.loads(path.read_text())
    del blob["families"]                    # a pre-v2 save
    path.write_text(json.dumps(blob))
    back = StrengthCalibrator.load(path)
    assert back.count("lorder") == 1
    assert back.count("lorder", family="analytics") == 0
    assert back.strength("lorder", family="analytics") == pytest.approx(
        back.strength("lorder"))            # falls back to global
