"""End-to-end driver — train a ~100M-parameter qwen-family model for a few
hundred steps on the synthetic Zipf corpus, with the paper's vocab-LOrder
preprocessing, checkpointing, and a mid-run simulated crash + restart.

This is the deliverable (b) end-to-end example: data pipeline → LOrder
vocab permutation → sharded train step → async checkpoints → elastic
resume. At CPU scale it uses a reduced-depth trunk; the same driver runs
the full configs on a TPU fleet (see repro/launch/train.py --help).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import shutil
import tempfile

import dataclasses

import jax
import numpy as np


def build_100m_config(small: bool = False):
    """qwen2.5-family trunk on the full-model code path.

    Default ≈100M params (8L d768 ff2304 v49152, tied embeddings);
    ``--small`` builds the 28M variant for quick CPU validation runs
    (what CI exercises — one 1-core container step of the 100M config
    takes ~30 s).
    """
    from repro.configs import get_config
    cfg = get_config("qwen2.5-3b")
    if small:
        return dataclasses.replace(
            cfg, num_layers=4, d_model=512, num_heads=8, num_kv_heads=2,
            head_dim=64, d_ff=1408, vocab_size=32_768,
            block_pattern=("attn",) * 4, loss_chunk=128, remat=False)
    return dataclasses.replace(
        cfg, num_layers=8, d_model=768, num_heads=12, num_kv_heads=2,
        head_dim=64, d_ff=2304, vocab_size=49_152,
        block_pattern=("attn",) * 8, loss_chunk=128, remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--crash-at", type=int, default=None,
                    help="simulate a crash at this step (default: steps//2)")
    ap.add_argument("--small", action="store_true",
                    help="28M quick variant (CPU validation)")
    args = ap.parse_args()

    from repro.ckpt.manager import CheckpointManager
    from repro.data.pipeline import DataConfig, DataLoader, corpus_sample
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import StragglerMonitor
    from repro.locality.vocab import hot_coverage, vocab_permutation
    from repro.models.transformer import init_params
    from repro.train.optim import TrainConfig, init_opt_state
    from repro.train.steps import make_train_step
    import jax.numpy as jnp

    cfg = build_100m_config(small=args.small)
    n_params = cfg.param_count()
    print(f"[model] {cfg.name}-100m: {n_params / 1e6:.0f}M params "
          f"({cfg.num_layers}L d{cfg.d_model} v{cfg.vocab_size})")

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                    global_batch=args.global_batch)

    # the paper's preprocessing: LOrder over the token co-occurrence graph
    sample = corpus_sample(dc, 1)
    vr = vocab_permutation(sample, cfg.vocab_size, hot_fraction=0.05)
    print(f"[vocab-lorder] 5% hot slab covers "
          f"{100 * hot_coverage(sample, vr):.1f}% of corpus tokens")

    mesh = make_host_mesh()
    tc = TrainConfig(learning_rate=6e-4, total_steps=args.steps,
                     warmup_steps=args.steps // 20, schedule="wsd")
    params = vr.apply_to_params(init_params(cfg, jax.random.PRNGKey(0)))
    opt = init_opt_state(params)
    step_fn, _ = make_train_step(cfg, tc, mesh)

    ckpt_dir = tempfile.mkdtemp(prefix="repro_100m_")
    ckpt = CheckpointManager(ckpt_dir, keep=2)
    monitor = StragglerMonitor()
    ckpt_every = max(10, args.steps // 6)
    crash_at = args.crash_at or min(args.steps - 5, 2 * ckpt_every)

    losses = []
    step = 0
    crashed = False
    loader = DataLoader(dc, vr, start_step=0)
    import time
    t_start = time.time()
    try:
        while step < args.steps:
            batch = {"tokens": jnp.asarray(next(loader)["tokens"])}
            t0 = time.time()
            params, opt, metrics = step_fn(params, opt, batch)
            monitor.observe(time.time() - t0)
            losses.append(float(metrics["loss"]))
            if step % 25 == 0:
                tok_s = args.global_batch * args.seq_len / (time.time() - t0)
                print(f"step {step:4d} loss {losses[-1]:.4f} "
                      f"({tok_s / 1e3:.1f}k tok/s)", flush=True)
            if (step + 1) % ckpt_every == 0:
                ckpt.save(step, {"params": params, "opt": opt})
            if step == crash_at and not crashed:
                crashed = True
                print(f"[fault] simulating node failure at step {step} "
                      "(state lost; restoring from last checkpoint)")
                loader.close()
                ckpt.wait()
                restored_step, state = ckpt.restore()
                if state is None:        # no commit yet: cold restart
                    restored_step = -1
                    params = vr.apply_to_params(
                        init_params(cfg, jax.random.PRNGKey(0)))
                    opt = init_opt_state(params)
                else:
                    params, opt = state["params"], state["opt"]
                step = restored_step + 1
                loader = DataLoader(dc, vr, start_step=step)
                continue
            step += 1
    finally:
        loader.close()
        ckpt.wait()
        shutil.rmtree(ckpt_dir, ignore_errors=True)

    dt = time.time() - t_start
    print(f"[done] loss {np.mean(losses[:10]):.3f} -> "
          f"{np.mean(losses[-10:]):.3f} in {dt / 60:.1f} min; "
          f"{monitor.flagged} straggler flags; crash+restart exercised: "
          f"{crashed}")
    assert np.mean(losses[-10:]) < np.mean(losses[:10])


if __name__ == "__main__":
    main()
