"""paligemma-3b [vlm]: gemma decoder 18L d2048 8H (MQA kv=1) ff16384
v257216 + SigLIP patch-embedding frontend (STUB: input_specs provides
precomputed patch embeddings as a 256-token prefix; prefix-LM attention).
[arXiv:2407.07726; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1,
    head_dim=256,                       # gemma: 8 heads × 256
    d_ff=16384, vocab_size=257_216,
    prefix_tokens=256,                  # SigLIP patch embeddings (stub)
    mlp_type="swiglu",                  # gemma geglu = gated mlp
    norm_type="rmsnorm",
    emb_scale=2048 ** 0.5,              # gemma embedding scaling
    tie_embeddings=True,
    vocab_reorder=True, hot_vocab_fraction=0.02,
)
