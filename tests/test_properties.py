"""Hypothesis property tests over arbitrary generated graphs: system
invariants of the reordering machinery and the relabeling contract."""
from __future__ import annotations

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.csr import from_edges, validate_permutation
from repro.core.lorder import form_localities, lorder, lorder_v2
from repro.core.baselines import (dbg_order, hubcluster_order, norder_order,
                                  sorder_order, sort_order)
from repro.core.diameter import estimate_diameter
from repro.core.traversal import bfs_levels


@st.composite
def graphs(draw, max_v: int = 64, max_e: int = 256):
    n = draw(st.integers(min_value=1, max_value=max_v))
    m = draw(st.integers(min_value=0, max_value=max_e))
    src = draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
    dst = draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
    return from_edges(n, np.array(src, np.int64), np.array(dst, np.int64))


@st.composite
def graph_and_kappa(draw):
    g = draw(graphs())
    k = draw(st.integers(min_value=1, max_value=8))
    return g, k


@settings(max_examples=60, deadline=None)
@given(graph_and_kappa())
def test_lorder_always_bijective(gk):
    g, k = gk
    perm = lorder(g, kappa=k)
    assert validate_permutation(np.asarray(perm), g.num_vertices)


@settings(max_examples=60, deadline=None)
@given(graph_and_kappa())
def test_localities_partition_vertices(gk):
    g, k = gk
    members, info = form_localities(g, kappa=k, hot=g.hot_mask())
    cat = np.concatenate(members) if members else np.empty(0, np.int64)
    assert sorted(cat.tolist()) == list(range(g.num_vertices))
    assert (info.sizes >= 1).all()
    # seeds are the first member of their locality
    for s, m in zip(info.seeds, members):
        assert m[0] == s


@settings(max_examples=40, deadline=None)
@given(graphs())
def test_baselines_bijective(g):
    for fn in (sort_order, dbg_order, hubcluster_order, norder_order,
               lorder_v2):
        assert validate_permutation(np.asarray(fn(g)), g.num_vertices)
    assert validate_permutation(
        np.asarray(sorder_order(g, hot_threshold=None)), g.num_vertices)


@settings(max_examples=40, deadline=None)
@given(graphs(max_v=32, max_e=128), st.integers(0, 10_000))
def test_relabel_preserves_multigraph(g, seed):
    rng = np.random.default_rng(seed)
    perm = rng.permutation(g.num_vertices)
    gp = g.apply_permutation(perm)
    orig = g.edge_multiset()
    mapped = np.stack([perm[orig[:, 0]], perm[orig[:, 1]]], 1)
    order = np.lexsort((mapped[:, 1], mapped[:, 0]))
    assert np.array_equal(mapped[order], gp.edge_multiset())


@settings(max_examples=40, deadline=None)
@given(graphs(max_v=32, max_e=128), st.integers(0, 10_000))
def test_bfs_levels_permutation_equivariant(g, seed):
    rng = np.random.default_rng(seed)
    perm = rng.permutation(g.num_vertices)
    gp = g.apply_permutation(perm)
    src = int(rng.integers(g.num_vertices))
    d1 = bfs_levels(g, src)
    d2 = bfs_levels(gp, int(perm[src]))
    assert np.array_equal(d1, d2[perm])


def _exact_diameter(g):
    und = g.undirected
    best = 0
    for v in range(und.num_vertices):
        d = bfs_levels(und, v)
        best = max(best, int(d.max()))
    return best


@settings(max_examples=30, deadline=None)
@given(graphs(max_v=48))
def test_diameter_estimate_is_sound_lower_bound(g):
    """Double-sweep ≤ exact diameter; exact diameter is relabel-invariant.
    (The estimate itself is a heuristic whose tie-breaking is id-dependent,
    so only the bound — not the estimate — is a structural invariant.)"""
    exact = _exact_diameter(g)
    est = estimate_diameter(g)
    assert est <= exact
    rng = np.random.default_rng(0)
    perm = rng.permutation(g.num_vertices)
    gp = g.apply_permutation(perm)
    assert _exact_diameter(gp) == exact
    assert estimate_diameter(gp) <= exact


@settings(max_examples=40, deadline=None)
@given(graphs(max_v=48, max_e=192))
def test_hot_mask_threshold_semantics(g):
    hot = g.hot_mask()
    thr = g.average_degree
    assert np.array_equal(hot, g.degree > thr)
    # hot vertices are a minority for any skewed distribution with a mean
    # threshold... not guaranteed in adversarial graphs, but the count must
    # be consistent
    assert 0 <= hot.sum() <= g.num_vertices
