"""The six JAX graph kernels vs independent host oracles + equivariance."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest

from repro.algos.graph_arrays import to_device
from repro.algos.kernels import (bc, bc_single_source, bfs, cc_labelprop,
                                 cc_shiloach_vishkin, pagerank, sssp)
from repro.core.lorder import lorder
from repro.core.traversal import bfs_levels

# The host oracles now live next to the reordering baselines
# (core/baselines.py) so the cross-backend parity matrix shares them.
from repro.core.baselines import (bc_baseline as bc_oracle,
                                  cc_baseline as cc_oracle,
                                  pagerank_baseline as pr_oracle,
                                  sssp_baseline as sssp_oracle)


# ----------------------------------------------------------------- tests
def test_bfs_matches_host(any_graph):
    g = any_graph
    ga = to_device(g)
    got = np.asarray(bfs(ga, jnp.int32(0)))
    want = bfs_levels(g, 0)
    assert np.array_equal(got, want)


def test_pagerank_matches_oracle(plc_graph):
    g = plc_graph
    got = np.asarray(pagerank(to_device(g)))
    want = pr_oracle(g)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-9)


def test_pagerank_sums_to_one(rmat_graph):
    r = np.asarray(pagerank(to_device(rmat_graph)))
    assert abs(r.sum() - 1.0) < 1e-3


def test_cc_labelprop_matches_oracle(any_graph):
    g = any_graph
    got = np.asarray(cc_labelprop(to_device(g)))
    want = cc_oracle(g)
    assert np.array_equal(got, want)


def test_ccsv_same_partition_as_labelprop(any_graph):
    g = any_graph
    ga = to_device(g)
    a = np.asarray(cc_labelprop(ga))
    b = np.asarray(cc_shiloach_vishkin(ga))
    # identical partitions (labels may differ per component representative)
    import collections
    amap, bmap = {}, {}
    for x, y in zip(a, b):
        assert amap.setdefault(x, y) == y
        assert bmap.setdefault(y, x) == x


def test_sssp_matches_oracle(plc_graph):
    g = plc_graph
    ga = to_device(g)
    got = np.asarray(sssp(ga, jnp.int32(0)), dtype=np.int64)
    want = sssp_oracle(g, np.asarray(ga.weights), 0)
    assert np.array_equal(got, want)


def test_bc_matches_oracle(tiny_graph):
    g = tiny_graph
    got = np.asarray(bc(to_device(g), sources=(0, 3)))
    want = bc_oracle(g, (0, 3))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_bc_larger_graph(plc_graph):
    g = plc_graph
    got = np.asarray(bc(to_device(g), sources=(0, 1)))
    want = bc_oracle(g, (0, 1))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("kernel,extract", [
    ("bfs", lambda ga, g: np.asarray(bfs(ga, jnp.int32(0)))),
    ("pr", lambda ga, g: np.asarray(pagerank(ga))),
    ("sssp", lambda ga, g: np.asarray(sssp(ga, jnp.int32(0)))),
])
def test_kernels_equivariant_under_lorder(plc_graph, kernel, extract):
    """The paper's contract: reordering changes layout, never results."""
    g = plc_graph
    perm = np.asarray(lorder(g, kappa=3))
    gp = g.apply_permutation(perm)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm))
    ga, gpa = to_device(g), to_device(gp, canonical_ids=inv)
    if kernel in ("bfs", "sssp"):
        a = extract(ga, g)
        b_full = (np.asarray(bfs(gpa, jnp.int32(int(perm[0]))))
                  if kernel == "bfs"
                  else np.asarray(sssp(gpa, jnp.int32(int(perm[0])))))
        np.testing.assert_allclose(a, b_full[perm], rtol=1e-5, atol=1e-6)
    else:
        a = extract(ga, g)
        b = extract(gpa, gp)
        np.testing.assert_allclose(a, b[perm], rtol=1e-4, atol=1e-8)


def test_bfs_unreachable_is_minus_one():
    from repro.core.csr import from_edges
    g = from_edges(4, [0], [1])   # 2,3 unreachable
    d = np.asarray(bfs(to_device(g), jnp.int32(0)))
    assert d.tolist() == [0, 1, -1, -1]


def test_sssp_weights_relabel_invariant(plc_graph):
    """Edge weights are a function of edge identity, not layout."""
    g = plc_graph
    perm = np.asarray(lorder(g, kappa=2))
    gp = g.apply_permutation(perm)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm))
    wa = {}
    ga = to_device(g)
    for s, d, w in zip(np.asarray(ga.src), np.asarray(ga.indices),
                       np.asarray(ga.weights)):
        wa[(int(s), int(d))] = int(w)
    gpa = to_device(gp, canonical_ids=inv)
    for s, d, w in zip(np.asarray(gpa.src)[:500], np.asarray(gpa.indices)[:500],
                       np.asarray(gpa.weights)[:500]):
        assert wa[(int(inv[s]), int(inv[d]))] == int(w)
