"""MoE routing-locality diagnostics — LOrder's mechanism on expert dispatch.

The sorted dispatch itself lives in ``models/moe.py`` (it is the compute
path). This module provides the *analysis* side used by benchmarks and
tests:

* ``routing_graph`` — the token→expert bipartite access graph as a Graph,
  so the paper's skew metrics (hot fraction, edge concentration) apply
  verbatim to routing;
* ``dispatch_stats`` — contiguity/fragmentation metrics of sorted vs
  unsorted dispatch (blocks touched per expert, weight-stream bytes), the
  MoE analogue of cache-line statistics;
* ``expert_affinity_permutation`` — LOrder over the expert co-activation
  graph: experts that fire on the same tokens land on the same EP shard,
  reducing cross-shard all-to-all payload (used by the EP placement
  benchmark).
"""
from __future__ import annotations

import numpy as np

from ..core.csr import Graph, from_edges
from ..core.lorder import lorder


def routing_graph(experts: np.ndarray, num_experts: int,
                  num_tokens: int | None = None) -> Graph:
    """Bipartite token→expert graph (tokens then experts as vertex ids)."""
    experts = np.asarray(experts)
    t, k = experts.shape
    nt = t if num_tokens is None else num_tokens
    src = np.repeat(np.arange(t, dtype=np.int64), k)
    dst = nt + experts.reshape(-1).astype(np.int64)
    return from_edges(nt + num_experts, src, dst, name="moe-routing")


def dispatch_stats(experts: np.ndarray, num_experts: int,
                   tile_m: int = 128, d_model: int = 4096,
                   d_ff: int = 14336, bytes_per: int = 2) -> dict:
    """Weight-streaming cost of sorted vs unsorted dispatch.

    Unsorted: every assignment row gathers its expert's weights — the
    random property-array access of the paper. Sorted: each expert's
    weights stream once per contiguous group (plus tile padding).
    """
    flat = np.asarray(experts).reshape(-1)
    counts = np.bincount(flat, minlength=num_experts)
    w_bytes = 3 * d_model * d_ff * bytes_per           # swiglu: 3 mats
    # unsorted: switches of expert id along the token stream
    switches = int((np.diff(flat) != 0).sum()) + 1
    unsorted_bytes = switches * w_bytes
    # sorted: one stream per non-empty expert group
    nonempty = int((counts > 0).sum())
    sorted_bytes = nonempty * w_bytes
    tiles = int(np.ceil(counts / tile_m).sum())
    pad_rows = int(tiles * tile_m - counts.sum())
    return {
        "assignments": int(flat.size),
        "experts_hit": nonempty,
        "weight_bytes_unsorted": unsorted_bytes,
        "weight_bytes_sorted": sorted_bytes,
        "weight_stream_reduction": unsorted_bytes / max(sorted_bytes, 1),
        "row_tiles": tiles,
        "pad_fraction": pad_rows / max(tiles * tile_m, 1),
        "load_cv": float(counts.std() / max(counts.mean(), 1e-9)),
    }


def expert_coactivation_graph(experts: np.ndarray,
                              num_experts: int) -> Graph:
    """Expert co-activation graph: edge (e1, e2) per token routing to both."""
    experts = np.asarray(experts)
    t, k = experts.shape
    srcs, dsts = [], []
    for i in range(k):
        for j in range(k):
            if i != j:
                srcs.append(experts[:, i])
                dsts.append(experts[:, j])
    return from_edges(num_experts, np.concatenate(srcs).astype(np.int64),
                      np.concatenate(dsts).astype(np.int64),
                      name="expert-coact")


def expert_affinity_permutation(experts: np.ndarray, num_experts: int,
                                kappa: int = 1) -> np.ndarray:
    """LOrder over expert co-activation: perm[expert] = new slot. Experts
    that co-fire land adjacently → same EP shard under contiguous
    partitioning → top-k sets resolve on fewer shards."""
    g = expert_coactivation_graph(experts, num_experts)
    return np.asarray(lorder(g, kappa=kappa), dtype=np.int64)


def cross_shard_traffic(experts: np.ndarray, num_experts: int,
                        num_shards: int,
                        perm: np.ndarray | None = None) -> float:
    """Mean number of distinct EP shards each token's top-k set touches —
    proportional to all-to-all message count per token."""
    e = np.asarray(experts)
    if perm is not None:
        e = np.asarray(perm)[e]
    per = max(1, num_experts // num_shards)
    shards = e // per
    # distinct shards per row
    s = np.sort(shards, axis=1)
    distinct = 1 + (np.diff(s, axis=1) != 0).sum(axis=1)
    return float(distinct.mean())
