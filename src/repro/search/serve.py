"""Serving-side glue for the `knn_search` workload.

A k-NN query's "source" is a float32 vector, not a vertex id, so the
request plane needs three adapters: a **digest** that turns a query row
into the hashable int the result cache keys on, a **padding** rule that
rounds a query batch up to the compile-cache-friendly bucket shape, and
a **SearchSpec** carrying the served-order vector matrix + entry point
that backends thread into the kernel. The visit-ordered permutation for
``hotness_source == "visits"`` lives here too.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np


def default_max_steps(beam_width: int) -> int:
    """Expansion budget: beam refills stop paying off well before this."""
    return 2 * beam_width + 32


@dataclasses.dataclass(frozen=True)
class SearchParams:
    """Beam-search shape knobs, fixed per registered graph (they are
    static arguments of the compiled kernel)."""
    k_out: int
    beam_width: int = 32
    k_return: int = 10
    max_steps: int | None = None

    def __post_init__(self):
        if self.k_return > self.beam_width:
            raise ValueError("k_return must be <= beam_width")
        if self.max_steps is None:
            object.__setattr__(self, "max_steps",
                               default_max_steps(self.beam_width))


@dataclasses.dataclass(frozen=True)
class SearchSpec:
    """Layout-bound search state handed to ``backend.prepare``.

    ``vectors`` is the corpus in **served order** (row i = vector of
    served vertex i, padded rows at the bucketed tail are never read),
    ``entry`` the served id of the entry point, and ``canon`` the
    served->original id map whose values salt the kernel's composite
    sort keys — which is what makes results bit-identical across
    layouts and backends.
    """
    vectors: np.ndarray      # (V_pad, d) float32, served order
    entry: int               # served id of the entry vertex
    canon: np.ndarray        # (V_pad,) int32 served -> original
    params: SearchParams

    @property
    def dim(self) -> int:
        return int(self.vectors.shape[1])


def query_digest(query: np.ndarray) -> int:
    """Stable positive-int key for one float32 query row — what the
    result cache uses in place of an integer source id."""
    row = np.ascontiguousarray(query, dtype=np.float32)
    h = hashlib.blake2b(row.tobytes(), digest_size=8).digest()
    return int.from_bytes(h, "big") >> 1  # keep it non-negative


def pad_queries(queries: np.ndarray, multiple: int = 1
                ) -> tuple[np.ndarray, np.ndarray, int]:
    """Round a (S, d) query batch up to a power-of-two row count (also a
    multiple of ``multiple``, for sharded row splits). Returns
    ``(padded, valid_lane_mask, real_rows)``; pad lanes repeat row 0 and
    are excluded from visit accounting by the mask."""
    q = np.ascontiguousarray(queries, dtype=np.float32)
    s = len(q)
    target = max(multiple, 1 << (s - 1).bit_length())
    if target % multiple:
        target = ((target + multiple - 1) // multiple) * multiple
    if target > s:
        q = np.concatenate([q, np.repeat(q[:1], target - s, axis=0)])
    valid = np.zeros(target, dtype=bool)
    valid[:s] = True
    return q, valid, s


def visit_order(visits: np.ndarray) -> np.ndarray:
    """Hot-prefix permutation from observed visit counts: vertices with
    above-mean visits first, sorted by visits descending (stable), cold
    tail keeps original relative order — hubsort with telemetry standing
    in for degree. Returns ``perm[old_id] = new_id``."""
    v = np.asarray(visits, dtype=np.float64)
    hot = v > v.mean()
    hot_ids = np.nonzero(hot)[0]
    hot_ids = hot_ids[np.argsort(-v[hot_ids], kind="stable")]
    cold_ids = np.nonzero(~hot)[0]
    perm = np.empty(len(v), dtype=np.int64)
    perm[np.concatenate([hot_ids, cold_ids])] = np.arange(len(v))
    return perm


def visit_hot_mask(visits: np.ndarray) -> np.ndarray:
    """Hot set under visit telemetry (above-mean visits), the mask fed to
    ``patch_permutation`` when ``hotness_source == "visits"``."""
    v = np.asarray(visits, dtype=np.float64)
    return v > v.mean()
