"""§Roofline — three-term roofline per (arch × shape × mesh) from the
dry-run artifacts.

Terms (seconds, per step, per chip — SPMD ⇒ every chip runs the same
program):

  compute    = flops_per_chip / PEAK_FLOPS
  memory     = hbm_bytes_per_chip / HBM_BW
  collective = collective_bytes_per_chip / ICI_BW

Accounting: XLA's ``cost_analysis`` counts while bodies once, so scanned
programs (scan-over-layers, microbatch accumulation, RWKV time scan)
under-report by the trip count. We therefore re-derive all three terms
from the optimized HLO with ``hlo_analysis.analyse_hlo`` (while-loop trip
multiplication, fusion-level HBM accounting, collective payload summing)
— stored per cell by the dry-run under ``hlo_terms``. Hardware: TPU v5e-
class — 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI (one shared ICI
figure; we do not model per-axis topology).

MODEL_FLOPS = 6·N·T (dense) or 6·N_active·T (MoE) with T = tokens per
step; ratio MODEL_FLOPS / (flops_per_chip × chips) measures how much
compiled compute is "useful" (catches remat/redundancy waste; > 1 would
mean the compiler *saved* flops vs the analytic count, < 1/3 typically
means remat or waste).
"""
from __future__ import annotations

import json
import pathlib

from .common import fmt_table, save_json

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link

RESULTS_DIR = pathlib.Path(__file__).resolve().parent / "results" / "dryrun"


def tokens_per_step(shape: str) -> int:
    return {
        "train_4k": 4096 * 256,
        "prefill_32k": 32_768 * 32,
        "decode_32k": 128,       # one new token × batch
        "long_500k": 1,
    }[shape]


def model_flops(arch: str, shape: str) -> float:
    from repro.configs import get_config
    cfg = get_config(arch)
    n = cfg.active_param_count()
    t = tokens_per_step(shape)
    mult = 6.0 if shape == "train_4k" else 2.0   # fwd+bwd vs fwd
    return mult * n * t


def min_bytes(arch: str, shape: str) -> float:
    """Bandwidth-ideal floor: bytes that MUST move per step (global).

    Decode is bandwidth-bound: every step reads the active params (bf16)
    and the KV/state cache once. Train/prefill floors are param reads +
    one activation residency (params dominate at these batch sizes)."""
    from repro.configs import get_config
    cfg = get_config(arch)
    params = 2.0 * cfg.active_param_count()          # bf16 reads
    if shape in ("decode_32k", "long_500k"):
        b = 128 if shape == "decode_32k" else 1
        s = 32_768 if shape == "decode_32k" else 524_288
        kv = 0.0
        if any(x in ("attn", "shared_attn") for x in cfg.block_pattern):
            n_attn = sum(x in ("attn", "shared_attn")
                         for x in cfg.block_pattern)
            t = min(s, cfg.window) if cfg.window else s
            kv = n_attn * 2 * b * t * cfg.num_kv_heads * cfg.head_dim * 2
        state = 0.0
        if any(x in ("mamba", "rwkv") for x in cfg.block_pattern):
            n_ssm = sum(x in ("mamba", "rwkv") for x in cfg.block_pattern)
            per = (cfg.ssm_heads * cfg.ssm_state * cfg.ssm_head_dim
                   if cfg.ssm_state else cfg.d_model * cfg.d_model //
                   max(cfg.num_heads, 1))
            state = n_ssm * b * per * 4
        return params + kv + state
    return params


def load_cells(tag: str = "") -> list[dict]:
    cells = []
    for p in sorted(RESULTS_DIR.glob("*.json")):
        rec = json.loads(p.read_text())
        want_tag = rec.get("tag", "") == tag if "tag" in rec else \
            (("_" + tag) in p.name if tag else
             p.stem.count("_") <= 2 or p.stem.endswith(("pod1", "pod2")))
        if "error" in rec or "skipped" in rec:
            continue
        if not want_tag:
            continue
        cells.append(rec)
    return cells


def analyse_cell(rec: dict) -> dict:
    chips = 1
    for v in rec["mesh"].values():
        chips *= v
    # prefer while-aware HLO terms when the dry-run recorded them;
    # fall back to cost_analysis numbers (legacy records)
    ht = rec.get("hlo_terms")
    if ht:
        flops = ht["dot_flops"]
        mem = ht["mem_bytes"]
        coll = ht["collective_bytes"]
    else:
        flops = rec["flops"]
        mem = rec["bytes_accessed"]
        coll = rec["collectives"]["total_bytes"]
    t_comp = flops / PEAK_FLOPS
    t_mem = mem / HBM_BW
    t_coll = coll / ICI_BW
    dom = max(("compute", t_comp), ("memory", t_mem),
              ("collective", t_coll), key=lambda kv: kv[1])
    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / max(flops * chips, 1.0)
    bound = max(t_comp, t_mem, t_coll)
    # roofline-ideal step time: compute floor OR the bandwidth floor,
    # whichever binds (decode is bandwidth-bound — params+cache must move)
    ideal = max(mf / (chips * PEAK_FLOPS),
                min_bytes(rec["arch"], rec["shape"]) / (chips * HBM_BW))
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "mesh": "x".join(str(v) for v in rec["mesh"].values()),
        "chips": chips,
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "bottleneck": dom[0],
        "model_flops": mf,
        "useful_ratio": useful,
        # fraction of the roofline-ideal step time actually achievable:
        # ideal time (all chips at peak on useful flops) / bounded time
        "roofline_frac": ideal / bound if bound > 0 else 0.0,
        "fits_hbm": rec.get("temp_size_in_bytes", 0) is not None and
                    (rec.get("temp_size_in_bytes", 0) +
                     rec.get("argument_size_in_bytes", 0)) < 16e9,
        "temp_gb": round((rec.get("temp_size_in_bytes") or 0) / 1e9, 1),
    }


def fmt_row(a: dict) -> dict:
    return {
        "arch": a["arch"], "shape": a["shape"], "mesh": a["mesh"],
        "compute_ms": round(1e3 * a["compute_s"], 2),
        "memory_ms": round(1e3 * a["memory_s"], 2),
        "collective_ms": round(1e3 * a["collective_s"], 2),
        "bottleneck": a["bottleneck"],
        "useful": round(a["useful_ratio"], 2),
        "roofline%": round(100 * a["roofline_frac"], 1),
        "temp_gb": a["temp_gb"],
        "fits": "y" if a["fits_hbm"] else "N",
    }


def main(tag: str = ""):
    cells = load_cells(tag)
    rows = [analyse_cell(c) for c in cells]
    rows.sort(key=lambda r: (r["chips"], r["arch"], r["shape"]))
    out = [fmt_row(r) for r in rows]
    print(fmt_table(out, ["arch", "shape", "mesh", "compute_ms",
                          "memory_ms", "collective_ms", "bottleneck",
                          "useful", "roofline%", "temp_gb", "fits"]))
    save_json("roofline" + (f"_{tag}" if tag else ""), rows)
    worst = sorted((r for r in rows if r["mesh"].count("x") == 1),
                   key=lambda r: r["roofline_frac"])[:5]
    print("\nworst roofline fraction (single-pod):")
    for r in worst:
        print(f"  {r['arch']} {r['shape']}: {100 * r['roofline_frac']:.1f}% "
              f"({r['bottleneck']}-bound)")
    return rows


if __name__ == "__main__":
    import sys
    main(sys.argv[1] if len(sys.argv) > 1 else "")
