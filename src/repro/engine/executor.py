"""Batched query executor: compile-cached, vmapped multi-source kernels.

This is the serving-side answer to the paper's framing (section 4: the
traversal kernels whose cache behaviour reordering improves): the same
jitted kernels the benchmarks time, run behind caches so a query stream
pays compile and launch costs once, not per query. Two amortizations
happen here:

* **compile cache** — jitted kernel callables are cached on
  ``(kernel, num_vertices, num_edges)``; any graph with the same CSR shape
  reuses the compiled executable (XLA specializes on shapes, not
  contents). Telemetry counts hits/misses so serving cost is attributable.
* **source batching** — multi-source queries run as one ``vmap``-batched
  device launch (`algos.kernels.bfs_multi`/`sssp_multi`/`bc_multi`)
  instead of a Python loop. Batches are padded to power-of-two buckets so
  a stream of ragged batch sizes hits a handful of compiled shapes.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from ..algos import kernels as K
from ..algos.graph_arrays import GraphArrays

# kernels taking a batch of sources -> (S, V) per-source rows
MULTI_SOURCE = ("bfs", "sssp", "bc")
# source-independent kernels -> (V,)
GLOBAL = ("pr", "cc", "ccsv")


def _bucket(n: int) -> int:
    """Next power-of-two batch bucket (>= 1)."""
    return 1 << max(0, (n - 1).bit_length())


# All entries are already jitted in algos.kernels; jax's own cache
# specializes per CSR shape. The executor's key-level dict on top exists
# to *attribute* compiles to serving traffic (hit/miss telemetry).
_FNS = {
    "bfs": K.bfs_multi,
    "sssp": K.sssp_multi,
    "bc": K.bc_multi,
    "pr": K.pagerank,
    "cc": K.cc_labelprop,
    "ccsv": K.cc_shiloach_vishkin,
}


def _build(kernel: str):
    try:
        return _FNS[kernel]
    except KeyError:
        raise ValueError(f"unknown kernel {kernel!r}; "
                         f"have {MULTI_SOURCE + GLOBAL}") from None


class BatchedExecutor:
    """Runs kernels against device graph arrays through a compile cache."""

    def __init__(self):
        self._cache: dict[tuple, object] = {}
        self.cache_hits = 0
        self.cache_misses = 0
        self.queries_run = 0
        self.sources_run = 0

    def _compiled(self, kernel: str, ga: GraphArrays):
        key = (kernel, ga.num_vertices, ga.num_edges)
        fn = self._cache.get(key)
        if fn is None:
            self.cache_misses += 1
            fn = self._cache[key] = _build(kernel)
        else:
            self.cache_hits += 1
        return fn

    def run(self, ga: GraphArrays, kernel: str,
            sources=None) -> jnp.ndarray:
        """Execute one query batch.

        Multi-source kernels return per-source rows ``(S, V)``; global
        kernels ignore ``sources`` and return ``(V,)``. Results are
        blocked on (serving latency = device latency).
        """
        fn = self._compiled(kernel, ga)
        self.queries_run += 1
        if kernel in GLOBAL:
            out = fn(ga)
            return jax.block_until_ready(out)
        srcs = np.atleast_1d(np.asarray(sources, dtype=np.int32))
        if srcs.size == 0:
            raise ValueError(f"{kernel} needs at least one source")
        self.sources_run += int(srcs.size)
        pad = _bucket(srcs.size)
        padded = np.full(pad, srcs[0], np.int32)
        padded[:srcs.size] = srcs
        out = fn(ga, jnp.asarray(padded))
        return jax.block_until_ready(out)[:srcs.size]

    def telemetry(self) -> dict:
        return {
            "compile_cache_hits": self.cache_hits,
            "compile_cache_misses": self.cache_misses,
            "cached_keys": sorted(str(k) for k in self._cache),
            "queries_run": self.queries_run,
            "sources_run": self.sources_run,
        }
