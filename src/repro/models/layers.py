"""Shared neural layers: norms, RoPE variants, attention, MLPs, embedding.

Conventions
-----------
* Params are plain nested dicts of jnp arrays; init fns take (key, cfg).
* Master params float32; matmul inputs cast to ``COMPUTE_DTYPE`` (bf16).
* Attention is computed in query chunks (no S×S materialization) — the
  XLA analogue of the Pallas flash kernel, used for CPU/dry-run paths.
* Decode paths take a cache entry and a position offset.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

COMPUTE_DTYPE = jnp.bfloat16
Q_CHUNK = 1024


def _dense(x, w, b=None):
    y = jnp.einsum("...d,df->...f", x.astype(COMPUTE_DTYPE),
                   w.astype(COMPUTE_DTYPE))
    if b is not None:
        y = y + b.astype(COMPUTE_DTYPE)
    return y


# ------------------------------------------------------------------- norms
def init_norm(cfg: ModelConfig, d: int | None = None):
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p, x, cfg: ModelConfig):
    x32 = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = x32.mean(-1, keepdims=True)
        var = ((x32 - mu) ** 2).mean(-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"] + p["bias"]
    else:
        var = (x32 ** 2).mean(-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"]
    return y.astype(COMPUTE_DTYPE)


# -------------------------------------------------------------------- RoPE
def rope_frequencies(cfg: ModelConfig) -> jnp.ndarray:
    rot = int(cfg.head_dim * cfg.rotary_pct)
    rot -= rot % 2
    return 1.0 / (cfg.rope_theta ** (jnp.arange(0, rot, 2,
                                                dtype=jnp.float32) / rot))


def apply_rope(x, positions, cfg: ModelConfig):
    """x: (..., S, H, dh); positions: (..., S). Partial rotary supported
    (rotary_pct<1 rotates only the leading dims — chatglm3's 2-D RoPE)."""
    freqs = rope_frequencies(cfg)
    rot = 2 * freqs.shape[0]
    if rot == 0:
        return x
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (...,S,rot/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return jnp.concatenate([out.astype(x.dtype), xp], -1)


# --------------------------------------------------------------- attention
def init_attention(key, cfg: ModelConfig):
    d, h, kv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    sc = d ** -0.5
    p = {
        "wq": jax.random.normal(ks[0], (d, h * dh), jnp.float32) * sc,
        "wk": jax.random.normal(ks[1], (d, kv * dh), jnp.float32) * sc,
        "wv": jax.random.normal(ks[2], (d, kv * dh), jnp.float32) * sc,
        "wo": jax.random.normal(ks[3], (h * dh, d), jnp.float32) * sc,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), jnp.float32)
        p["bk"] = jnp.zeros((kv * dh,), jnp.float32)
        p["bv"] = jnp.zeros((kv * dh,), jnp.float32)
    if cfg.attn_out_bias:
        p["bo"] = jnp.zeros((d,), jnp.float32)
    return p


def _attn_mask(q_pos, k_pos, cfg: ModelConfig, k_valid=None):
    """(..., Q, K) boolean mask from absolute positions."""
    q = q_pos[..., :, None]
    k = k_pos[..., None, :]
    if cfg.causal:
        mask = q >= k
        if cfg.prefix_tokens > 0:  # prefix-LM: bidirectional over the prefix
            mask |= (q < cfg.prefix_tokens) & (k < cfg.prefix_tokens)
        if cfg.window > 0:
            mask &= (q - k) < cfg.window
    else:
        mask = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), bool)
    if k_valid is not None:
        mask &= k_valid[..., None, :]
    return mask


def _sdpa_chunked(q, k, v, q_pos, k_pos, cfg: ModelConfig, k_valid=None):
    """Query-chunked GQA attention. q: (B,S,H,dh); k,v: (B,T,KV,dh)."""
    b, s, h, dh = q.shape
    t = k.shape[1]
    kvh = cfg.num_kv_heads
    rep = h // kvh
    scale = dh ** -0.5
    qs = q.reshape(b, s, kvh, rep, dh)

    def one_chunk(args):
        qc, qp = args  # (B,C,KV,rep,dh), (C,)
        logits = jnp.einsum("bcgrd,btgd->bgrct", qc.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        mask = _attn_mask(qp, k_pos, cfg, k_valid)          # (C,T)
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bgrct,btgd->bcgrd", probs.astype(COMPUTE_DTYPE),
                         v.astype(COMPUTE_DTYPE))
        return out

    chunk = min(Q_CHUNK, s)
    if s % chunk == 0 and s > chunk:
        n = s // chunk
        qs_c = qs.reshape(b, n, chunk, kvh, rep, dh).transpose(1, 0, 2, 3, 4, 5)
        qp_c = q_pos.reshape(n, chunk)
        out = jax.lax.map(one_chunk, (qs_c, qp_c))
        out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, dh)
    else:
        out = one_chunk((qs, q_pos)).reshape(b, s, h, dh)
    return out


def _seq_shards(mesh, cfg: ModelConfig, t: int) -> int:
    """Shards for a sequence-sharded KV cache (the §Perf decode fix):
    applies when kv heads do NOT divide the model axis (else heads shard)
    and the cache length does."""
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return 1
    n = mesh.shape["model"]
    if n > 1 and cfg.num_kv_heads % n != 0 and t % n == 0 \
            and cfg.window == 0:
        return n
    return 1


def _decode_attn_seqsharded(q, k_new, v_new, cache, cfg: ModelConfig, mesh):
    """One-token decode against a sequence-sharded KV cache.

    Each model-shard owns a contiguous T/n slice of the cache: it applies
    the (single-shard) in-place update, computes partial attention over its
    slice and combines via online-softmax psum — context parallelism for
    decode. Replaces the replicated cache + full all-gather that appears
    when kv-head count does not divide the model axis (minicpm 36 heads,
    starcoder2 kv=4, qwen/chatglm kv=2 on a 16-way axis).
    """
    from jax.sharding import PartitionSpec as P
    b, _, kvh, rep, dh = q.shape
    scale = dh ** -0.5
    # preserve batch sharding over the dp axes — P(None, ...) here would
    # force an all-gather of the whole cache across 'data' at every step
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dpn = 1
    for a in dp:
        dpn *= mesh.shape[a]
    bax = dp if (dp and b % dpn == 0) else None
    cspec = P(bax, "model", None, None)
    qspec = P(bax, None, None, None, None)
    kspec = P(bax, None, None, None)

    def body(qf, kn, vn, ck, cv, length):
        ax = jax.lax.axis_index("model")
        tl = ck.shape[1]
        slot = length - ax * tl
        ok = (slot >= 0) & (slot < tl)
        slot_c = jnp.clip(slot, 0, tl - 1)

        def upd(c, new):
            return jax.lax.cond(
                ok,
                lambda: jax.lax.dynamic_update_slice_in_dim(
                    c, new.astype(c.dtype), slot_c, axis=1),
                lambda: c)

        ck2, cv2 = upd(ck, kn), upd(cv, vn)
        kpos = ax * tl + jnp.arange(tl)
        kvalid = kpos <= length
        logits = jnp.einsum("bqgrd,btgd->bgrqt", qf.astype(jnp.float32),
                            ck2.astype(jnp.float32)) * scale
        logits = jnp.where(kvalid[None, None, None, None], logits, -1e30)
        m = jax.lax.pmax(logits.max(-1), "model")        # (B,G,R,Q)
        pvals = jnp.exp(logits - m[..., None])
        l = jax.lax.psum(pvals.sum(-1), "model")
        num = jax.lax.psum(
            jnp.einsum("bgrqt,btgd->bqgrd", pvals,
                       cv2.astype(jnp.float32)), "model")
        out = num / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
        return out.astype(COMPUTE_DTYPE), ck2, cv2

    out, ck2, cv2 = jax.shard_map(
        body, mesh=mesh,
        in_specs=(qspec, kspec, kspec, cspec, cspec, P()),
        out_specs=(qspec, cspec, cspec),
    )(q, k_new, v_new, cache["k"], cache["v"], cache["length"])
    new_cache = {"k": ck2, "v": cv2, "length": cache["length"] + 1}
    return out, new_cache


def apply_attention(p, x, cfg: ModelConfig, positions, cache=None,
                    use_pallas: bool = False, mesh=None):
    """Returns (out, new_cache). cache=None -> full self-attention (train).

    cache: dict(k=(B,T,KV,dh), v=..., length=scalar) for decode/prefill-
    continuation; positions are absolute token positions of x's tokens.
    """
    b, s, d = x.shape
    h, kv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = _dense(x, p["wq"], p.get("bq")).reshape(b, s, h, dh)
    k = _dense(x, p["wk"], p.get("bk")).reshape(b, s, kv, dh)
    v = _dense(x, p["wv"], p.get("bv")).reshape(b, s, kv, dh)
    q = apply_rope(q, positions, cfg)
    k = apply_rope(k, positions, cfg)

    if cache is None:
        if use_pallas and s % 256 == 0 and kv == h and cfg.prefix_tokens == 0:
            from ..kernels.flash_attn.ops import causal_attention
            qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
            kf = k.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
            vf = v.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
            of = causal_attention(qf, kf, vf, window=cfg.window)
            out = of.reshape(b, h, s, dh).transpose(0, 2, 1, 3)
        else:
            out = _sdpa_chunked(q, k, v, positions, positions, cfg)
        new_cache = None
    else:
        # decode step (s == 1). Sliding-window configs use a ring buffer of
        # size `window`; full-attention configs use a linear buffer.
        assert s == 1, "cached attention path is decode-only (s == 1)"
        t = cache["k"].shape[1]
        pos = positions[-1]
        if _seq_shards(mesh, cfg, t) > 1:
            qh = q.reshape(b, 1, kv, h // kv, dh)
            out, new_cache = _decode_attn_seqsharded(qh, k, v, cache, cfg,
                                                     mesh)
            out = _dense(out.reshape(b, s, h * dh), p["wo"], p.get("bo"))
            return out, new_cache
        if cfg.window > 0 and t <= cfg.window:
            slot = pos % t
            ck = cache["k"].at[:, slot].set(k[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[:, slot].set(v[:, 0].astype(cache["v"].dtype))
            k_pos = pos - ((slot - jnp.arange(t)) % t)
            k_valid = k_pos >= 0
        else:
            start = cache["length"]
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), start, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), start, axis=1)
            k_pos = jnp.arange(t)
            k_valid = k_pos < cache["length"] + 1
        out = _sdpa_chunked(q, ck, cv, positions, k_pos, cfg, k_valid)
        new_cache = {"k": ck, "v": cv, "length": cache["length"] + 1}

    out = _dense(out.reshape(b, s, h * dh), p["wo"], p.get("bo"))
    return out, new_cache


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int,
                    dtype=COMPUTE_DTYPE):
    t = min(max_len, cfg.window) if cfg.window > 0 else max_len
    return {
        "k": jnp.zeros((batch, t, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, t, cfg.num_kv_heads, cfg.head_dim), dtype),
        "length": jnp.zeros((), jnp.int32),
    }


# --------------------------------------------------------------------- MLP
def init_mlp(key, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    sc_in, sc_out = d ** -0.5, f ** -0.5
    if cfg.mlp_type == "swiglu":
        p = {
            "w_gate": jax.random.normal(ks[0], (d, f), jnp.float32) * sc_in,
            "w_up": jax.random.normal(ks[1], (d, f), jnp.float32) * sc_in,
            "w_down": jax.random.normal(ks[2], (f, d), jnp.float32) * sc_out,
        }
    else:
        p = {
            "w_in": jax.random.normal(ks[0], (d, f), jnp.float32) * sc_in,
            "w_out": jax.random.normal(ks[1], (f, d), jnp.float32) * sc_out,
        }
        if cfg.mlp_bias:
            p["b_in"] = jnp.zeros((f,), jnp.float32)
            p["b_out"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_mlp(p, x, cfg: ModelConfig):
    if cfg.mlp_type == "swiglu":
        return _dense(jax.nn.silu(_dense(x, p["w_gate"]))
                      * _dense(x, p["w_up"]), p["w_down"])
    h = jax.nn.gelu(_dense(x, p["w_in"], p.get("b_in")))
    return _dense(h, p["w_out"], p.get("b_out"))


# --------------------------------------------------------------- embedding
def init_embedding(key, cfg: ModelConfig):
    p = {"table": jax.random.normal(key, (cfg.vocab_size, cfg.d_model),
                                    jnp.float32) * 0.02}
    if not cfg.tie_embeddings:
        p["head"] = jax.random.normal(
            jax.random.fold_in(key, 1), (cfg.d_model, cfg.vocab_size),
            jnp.float32) * cfg.d_model ** -0.5
    return p


def embed_tokens(p, ids, cfg: ModelConfig, use_pallas: bool = False):
    if use_pallas and cfg.hot_vocab_fraction > 0:
        from ..kernels.hot_embed.ops import hot_cold_lookup
        hot = max(1, int(cfg.vocab_size * cfg.hot_vocab_fraction))
        x = hot_cold_lookup(ids, p["table"], hot)
    else:
        x = jnp.take(p["table"], ids, axis=0)
    return (x * cfg.emb_scale).astype(COMPUTE_DTYPE)


def lm_logits(p, x, cfg: ModelConfig):
    w = p["table"].T if cfg.tie_embeddings else p["head"]
    return _dense(x, w) * cfg.logit_scale
