"""Closed-loop policy calibration: fit per-scheme strengths from outcomes.

The policy's payoff model (docs/policy.md) predicts a fractional miss-rate
reduction ``gain = skew x strength[scheme]``, where ``skew`` is a probe
composite and ``strength`` measures how well a scheme converts skew into
locality. PR 1 hard-coded the strengths against benchmarks/speedups.py
geomeans; Faldu et al. ("A Closer Look at Lightweight Graph Reordering")
show such static rankings mispredict across graph families — the paper's
own result (section 5) is that payoff is modulated by structure, not fixed
per scheme. This module closes the loop: every ``PolicyRecord`` (predicted
vs realized gain) becomes a regression sample, and the policy consults the
*fitted* strengths on the next decision.

Model: per scheme, ridge regression of realized gain against skew through
the origin, shrunk toward the static prior when samples are few::

    strength = (sum(skew_i * gain_i) + shrinkage * prior)
               / (sum(skew_i ** 2)  + shrinkage)

With zero observations this is exactly the prior (PR 1 behaviour); as
evidence accumulates the data term dominates. Sums-of-products are the
only state, so calibration is O(1) per observation, mergeable, and
trivially serializable — ``save``/``load`` persist it across sessions.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

# Prior relative strength of each scheme at converting skew into miss
# reduction, calibrated against benchmarks/speedups.py geomeans
# (original = 0 by construction: it moves nothing). "visitsort" is the
# search-family telemetry packing (search/serve.py) — hubsort over
# observed visits; its prior sits at hubsort-like strength.
DEFAULT_PRIORS = {
    "original": 0.0,
    "hubcluster": 0.35,
    "dbg": 0.5,
    "lorder": 0.75,
    "visitsort": 0.5,
}


@dataclasses.dataclass
class SchemeStats:
    """Sufficient statistics for one scheme's strength regression."""

    prior: float
    count: int = 0
    sum_ss: float = 0.0   # sum of skew_i^2
    sum_sg: float = 0.0   # sum of skew_i * gain_i

    def observe(self, skew: float, realized_gain: float) -> None:
        self.count += 1
        self.sum_ss += skew * skew
        self.sum_sg += skew * realized_gain

    def fitted(self, shrinkage: float) -> float:
        """Ridge estimate shrunk toward the prior, clamped to [0, 1]."""
        est = (self.sum_sg + shrinkage * self.prior) / (self.sum_ss + shrinkage)
        return min(max(est, 0.0), 1.0)


class StrengthCalibrator:
    """Accumulates PolicyRecords into fitted per-scheme strengths.

    ``shrinkage`` is the ridge weight on the prior, in units of
    sum-of-squared-skew: with typical skews around 0.5 (skew^2 ~ 0.25),
    the default of 2.0 means ~8 observations pull the estimate halfway
    from the prior to the data.
    """

    def __init__(self, priors: dict[str, float] | None = None,
                 shrinkage: float = 2.0):
        self.shrinkage = float(shrinkage)
        if priors is None:
            priors = DEFAULT_PRIORS
        self._stats = {scheme: SchemeStats(prior)
                       for scheme, prior in priors.items()}
        # calibration v2: per-(family, scheme) sufficient statistics.
        # Faldu et al.'s point — payoff is modulated by graph family —
        # applies *within* the fitted model too: a scheme's realized
        # strength on search graphs (visit-skewed, fixed degree) need not
        # match its strength on analytics graphs. Family fits shrink
        # toward the *global* fit (not the static prior), so a family
        # with no observations inherits everything the global pool knows.
        self._family_stats: dict[tuple[str, str], SchemeStats] = {}

    # ----------------------------------------------------------- observe
    def observe(self, scheme: str, skew: float, realized_gain: float,
                family: str | None = None) -> None:
        if scheme not in self._stats:
            self._stats[scheme] = SchemeStats(prior=0.0)
        self._stats[scheme].observe(float(skew), float(realized_gain))
        if family is not None:
            key = (str(family), scheme)
            if key not in self._family_stats:
                # prior field unused for family stats: fitted() shrinks
                # toward the live global fit instead (see strength())
                self._family_stats[key] = SchemeStats(prior=0.0)
            self._family_stats[key].observe(float(skew),
                                            float(realized_gain))

    def observe_record(self, record) -> bool:
        """Feed one ``PolicyRecord``; returns whether it was usable.

        ``original`` decisions carry no measurement (strength is pinned at
        0), and records without a before-miss-rate have no realized gain.
        """
        decision = record.decision
        if decision.scheme == "original" or record.miss_rate_before <= 0:
            return False
        self.observe(decision.scheme, decision.skew, record.realized_gain,
                     family=getattr(record, "family", None))
        return True

    # ------------------------------------------------------------- query
    def strength(self, scheme: str, family: str | None = None) -> float:
        stats = self._stats.get(scheme)
        if stats is None:
            return 0.0
        if scheme == "original":
            return 0.0
        global_fit = stats.fitted(self.shrinkage)
        if family is None:
            return global_fit
        fs = self._family_stats.get((str(family), scheme))
        if fs is None:
            return global_fit
        # family ridge shrunk toward the *leave-this-family-out* fit:
        # the family's own samples must not appear in its shrinkage
        # target too, or a family holding all the evidence gets shrunk
        # twice. With one family in play this reduces exactly to the
        # global fit; evidence from *other* families moves the target.
        other_ss = max(stats.sum_ss - fs.sum_ss, 0.0)
        other_sg = stats.sum_sg - fs.sum_sg
        prior_fit = ((other_sg + self.shrinkage * stats.prior)
                     / (other_ss + self.shrinkage))
        prior_fit = min(max(prior_fit, 0.0), 1.0)
        est = ((fs.sum_sg + self.shrinkage * prior_fit)
               / (fs.sum_ss + self.shrinkage))
        return min(max(est, 0.0), 1.0)

    def count(self, scheme: str, family: str | None = None) -> int:
        if family is not None:
            fs = self._family_stats.get((str(family), scheme))
            return fs.count if fs else 0
        stats = self._stats.get(scheme)
        return stats.count if stats else 0

    def strengths(self) -> dict[str, float]:
        return {s: self.strength(s) for s in self._stats}

    def as_dict(self) -> dict:
        return {
            "shrinkage": self.shrinkage,
            "schemes": {
                s: {"prior": st.prior, "fitted": self.strength(s),
                    "count": st.count, "sum_ss": st.sum_ss,
                    "sum_sg": st.sum_sg}
                for s, st in self._stats.items()
            },
            "families": {
                f"{fam}/{s}": {"fitted": self.strength(s, family=fam),
                               "count": st.count, "sum_ss": st.sum_ss,
                               "sum_sg": st.sum_sg}
                for (fam, s), st in self._family_stats.items()
            },
        }

    # ----------------------------------------------------------- persist
    def save(self, path) -> pathlib.Path:
        """Write calibration state as JSON so it survives sessions."""
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.as_dict(), indent=1))
        return p

    @classmethod
    def load(cls, path) -> "StrengthCalibrator":
        blob = json.loads(pathlib.Path(path).read_text())
        cal = cls(priors={}, shrinkage=blob["shrinkage"])
        for scheme, st in blob["schemes"].items():
            cal._stats[scheme] = SchemeStats(
                prior=st["prior"], count=st["count"],
                sum_ss=st["sum_ss"], sum_sg=st["sum_sg"])
        # "families" is absent in pre-v2 saves — loads as global-only
        for key, st in blob.get("families", {}).items():
            fam, scheme = key.split("/", 1)
            cal._family_stats[(fam, scheme)] = SchemeStats(
                prior=0.0, count=st["count"],
                sum_ss=st["sum_ss"], sum_sg=st["sum_sg"])
        return cal
