"""Model configuration — one dataclass covers all 10 assigned families.

Heterogeneous stacks (hybrid) are expressed with ``block_pattern``: a
per-layer tag in {"attn", "mamba", "rwkv", "shared_attn"}. Homogeneous
stacks leave it empty (= all "attn"). All archs execute through the same
scan-over-layers trunk (models/transformer.py).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "ssm", "hybrid", "vlm", "audio", "moe"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                     # 0 -> d_model // num_heads

    # attention
    rope_theta: float = 10_000.0
    rotary_pct: float = 1.0               # chatglm3: 0.5 ("RoPE 2d")
    qkv_bias: bool = False                # qwen2.5
    attn_out_bias: bool = False
    window: int = 0                       # mixtral SWA
    causal: bool = True                   # hubert: False (encoder)
    prefix_tokens: int = 0                # paligemma: image prefix (prefix-LM)

    # ffn
    mlp_type: Literal["swiglu", "gelu"] = "swiglu"
    mlp_bias: bool = False                # starcoder2: True
    norm_type: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    norm_eps: float = 1e-5

    # moe
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0           # moonshot/moonlight-style
    router_aux_coef: float = 0.01

    # ssm / rwkv
    block_pattern: tuple[str, ...] = ()
    ssm_state: int = 0                    # mamba2 N
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    conv_width: int = 4
    shared_attn_period: int = 6           # zamba2: shared block cadence

    # embedding / scaling (minicpm mup-style knobs)
    tie_embeddings: bool = False
    emb_scale: float = 1.0
    logit_scale: float = 1.0
    residual_scale: float = 1.0

    # modality frontend stub: "tokens" or "embeddings" (audio/vlm)
    input_mode: Literal["tokens", "embeddings"] = "tokens"

    # locality features (the paper's technique, DESIGN.md §3)
    vocab_reorder: bool = False           # LOrder vocab permutation
    hot_vocab_fraction: float = 0.0       # hot slab size for hot_embed kernel
    moe_locality_sort: bool = True        # sorted (dropless) dispatch

    # training
    remat: bool = True
    remat_policy: str = "save_attn"       # "save_attn" | "full" (§Perf it.6)
    loss_chunk: int = 512                 # chunked softmax-xent (memory)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if not self.block_pattern:
            object.__setattr__(self, "block_pattern",
                               ("attn",) * self.num_layers)
        assert len(self.block_pattern) == self.num_layers
        assert self.num_heads % max(self.num_kv_heads, 1) == 0

    # ------------------------------------------------------------ derived
    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_encoder(self) -> bool:
        return not self.causal

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attn_positions(self) -> tuple[int, ...]:
        return tuple(i for i, b in enumerate(self.block_pattern)
                     if b in ("attn", "shared_attn"))

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the 500k long-context decode cell."""
        full_attn = any(b == "attn" and self.window == 0
                        for b in self.block_pattern)
        # shared_attn layers hold full caches but are O(few) per model —
        # hybrids qualify per the assignment ("run for SSM/hybrid").
        return not full_attn or self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (embedding + trunk + head)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        hd, nh, nkv = self.head_dim, self.num_heads, self.num_kv_heads
        total = v * d                                 # embed
        if not self.tie_embeddings:
            total += d * v                            # head
        attn = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
        ffn = (3 if self.mlp_type == "swiglu" else 2) * d * f
        if self.is_moe:
            ffn *= (self.num_experts + self.num_shared_experts)
            ffn += d * self.num_experts               # router
        mamba = (d * (2 * self.d_inner + 2 * self.ssm_state + self.ssm_heads)
                 + self.d_inner * d + 3 * self.ssm_heads)
        rwkv = 4 * d * d + d * self.d_ff + self.d_ff * d  # rkvg + out, ffn
        for b in self.block_pattern:
            total += 2 * d  # norms
            if b == "attn":
                total += attn + ffn
            elif b == "shared_attn":
                total += 0  # shared params counted once below
            elif b == "mamba":
                total += mamba          # mamba blocks carry no FFN
            elif b == "rwkv":
                total += rwkv
        if "shared_attn" in self.block_pattern:
            total += attn + ffn
        return total

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: only routed experts)."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        per_expert = (3 if self.mlp_type == "swiglu" else 2) * d * f
        dense_experts = self.experts_per_token + self.num_shared_experts
        inactive = (self.num_experts + self.num_shared_experts
                    - dense_experts) * per_expert * self.num_layers
        return self.param_count() - inactive
