"""Pure-jnp oracle for the csr_spmv kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def csr_spmv_ref(t_indptr, t_indices, weights, x):
    """y[v] = Σ_{u→v} w(u,v)·x[u] over the in-CSR arrays."""
    n = t_indptr.shape[0] - 1
    dst = jnp.repeat(jnp.arange(n, dtype=jnp.int32), jnp.diff(t_indptr),
                     total_repeat_length=t_indices.shape[0])
    vals = x[t_indices] * weights
    return jax.ops.segment_sum(vals, dst, num_segments=n)
