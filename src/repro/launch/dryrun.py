import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces, without allocating any real arrays:
  * compiled.memory_analysis()  — proves the cell fits per-device HBM;
  * compiled.cost_analysis()    — HLO FLOPs / bytes for §Roofline;
  * collective_bytes            — parsed from the optimized HLO, summed
    over all-reduce / all-gather / reduce-scatter / all-to-all /
    collective-permute ops (async *-start counted once, *-done skipped).

Results append to benchmarks/results/dryrun/<cell>.json, consumed by
benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--single-pod]
"""

import argparse
import json
import pathlib
import re
import time

import jax
import jax.numpy as jnp

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] \
    / "benchmarks" / "results" / "dryrun"

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Total payload bytes + op counts per collective kind."""
    out: dict = {"total_bytes": 0}
    for m in _COLL_RE.finditer(hlo_text):
        type_str, kind, _ = m.groups()
        b = _shape_bytes(type_str)
        out[kind] = out.get(kind, {"count": 0, "bytes": 0})
        out[kind]["count"] += 1
        out[kind]["bytes"] += b
        out["total_bytes"] += b
    return out


def build_cell(arch: str, shape_name: str, mesh, extra: dict | None = None,
               microbatch: int | None = None):
    """Lower one cell. Returns (lowered, compiled, meta)."""
    from ..configs import get_config
    from ..configs.shapes import SHAPES, cell_supported, input_specs
    from ..launch.shardings import batch_specs, to_named
    from ..models.transformer import init_params
    from ..train.optim import TrainConfig, init_opt_state
    from ..train.steps import make_forward, make_serve_step, make_train_step

    cfg = get_config(arch)
    if extra:
        import dataclasses
        cfg = dataclasses.replace(cfg, **extra)
    shape = SHAPES[shape_name]
    ok, reason = cell_supported(cfg, shape)
    if not ok:
        return None, None, {"skipped": reason}

    specs = input_specs(cfg, shape)
    params_shape = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))
    if shape.kind in ("prefill", "decode"):
        # inference serves bf16 weights (float32 masters are a training
        # artifact); halves weight reads and makes replicated-over-data
        # serving layouts fit HBM
        params_shape = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.bfloat16 if s.dtype == jnp.float32
                else s.dtype), params_shape)

    with mesh:
        if shape.kind == "train":
            # default: 4-way gradient accumulation so train cells fit v5e
            # HBM (16 GB) — per-device microbatch = 64/|dp| = 4 sequences.
            mb = 64 if microbatch is None else microbatch
            tc = TrainConfig(microbatch=mb if mb > 0 else 0)
            step, pspecs = make_train_step(cfg, tc, mesh)
            opt_shape = jax.eval_shape(
                lambda: init_opt_state(params_shape))
            lowered = step.lower(params_shape, opt_shape, specs)
        elif shape.kind == "prefill":
            fwd, pspecs = make_forward(cfg, mesh)
            lowered = fwd.lower(params_shape, specs)
        else:  # decode
            step, pspecs, cspecs = make_serve_step(
                cfg, mesh, shape.global_batch, shape.seq_len)
            lowered = step.lower(params_shape, specs["cache"],
                                 specs["tokens"])
        compiled = lowered.compile()

    meta = {"arch": arch, "shape": shape_name,
            "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
            "kind": shape.kind}
    return lowered, compiled, meta


def analyse(lowered, compiled, meta: dict) -> dict:
    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = collective_stats(hlo)
    rec = dict(meta)
    rec["flops"] = float(cost.get("flops", -1.0))
    rec["bytes_accessed"] = float(cost.get("bytes accessed", -1.0))
    rec["collectives"] = coll
    # while-loop-aware accounting (scan bodies × trip counts) — the
    # roofline's primary source; cost_analysis kept for cross-checking
    from .hlo_analysis import analyse_hlo
    ht = analyse_hlo(hlo)
    rec["hlo_terms"] = {
        "dot_flops": ht["dot_flops"],
        "mem_bytes": ht["mem_bytes"],
        "collective_bytes": ht["collective_bytes"],
        "collectives_by_kind": ht["collectives_by_kind"],
    }
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        rec[k] = getattr(mem, k, None)
    # count remat-style duplication: fusion instruction count as proxy
    rec["hlo_bytes"] = len(hlo)
    return rec


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             extra: dict | None = None, tag: str = "",
             microbatch: int | None = None, reraise: bool = True) -> dict:
    from .mesh import make_production_mesh
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        lowered, compiled, meta = build_cell(arch, shape_name, mesh, extra,
                                             microbatch=microbatch)
    except Exception as e:  # a failed cell is a bug: record it loudly
        rec = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
               "error": f"{type(e).__name__}: {e}"[:2000]}
        _save(rec, tag)
        if reraise:
            raise
        return rec
    if lowered is None:
        rec = dict(meta, arch=arch, shape=shape_name, multi_pod=multi_pod)
    else:
        rec = analyse(lowered, compiled, meta)
        rec["multi_pod"] = multi_pod
        rec["compile_seconds"] = round(time.time() - t0, 1)
    _save(rec, tag)
    return rec


def _save(rec: dict, tag: str = ""):
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    pod = "pod2" if rec.get("multi_pod") else "pod1"
    name = f"{rec['arch']}_{rec['shape']}_{pod}{('_' + tag) if tag else ''}.json"
    (RESULTS_DIR / name).write_text(json.dumps(rec, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    from ..configs import ARCH_IDS
    from ..configs.shapes import SHAPES

    archs = list(ARCH_IDS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    sweep = args.all or len(archs) * len(shapes) * len(meshes) > 1

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                if args.skip_existing:
                    pod = "pod2" if mp else "pod1"
                    tag = ("_" + args.tag) if args.tag else ""
                    f = RESULTS_DIR / f"{arch}_{shape}_{pod}{tag}.json"
                    if f.exists() and "error" not in json.loads(f.read_text()):
                        print(f"HAVE {arch} {shape} {pod}", flush=True)
                        continue
                rec = run_cell(arch, shape, mp, tag=args.tag,
                               microbatch=args.microbatch,
                               reraise=not sweep)
                if "error" in rec:
                    print(f"FAIL {arch} {shape} pod{2 if mp else 1}: "
                          f"{rec['error'][:200]}", flush=True)
                elif "skipped" in rec:
                    print(f"SKIP {arch} {shape} pod{2 if mp else 1}: "
                          f"{rec['skipped']}", flush=True)
                else:
                    coll = rec["collectives"]["total_bytes"]
                    print(f"OK {arch} {shape} pod{2 if mp else 1} "
                          f"flops={rec['flops']:.3e} "
                          f"coll={coll:.3e}B "
                          f"temp={rec['temp_size_in_bytes']} "
                          f"t={rec['compile_seconds']}s", flush=True)


if __name__ == "__main__":
    main()
