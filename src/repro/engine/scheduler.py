"""Request plane: futures + micro-batch scheduling over the serving engine.

The paper's economic argument is *amortization* — a reorder pays off only
across many traversals — yet a blocking one-caller ``submit`` launches one
device program per call, so concurrent traffic can never share a vmapped
launch and the policy never observes real batch shapes. This module turns
the front door into a request plane:

* ``EngineSession.enqueue(...)`` returns a `QueryFuture` immediately;
  nothing touches a device until a **flush boundary**.
* `MicroBatchScheduler` queues requests per ``(graph_id, kernel)`` and, at
  ``flush()``/``drain()``:

  - **coalesces** pending multi-source requests (bfs/sssp/bc) into one
    vmapped launch whose concatenated sources fill a power-of-two
    `source_bucket`, then slices each request's rows back out of the
    ``(S, V)`` result — N requests, one device program;
  - **deduplicates** concurrent global-kernel requests (pr/cc/ccsv) into
    a single run fanned out to every waiter — the result is
    source-independent, so running it twice is pure waste;
  - drains queues in **priority / deadline order** (higher ``priority``
    first, then earlier absolute deadline, then FIFO), so a latency-bound
    request is never stuck behind a bulk scan that arrived first.

* **generations** — every (re-)applied policy decision bumps the graph
  entry's ``generation``; a request's sources are translated through the
  layout *at launch time* and its result translated back before the
  flush-boundary re-decision check runs, so an in-flight future is never
  served half from a layout that was just replaced. Re-decision moves
  from per-submit to per-flush: one check per graph per flush, after all
  of its pending requests were served.

* **telemetry** — every future carries per-request serving facts: the
  launch it rode, how many requests shared it, its wall share, the
  generation that served it, whether its deadline was met, and (sharded
  placements) the per-run `ExchangeStats` delta from ``core/dist.py``.

* **observability** (obs.py, docs/observability.md) — every counter here
  is a view over the session's `MetricsRegistry` (the old ``telemetry()``
  dict shape is preserved as a facade), queue-wait / serve-latency /
  deadline-slack histograms are recorded per ``(graph_id, kernel)``, and
  each request carries a ``trace_id`` tying its per-request trace track
  (enqueue → queue_wait → serve) to the engine track's flush / coalesce /
  translate / launch spans. All timing flows through the session's
  injectable clock, so latency tests are deterministic.

``EngineSession.submit`` is reimplemented as enqueue + flush sugar, so
the blocking API is exactly one request riding a one-element batch —
bit-identical results, same id translation, same ledger accounting.
docs/scheduler.md documents the lifecycle and the migration path.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import TYPE_CHECKING

import numpy as np

from .backends import GLOBAL, MULTI_SOURCE, build_kernel, source_bucket
from .obs import REQUEST_TID_BASE, signed_log_boundaries

if TYPE_CHECKING:  # import cycle: session builds the scheduler
    from .session import EngineSession

# component-label kernels whose *values* (not just positions) are vertex
# ids and must be canonicalized back to original id space at the boundary
LABEL_KERNELS = ("cc", "ccsv")


def canonical_component_labels(labels: np.ndarray) -> np.ndarray:
    """Relabel component ids to the **minimum original vertex id** of each
    component.

    ``labels[v]`` must be a consistent per-component representative (any
    id space — the engine's served layout uses served ids). The output is
    layout-independent: bit-identical to `core.baselines.cc_baseline`
    whatever permutation the graph was served under, which is what lets
    the parity matrix demand cross-backend bit-identity for cc/ccsv.
    """
    labels = np.asarray(labels)
    n = labels.shape[-1]
    flat = labels.reshape(-1, n).astype(np.int64, copy=False)
    out = np.empty_like(flat)
    for i, row in enumerate(flat):
        rep_min = np.full(int(row.max()) + 1, n, dtype=np.int64)
        np.minimum.at(rep_min, row, np.arange(n, dtype=np.int64))
        out[i] = rep_min[row]
    return out.reshape(labels.shape)


@dataclasses.dataclass
class Request:
    """One enqueued query: what to run, how urgently, and for whom."""

    seq: int                       # FIFO tiebreak, assigned at enqueue
    graph_id: str
    kernel: str
    sources: np.ndarray | None     # original-id space; None for GLOBAL
    priority: int                  # higher drains first
    deadline: float | None         # absolute perf_counter() time, or None
    enqueued_at: float
    future: "QueryFuture"
    generation: int | None = None  # layout generation that served it
    trace_id: str | None = None    # ties this request's spans together

    @property
    def num_sources(self) -> int:
        return 0 if self.sources is None else int(self.sources.size)

    def order_key(self) -> tuple:
        """Drain order: priority desc, earliest deadline, FIFO."""
        return (-self.priority,
                self.deadline if self.deadline is not None else float("inf"),
                self.seq)


class QueryFuture:
    """Handle to a pending (or served) request.

    ``result()`` is the blocking read: if the request has not been served
    yet it flushes the owning scheduler for this request's graph first,
    so a lone ``enqueue(...).result()`` behaves exactly like the old
    blocking ``submit``. ``telemetry`` is populated at serve time (see
    `MicroBatchScheduler._account`).
    """

    def __init__(self, scheduler: "MicroBatchScheduler", request: Request):
        self._scheduler = scheduler
        self._result: np.ndarray | None = None
        self._exception: BaseException | None = None
        self._done = False
        self.request = request
        self.telemetry: dict = {}

    # ------------------------------------------------------------ protocol
    def done(self) -> bool:
        return self._done

    def result(self) -> np.ndarray:
        if not self._done:
            self._scheduler.flush(self.request.graph_id)
        if not self._done:  # defensive: flush must have served us
            raise RuntimeError(
                f"flush did not serve request {self.request.seq} "
                f"({self.request.graph_id}/{self.request.kernel})")
        if self._exception is not None:
            raise self._exception
        return self._result

    def exception(self) -> BaseException | None:
        """The launch failure, if any (None while pending or on success)."""
        return self._exception

    @property
    def trace_id(self) -> str:
        """Id shared by every trace span of this request's lifecycle."""
        return self.request.trace_id

    # ------------------------------------------------------------ internal
    def _set_result(self, value: np.ndarray) -> None:
        self._result = value
        self._done = True

    def _set_exception(self, exc: BaseException) -> None:
        self._exception = exc
        self._done = True


class MicroBatchScheduler:
    """Per-(graph, kernel) request queues drained as micro-batches.

    One scheduler fronts one `EngineSession`; the session owns the
    registry/policy/executor and exposes the launch internals the
    scheduler drives (`EngineSession._launch` / ``_finalize`` /
    ``_maybe_redecide``). ``max_batch_sources`` caps how many concatenated
    sources one coalesced launch may carry (None = coalesce everything
    pending into a single launch; the executor still pads the batch to
    its power-of-two `source_bucket`).
    """

    def __init__(self, session: "EngineSession",
                 max_batch_sources: int | None = None):
        if max_batch_sources is not None and max_batch_sources < 1:
            raise ValueError("max_batch_sources must be >= 1 or None")
        self.session = session
        self.max_batch_sources = max_batch_sources
        self._queues: dict[tuple[str, str], list[Request]] = {}
        self._seq = itertools.count()
        # counters live in the session's metrics registry; the public
        # attributes below (and telemetry()) are read-through views, so
        # the pre-obs shapes survive while the registry is the one truth
        m = session.metrics_registry
        self._c_enqueued = m.counter(
            "engine_requests_enqueued_total", "requests accepted by enqueue")
        self._c_served = m.counter(
            "engine_requests_served_total", "futures resolved with a result")
        self._c_failed = m.counter(
            "engine_requests_failed_total", "futures resolved with an error")
        self._c_launches = m.counter(
            "engine_launches_total", "device launches issued")
        self._c_launches_failed = m.counter(
            "engine_launches_failed_total", "device launches that raised")
        self._c_coalesced = m.counter(
            "engine_coalesced_requests_total", "requests that shared a launch")
        self._c_dedup = m.counter(
            "engine_dedup_hits_total", "global requests served without a run")
        self._c_flushes = m.counter("engine_flushes_total", "flush boundaries")
        self._c_deadlines = m.counter(
            "engine_deadlines_missed_total", "requests served past deadline")
        self._g_pending = m.gauge(
            "engine_pending_requests", "requests enqueued but not served")
        self._metrics = m

    # --------------------------------------------- registry-backed counters
    @property
    def requests_enqueued(self) -> int:
        return self._c_enqueued.value

    @property
    def requests_served(self) -> int:
        return self._c_served.value

    @property
    def requests_failed(self) -> int:
        return self._c_failed.value

    @property
    def launches(self) -> int:
        return self._c_launches.value

    @property
    def launches_failed(self) -> int:
        return self._c_launches_failed.value

    @property
    def coalesced_requests(self) -> int:
        return self._c_coalesced.value

    @property
    def dedup_hits(self) -> int:
        return self._c_dedup.value

    @property
    def flushes(self) -> int:
        return self._c_flushes.value

    @property
    def deadlines_missed(self) -> int:
        return self._c_deadlines.value

    # ------------------------------------------------------------- enqueue
    def enqueue(self, graph_id: str, kernel: str, sources=None,
                priority: int = 0,
                deadline_seconds: float | None = None) -> QueryFuture:
        """Queue one request; returns its future. Validation is eager —
        unknown kernel/graph and empty source batches raise *here*, not at
        flush time where they would poison a coalesced batch."""
        build_kernel(kernel)                    # ValueError on unknown
        entry = self.session.registry.get(graph_id)  # KeyError on unknown
        srcs = None
        if kernel in MULTI_SOURCE:
            srcs = np.atleast_1d(np.asarray(sources, dtype=np.int64))
            if srcs.size == 0:
                raise ValueError(f"{kernel} needs at least one source")
            n = entry.graph.num_vertices
            if int(srcs.min()) < 0 or int(srcs.max()) >= n:
                # out-of-range ids must fail *this* caller now — at launch
                # time they would poison every request coalesced alongside
                raise ValueError(
                    f"{kernel} sources must be in [0, {n}); got "
                    f"[{int(srcs.min())}, {int(srcs.max())}]")
        now = self.session.clock.now()
        seq = next(self._seq)
        req = Request(
            seq=seq, graph_id=graph_id, kernel=kernel,
            sources=srcs, priority=priority,
            deadline=(now + deadline_seconds
                      if deadline_seconds is not None else None),
            enqueued_at=now, future=None,  # type: ignore[arg-type]
            trace_id=f"req-{seq}")
        req.future = QueryFuture(self, req)
        self._queues.setdefault((graph_id, kernel), []).append(req)
        self._c_enqueued.inc()
        self._g_pending.inc()
        tracer = self.session.tracer
        tracer.set_thread_name(REQUEST_TID_BASE + seq, req.trace_id)
        tracer.instant("enqueue", tid=REQUEST_TID_BASE + seq,
                       trace_id=req.trace_id, graph_id=graph_id,
                       kernel=kernel, priority=priority)
        return req.future

    def pending(self, graph_id: str | None = None) -> int:
        return sum(len(reqs) for (gid, _), reqs in self._queues.items()
                   if graph_id is None or gid == graph_id)

    # --------------------------------------------------------------- flush
    def flush(self, graph_id: str | None = None) -> int:
        """Serve everything currently pending (for one graph, or all).

        Queues drain in priority/deadline order; each graph gets exactly
        one re-decision check *after* all of its pending requests were
        served — the flush boundary — so no in-flight future straddles a
        layout replacement.
        """
        graphs: list[str] = []
        for (gid, _), reqs in self._queues.items():
            if reqs and (graph_id is None or gid == graph_id):
                if gid not in graphs:
                    graphs.append(gid)
        served = 0
        self._c_flushes.inc()
        for gid in graphs:
            served += self._flush_graph(gid)
        return served

    def drain(self) -> int:
        """Flush until no request is pending anywhere (lifecycle close)."""
        served = 0
        while self.pending():
            served += self.flush()
        return served

    # ------------------------------------------------------ flush internals
    def _take_queues(self, graph_id: str) -> list[tuple[str, list[Request]]]:
        """Pop this graph's non-empty queues, ordered by their most urgent
        request (so a high-priority sssp drains before a bulk bfs)."""
        taken = []
        for (gid, kernel), reqs in list(self._queues.items()):
            if gid == graph_id and reqs:
                taken.append((kernel, reqs))
                del self._queues[(gid, kernel)]
        taken.sort(key=lambda kv: min(r.order_key() for r in kv[1]))
        return taken

    def _flush_graph(self, graph_id: str) -> int:
        session = self.session
        entry = session.registry.get(graph_id)
        served = 0
        taken = self._take_queues(graph_id)
        try:
            with session.tracer.span("flush", graph_id=graph_id,
                                     requests=sum(len(r) for _, r in taken)):
                for kernel, reqs in taken:
                    reqs.sort(key=Request.order_key)
                    if kernel in GLOBAL:
                        self._serve_global(entry, kernel, reqs)
                    else:
                        for chunk in self._chunks(reqs):
                            self._serve_multi(entry, kernel, chunk)
                    served += len(reqs)
        except Exception as exc:
            # a failed launch must not strand the rest of the flush set:
            # every taken-but-unserved future fails with the same cause
            for _, reqs in taken:
                for r in reqs:
                    if not r.future.done():
                        r.future._set_exception(exc)
                        self._c_failed.inc()
                        self._g_pending.dec()
            raise
        finally:
            # requests resolved before a mid-flush failure were genuinely
            # served: keep the counter consistent with their futures
            self._c_served.inc(served)
        # flush boundary: all pending requests for this graph are answered
        # and translated under the generation that served them — only now
        # may the layout be replaced (skipped if the flush aborted above)
        session._maybe_redecide(entry)
        return served

    def _chunks(self, reqs: list[Request]) -> list[list[Request]]:
        """Greedy coalescing under the source cap, in drain order."""
        if self.max_batch_sources is None:
            return [reqs]
        chunks: list[list[Request]] = []
        cur: list[Request] = []
        total = 0
        for r in reqs:
            if cur and total + r.num_sources > self.max_batch_sources:
                chunks.append(cur)
                cur, total = [], 0
            cur.append(r)
            total += r.num_sources
        if cur:
            chunks.append(cur)
        return chunks

    def _serve_multi(self, entry, kernel: str, reqs: list[Request]) -> None:
        """One vmapped launch for every request in ``reqs``; per-request
        rows sliced back out of the (S, V) result."""
        session = self.session
        launch_begin = session.clock.now()
        with session.tracer.span("coalesce", graph_id=entry.graph_id,
                                 kernel=kernel, requests=len(reqs)):
            all_sources = np.concatenate([r.sources for r in reqs])
        try:
            out, wall = session._launch(entry, kernel, all_sources)
        except Exception as exc:
            self._fail_launch(reqs, exc)
            raise
        exchange = session._last_exchange(entry)
        total = int(all_sources.size)
        session.policy.observe_batch_sources(total)
        self._c_launches.inc()
        if len(reqs) > 1:
            self._c_coalesced.inc(len(reqs))
        offset = 0
        with session.tracer.span("slice_out", graph_id=entry.graph_id,
                                 kernel=kernel, requests=len(reqs)):
            for r in reqs:
                # copy: a slice view would pin the whole (S_total, V) launch
                # array for as long as any one future's result is retained
                rows = out[offset:offset + r.num_sources].copy()
                offset += r.num_sources
                share = wall * (r.num_sources / max(total, 1))
                self._account(entry, r, rows, wall, share, len(reqs), total,
                              exchange, launch_begin)

    def _serve_global(self, entry, kernel: str, reqs: list[Request]) -> None:
        """One run, fanned out to every waiter (the result is
        source-independent, so concurrent requests are duplicates)."""
        session = self.session
        launch_begin = session.clock.now()
        try:
            out, wall = session._launch(entry, kernel, None)
        except Exception as exc:
            self._fail_launch(reqs, exc)
            raise
        exchange = session._last_exchange(entry)
        self._c_launches.inc()
        if len(reqs) > 1:
            self._c_coalesced.inc(len(reqs))
            self._c_dedup.inc(len(reqs) - 1)
        for r in reqs:
            self._account(entry, r, out, wall, wall / len(reqs), len(reqs),
                          0, exchange, launch_begin)

    def _fail_launch(self, reqs: list[Request], exc: BaseException) -> None:
        """One launch raised: fail its riders, count the outcome."""
        self._c_launches_failed.inc()
        for r in reqs:
            r.future._set_exception(exc)
            self._c_failed.inc()
            self._g_pending.dec()

    def _account(self, entry, req: Request, result: np.ndarray, wall: float,
                 wall_share: float, sharing: int, batch_sources: int,
                 exchange: dict | None, launch_begin: float) -> None:
        """Resolve one future: ledger, realized-volume, telemetry,
        latency histograms, and the request's trace track."""
        session = self.session
        req.generation = entry.generation
        entry.ledger.record_query(req.num_sources, wall_share)
        session.registry.note_queries(entry.graph_id)
        served_at = session.clock.now()
        missed = req.deadline is not None and served_at > req.deadline
        if missed:
            self._c_deadlines.inc()
        labels = {"graph_id": req.graph_id, "kernel": req.kernel}
        queue_wait = launch_begin - req.enqueued_at
        serve_latency = served_at - req.enqueued_at
        m = self._metrics
        m.histogram("engine_queue_wait_seconds",
                    "enqueue -> launch start", **labels).observe(queue_wait)
        m.histogram("engine_serve_seconds",
                    "enqueue -> result resolved (end-to-end)",
                    **labels).observe(serve_latency)
        if req.deadline is not None:
            # slack > 0: met with room; < 0: by how much it was missed —
            # the attributable version of the deadlines_missed counter
            m.histogram("engine_deadline_slack_seconds",
                        "deadline - served_at (negative = missed by)",
                        boundaries=signed_log_boundaries(),
                        **labels).observe(req.deadline - served_at)
        tid = REQUEST_TID_BASE + req.seq
        tracer = session.tracer
        span_args = {"trace_id": req.trace_id, **labels}
        tracer.emit("queue_wait", req.enqueued_at, launch_begin, tid=tid,
                    args=span_args)
        tracer.emit("serve", launch_begin, served_at, tid=tid,
                    args={**span_args, "coalesced_with": sharing - 1,
                          "deadline_missed": missed})
        self._g_pending.dec()
        req.future.telemetry = {
            "kernel": req.kernel,
            "graph_id": req.graph_id,
            "priority": req.priority,
            "generation": req.generation,
            "launch_index": self.launches,  # 1-based, in launch order
            "launch_wall_seconds": wall,
            "wall_share_seconds": wall_share,
            "coalesced_with": sharing - 1,
            "launch_batch_sources": batch_sources,
            "queue_seconds": serve_latency,
            "deadline_missed": missed,
            "exchange": exchange,
            "trace_id": req.trace_id,
        }
        req.future._set_result(result)

    # ----------------------------------------------------------- telemetry
    def telemetry(self) -> dict:
        """Pre-obs dict shape (a view over the metrics registry) plus the
        launch/request failure counters."""
        return {
            "requests_enqueued": self.requests_enqueued,
            "requests_served": self.requests_served,
            "pending": self.pending(),
            "launches": self.launches,
            "coalesced_requests": self.coalesced_requests,
            "dedup_hits": self.dedup_hits,
            "flushes": self.flushes,
            "deadlines_missed": self.deadlines_missed,
            "launches_failed": self.launches_failed,
            "requests_failed": self.requests_failed,
            "max_batch_sources": self.max_batch_sources,
        }


__all__ = ["LABEL_KERNELS", "MicroBatchScheduler", "QueryFuture", "Request",
           "canonical_component_labels", "source_bucket"]
